//! Perf/leak probe behind EXPERIMENTS.md §Perf rows 1–2: RSS stability
//! of the buffer-based PJRT path and the cached-params inference
//! speedup.
//!
//!     cargo run --release --example perf_probe

use std::sync::Arc;
use std::time::Instant;
use tleague::runtime::Engine;

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    let line = s.lines().find(|l| l.starts_with("VmRSS")).unwrap();
    line.split_whitespace().nth(1).unwrap().parse::<f64>().unwrap() / 1024.0
}

fn main() {
    let engine = Arc::new(Engine::load("artifacts").unwrap());
    let params = engine.init_params("pommerman").unwrap();
    let obs = vec![0.1f32; 2 * 980];
    println!("start rss={:.0} MB", rss_mb());
    let t0 = Instant::now();
    for i in 0..2000 {
        let _ = engine.infer("pommerman", 1, &params, &obs).unwrap();
        if i % 1000 == 999 {
            println!("uncached iter {i}: rss={:.0} MB", rss_mb());
        }
    }
    let uncached = t0.elapsed().as_secs_f64() / 2000.0;
    let t0 = Instant::now();
    for i in 0..2000 {
        let _ = engine
            .infer_cached("pommerman", 1, 7, &params, &obs)
            .unwrap();
        if i % 1000 == 999 {
            println!("cached   iter {i}: rss={:.0} MB", rss_mb());
        }
    }
    let cached = t0.elapsed().as_secs_f64() / 2000.0;
    println!(
        "infer b1 pommerman: uncached {:.3} ms, cached {:.3} ms ({:.2}x)",
        uncached * 1e3,
        cached * 1e3,
        uncached / cached
    );
    println!("(rss must stay flat: the literal-arg execute path leaked ~2.9 MB/call)");
}
