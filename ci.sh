#!/usr/bin/env bash
# CI gate: formatting, lints (deny warnings), then the tier-1 command.
# Usage: ./ci.sh [--no-lint]   (--no-lint skips fmt/clippy, e.g. on
# toolchains without those components)
set -euo pipefail
cd "$(dirname "$0")"

if [[ "${1:-}" != "--no-lint" ]]; then
    echo "== cargo fmt --check"
    cargo fmt --check
    echo "== cargo clippy -D warnings"
    cargo clippy -- -D warnings
fi

echo "== tier-1: cargo build --release && cargo test -q"
cargo build --release
cargo test -q

# Perf smoke: codec + model-pool data plane.  Refreshes the committed
# perf-trajectory file with this image's numbers (see BENCH_pr2.json).
echo "== bench smoke: cargo bench --bench bench_main -- codec pool"
# --bench bench_main: the lib/bin libtest harnesses would reject --json
cargo bench --bench bench_main -- codec pool --json BENCH_pr2.json

# Rollout-engine smoke: single-env vs vectorized actor frames/sec
# (N in {1, 8, 32}; see BENCH_pr3.json).
echo "== bench smoke: cargo bench --bench bench_main -- rollout"
cargo bench --bench bench_main -- rollout --json BENCH_pr3.json

# Multi-process deployment smoke: controller + real worker subprocesses
# (register/heartbeat/reassign; also covered inside `cargo test` above,
# rerun here standalone so a deploy regression is called out by name).
echo "== procs smoke: cargo test --test procs_deploy"
cargo test -q --test procs_deploy

# Control-plane bench: task-assignment round-trip + heartbeat overhead
# at 64 simulated workers (see BENCH_pr4.json).
echo "== bench smoke: cargo bench --bench bench_main -- deploy"
cargo bench --bench bench_main -- deploy --json BENCH_pr4.json
echo "CI OK"
