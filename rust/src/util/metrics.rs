//! Throughput meters and rolling statistics.
//!
//! rfps / cfps — the paper's two headline throughput counters (§4.4):
//! frames received from Actors vs frames consumed by the Learner.  All
//! counters are lock-free atomics so the hot paths never block on
//! metrics; a `MetricsHub` aggregates and renders Table-3-style rows.
//!
//! The telemetry plane (see DESIGN.md §Telemetry plane) is built on
//! **interval snapshots**: [`Meter::take_snapshot`] atomically drains
//! the delta since the previous snapshot, and [`MetricsHub::snapshot`]
//! packages every registered meter's delta plus every rolling gauge's
//! current window into one report a worker can piggyback on its
//! heartbeat.  Rates derived from snapshots reflect the *current*
//! interval, not a lifetime average.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Monotonic event counter with delta-based rate derivation.
///
/// `count()` never decreases (hot-path callers budget against it), so
/// interval accounting rides a separate snapshot base: each
/// [`take_snapshot`](Meter::take_snapshot) drains the events recorded
/// since the previous one.  Every `add` lands in exactly one snapshot's
/// delta — there is no reset window in which events can be lost or
/// misattributed (the old `reset()` stored the counter and the epoch
/// non-atomically and had exactly that bug).
pub struct Meter {
    count: AtomicU64,
    /// `count` as of the last snapshot
    snap_base: AtomicU64,
    /// epoch of the last snapshot (creation time initially); the lock
    /// also serializes concurrent snapshotters so each delta pairs with
    /// the interval it was collected over
    snap_at: Mutex<Instant>,
}

impl Default for Meter {
    fn default() -> Self {
        Self::new()
    }
}

impl Meter {
    pub fn new() -> Self {
        Meter {
            count: AtomicU64::new(0),
            snap_base: AtomicU64::new(0),
            snap_at: Mutex::new(Instant::now()),
        }
    }
    #[inline]
    pub fn add(&self, n: u64) {
        self.count.fetch_add(n, Ordering::Relaxed);
    }
    /// Lifetime total — monotonic, unaffected by snapshots.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
    /// Drain the current interval: `(events since last snapshot,
    /// seconds since last snapshot)`, then start a fresh interval.
    /// Deltas telescope — the sum of every snapshot's delta plus the
    /// not-yet-snapshotted remainder always equals `count()`.
    pub fn take_snapshot(&self) -> (u64, f64) {
        let mut at = self.snap_at.lock().unwrap();
        let total = self.count.load(Ordering::Relaxed);
        let delta = total - self.snap_base.swap(total, Ordering::Relaxed);
        let now = Instant::now();
        let secs = now.duration_since(*at).as_secs_f64();
        *at = now;
        (delta, secs)
    }
    /// Events per second over the current interval (since the last
    /// `take_snapshot`; since creation if never snapshotted).  Does not
    /// consume the interval.
    pub fn rate(&self) -> f64 {
        let at = self.snap_at.lock().unwrap();
        let secs = at.elapsed().as_secs_f64();
        let delta = self.count() - self.snap_base.load(Ordering::Relaxed);
        if secs <= 0.0 {
            0.0
        } else {
            delta as f64 / secs
        }
    }
}

/// Windowed scalar statistic (mean/min/max over the recent window).
pub struct Rolling {
    inner: Mutex<RollingInner>,
}

struct RollingInner {
    window: Vec<f64>,
    cap: usize,
    next: usize,
}

impl Default for Rolling {
    /// A zero-capacity ring is unusable (the first wrapped push would
    /// index an empty window), so the default is the same 256-sample
    /// window `MetricsHub::rolling` registers.
    fn default() -> Self {
        Rolling::with_capacity(256)
    }
}

impl Rolling {
    pub fn with_capacity(cap: usize) -> Self {
        Rolling {
            inner: Mutex::new(RollingInner {
                window: Vec::with_capacity(cap),
                cap: cap.max(1),
                next: 0,
            }),
        }
    }
    pub fn push(&self, v: f64) {
        let mut g = self.inner.lock().unwrap();
        let cap = g.cap;
        if g.window.len() < cap {
            g.window.push(v);
        } else {
            let i = g.next;
            g.window[i] = v;
            g.next = (i + 1) % cap;
        }
    }
    pub fn mean(&self) -> f64 {
        let g = self.inner.lock().unwrap();
        if g.window.is_empty() {
            return 0.0;
        }
        g.window.iter().sum::<f64>() / g.window.len() as f64
    }
    pub fn minmax(&self) -> (f64, f64) {
        let g = self.inner.lock().unwrap();
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &v in &g.window {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if g.window.is_empty() {
            (0.0, 0.0)
        } else {
            (lo, hi)
        }
    }
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().window.len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One interval's worth of a hub's metrics: counter deltas collected
/// over `interval_secs`, plus the current rolling-gauge values.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnap {
    pub interval_secs: f64,
    /// meter name → events since the hub's previous snapshot
    pub counters: Vec<(String, u64)>,
    /// rolling name → current window mean
    pub gauges: Vec<(String, f64)>,
}

/// Named registry shared across modules (one per role instance).
pub struct MetricsHub {
    meters: Mutex<BTreeMap<String, Arc<Meter>>>,
    rollings: Mutex<BTreeMap<String, Arc<Rolling>>>,
    /// epoch of the last hub snapshot (drives `interval_secs`)
    snap_at: Mutex<Instant>,
}

impl Default for MetricsHub {
    fn default() -> Self {
        MetricsHub {
            meters: Mutex::new(BTreeMap::new()),
            rollings: Mutex::new(BTreeMap::new()),
            snap_at: Mutex::new(Instant::now()),
        }
    }
}

impl MetricsHub {
    pub fn meter(&self, name: &str) -> Arc<Meter> {
        self.meters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Meter::new()))
            .clone()
    }
    pub fn rolling(&self, name: &str) -> Arc<Rolling> {
        self.rollings
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Rolling::with_capacity(256)))
            .clone()
    }
    /// "name=rate/s" report, sorted by name (used by the throughput
    /// table).  Rates cover the current interval; see [`Meter::rate`].
    pub fn report(&self) -> Vec<(String, f64)> {
        self.meters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, m)| (k.clone(), m.rate()))
            .collect()
    }
    /// Drain one reporting interval: every meter's delta since the
    /// previous hub snapshot plus every gauge's current mean.  Intended
    /// for a single periodic consumer per hub (the role's telemetry
    /// reporter) — concurrent snapshotters would split deltas between
    /// them.
    pub fn snapshot(&self) -> MetricsSnap {
        let interval_secs = {
            let mut at = self.snap_at.lock().unwrap();
            let now = Instant::now();
            let secs = now.duration_since(*at).as_secs_f64();
            *at = now;
            secs
        };
        let counters = self
            .meters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, m)| (k.clone(), m.take_snapshot().0))
            .collect();
        let gauges = self
            .rollings
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, r)| !r.is_empty())
            .map(|(k, r)| (k.clone(), r.mean()))
            .collect();
        MetricsSnap { interval_secs, counters, gauges }
    }
}

/// Simple wall-clock stopwatch used by the bench harness.
pub struct Stopwatch(Instant);

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_counts() {
        let m = Meter::new();
        m.add(3);
        m.add(4);
        assert_eq!(m.count(), 7);
        assert!(m.rate() > 0.0);
        let (delta, secs) = m.take_snapshot();
        assert_eq!(delta, 7);
        assert!(secs >= 0.0);
        // the lifetime count survives the snapshot; the interval drains
        assert_eq!(m.count(), 7);
        assert_eq!(m.take_snapshot().0, 0);
        m.add(2);
        assert_eq!(m.take_snapshot().0, 2);
        assert_eq!(m.count(), 9);
    }

    /// No-lost-events: with a concurrent adder hammering the meter, the
    /// sum of every snapshot delta must equal the final count — the old
    /// two-store `reset()` dropped or misattributed events that landed
    /// between its stores.
    #[test]
    fn snapshot_deltas_lose_no_events_under_concurrency() {
        let m = Arc::new(Meter::new());
        let m2 = m.clone();
        let adder = std::thread::spawn(move || {
            let mut added = 0u64;
            for i in 0..200_000u64 {
                let n = i % 3 + 1;
                m2.add(n);
                added += n;
            }
            added
        });
        let mut snapped = 0u64;
        while !adder.is_finished() {
            snapped += m.take_snapshot().0;
        }
        let added = adder.join().unwrap();
        snapped += m.take_snapshot().0;
        assert_eq!(snapped, added, "snapshot deltas must telescope");
        assert_eq!(m.count(), added, "lifetime count must be exact");
    }

    /// Regression: `Rolling::default()` used to derive a zero-capacity
    /// ring whose wrap path indexed an empty Vec and panicked on the
    /// first push past the (empty) window.
    #[test]
    fn rolling_default_survives_many_pushes() {
        let r = Rolling::default();
        for v in 0..300 {
            r.push(v as f64);
        }
        assert_eq!(r.len(), 256);
        // window holds {44..=299}: the first 256 pushes fill 0..=255,
        // the remaining 44 overwrite slots 0..=43 with 256..=299
        assert_eq!(r.minmax(), (44.0, 299.0));
        let want = (44..=299).sum::<i64>() as f64 / 256.0;
        assert!((r.mean() - want).abs() < 1e-9, "{} vs {want}", r.mean());
    }

    #[test]
    fn rolling_window_wraps() {
        let r = Rolling::with_capacity(3);
        for v in [1.0, 2.0, 3.0, 4.0] {
            r.push(v);
        }
        // window now holds {4, 2, 3}
        assert_eq!(r.len(), 3);
        assert!((r.mean() - 3.0).abs() < 1e-9);
        assert_eq!(r.minmax(), (2.0, 4.0));
    }

    #[test]
    fn hub_shares_meters() {
        let hub = MetricsHub::default();
        hub.meter("rfps").add(10);
        assert_eq!(hub.meter("rfps").count(), 10);
        assert_eq!(hub.report().len(), 1);
    }

    #[test]
    fn hub_snapshot_drains_deltas_and_reads_gauges() {
        let hub = MetricsHub::default();
        hub.meter("frames").add(40);
        hub.meter("episodes").add(2);
        hub.rolling("lag").push(1.0);
        hub.rolling("lag").push(3.0);
        hub.rolling("empty"); // registered but never pushed: omitted
        let s = hub.snapshot();
        assert!(s.interval_secs >= 0.0);
        assert_eq!(
            s.counters,
            vec![("episodes".into(), 2), ("frames".into(), 40)]
        );
        assert_eq!(s.gauges, vec![("lag".into(), 2.0)]);
        // second snapshot: counters drained, gauge window persists
        hub.meter("frames").add(5);
        let s2 = hub.snapshot();
        assert_eq!(
            s2.counters,
            vec![("episodes".into(), 0), ("frames".into(), 5)]
        );
        assert_eq!(s2.gauges, vec![("lag".into(), 2.0)]);
    }
}
