// Control fixture: exercises every rule's *happy* path — the self-test
// fails if any rule flags this file.
// lint: proto-registry
// lint: netpath
pub const TAG_A: u8 = 1;
pub const TAG_B: u8 = 2;

impl Wire for Msg {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Msg::A => buf.put_u8(TAG_A),
            Msg::B(x) => {
                buf.put_u8(TAG_B);
                buf.put_u32(*x);
            }
        }
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        let tag = cur.u8()?;
        Ok(match tag {
            TAG_A => Msg::A,
            TAG_B => Msg::B(cur.u32()?),
            t => bail!("unknown tag {t}"),
        })
    }
}

fn open_fd(path: &CStr) -> i32 {
    // SAFETY: path is NUL-terminated; open() has the declared signature.
    unsafe { open(path.as_ptr(), 0) }
}

// lint: nonblocking
fn try_pump(&mut self) -> bool {
    // a "blocking" waiver with a reason keeps the listed op legal
    let g = self.q.lock(); // lint: blocking-ok: sub-microsecond critical section
    !g.is_empty()
}

fn on_bytes(b: &[u8]) -> Result<Msg> {
    // netpath file, but errors are propagated, never unwrapped
    Msg::from_bytes(b)
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_unwraps_are_free() {
        on_bytes(&[1]).unwrap();
    }
}
