//! league-lint CLI: walk `rust/src`, enforce the project invariants
//! (proto tag registry, unsafe hygiene, nonblocking regions, unwrap
//! budget), exit nonzero on any finding.  See DESIGN.md "Correctness
//! tooling" for the rule set and `lint-allow.toml` format.
//!
//! Usage:
//!   league-lint [--root DIR] [--allow FILE]   lint the tree (CI mode)
//!   league-lint --check-file FILE [...]       lint one file
//!   league-lint --self-test DIR               run the fixture suite

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use tleague::lint;

fn main() -> ExitCode {
    let mut root = PathBuf::from("rust/src");
    let mut allow_path = PathBuf::from("lint-allow.toml");
    let mut check_files: Vec<PathBuf> = Vec::new();
    let mut self_test: Option<PathBuf> = None;

    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(v) => root = PathBuf::from(v),
                None => return usage("--root needs a directory"),
            },
            "--allow" => match it.next() {
                Some(v) => allow_path = PathBuf::from(v),
                None => return usage("--allow needs a file"),
            },
            "--check-file" => match it.next() {
                Some(v) => check_files.push(PathBuf::from(v)),
                None => return usage("--check-file needs a file"),
            },
            "--self-test" => match it.next() {
                Some(v) => self_test = Some(PathBuf::from(v)),
                None => return usage("--self-test needs a directory"),
            },
            "--help" | "-h" => return usage(""),
            other => return usage(&format!("unknown flag '{other}'")),
        }
    }

    if let Some(dir) = self_test {
        return match lint::self_test(&dir) {
            Ok(msg) => {
                println!("league-lint {msg}");
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("league-lint self-test FAILED:\n{e}");
                ExitCode::FAILURE
            }
        };
    }

    // The allowlist is optional on disk (treated as empty), but a
    // malformed one is a hard error — a typo must not allow everything.
    let allow = if allow_path.exists() {
        match lint::Allowlist::load(&allow_path) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("league-lint: bad allowlist: {e}");
                return ExitCode::FAILURE;
            }
        }
    } else {
        lint::Allowlist::empty()
    };

    if !check_files.is_empty() {
        let mut findings = Vec::new();
        for p in &check_files {
            let src = match std::fs::read_to_string(p) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("league-lint: read {}: {e}", p.display());
                    return ExitCode::FAILURE;
                }
            };
            findings.extend(lint::lint_file(&rel_of(p), &src, &allow));
        }
        return exit_of(report(findings, check_files.len()));
    }

    match lint::lint_tree(&root, &allow) {
        Ok((findings, files, bytes)) => {
            let clean = report(findings, files);
            if clean {
                println!(
                    "league-lint OK: {files} files / {bytes} bytes clean ({} allowlisted)",
                    allow.len()
                );
            }
            exit_of(clean)
        }
        Err(e) => {
            eprintln!("league-lint: {e}");
            ExitCode::FAILURE
        }
    }
}

fn exit_of(clean: bool) -> ExitCode {
    if clean {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

/// Rel path used for path-scoped rules: the suffix after `rust/src/`
/// when present, else the bare file name.
fn rel_of(p: &Path) -> String {
    let s = p.to_string_lossy().replace('\\', "/");
    match s.split_once("rust/src/") {
        Some((_, rel)) => rel.to_string(),
        None => p.file_name().map(|f| f.to_string_lossy().to_string()).unwrap_or(s),
    }
}

/// Print findings; returns true when clean.
fn report(findings: Vec<lint::Finding>, files: usize) -> bool {
    if findings.is_empty() {
        return true;
    }
    for f in &findings {
        eprintln!("{f}");
    }
    eprintln!("league-lint: {} finding(s) across {files} file(s) checked", findings.len());
    false
}

fn usage(err: &str) -> ExitCode {
    if !err.is_empty() {
        eprintln!("league-lint: {err}");
    }
    eprintln!(
        "usage: league-lint [--root DIR] [--allow FILE]\n       \
         league-lint --check-file FILE [--check-file FILE ...]\n       \
         league-lint --self-test FIXTURE_DIR"
    );
    if err.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
