// Seeded-bad fixture: an `unsafe` block with no SAFETY comment within
// the lookback window.

fn grow(ptr: *mut u8, len: usize) {
    unsafe {
        std::ptr::write_bytes(ptr, 0, len);
    }
}
