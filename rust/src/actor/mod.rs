//! Actor: produces trajectories (paper §3.2), vectorized.
//!
//! Embeds a [`VecEnv`] of N concurrent episodes ("slots") plus the
//! Agents.  Each slot runs its own LeagueMgr task: at its episode
//! beginning the slot requests a task (which learning policy, which
//! opponent(s)); at its episode end it reports the outcome.  Every tick
//! the actor gathers ALL slots' observations into one multi-row forward
//! pass per distinct `ModelKey` — one `InferReq` per key on the Remote
//! backend (so the InfServer's per-key deadline batcher sees wide rows
//! instead of batch-of-1), one chunked wide-artifact call per key on the
//! Local backend — then scatters actions back and steps every slot.
//!
//! Per slot, the learning agent's trajectory segments (length L = the
//! manifest's train_t, spanning episode boundaries IMPALA-style) are
//! pushed to the Learner, and policy parameters are pulled from the
//! ModelPool (shared across slots; delta-aware refresh).  With one slot
//! (`--envs-per-actor 1`, the default) the actor reproduces the
//! single-env rollout: same seed, same RNG stream (consumed in the
//! same order), same per-episode task/outcome/segment wire traffic —
//! role groups sharing one model now ride one wider `InferReq` instead
//! of several batch-of-1 requests.

use crate::envs::{self, VecEnv};
use crate::inference::{infer_local_rows, infer_remote_traced};
use crate::league::LeagueClient;
use crate::model_pool::{LatestFetch, ModelPoolClient};
use crate::proto::{MatchOutcome, ModelKey, Msg, TaskSpec, TraceCtx, TrajSegment};
use crate::runtime::Engine;
use crate::telemetry::trace;
use crate::transport::{PushClient, ReqClient};
use crate::util::metrics::{Hist, Meter, MetricsHub};
use crate::util::rng::{log_softmax_at, Pcg32};
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Finished trajectory segments waiting out a learner outage.  Beyond
/// this many, the OLDEST segment is dropped (off-policy data ages
/// fastest) and `segments_dropped` accounts for it — the rollout loop
/// itself never blocks on, or dies from, a push failure.
const PUSH_QUEUE_CAP: usize = 64;

/// How this actor evaluates policies.
pub enum PolicyBackend {
    Local(Arc<Engine>),
    Remote(ReqClient),
}

/// Which env slots the learning (meta-)agent controls and how the
/// opponents group.  E.g. Pommerman Team: learner = [0, 2] acting as
/// one meta-agent, one opponent controlling [1, 3].
#[derive(Clone, Debug)]
pub struct RoleLayout {
    pub learner_slots: Vec<usize>,
    pub opponent_groups: Vec<Vec<usize>>,
}

pub fn role_layout(env_name: &str, n_agents: usize) -> RoleLayout {
    match envs::spec(env_name).0 {
        "pommerman" => RoleLayout {
            learner_slots: vec![0, 2],
            opponent_groups: vec![vec![1, 3]],
        },
        // everything else (incl. pommerman_ffa): learner in slot 0, one
        // singleton opponent group per remaining agent — derived from
        // n_agents, never hardcoded
        _ => RoleLayout {
            learner_slots: vec![0],
            opponent_groups: (1..n_agents).map(|i| vec![i]).collect(),
        },
    }
}

#[derive(Clone)]
pub struct ActorConfig {
    /// env spec name (envs::make; parameterized forms like `doom_lite:4`)
    pub env: String,
    /// "<agent>/<name>" — the prefix routes LeagueMgr tasks
    pub actor_id: String,
    pub seed: u64,
    pub gamma: f32,
    /// pull fresh learning-model params every N episodes
    pub refresh_every: u32,
    /// trajectory segment length; 0 = read from the local engine's
    /// manifest (required explicitly for the Remote backend)
    pub train_t: usize,
    /// fraction of ticks traced end-to-end (0.0 = tracing off; the
    /// `row_e2e_us` latency histogram is recorded regardless)
    pub trace_sample: f32,
}

impl Default for ActorConfig {
    fn default() -> Self {
        ActorConfig {
            env: "rps".into(),
            actor_id: "0/actor".into(),
            seed: 0,
            gamma: 0.99,
            refresh_every: 1,
            train_t: 0,
            trace_sample: 0.0,
        }
    }
}

struct SegBuffer {
    obs: Vec<f32>,
    actions: Vec<i32>,
    logp: Vec<f32>,
    rewards: Vec<f32>,
    discounts: Vec<f32>,
    steps: usize,
}

impl SegBuffer {
    fn new() -> Self {
        SegBuffer {
            obs: Vec::new(),
            actions: Vec::new(),
            logp: Vec::new(),
            rewards: Vec::new(),
            discounts: Vec::new(),
            steps: 0,
        }
    }
    fn clear(&mut self) {
        self.obs.clear();
        self.actions.clear();
        self.logp.clear();
        self.rewards.clear();
        self.discounts.clear();
        self.steps = 0;
    }
}

/// Per-env-slot rollout state: each slot runs its own episode under its
/// own LeagueMgr task, with its own segment buffer and RNG stream (so a
/// 1-slot actor reproduces the old single-env action sequence exactly).
struct Slot {
    task: Option<TaskSpec>,
    seg: SegBuffer,
    cur_obs: Vec<Vec<f32>>,
    episode_steps: u32,
    rng: Pcg32,
}

/// One (slot, role-group) contribution to a per-key gather, recorded in
/// canonical order (slot-major, learner group first): `group` is -1 for
/// the learner meta-agent, else an opponent-group index.  `key_idx` /
/// `row` locate the group's logits inside its key's gathered batch, so
/// sampling can run in canonical order even when one key's gather
/// merges non-adjacent groups (duplicate opponent draws) — the slot RNG
/// streams are consumed in the exact pre-vectorized order.
#[derive(Clone, Copy)]
struct PlanEntry {
    slot: usize,
    group: i32,
    key_idx: usize,
    row: usize,
}

pub struct Actor {
    pub cfg: ActorConfig,
    env: VecEnv,
    layout: RoleLayout,
    backend: PolicyBackend,
    league: LeagueClient,
    pool: ModelPoolClient,
    push: PushClient,
    manifest_env: String,
    train_t: usize,
    act_dim: usize,
    /// env-slot rows per forward-pass row (2 for team manifests, else
    /// 1); Local backend only — the InfServer does its own accounting
    rows_per_pass: usize,
    /// host params + device-buffer cache id (bumped on refresh)
    params: HashMap<ModelKey, (Arc<Vec<f32>>, u64)>,
    /// per-agent (version, rev) held from the last if-newer refresh, so
    /// steady-state refreshes transfer O(1) bytes (NotModified)
    latest_have: HashMap<u32, (u32, u64)>,
    slots: Vec<Slot>,
    episodes_done: u32,
    // ---- per-tick scratch, reused so the hot loop stays off the
    // allocator (obs gather buffers keep their capacity across ticks)
    gather_buf: Vec<(ModelKey, Vec<f32>, usize)>,
    plan: Vec<PlanEntry>,
    actions_buf: Vec<Vec<usize>>,
    learner_acts_buf: Vec<Vec<(usize, f32)>>,
    pub frames: Arc<Meter>,
    pub episodes: Arc<Meter>,
    /// end-to-end latency of one forward pass as the actor sees it
    /// (gathered obs in → logits out), in µs — always recorded
    pub row_e2e: Arc<Hist>,
    /// dedicated sampling RNG: tracing must never perturb the slot RNG
    /// streams (1-slot bit-compatibility)
    trace_rng: Pcg32,
    /// trace context of the most recent sampled tick, attached to the
    /// next pushed segment (then cleared) so the learner's consume span
    /// joins the trace
    pending_ctx: Option<TraceCtx>,
    /// finished segments not yet delivered to the learner (bounded at
    /// [`PUSH_QUEUE_CAP`], drop-oldest) — push failures park segments
    /// here instead of erroring out of the rollout tick
    pending_segs: VecDeque<Msg>,
    /// segments evicted from the full retry queue during an outage
    pub segments_dropped: Arc<Meter>,
    /// consecutive failed delivery attempts (drives the retry backoff)
    push_fail_streak: u32,
    /// do not retry delivery before this instant
    push_retry_at: Option<Instant>,
    /// frames stepped by THIS actor — `frames` may be a hub meter
    /// shared with other actors after [`use_hub`](Actor::use_hub), so
    /// `run`'s budget must not count their work
    frames_done: u64,
}

impl Actor {
    /// Single-episode actor (`envs_per_actor = 1`): the exact behavior
    /// of the pre-vectorized rollout loop.
    pub fn new(
        cfg: ActorConfig,
        backend: PolicyBackend,
        league_addr: &str,
        pool_addrs: &[String],
        learner_data_addr: &str,
    ) -> Result<Actor> {
        Self::new_vec(cfg, 1, backend, league_addr, pool_addrs, learner_data_addr)
    }

    /// Vectorized actor: `n_slots` concurrent episodes (the
    /// `--envs-per-actor` knob).  Slot 0 keeps the actor's base seed and
    /// RNG stream, so `n_slots = 1` is bit-compatible with [`Actor::new`].
    pub fn new_vec(
        cfg: ActorConfig,
        n_slots: usize,
        backend: PolicyBackend,
        league_addr: &str,
        pool_addrs: &[String],
        learner_data_addr: &str,
    ) -> Result<Actor> {
        let n_slots = n_slots.max(1);
        let env = VecEnv::make(&cfg.env, n_slots, cfg.seed)?;
        let layout = role_layout(&cfg.env, env.n_agents());
        let manifest_env = envs::manifest_name(&cfg.env).to_string();
        let (train_t, obs_dim, act_dim, rows_per_pass) = match &backend {
            PolicyBackend::Local(engine) => {
                let m = engine.manifest.env(&manifest_env)?;
                let t = if cfg.train_t > 0 { cfg.train_t } else { m.train_t };
                (t, m.obs_dim, m.act_dim, m.n_agents())
            }
            PolicyBackend::Remote(_) => {
                anyhow::ensure!(
                    cfg.train_t > 0,
                    "ActorConfig.train_t must be set for the Remote backend"
                );
                (cfg.train_t, env.obs_dim(), env.act_dim(), 1)
            }
        };
        anyhow::ensure!(
            obs_dim == env.obs_dim() && act_dim == env.act_dim(),
            "env/manifest shape mismatch for {}: {}x{} vs {}x{}",
            cfg.env, obs_dim, act_dim, env.obs_dim(), env.act_dim()
        );
        let slots = (0..n_slots)
            .map(|i| Slot {
                task: None,
                seg: SegBuffer::new(),
                cur_obs: Vec::new(),
                episode_steps: 0,
                rng: if i == 0 {
                    Pcg32::from_label(cfg.seed, &cfg.actor_id)
                } else {
                    Pcg32::from_label(
                        cfg.seed,
                        &format!("{}#slot{i}", cfg.actor_id),
                    )
                },
            })
            .collect();
        let env_agents = env.n_agents();
        Ok(Actor {
            layout,
            backend,
            league: LeagueClient::connect(league_addr),
            pool: ModelPoolClient::connect(pool_addrs),
            push: PushClient::connect(learner_data_addr),
            manifest_env,
            train_t,
            act_dim,
            rows_per_pass,
            params: HashMap::new(),
            latest_have: HashMap::new(),
            slots,
            episodes_done: 0,
            gather_buf: Vec::new(),
            plan: Vec::new(),
            actions_buf: vec![vec![0; env_agents]; n_slots],
            learner_acts_buf: vec![Vec::new(); n_slots],
            env,
            frames: Arc::new(Meter::new()),
            episodes: Arc::new(Meter::new()),
            row_e2e: Arc::new(Hist::new()),
            trace_rng: Pcg32::from_label(
                cfg.seed,
                &format!("{}#trace", cfg.actor_id),
            ),
            pending_ctx: None,
            pending_segs: VecDeque::new(),
            segments_dropped: Arc::new(Meter::new()),
            push_fail_streak: 0,
            push_retry_at: None,
            frames_done: 0,
            cfg,
        })
    }

    /// Route this actor's throughput counters through `hub` so the
    /// telemetry plane can snapshot them (counters `env_frames` /
    /// `episodes`, histogram `row_e2e_us`, transport byte meters).
    /// Call before the first step — re-pointing later would drop counts
    /// already accumulated on the private meters.
    pub fn use_hub(&mut self, hub: &MetricsHub) {
        self.frames = hub.meter("env_frames");
        self.episodes = hub.meter("episodes");
        self.row_e2e = hub.hist("row_e2e_us");
        self.segments_dropped = hub.meter("segments_dropped");
        // transport byte accounting: segment pushes + remote inference
        // share the role-level bytes_in/bytes_out meters
        self.push.bytes_out = hub.meter("bytes_out");
        if let PolicyBackend::Remote(client) = &mut self.backend {
            client.bytes_in = hub.meter("bytes_in");
            client.bytes_out = hub.meter("bytes_out");
        }
    }

    /// Concurrent episodes this actor drives.
    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// Override the segment length (tests / throughput harness).
    pub fn set_train_t(&mut self, t: usize) {
        self.train_t = t;
    }

    /// Install fetched params under `key` (the key requests are pinned
    /// to), evicting the predecessor's device buffer and bounding the
    /// cache.
    fn install_params(&mut self, key: ModelKey, params: Vec<f32>) -> Arc<Vec<f32>> {
        let p = Arc::new(params);
        let id = crate::runtime::new_cache_id();
        if let Some((_, old_id)) = self.params.insert(key, (p.clone(), id)) {
            if let PolicyBackend::Local(engine) = &self.backend {
                engine.evict_cached(old_id);
            }
        }
        // bound the cache (frozen models accumulate over a long run)
        if self.params.len() > 64 {
            let drop_key = *self.params.keys().next().unwrap();
            if let Some((_, old_id)) = self.params.remove(&drop_key) {
                if let PolicyBackend::Local(engine) = &self.backend {
                    engine.evict_cached(old_id);
                }
            }
        }
        p
    }

    fn fetch_params(&mut self, key: ModelKey, force: bool) -> Result<Arc<Vec<f32>>> {
        if !force {
            if let Some((p, _)) = self.params.get(&key) {
                return Ok(p.clone());
            }
        }
        let blob = self
            .pool
            .get(key)?
            .or_else(|| self.pool.get_latest(key.agent).ok().flatten())
            .with_context(|| format!("model {key} not in pool"))?;
        Ok(self.install_params(key, blob.params))
    }

    /// Roll the tracing sampler: `Some(ctx)` on a sampled event, `None`
    /// (no RNG draw, no allocation) when tracing is off.  The ctx's
    /// `span_id` is pre-allocated so it can ride the wire as the parent
    /// of downstream server-side spans before the local span finishes.
    fn roll_trace(&mut self) -> Option<TraceCtx> {
        (self.cfg.trace_sample > 0.0
            && self.trace_rng.next_f32() < self.cfg.trace_sample)
            .then(|| TraceCtx {
                trace_id: trace::next_id(),
                span_id: trace::next_id(),
            })
    }

    /// Delta-aware learner refresh: echo the (version, rev) we hold so
    /// an unchanged in-training model costs a NotModified instead of a
    /// full params transfer.
    fn refresh_learner(&mut self, key: ModelKey) -> Result<()> {
        let (hv, hr) =
            self.latest_have.get(&key.agent).copied().unwrap_or((0, 0));
        let ctx = self.roll_trace();
        let t0 = Instant::now();
        let fetched = self.pool.get_latest_if_newer_traced(key.agent, hv, hr, ctx);
        if let Some(c) = ctx {
            trace::finish_span_id(
                c.trace_id, c.span_id, 0, "pool_get", "actor", t0, 0,
            );
        }
        match fetched {
            Ok(LatestFetch::NotModified) if self.params.contains_key(&key) => {
                return Ok(());
            }
            Ok(LatestFetch::New { rev, blob }) => {
                self.latest_have.insert(key.agent, (blob.key.version, rev));
                self.install_params(key, blob.params);
                return Ok(());
            }
            // NotFound, transport error, or NotModified without a local
            // copy under this task's key: take the legacy full fetch
            _ => {}
        }
        self.fetch_params(key, true)?;
        Ok(())
    }

    /// Start a fresh episode in slot `si`: fetch the next LeagueMgr
    /// task, refresh/prime params, reset the env slot.
    fn begin_task_slot(&mut self, si: usize) -> Result<()> {
        let task = self.league.request_actor_task(&self.cfg.actor_id)?;
        let refresh = self.episodes_done % self.cfg.refresh_every.max(1) == 0;
        if refresh {
            self.refresh_learner(task.learner_key)?;
        } else {
            self.fetch_params(task.learner_key, false)?;
        }
        for &op in &task.opponents {
            self.fetch_params(op, false)?;
        }
        let obs = self.env.reset_slot(si);
        let slot = &mut self.slots[si];
        slot.task = Some(task);
        slot.cur_obs = obs;
        slot.episode_steps = 0;
        Ok(())
    }

    /// Forward pass for `rows` env-slot observation rows (each `obs_dim`
    /// f32s) under `key`'s policy; returns `rows * act_dim` logits.
    /// `trace` rides the `InferReq` on the Remote backend (the InfServer
    /// parents its queue/compute/reply spans to `trace.span_id`).
    fn infer(
        &mut self,
        key: ModelKey,
        obs: &[f32],
        rows: usize,
        trace: Option<TraceCtx>,
    ) -> Result<Vec<f32>> {
        let logits = match &self.backend {
            PolicyBackend::Local(engine) => {
                anyhow::ensure!(
                    rows % self.rows_per_pass == 0,
                    "{rows} rows not divisible into {}-row passes",
                    self.rows_per_pass
                );
                let (params, id) =
                    self.params.get(&key).context("params not cached")?;
                let (logits, _value) = infer_local_rows(
                    engine,
                    &self.manifest_env,
                    *id,
                    params,
                    obs,
                    rows / self.rows_per_pass,
                )?;
                logits
            }
            PolicyBackend::Remote(client) => {
                let (logits, _value) =
                    infer_remote_traced(client, key, obs, rows as u32, trace)?;
                logits
            }
        };
        anyhow::ensure!(
            logits.len() == rows * self.act_dim,
            "policy {key}: got {} logits for {rows} rows x {}",
            logits.len(),
            self.act_dim
        );
        Ok(logits)
    }

    /// Queue the slot's finished segment for the learner and attempt
    /// delivery.  Delivery failure is NON-fatal: the segment waits in
    /// the bounded retry queue (drop-oldest past [`PUSH_QUEUE_CAP`],
    /// `segments_dropped` accounting) so a learner restart never kills
    /// or silently stalls the rollout loop.
    fn push_segment(&mut self, si: usize) {
        let model_key = self.slots[si]
            .task
            .as_ref()
            .expect("segment push inside an episode")
            .learner_key;
        let na = self.layout.learner_slots.len() as u32;
        let slot = &mut self.slots[si];
        // bootstrap obs = current learner-slot observations
        let mut obs = std::mem::take(&mut slot.seg.obs);
        for &s in &self.layout.learner_slots {
            obs.extend_from_slice(&slot.cur_obs[s]);
        }
        let seg = TrajSegment {
            model_key,
            t: slot.seg.steps as u32,
            n_agents: na,
            obs,
            actions: std::mem::take(&mut slot.seg.actions),
            behavior_logp: std::mem::take(&mut slot.seg.logp),
            rewards: std::mem::take(&mut slot.seg.rewards),
            discounts: std::mem::take(&mut slot.seg.discounts),
            trace: self.pending_ctx.take(),
        };
        slot.seg.clear();
        self.pending_segs.push_back(Msg::Traj(seg));
        while self.pending_segs.len() > PUSH_QUEUE_CAP {
            self.pending_segs.pop_front();
            self.segments_dropped.add(1);
        }
        self.flush_segments();
    }

    /// Drain queued segments to the learner.  One failed attempt parks
    /// the segment back at the queue front and arms an exponential
    /// backoff (25ms doubling to an 800ms cap) so a dead learner costs
    /// at most one fast-failing connect per tick, not a retry ladder.
    fn flush_segments(&mut self) {
        if self.pending_segs.is_empty() {
            return;
        }
        if let Some(at) = self.push_retry_at {
            if Instant::now() < at {
                return;
            }
        }
        while let Some(msg) = self.pending_segs.pop_front() {
            match self.push.try_push(&msg) {
                Ok(()) => {
                    if self.push_fail_streak > 0 {
                        self.push_fail_streak = 0;
                        crate::transport::fault::on_recovery();
                    }
                    self.push_retry_at = None;
                }
                Err(_) => {
                    self.pending_segs.push_front(msg);
                    let shift = self.push_fail_streak.min(5);
                    self.push_fail_streak =
                        self.push_fail_streak.saturating_add(1);
                    self.push_retry_at =
                        Some(Instant::now() + Duration::from_millis(25 << shift));
                    return;
                }
            }
        }
    }

    /// Advance every env slot by one step (all agents in all slots
    /// act; one gathered forward pass per distinct model).  Returns
    /// true if any slot finished its episode this tick.
    pub fn step_once(&mut self) -> Result<bool> {
        // 0. segments parked by an earlier push failure get a delivery
        //    attempt each tick (subject to the backoff)
        self.flush_segments();

        // 1. fresh episodes: any slot without a task gets its next one
        for si in 0..self.slots.len() {
            if self.slots[si].task.is_none() {
                self.begin_task_slot(si)?;
            }
        }

        // 2. gather: one obs batch per distinct ModelKey, with a plan
        //    entry per (slot, group) in canonical order — slot-major,
        //    learner group first.  Scratch buffers are reused across
        //    ticks; a gather slot is live this tick once it has rows.
        //    A sampled tick (`trace_sample`) opens an actor_gather span
        //    whose trace threads through every InferReq this tick.
        let tick_ctx = self.roll_trace();
        let gather_t0 = tick_ctx.map(|_| Instant::now());
        self.plan.clear();
        let mut gathers = std::mem::take(&mut self.gather_buf);
        for g in &mut gathers {
            g.1.clear();
            g.2 = 0;
        }
        for si in 0..self.slots.len() {
            let task = self.slots[si].task.as_ref().expect("task set above");
            let learner_key = task.learner_key;
            let (key_idx, row) = gather_group(
                &mut gathers,
                learner_key,
                &self.layout.learner_slots,
                &self.slots[si].cur_obs,
            );
            self.plan.push(PlanEntry { slot: si, group: -1, key_idx, row });
            for (gi, group) in self.layout.opponent_groups.iter().enumerate() {
                let key =
                    task.opponents.get(gi).copied().unwrap_or(learner_key);
                let (key_idx, row) = gather_group(
                    &mut gathers,
                    key,
                    group,
                    &self.slots[si].cur_obs,
                );
                self.plan.push(PlanEntry {
                    slot: si,
                    group: gi as i32,
                    key_idx,
                    row,
                });
            }
        }

        // 3. one forward pass per live key (multi-row InferReq /
        //    chunked wide-artifact call) ...
        if let (Some(ctx), Some(t0)) = (tick_ctx, gather_t0) {
            let rows: usize = gathers.iter().map(|g| g.2).sum();
            trace::finish_span_id(
                ctx.trace_id, ctx.span_id, 0,
                "actor_gather", "actor", t0, rows as u32,
            );
            // the next pushed segment joins this trace (learner_consume)
            self.pending_ctx = Some(ctx);
        }
        let mut key_logits: Vec<Vec<f32>> = Vec::with_capacity(gathers.len());
        for (key, obs, rows) in &gathers {
            if *rows == 0 {
                key_logits.push(Vec::new()); // stale scratch slot
                continue;
            }
            let t0 = Instant::now();
            let ctx = tick_ctx.map(|t| TraceCtx {
                trace_id: t.trace_id,
                span_id: trace::next_id(),
            });
            let logits = self.infer(*key, obs, *rows, ctx)?;
            // always-on e2e row latency, sampled or not
            self.row_e2e.record_micros(t0.elapsed());
            if let (Some(c), Some(t)) = (ctx, tick_ctx) {
                trace::finish_span_id(
                    c.trace_id, c.span_id, t.span_id,
                    "actor_infer", "actor", t0, *rows as u32,
                );
            }
            key_logits.push(logits);
        }
        self.gather_buf = gathers;

        //    ... then scatter in PLAN order (not gather order): sample
        //    each row with its slot's RNG and route actions back to
        //    (slot, agent).  Plan order == the pre-vectorized sampling
        //    order, even when one key's gather merged duplicate
        //    opponent draws from non-adjacent groups.
        for acts in &mut self.learner_acts_buf {
            acts.clear();
        }
        for &p in &self.plan {
            let members: &[usize] = if p.group < 0 {
                &self.layout.learner_slots
            } else {
                &self.layout.opponent_groups[p.group as usize]
            };
            let logits = &key_logits[p.key_idx];
            for (i, &m) in members.iter().enumerate() {
                let rl = &logits
                    [(p.row + i) * self.act_dim..(p.row + i + 1) * self.act_dim];
                let act = self.slots[p.slot].rng.sample_logits(rl);
                self.actions_buf[p.slot][m] = act;
                if p.group < 0 {
                    self.learner_acts_buf[p.slot]
                        .push((act, log_softmax_at(rl, act)));
                }
            }
        }

        // 4. step every slot, record the learning agent's transition,
        //    push full segments, report finished episodes
        let n_slots = self.slots.len();
        let mut any_done = false;
        for si in 0..n_slots {
            // record obs+action+logp for the learning agent BEFORE stepping
            {
                let slot = &mut self.slots[si];
                for &s in &self.layout.learner_slots {
                    slot.seg.obs.extend_from_slice(&slot.cur_obs[s]);
                }
                for &(act, logp) in &self.learner_acts_buf[si] {
                    slot.seg.actions.push(act as i32);
                    slot.seg.logp.push(logp);
                }
            }

            let step = self.env.step_slot(si, &self.actions_buf[si]);
            self.frames.add(1);
            self.frames_done += 1;

            // team reward = mean over learner slots
            let r: f32 = self
                .layout
                .learner_slots
                .iter()
                .map(|&s| step.rewards[s])
                .sum::<f32>()
                / self.layout.learner_slots.len() as f32;
            let slot = &mut self.slots[si];
            slot.episode_steps += 1;
            slot.seg.rewards.push(r);
            slot.seg.discounts.push(if step.done {
                0.0
            } else {
                self.cfg.gamma
            });
            slot.seg.steps += 1;
            slot.cur_obs = step.obs;

            if self.slots[si].seg.steps >= self.train_t {
                self.push_segment(si);
            }

            if step.done {
                let task = self.slots[si].task.take().expect("episode task");
                let outcome = step
                    .info
                    .outcome
                    .as_ref()
                    .map(|o| {
                        self.layout
                            .learner_slots
                            .iter()
                            .map(|&s| o[s])
                            .sum::<f32>()
                            / self.layout.learner_slots.len() as f32
                    })
                    .unwrap_or(0.5);
                let episode_len = self.slots[si].episode_steps;
                self.league.report_outcome(MatchOutcome {
                    task_id: task.task_id,
                    learner_key: task.learner_key,
                    opponents: task.opponents,
                    outcome,
                    episode_len,
                    frames: episode_len as u64,
                })?;
                self.episodes.add(1);
                self.episodes_done += 1;
                any_done = true; // next step_once() starts a fresh task
            }
        }
        Ok(any_done)
    }

    /// Run until `stop` or `max_frames` env steps (summed over slots).
    /// Budgets on this actor's own step count, which stays correct even
    /// when `frames` is a hub meter shared with sibling actors.
    pub fn run(&mut self, max_frames: u64, stop: &AtomicBool) -> Result<u64> {
        let start = self.frames_done;
        while self.frames_done - start < max_frames
            && !stop.load(Ordering::Relaxed)
        {
            self.step_once()?;
        }
        Ok(self.frames_done - start)
    }
}

/// Append `members`' observations to the gather for `key`; returns the
/// gather's index and the group's starting row inside it.  Gathers are
/// scratch slots reused across ticks (`rows == 0` marks a stale slot
/// whose obs buffer capacity is up for reclaiming under a new key).
fn gather_group(
    gathers: &mut Vec<(ModelKey, Vec<f32>, usize)>,
    key: ModelKey,
    members: &[usize],
    cur_obs: &[Vec<f32>],
) -> (usize, usize) {
    let idx = match gathers.iter().position(|g| g.0 == key && g.2 > 0) {
        Some(i) => i,
        None => match gathers.iter().position(|g| g.2 == 0) {
            Some(i) => {
                gathers[i].0 = key;
                i
            }
            None => {
                gathers.push((key, Vec::new(), 0));
                gathers.len() - 1
            }
        },
    };
    let g = &mut gathers[idx];
    let row = g.2;
    for &m in members {
        g.1.extend_from_slice(&cur_obs[m]);
    }
    g.2 += members.len();
    (idx, row)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Regression: pommerman_ffa's opponent groups used to hardcode
    /// (1..4) — they must derive from n_agents.
    #[test]
    fn ffa_layout_derives_from_n_agents() {
        let l = role_layout("pommerman_ffa", 4);
        assert_eq!(l.learner_slots, vec![0]);
        assert_eq!(l.opponent_groups, vec![vec![1], vec![2], vec![3]]);
        let l = role_layout("pommerman_ffa", 6);
        assert_eq!(l.opponent_groups.len(), 5);
        let l = role_layout("pommerman_ffa", 2);
        assert_eq!(l.opponent_groups, vec![vec![1]]);
        // parameterized specs resolve through their base name
        let l = role_layout("doom_lite:4", 4);
        assert_eq!(l.learner_slots, vec![0]);
        assert_eq!(l.opponent_groups.len(), 3);
    }

    /// Every env's layout covers each agent slot exactly once.
    #[test]
    fn layouts_partition_all_env_slots() {
        for &name in crate::envs::ALL {
            let env = crate::envs::make(name, 1).unwrap();
            let l = role_layout(name, env.n_agents());
            let mut seen = vec![false; env.n_agents()];
            for &s in &l.learner_slots {
                assert!(!seen[s], "{name}: slot {s} double-assigned");
                seen[s] = true;
            }
            for g in &l.opponent_groups {
                for &s in g {
                    assert!(!seen[s], "{name}: slot {s} double-assigned");
                    seen[s] = true;
                }
            }
            assert!(seen.iter().all(|&b| b), "{name}: every slot covered");
        }
    }
}
