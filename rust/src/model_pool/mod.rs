//! ModelPool: versioned in-memory parameter store (paper §3.2).
//!
//! "During the whole training lifecycle, ModelPool must respond to any
//! parameter requesting (read) or updating (write) instantaneously" —
//! parameters are kept in memory; up to M_M replicas run simultaneously
//! and clients pick a random replica per read (load balancing), writing
//! through to all replicas.

use crate::proto::{ModelBlob, ModelKey, Msg};
use crate::transport::{RepServer, ReqClient};
use crate::util::rng::Pcg32;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

#[derive(Default)]
struct Store {
    blobs: BTreeMap<ModelKey, ModelBlob>,
    latest: BTreeMap<u32, ModelKey>, // per-agent newest version
}

/// One ModelPool replica: a REQ/REP service over the in-memory store.
pub struct ModelPoolServer {
    pub addr: String,
    store: Arc<Mutex<Store>>,
    _server: RepServer,
}

impl ModelPoolServer {
    pub fn start(bind: &str) -> Result<ModelPoolServer> {
        let store = Arc::new(Mutex::new(Store::default()));
        let s2 = store.clone();
        let server = RepServer::serve(bind, move |msg| match msg {
            Msg::PutModel(blob) => {
                let mut st = s2.lock().unwrap();
                let newer = st
                    .latest
                    .get(&blob.key.agent)
                    .map_or(true, |cur| blob.key.version >= cur.version);
                if newer {
                    st.latest.insert(blob.key.agent, blob.key);
                }
                st.blobs.insert(blob.key, blob);
                Msg::Ok
            }
            Msg::GetModel { key } => {
                let st = s2.lock().unwrap();
                match st.blobs.get(&key) {
                    Some(b) => Msg::Model(b.clone()),
                    None => Msg::NotFound,
                }
            }
            Msg::GetLatest { agent } => {
                let st = s2.lock().unwrap();
                match st.latest.get(&agent).and_then(|k| st.blobs.get(k)) {
                    Some(b) => Msg::Model(b.clone()),
                    None => Msg::NotFound,
                }
            }
            Msg::Ping => Msg::Pong,
            other => Msg::Err(format!("model_pool: unexpected {other:?}")),
        })?;
        Ok(ModelPoolServer { addr: server.addr.clone(), store, _server: server })
    }

    pub fn model_count(&self) -> usize {
        self.store.lock().unwrap().blobs.len()
    }
}

/// Client over one or more ModelPool replicas: writes go to every
/// replica, reads go to a random one.
pub struct ModelPoolClient {
    replicas: Vec<ReqClient>,
    rng: Mutex<Pcg32>,
}

impl ModelPoolClient {
    pub fn connect(addrs: &[String]) -> ModelPoolClient {
        assert!(!addrs.is_empty());
        ModelPoolClient {
            replicas: addrs.iter().map(|a| ReqClient::connect(a)).collect(),
            rng: Mutex::new(Pcg32::from_label(0x6d70, "mp-client")),
        }
    }

    fn pick(&self) -> &ReqClient {
        let i = self.rng.lock().unwrap().below(self.replicas.len() as u32);
        &self.replicas[i as usize]
    }

    pub fn put(&self, blob: ModelBlob) -> Result<()> {
        for r in &self.replicas {
            match r.request(&Msg::PutModel(blob.clone()))? {
                Msg::Ok => {}
                other => bail!("put: unexpected reply {other:?}"),
            }
        }
        Ok(())
    }

    pub fn get(&self, key: ModelKey) -> Result<Option<ModelBlob>> {
        match self.pick().request(&Msg::GetModel { key })? {
            Msg::Model(b) => Ok(Some(b)),
            Msg::NotFound => Ok(None),
            other => bail!("get: unexpected reply {other:?}"),
        }
    }

    pub fn get_latest(&self, agent: u32) -> Result<Option<ModelBlob>> {
        match self.pick().request(&Msg::GetLatest { agent })? {
            Msg::Model(b) => Ok(Some(b)),
            Msg::NotFound => Ok(None),
            other => bail!("get_latest: unexpected reply {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(agent: u32, version: u32, val: f32) -> ModelBlob {
        ModelBlob {
            key: ModelKey::new(agent, version),
            params: vec![val; 8],
            hp: vec![3e-4],
            frozen: false,
        }
    }

    #[test]
    fn put_get_roundtrip() {
        let server = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        client.put(blob(0, 1, 1.5)).unwrap();
        let got = client.get(ModelKey::new(0, 1)).unwrap().unwrap();
        assert_eq!(got.params, vec![1.5; 8]);
        assert!(client.get(ModelKey::new(0, 9)).unwrap().is_none());
        assert_eq!(server.model_count(), 1);
    }

    #[test]
    fn latest_tracks_highest_version() {
        let server = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        client.put(blob(0, 1, 1.0)).unwrap();
        client.put(blob(0, 3, 3.0)).unwrap();
        client.put(blob(0, 2, 2.0)).unwrap(); // stale write must not win
        let latest = client.get_latest(0).unwrap().unwrap();
        assert_eq!(latest.key.version, 3);
        assert!(client.get_latest(7).unwrap().is_none());
    }

    #[test]
    fn replicated_writes_readable_from_any() {
        let s1 = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let s2 = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let client = ModelPoolClient::connect(&[s1.addr.clone(), s2.addr.clone()]);
        client.put(blob(1, 4, 4.0)).unwrap();
        // both replicas hold the model, so any single-replica client sees it
        for addr in [&s1.addr, &s2.addr] {
            let c = ModelPoolClient::connect(&[addr.clone()]);
            assert!(c.get(ModelKey::new(1, 4)).unwrap().is_some());
        }
    }

    #[test]
    fn concurrent_readers_and_writers() {
        let server = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let addr = server.addr.clone();
        let mut handles = Vec::new();
        for t in 0..4u32 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let c = ModelPoolClient::connect(&[addr]);
                for v in 0..20 {
                    c.put(blob(t, v, v as f32)).unwrap();
                    let got = c.get(ModelKey::new(t, v)).unwrap().unwrap();
                    assert_eq!(got.params[0], v as f32);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.model_count(), 80);
    }
}
