#!/usr/bin/env bash
# CI gate: formatting, lints (deny warnings), league-lint, then the
# tier-1 command.
# Usage: ./ci.sh [--no-lint] [--miri] [--tsan]
#   --no-lint  skip fmt/clippy (e.g. on toolchains without those components)
#   --miri     also run `cargo +nightly miri test` on the pure-compute
#              modules (self-skips when nightly miri is not installed)
#   --tsan     also run the lib tests under -Zsanitizer=thread
#              (self-skips when nightly rust-src is not installed)
set -euo pipefail
cd "$(dirname "$0")"

NO_LINT=0 RUN_MIRI=0 RUN_TSAN=0
for arg in "$@"; do
    case "$arg" in
        --no-lint) NO_LINT=1 ;;
        --miri) RUN_MIRI=1 ;;
        --tsan) RUN_TSAN=1 ;;
        *)
            echo "usage: ./ci.sh [--no-lint] [--miri] [--tsan]" >&2
            exit 2
            ;;
    esac
done

if [[ "$NO_LINT" != 1 ]]; then
    echo "== cargo fmt --check"
    cargo fmt --check
    echo "== cargo clippy -D warnings"
    cargo clippy -- -D warnings
fi

# Project-invariant static analysis (hard gate): proto tag registry,
# unsafe hygiene, nonblocking regions, unwrap budget.  The self-test
# first proves the analyzer still flags its seeded-bad fixtures, then
# the tree walk must come back clean under lint-allow.toml.
echo "== league-lint --self-test rust/lint-fixtures"
cargo run -q --release --bin league-lint -- --self-test rust/lint-fixtures
echo "== league-lint (tree walk, hard fail)"
cargo run -q --release --bin league-lint

echo "== tier-1: cargo build --release && cargo test -q"
cargo build --release
cargo test -q

# Perf smoke: codec + model-pool data plane.  Refreshes the committed
# perf-trajectory file with this image's numbers (see BENCH_pr2.json).
echo "== bench smoke: cargo bench --bench bench_main -- codec pool"
# --bench bench_main: the lib/bin libtest harnesses would reject --json
cargo bench --bench bench_main -- codec pool --json BENCH_pr2.json

# Rollout-engine smoke: single-env vs vectorized actor frames/sec
# (N in {1, 8, 32}; see BENCH_pr3.json).
echo "== bench smoke: cargo bench --bench bench_main -- rollout"
cargo bench --bench bench_main -- rollout --json BENCH_pr3.json

# Multi-process deployment smoke: controller + real worker subprocesses
# (register/heartbeat/reassign; also covered inside `cargo test` above,
# rerun here standalone so a deploy regression is called out by name).
echo "== procs smoke: cargo test --test procs_deploy"
cargo test -q --test procs_deploy

# Control-plane bench: task-assignment round-trip + heartbeat overhead
# at 64 simulated workers (see BENCH_pr4.json).
echo "== bench smoke: cargo bench --bench bench_main -- deploy"
cargo bench --bench bench_main -- deploy --json BENCH_pr4.json

# Telemetry-plane bench: snapshot codec, 64-slot merge, and the
# heartbeat-with-stats round-trip (see BENCH_pr5.json).
echo "== bench smoke: cargo bench --bench bench_main -- telemetry"
cargo bench --bench bench_main -- telemetry --json BENCH_pr5.json

# Request-path tracing bench: span record overhead, latency-hist record
# + 64-way merge, and the actor row path at trace-sample 0 / 1% / 100%
# (the off row is the no-overhead-when-untraced claim; see BENCH_pr6.json).
echo "== bench smoke: cargo bench --bench bench_main -- trace"
cargo bench --bench bench_main -- trace --json BENCH_pr6.json

# Fault-injection bench: the per-transport-op guard disabled vs armed,
# plus the actor row path both ways (the disabled rows are the
# no-overhead claim; see BENCH_pr7.json).
echo "== bench smoke: cargo bench --bench bench_main -- faults"
cargo bench --bench bench_main -- faults --json BENCH_pr7.json

# Transport-scale bench: fan-in heartbeat/echo at 64/512/4096 conns on
# one event-loop pool (the 4096 row self-skips when ulimit -n is too
# low), plus the multi-row infer request over loopback TCP vs a
# shared-memory lane (see BENCH_pr8.json).
echo "== bench smoke: cargo bench --bench bench_main -- transport_scale"
cargo bench --bench bench_main -- transport_scale --json BENCH_pr8.json

# Elastic-league bench: consistent-hash ring owner lookup, the bytes a
# replica-bounce rebalance pushes through the rev protocol, and the
# autoscaler's per-tick policy evaluation at 64 slots per role
# (see BENCH_pr9.json).
echo "== bench smoke: cargo bench --bench bench_main -- elastic"
cargo bench --bench bench_main -- elastic --json BENCH_pr9.json

# Analyzer-cost bench: full-tree league-lint walk + the proto registry
# parse alone — keeps the hard lint gate measurably cheap
# (see BENCH_pr10.json).
echo "== bench smoke: cargo bench --bench bench_main -- lint"
cargo bench --bench bench_main -- lint --json BENCH_pr10.json

# Lane/TCP equivalence: same seeded request sequence over both paths
# must be bit-identical (also inside `cargo test` above, rerun by name).
echo "== lane equivalence: cargo test --test transport_lanes"
cargo test -q --test transport_lanes

# Chaos drills: deterministic fault plans + scheduled kills (inf-server,
# pool replica, learner, and the controller itself) over real worker
# subprocesses; asserts completed runs, reassigned slots, and surviving
# league totals (also inside `cargo test` above, rerun by name so a
# recovery regression is called out).
echo "== chaos drills: cargo test --test chaos"
cargo test -q --test chaos

# Telemetry stats smoke: a short thread-mode league writing a JSONL
# trajectory; assert the file is non-empty valid JSONL with monotone
# timestamps and that the summed actor frame deltas (= the last row's
# run total) match the league frame counter within 1%.
if [[ -f artifacts/manifest.json ]] && command -v python3 >/dev/null; then
    echo "== stats smoke: thread-mode league with --stats-jsonl"
    SJ="$(mktemp -t tleague-stats-XXXXXX.jsonl)"
    ./target/release/tleague run --env rps --total-steps 30 --period-steps 10 \
        --stats-every 1 --stats-jsonl "$SJ"
    python3 - "$SJ" <<'EOF'
import json, sys
rows = [json.loads(l) for l in open(sys.argv[1]) if l.strip()]
assert rows, "stats jsonl is empty"
ts = [r["t"] for r in rows]
assert ts == sorted(ts), "timestamps not monotone: %r" % ts
last = rows[-1]
frames = last["league"]["frames"]
actor = last["roles"]["actor"]["totals"]["env_frames"]
assert frames > 0, "league recorded no frames"
slack = max(0.01 * max(actor, frames), 64)  # 1%, floored for tiny runs
assert abs(actor - frames) <= slack, \
    "actor env_frames total %d vs league frames %d (slack %d)" % (actor, frames, slack)
print("stats smoke OK: %d rows, actor env_frames=%d, league frames=%d"
      % (len(rows), actor, frames))
EOF
    rm -f "$SJ"
else
    echo "(artifacts or python3 missing; skipping stats smoke)"
fi

# Tracing smoke: a fully-sampled thread-mode league exporting its flight
# recorder as Chrome trace JSON; assert it parses, events are complete
# ("X") spans covering the actor request path, and timestamps are
# monotone in the sorted export.
if [[ -f artifacts/manifest.json ]] && command -v python3 >/dev/null; then
    echo "== trace smoke: thread-mode league with --trace-sample 1 --trace-out"
    TJ="$(mktemp -t tleague-trace-XXXXXX.json)"
    ./target/release/tleague run --env rps --total-steps 30 --period-steps 10 \
        --trace-sample 1 --trace-slow-ms 1000 --trace-out "$TJ"
    python3 - "$TJ" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
evs = doc["traceEvents"]
assert evs, "no trace events exported"
assert all(e["ph"] == "X" for e in evs), "non-complete event phase"
ts = [e["ts"] for e in evs]
assert ts == sorted(ts), "trace timestamps not monotone"
names = {e["name"] for e in evs}
for want in ("actor_gather", "actor_infer", "learner_consume"):
    assert want in names, "missing span %r in %r" % (want, sorted(names))
print("trace smoke OK: %d events, %d span kinds" % (len(evs), len(names)))
EOF
    rm -f "$TJ"
else
    echo "(artifacts or python3 missing; skipping trace smoke)"
fi

# Autoscale smoke: a procs-mode league with ONE inf server and
# vectorized actors whose 32-row requests keep every forward pass full
# (batch_fill ~1.0 > the 0.8 grow threshold) — the closed-loop
# controller must grow inf slots, the supervisor must spawn workers
# into them, and the decisions must land in the JSONL telemetry as
# role "autoscaler" counters.
if [[ -f artifacts/manifest.json ]] && command -v python3 >/dev/null; then
    echo "== autoscale smoke: run --mode procs --autoscale (starved inf server)"
    ASPEC="$(mktemp -t tleague-autoscale-spec-XXXXXX.json)"
    AJ="$(mktemp -t tleague-autoscale-XXXXXX.jsonl)"
    cat > "$ASPEC" <<'EOF'
{
  "env": "rps", "mode": "procs", "seed": 7,
  "total_steps": 16, "period_steps": 4,
  "actors_per_learner": 2, "envs_per_actor": 32, "inf_servers": 1,
  "autoscale": true, "scale_every_secs": 1,
  "heartbeat_ms": 100, "heartbeat_timeout_ms": 1000,
  "stats_every_secs": 1
}
EOF
    ./target/release/tleague run --config "$ASPEC" --stats-jsonl "$AJ" \
        | tee /dev/stderr | grep -q "done:"
    python3 - "$AJ" <<'EOF'
import json, sys
rows = [json.loads(l) for l in open(sys.argv[1]) if l.strip()]
assert rows, "autoscale jsonl is empty"
ups = max(r["roles"].get("autoscaler", {}).get("totals", {}).get("scale_up_inf", 0)
          for r in rows)
assert ups > 0, "autoscaler never grew inf slots; roles seen: %r" % (
    sorted(rows[-1]["roles"]))
print("autoscale smoke OK: %d inf slot grow decision(s) in telemetry" % ups)
EOF
    rm -f "$ASPEC" "$AJ"
else
    echo "(artifacts or python3 missing; skipping autoscale smoke)"
fi

# Chaos smoke: the one-command drill — a procs-mode league under a
# seeded fault plan with a mid-run actor kill; the run must absorb the
# kill (respawn + slot reassignment) and print its completion line.
if [[ -f artifacts/manifest.json ]]; then
    echo "== chaos smoke: run --mode procs --chaos kill:actor@400"
    ./target/release/tleague run --env rps --mode procs \
        --total-steps 6 --period-steps 2 --actors 1 \
        --heartbeat-ms 100 --heartbeat-timeout-ms 1000 \
        --chaos "kill:actor@400" --faults "delay:*@0.02+2" --fault-seed 7 \
        | tee /dev/stderr | grep -q "done:"
    echo "chaos smoke OK"
else
    echo "(artifacts missing; skipping chaos smoke)"
fi

# Miri: interpret the pure-compute modules (wire codec, metrics/Hist,
# shm ring cursor logic) for UB.  mmap-backed shm tests carry
# cfg_attr(miri, ignore) and self-skip inside the harness.
if [[ "$RUN_MIRI" == 1 ]]; then
    if command -v rustup >/dev/null \
        && rustup toolchain list 2>/dev/null | grep -q nightly \
        && rustup component list --toolchain nightly --installed 2>/dev/null \
            | grep -q miri; then
        echo "== miri: cargo +nightly miri test --lib (codec, metrics, shm)"
        cargo +nightly miri test --lib -- util::codec util::metrics transport::shm
    else
        echo "(nightly miri not installed; skipping miri stage)"
    fi
fi

# ThreadSanitizer: lib tests under -Zsanitizer=thread (needs nightly +
# rust-src to rebuild std instrumented).  Catches data races the
# OrderedMutex lock-order checks cannot.
if [[ "$RUN_TSAN" == 1 ]]; then
    if command -v rustup >/dev/null \
        && rustup toolchain list 2>/dev/null | grep -q nightly \
        && rustup component list --toolchain nightly --installed 2>/dev/null \
            | grep -q rust-src; then
        echo "== tsan: cargo +nightly test --lib with -Zsanitizer=thread"
        RUSTFLAGS="-Zsanitizer=thread" cargo +nightly test --lib -q \
            -Zbuild-std --target "$(uname -m)-unknown-linux-gnu"
    else
        echo "(nightly rust-src not installed; skipping tsan stage)"
    fi
fi
echo "CI OK"
