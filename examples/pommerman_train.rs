//! END-TO-END DRIVER (Fig 4 of the paper): Pommerman Team-mode CSP-MARL.
//!
//! Trains a ~770k-parameter centralized-value team policy with PPO and
//! the paper's 35% self-play + 65% PFSP opponent sampling, through the
//! full distributed stack (LeagueMgr / ModelPool / Learner / Actors).
//! At every checkpoint the current model is evaluated against
//! SimpleAgent (win-rate, tie = 0.5) and the Navocado stand-in (W/L/T) —
//! the two curves of the paper's Figure 4.
//!
//!     cargo run --release --example pommerman_train -- [steps] [eval-games]

use std::sync::Arc;
use std::time::Duration;
use tleague::config::RunConfig;
use tleague::envs::pommerman::agents::{Navocado, ScriptedPolicy, SimpleAgent};
use tleague::eval::{pommerman_record, NnPolicy};
use tleague::model_pool::ModelPoolClient;
use tleague::orchestrator::Deployment;
use tleague::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let total_steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let eval_games: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(20);

    let engine = Arc::new(Engine::load("artifacts")?);
    let mut cfg = RunConfig::default();
    cfg.env = "pommerman".into();
    cfg.game_mgr = "sp_pfsp".into(); // the paper's 35/65 mixture
    cfg.actors_per_learner = 6;
    cfg.total_steps = total_steps;
    cfg.period_steps = (total_steps / 6).max(10);
    cfg.publish_every = 4;
    cfg.gamma = 0.995;
    cfg.hp_overrides.insert("lr".into(), 1e-3);
    cfg.hp_overrides.insert("ent_coef".into(), 0.012);
    cfg.seed = 3;

    println!("== Fig-4 driver: Pommerman Team, PPO + SP/PFSP, {total_steps} learner steps ==");
    let dep = Deployment::start(cfg, engine.clone())?;
    let pool = ModelPoolClient::connect(dep.pool_addrs());

    let n_checkpoints = 6u64;
    let every = (total_steps / n_checkpoints).max(1);
    let mut next_eval = 0u64;
    let mut curve: Vec<(u64, f64, (u32, u32, u32))> = Vec::new();
    loop {
        let steps = dep.total_learner_steps();
        if steps >= next_eval || dep.learners_done() {
            if let Some(blob) = pool.get_latest(0)? {
                let mut nn =
                    NnPolicy::new(engine.clone(), "pommerman", blob.params, steps);
                let mut mk_simple = |s: u64| {
                    Box::new(SimpleAgent::new(s)) as Box<dyn ScriptedPolicy>
                };
                let (w, l, t) =
                    pommerman_record(&mut nn, &mut mk_simple, eval_games, steps)?;
                let winrate = (w as f64 + 0.5 * t as f64) / eval_games as f64;
                let mut nn2 = NnPolicy::new(
                    engine.clone(),
                    "pommerman",
                    pool.get_latest(0)?.unwrap().params,
                    steps + 1,
                );
                let mut mk_nav = |s: u64| {
                    Box::new(Navocado::new(s)) as Box<dyn ScriptedPolicy>
                };
                let nav =
                    pommerman_record(&mut nn2, &mut mk_nav, eval_games, steps)?;
                let lstats = dep.league_stats();
                let ts = dep.learner_status[0].stats.lock().unwrap().clone();
                println!(
                    "iter {steps:5}  pool={:2} episodes={:5} loss={:+.3} ent={:.3} | \
                     vs Simple: winrate {winrate:.2} | vs Navocado: {}/{}/{} (W/L/T)",
                    lstats.pool_size, lstats.episodes, ts.loss, ts.entropy,
                    nav.0, nav.1, nav.2
                );
                curve.push((steps, winrate, nav));
            }
            next_eval += every;
        }
        if dep.learners_done() {
            break;
        }
        std::thread::sleep(Duration::from_millis(500));
    }

    println!("\n== Fig-4 (left): win-rate vs SimpleAgent (tie = 0.5 win) ==");
    println!("{:>8} {:>10}", "iter", "winrate");
    for (s, w, _) in &curve {
        println!("{s:>8} {w:>10.2}");
    }
    println!("\n== Fig-4 (right): W/L/T vs Navocado stand-in ==");
    println!("{:>8} {:>5} {:>6} {:>5}", "iter", "wins", "losses", "ties");
    for (s, _, (w, l, t)) in &curve {
        println!("{s:>8} {w:>5} {l:>6} {t:>5}");
    }
    let first = curve.first().map(|c| c.1).unwrap_or(0.0);
    let last = curve.last().map(|c| c.1).unwrap_or(0.0);
    println!("\nwin-rate vs SimpleAgent: {first:.2} -> {last:.2}");
    let mut dep = dep;
    dep.shutdown();
    Ok(())
}
