//! Synthetic heavy environment for the Table-3 throughput harness.
//!
//! Stands in for the paper's closed/heavy envs (SC2 full game, Dota 2):
//! a configurable per-step CPU cost and a large opaque observation so
//! the Actor→Learner data plane is exercised at realistic frame sizes.
//! The "game" is a trivial 2-player score race so outcomes exist.

use super::{Info, MultiAgentEnv, Step};
use crate::util::rng::Pcg32;

pub struct Synthetic {
    rng: Pcg32,
    obs_dim: usize,
    act_dim: usize,
    /// busy-work iterations per step, calibrating in-game fps
    step_cost: u64,
    episode_len: usize,
    steps: usize,
    scores: [f32; 2],
    scratch: Vec<f32>,
}

impl Synthetic {
    pub fn new(seed: u64) -> Self {
        Self::with_cost(seed, 2_000, 256)
    }

    /// `step_cost` = busy-loop iterations (models game-core simulation
    /// cost); `episode_len` = fixed episode length in steps.
    pub fn with_cost(seed: u64, step_cost: u64, episode_len: usize) -> Self {
        let obs_dim = 1024;
        Synthetic {
            rng: Pcg32::from_label(seed, "synthetic"),
            obs_dim,
            act_dim: 16,
            step_cost,
            episode_len,
            steps: 0,
            scores: [0.0, 0.0],
            scratch: vec![0.0; obs_dim],
        }
    }

    fn gen_obs(&mut self) -> Vec<Vec<f32>> {
        // cheap pseudo-features; regenerated per agent per step
        (0..2)
            .map(|a| {
                let mut v = self.scratch.clone();
                let base = self.rng.next_f32();
                for (i, x) in v.iter_mut().enumerate() {
                    *x = base + (i as f32 * 0.001) + a as f32;
                }
                v
            })
            .collect()
    }
}

impl MultiAgentEnv for Synthetic {
    fn n_agents(&self) -> usize {
        2
    }
    fn obs_dim(&self) -> usize {
        self.obs_dim
    }
    fn act_dim(&self) -> usize {
        self.act_dim
    }
    fn max_steps(&self) -> usize {
        self.episode_len
    }

    fn reset(&mut self) -> Vec<Vec<f32>> {
        self.steps = 0;
        self.scores = [0.0, 0.0];
        self.gen_obs()
    }

    fn step(&mut self, actions: &[usize]) -> Step {
        self.steps += 1;
        // simulate game-core cost (SC2 steps are milliseconds of C++)
        let mut acc = 0u64;
        for i in 0..self.step_cost {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        std::hint::black_box(acc);

        let r0 = if actions[0] > actions[1] {
            0.01
        } else if actions[1] > actions[0] {
            -0.01
        } else {
            0.0
        };
        self.scores[0] += r0;
        self.scores[1] -= r0;
        let done = self.steps >= self.episode_len;
        let info = if done {
            let outcome = match self.scores[0]
                .partial_cmp(&self.scores[1])
                .unwrap()
            {
                std::cmp::Ordering::Greater => vec![1.0, 0.0],
                std::cmp::Ordering::Less => vec![0.0, 1.0],
                std::cmp::Ordering::Equal => vec![0.5, 0.5],
            };
            Info { outcome: Some(outcome), frags: None }
        } else {
            Info::default()
        };
        Step { obs: self.gen_obs(), rewards: vec![r0, -r0], done, info }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_episode_length() {
        let mut env = Synthetic::with_cost(0, 10, 32);
        env.reset();
        for t in 0..32 {
            let s = env.step(&[0, 1]);
            assert_eq!(s.done, t == 31);
        }
    }

    #[test]
    fn obs_sized_to_spec() {
        let mut env = Synthetic::new(0);
        let obs = env.reset();
        assert_eq!(obs[0].len(), 1024);
        assert_eq!(obs.len(), 2);
    }
}
