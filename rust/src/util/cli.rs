//! Minimal command-line parser (no clap in the offline crate set).
//!
//! Supports `program <subcommand> --flag value --bool-flag pos1 pos2`.

use std::collections::BTreeMap;

/// Top-level `--help` text, printed by the binary when invoked with no
/// subcommand or with `--help`.
pub const USAGE: &str = "\
tleague — competitive self-play distributed MARL (TLeague reproduction)

usage: tleague <subcommand> [--flag value ...]

subcommands:
  run          launch a full league (kube-lite orchestrator)
    --config <spec.json>     JSON run spec (flags below override it)
    --env <name>             rps|pong2p|pommerman|pommerman_ffa|doom_lite|synthetic
                             parameterized specs: doom_lite:<players 2..8>,
                             synthetic:<episode_len>
    --artifacts <dir>        AOT artifact directory (default: artifacts)
    --total-steps N          learner steps to run (default 100)
    --period-steps N         steps per learning period (default 25)
    --actors N               actors per learner (default 2)
    --envs-per-actor N       concurrent episodes per actor (vectorized
                             rollouts: each tick gathers every slot's
                             observations into one multi-row forward
                             pass per model; default 1 = classic actor)
    --game-mgr <name>        selfplay|uniform|pfsp|sp_pfsp|elo_match
    --checkpoint-dir <dir>   write durable league snapshots here
    --checkpoint-every S     seconds between snapshots (default 30)
    --resume <dir>           restart from the newest snapshot in <dir>
   data-plane knobs:
    --refresh-every N        actor param-refresh cadence in episodes
                             (delta-aware: an unchanged in-training model
                             costs an O(1) NotModified reply; default 1)
    --infer-max-wait-us U    InfServer partial-batch deadline in
                             microseconds (default 2000)
    --infer-refresh-ms M     InfServer in-training param cache TTL in
                             milliseconds (default 50)
  info         print the artifact manifest summary (--artifacts <dir>)
  eval-doom    FRAG matches, Tables 1-2
    --checkpoint <f32 file> --setting 1|2a|2b|2c --games N
  eval-rps     RPS pool exploitability demo (--artifacts <dir>)
  model-pool   standalone ModelPool replica (--bind host:port)
  league-mgr   standalone LeagueMgr
    --bind host:port --n-agents N --n-opponents N --game-mgr <name> --seed S
";

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse argv[1..]; the first non-flag token becomes the subcommand.
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("actor --env pommerman --replicas 4 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("actor"));
        assert_eq!(a.get("env"), Some("pommerman"));
        assert_eq!(a.usize_or("replicas", 1), 4);
        assert!(a.bool("verbose"));
    }

    #[test]
    fn equals_form_and_positional() {
        let a = parse("eval --games=10 file1 file2");
        assert_eq!(a.usize_or("games", 0), 10);
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.f64_or("lr", 3e-4), 3e-4);
        assert_eq!(a.str_or("mode", "thread"), "thread");
        assert!(!a.bool("missing"));
    }
}
