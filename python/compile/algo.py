"""RL algorithms as pure jax functions over flat parameter vectors.

PPO and V-trace learners (the two proxy algorithms TLeague ships, 2),
built on the Pallas kernels:
  - advantages / value targets: gae_pallas / vtrace_pallas (stop-gradient)
  - PPO per-sample terms incl. backward: ppo_terms_pallas (custom_vjp)

Hyper-parameters arrive as a runtime vector (envs_spec.HP_LAYOUT) so the
HyperMgr / PBT can change them between learning periods without
recompiling artifacts.  ``discounts`` fold gamma and termination on the
Rust side: discount_t = gamma * (1 - done_t).
"""

import jax
import jax.numpy as jnp

from . import nets
from .envs_spec import HP_LAYOUT
from .kernels.gae import gae_pallas
from .kernels.vtrace import vtrace_pallas
from .kernels.ppo_loss import ppo_terms_pallas
from .kernels import ref as kref

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def hp_get(hp, name):
    return hp[HP_LAYOUT.index(name)]


def adam_step(params, m, v, step, grads, lr):
    """One fused Adam update over the flat vectors; step is f32[1]."""
    t = step[0] + 1.0
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * grads
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * grads * grads
    mhat = m / (1.0 - ADAM_B1 ** t)
    vhat = v / (1.0 - ADAM_B2 ** t)
    params = params - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return params, m, v, jnp.reshape(t, (1,))


def clip_by_global_norm(grads, max_norm):
    gn = jnp.sqrt(jnp.sum(grads * grads) + 1e-12)
    scale = jnp.where(max_norm > 0.0,
                      jnp.minimum(1.0, max_norm / gn), 1.0)
    return grads * scale, gn


def _normalize(adv):
    return (adv - jnp.mean(adv)) / (jnp.std(adv) + 1e-8)


def ppo_loss(params, hp, batch, spec, use_pallas=True):
    """PPO clipped-surrogate loss.

    batch (time-major):
      obs           [T+1, B, D]   (team: [T+1, B, 2, D])
      actions       [T,   B] i32  (team: [T, B, 2])
      behavior_logp [T,   B]      (team: [T, B, 2])
      rewards       [T,   B]
      discounts     [T,   B]
    Returns (loss, stats[8]).
    """
    obs, actions, behavior_logp, rewards, discounts = batch
    T = rewards.shape[0]
    B = rewards.shape[1]
    apply_fn = nets.make_apply(spec)
    logits, values = apply_fn(params, obs)   # [T+1,B,(2,)A], [T+1,B]

    vals_c = jax.lax.stop_gradient(values)
    adv = gae_pallas(rewards, discounts, vals_c, hp_get(hp, "lam"))
    ret = adv + vals_c[:-1]
    adv_n = _normalize(adv)

    A = spec["act_dim"]
    if spec["team"]:
        # Team = one meta-agent stepped by two shared-weight forward passes
        # (paper 4.3): per-agent policy terms share the team advantage;
        # the value loss is on the single centralized value.
        lg = logits[:-1].reshape(T * B * 2, A)
        ac = actions.reshape(T * B * 2)
        lpo = behavior_logp.reshape(T * B * 2)
        ad = jnp.repeat(adv_n.reshape(T * B), 2)
        # per-sample value/ret arrays must align with the policy samples for
        # the fused kernel; weight the duplicated value loss by 0.5.
        va = jnp.repeat(values[:-1].reshape(T * B), 2)
        re = jnp.repeat(ret.reshape(T * B), 2)
        v_dup = 0.5
    else:
        lg = logits[:-1].reshape(T * B, A)
        ac = actions.reshape(T * B)
        lpo = behavior_logp.reshape(T * B)
        ad = adv_n.reshape(T * B)
        va = values[:-1].reshape(T * B)
        re = ret.reshape(T * B)
        v_dup = 1.0

    terms = ppo_terms_pallas if use_pallas else (
        lambda *a: kref.ppo_terms_ref(*a[:7]))
    pol, vl, ent, kl = terms(lg, ac, lpo, ad, va,
                             jax.lax.stop_gradient(re),
                             hp_get(hp, "clip_eps"))
    pol_loss = jnp.mean(pol)
    v_loss = v_dup * jnp.mean(vl)
    entropy = jnp.mean(ent)
    loss = pol_loss + hp_get(hp, "vf_coef") * v_loss \
        - hp_get(hp, "ent_coef") * entropy
    stats = jnp.stack([loss, pol_loss, v_loss, entropy, jnp.mean(kl),
                       jnp.max(kl), jnp.mean(adv), jnp.std(adv)])
    return loss, stats


def vtrace_loss(params, hp, batch, spec):
    """V-trace actor-critic loss (IMPALA); solo nets only.

    Same batch layout as ppo_loss.  log_rho = logp_target - logp_behavior.
    """
    obs, actions, behavior_logp, rewards, discounts = batch
    T, B = rewards.shape
    apply_fn = nets.make_apply(spec)
    logits, values = apply_fn(params, obs)
    A = spec["act_dim"]
    lg = logits[:-1].reshape(T * B, A)
    ac = actions.reshape(T * B)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    lp_all = lg - logz[:, None]
    logp = jnp.take_along_axis(
        lp_all, ac[:, None].astype(jnp.int32), axis=-1)[:, 0]
    log_rhos = (logp.reshape(T, B) - behavior_logp)
    vals_c = jax.lax.stop_gradient(values)
    vs, pg_adv = vtrace_pallas(
        jax.lax.stop_gradient(log_rhos), rewards, discounts, vals_c,
        hp_get(hp, "lam"), hp_get(hp, "rho_bar"), hp_get(hp, "c_bar"))
    pol_loss = -jnp.mean(pg_adv.reshape(-1) * logp)
    v_loss = 0.5 * jnp.mean(
        jnp.square(values[:-1] - vs))
    p = jnp.exp(lp_all)
    entropy = jnp.mean(-jnp.sum(p * lp_all, axis=-1))
    loss = pol_loss + hp_get(hp, "vf_coef") * v_loss \
        - hp_get(hp, "ent_coef") * entropy
    kl = behavior_logp.reshape(-1) - logp
    stats = jnp.stack([loss, pol_loss, v_loss, entropy, jnp.mean(kl),
                       jnp.max(kl), jnp.mean(pg_adv), jnp.std(pg_adv)])
    return loss, stats


def grads_of(loss_fn, params, hp, batch, spec, **kw):
    (loss, stats), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, hp, batch, spec, **kw)
    grads, gn = clip_by_global_norm(grads, hp_get(hp, "grad_clip"))
    stats = jnp.concatenate([stats, jnp.stack([gn])])
    return grads, stats


def train_step(loss_fn, params, m, v, step, hp, batch, spec, **kw):
    """Fused train step: grads + clip + Adam, all in-graph."""
    grads, stats = grads_of(loss_fn, params, hp, batch, spec, **kw)
    params, m, v, step = adam_step(params, m, v, step, grads,
                                   hp_get(hp, "lr"))
    return params, m, v, step, stats
