"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes and value regimes; every property asserts
allclose against the pure-jnp reference in kernels/ref.py.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gae import gae_pallas
from compile.kernels.vtrace import vtrace_pallas
from compile.kernels.ppo_loss import ppo_terms_pallas

SET = dict(max_examples=25, deadline=None)


def _seq_data(seed, T, B, reward_scale=1.0):
    rng = np.random.RandomState(seed)
    rewards = (rng.randn(T, B) * reward_scale).astype(np.float32)
    # mix of mid-episode terminations and gamma discounting
    done = rng.rand(T, B) < 0.1
    discounts = (0.99 * (1.0 - done)).astype(np.float32)
    values = rng.randn(T + 1, B).astype(np.float32)
    return rewards, discounts, values


class TestGAE:
    @settings(**SET)
    @given(seed=st.integers(0, 2**31 - 1), T=st.integers(1, 40),
           B=st.integers(1, 200), lam=st.floats(0.0, 1.0))
    def test_matches_ref(self, seed, T, B, lam):
        rewards, discounts, values = _seq_data(seed, T, B)
        got = gae_pallas(rewards, discounts, values, lam)
        want = ref.gae_ref(rewards, discounts, values, lam)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_termination_blocks_bootstrap(self):
        # A done at t cuts the recursion: adv_t = r_t - V_t exactly.
        T, B = 4, 1
        rewards = np.ones((T, B), np.float32)
        discounts = np.zeros((T, B), np.float32)  # every step terminal
        values = np.full((T + 1, B), 5.0, np.float32)
        adv = np.asarray(gae_pallas(rewards, discounts, values, 0.95))
        np.testing.assert_allclose(adv, 1.0 - 5.0)

    def test_lambda0_is_td_error(self):
        rewards, discounts, values = _seq_data(3, 8, 16)
        adv = np.asarray(gae_pallas(rewards, discounts, values, 0.0))
        td = rewards + discounts * values[1:] - values[:-1]
        np.testing.assert_allclose(adv, td, rtol=1e-5, atol=1e-6)

    def test_batch_padding_edge(self):
        # B not a multiple of the tile: padding must not leak.
        for B in (1, 127, 129, 255):
            rewards, discounts, values = _seq_data(B, 4, B)
            got = gae_pallas(rewards, discounts, values, 0.9)
            want = ref.gae_ref(rewards, discounts, values, 0.9)
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestVtrace:
    @settings(**SET)
    @given(seed=st.integers(0, 2**31 - 1), T=st.integers(1, 32),
           B=st.integers(1, 150), lam=st.floats(0.5, 1.0),
           rho_bar=st.floats(0.5, 2.0), c_bar=st.floats(0.5, 2.0))
    def test_matches_ref(self, seed, T, B, lam, rho_bar, c_bar):
        rewards, discounts, values = _seq_data(seed, T, B)
        rng = np.random.RandomState(seed + 1)
        log_rhos = (rng.randn(T, B) * 0.4).astype(np.float32)
        vs1, pg1 = vtrace_pallas(log_rhos, rewards, discounts, values,
                                 lam, rho_bar, c_bar)
        vs2, pg2 = ref.vtrace_ref(log_rhos, rewards, discounts, values,
                                  lam, rho_bar, c_bar)
        np.testing.assert_allclose(vs1, vs2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(pg1, pg2, rtol=1e-4, atol=1e-4)

    def test_on_policy_reduces_to_lambda_return(self):
        # log_rho = 0, rho_bar = c_bar = 1: vs - V == GAE advantages.
        rewards, discounts, values = _seq_data(7, 12, 33)
        zeros = np.zeros_like(rewards)
        vs, _ = vtrace_pallas(zeros, rewards, discounts, values,
                              0.95, 1.0, 1.0)
        adv = ref.gae_ref(rewards, discounts, values, 0.95)
        np.testing.assert_allclose(np.asarray(vs) - values[:-1], adv,
                                   rtol=1e-4, atol=1e-4)


class TestPPOFused:
    def _data(self, seed, N, A):
        rng = np.random.RandomState(seed)
        logits = rng.randn(N, A).astype(np.float32)
        actions = rng.randint(0, A, N).astype(np.int32)
        logp_old = (rng.randn(N) * 0.5 - 1.5).astype(np.float32)
        adv = rng.randn(N).astype(np.float32)
        value = rng.randn(N).astype(np.float32)
        ret = rng.randn(N).astype(np.float32)
        return logits, actions, logp_old, adv, value, ret

    @settings(**SET)
    @given(seed=st.integers(0, 2**31 - 1), N=st.integers(1, 400),
           A=st.integers(2, 16), clip=st.floats(0.05, 0.5))
    def test_forward_matches_ref(self, seed, N, A, clip):
        args = self._data(seed, N, A)
        got = ppo_terms_pallas(*args, clip)
        want = ref.ppo_terms_ref(*args, clip)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), N=st.integers(1, 300),
           A=st.integers(2, 12), clip=st.floats(0.05, 0.5))
    def test_backward_matches_autodiff(self, seed, N, A, clip):
        logits, actions, logp_old, adv, value, ret = self._data(seed, N, A)
        vf, ent = 0.5, 0.013

        def loss_pallas(lg, v):
            p, vl, e, _ = ppo_terms_pallas(lg, actions, logp_old, adv, v,
                                           ret, clip)
            return jnp.mean(p) + vf * jnp.mean(vl) - ent * jnp.mean(e)

        def loss_ref(lg, v):
            return ref.ppo_scalar_ref(lg, actions, logp_old, adv, v, ret,
                                      clip, vf, ent)

        g1 = jax.grad(loss_pallas, argnums=(0, 1))(logits, value)
        g2 = jax.grad(loss_ref, argnums=(0, 1))(logits, value)
        np.testing.assert_allclose(g1[0], g2[0], rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(g1[1], g2[1], rtol=1e-4, atol=1e-5)

    def test_clip_gradient_is_zero_outside_region(self):
        # ratio far above 1+eps with positive adv: clipped branch active,
        # policy gradient must vanish.
        N, A = 4, 3
        logits = np.zeros((N, A), np.float32)
        actions = np.zeros(N, np.int32)
        # logp under uniform policy = -log 3; make logp_old much smaller
        logp_old = np.full(N, -8.0, np.float32)
        adv = np.ones(N, np.float32)
        value = np.zeros(N, np.float32)
        ret = np.zeros(N, np.float32)

        def pol_only(lg):
            p, _, _, _ = ppo_terms_pallas(lg, actions, logp_old, adv,
                                          value, ret, 0.2)
            return jnp.mean(p)

        g = jax.grad(pol_only)(jnp.asarray(logits))
        np.testing.assert_allclose(g, 0.0, atol=1e-7)

    def test_entropy_max_at_uniform(self):
        N, A = 2, 5
        logits = np.zeros((N, A), np.float32)
        args = (jnp.asarray(logits), np.zeros(N, np.int32),
                np.zeros(N, np.float32), np.zeros(N, np.float32),
                np.zeros(N, np.float32), np.zeros(N, np.float32))
        _, _, ent, _ = ppo_terms_pallas(*args, 0.2)
        np.testing.assert_allclose(ent, np.log(A), rtol=1e-5)
