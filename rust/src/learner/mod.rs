//! Learner: consumes trajectories, runs the AOT train step, publishes
//! parameters (paper §3.2).
//!
//! Each Learner embeds a DataServer (the PULL endpoint) and a ReplayMem.
//! The train step itself is the AOT artifact (L2 JAX graph + L1 Pallas
//! kernels) executed via the PJRT runtime — one call per mini-batch.
//!
//! Multi-learner (M_L > 1): every rank computes gradients on its own
//! batch (`grad_*` artifact), the group allreduce-averages them, and
//! every rank applies the same Adam update (`apply_adam_*` artifact),
//! keeping replicas bit-identical.  Only rank 0 talks to the LeagueMgr
//! and ModelPool (the paper's "rank-0 machine in MPI semantics").

pub mod allreduce;
pub mod replay;

use crate::league::LeagueClient;
use crate::model_pool::ModelPoolClient;
use crate::proto::{ModelBlob, ModelKey, Msg};
use crate::runtime::{Engine, Tensor};
use crate::telemetry::trace;
use crate::transport::PullServer;
use crate::util::metrics::{Meter, MetricsHub, Rolling};
use allreduce::Allreduce;
use anyhow::{Context, Result};
use replay::{ReplayMem, ReplayMode};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

pub struct LearnerConfig {
    pub env: String,
    pub agent: u32,
    pub rank: usize,
    pub algo: String, // "ppo" | "vtrace"
    pub replay_mode: ReplayMode,
    /// train steps between ModelPool publications
    pub publish_every: u64,
    /// train steps per learning period (then the model is frozen)
    pub period_steps: u64,
    pub replay_cap: usize,
    pub seed: u64,
    /// bind address for the trajectory PULL endpoint; use a routable
    /// host (e.g. "0.0.0.0:0") when actors run on other machines
    pub data_bind: String,
}

impl Default for LearnerConfig {
    fn default() -> Self {
        LearnerConfig {
            env: "rps".into(),
            agent: 0,
            rank: 0,
            algo: "ppo".into(),
            replay_mode: ReplayMode::Blocking,
            publish_every: 4,
            period_steps: 32,
            replay_cap: 4096,
            seed: 0,
            data_bind: "127.0.0.1:0".into(),
        }
    }
}

/// Per-step training statistics (stats vector of the train artifact).
#[derive(Clone, Debug, Default)]
pub struct TrainStats {
    pub loss: f32,
    pub pol_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
    pub grad_norm: f32,
    pub steps: u64,
}

pub struct Learner {
    pub cfg: LearnerConfig,
    engine: Arc<Engine>,
    pool: ModelPoolClient,
    league: LeagueClient,
    data: PullServer,
    replay: ReplayMem,
    group: Option<Arc<Allreduce>>,
    // optimizer state (flat, host-side)
    params: Vec<f32>,
    adam_m: Vec<f32>,
    adam_v: Vec<f32>,
    opt_step: Vec<f32>,
    hp: Vec<f32>,
    pub key: ModelKey,
    pub steps: u64,
    pub rfps: Arc<Meter>,
    pub cfps: Arc<Meter>,
    /// mean version lag of each consumed batch's segments behind the
    /// current learner version — the telemetry plane's staleness gauge
    pub staleness: Arc<Rolling>,
    pub last_stats: TrainStats,
}

impl Learner {
    pub fn new(
        cfg: LearnerConfig,
        engine: Arc<Engine>,
        pool_addrs: &[String],
        league_addr: &str,
        group: Option<Arc<Allreduce>>,
    ) -> Result<Learner> {
        let data = PullServer::bind(&cfg.data_bind, 1024)?;
        let pool = ModelPoolClient::connect(pool_addrs);
        let league = LeagueClient::connect(league_addr);
        let task = league.request_learner_task(cfg.agent)?;
        let m = engine.manifest.env(&cfg.env)?;
        let p = m.param_count;
        // resume from the pool if possible, else fresh init
        let params = match pool.get_latest(cfg.agent)? {
            Some(blob) if blob.params.len() == p => blob.params,
            _ => engine.init_params(&cfg.env)?,
        };
        let replay = ReplayMem::new(cfg.replay_mode, cfg.replay_cap, cfg.seed);
        let mut learner = Learner {
            engine,
            pool,
            league,
            data,
            replay,
            group,
            params,
            adam_m: vec![0.0; p],
            adam_v: vec![0.0; p],
            opt_step: vec![0.0],
            hp: task.hp.clone(),
            key: task.learner_key,
            steps: 0,
            rfps: Arc::new(Meter::new()),
            cfps: Arc::new(Meter::new()),
            staleness: Arc::new(Rolling::default()),
            last_stats: TrainStats::default(),
            cfg,
        };
        if learner.cfg.rank == 0 {
            learner.publish_seed()?;
            learner.publish(false)?;
        }
        Ok(learner)
    }

    /// Address actors push trajectories to.
    pub fn data_addr(&self) -> String {
        self.data.addr.clone()
    }

    /// Route this learner's throughput counters through `hub` so the
    /// telemetry plane can snapshot them (counters `recv_frames` /
    /// `consumed_frames`, gauge `staleness`).  M_L ranks of one agent
    /// share a hub — the slot reports group-wide figures.
    pub fn use_hub(&mut self, hub: &MetricsHub) {
        self.rfps = hub.meter("recv_frames");
        self.cfps = hub.meter("consumed_frames");
        self.staleness = hub.rolling("staleness");
        // trajectory ingress byte accounting rides the same snapshot
        hub.register("bytes_in", self.data.bytes_in.clone());
    }

    /// Publish the version-0 seed model (random init or, in general,
    /// imitation-learned weights) as a frozen pool member.  On a resumed
    /// run the pool already holds the seed — leave it untouched.
    fn publish_seed(&self) -> Result<()> {
        let seed_key = ModelKey::new(self.cfg.agent, 0);
        if self.pool.get(seed_key)?.is_some() {
            return Ok(());
        }
        let init = self.engine.init_params(&self.cfg.env)?;
        self.pool.put(ModelBlob {
            key: seed_key,
            params: init,
            hp: self.hp.clone(),
            frozen: true,
        })
    }

    fn publish(&self, frozen: bool) -> Result<()> {
        self.pool.put(ModelBlob {
            key: self.key,
            params: self.params.clone(),
            hp: self.hp.clone(),
            frozen,
        })
    }

    /// Drain the data port into the replay memory (non-blocking).
    /// Traced segments close the request-path chain with a
    /// `learner_consume` span parented to the actor's tick span.
    pub fn ingest(&mut self) {
        while let Some(msg) = self.data.try_recv() {
            if let Msg::Traj(seg) = msg {
                let t0 = std::time::Instant::now();
                let ctx = seg.trace;
                let rows = seg.t;
                self.rfps.add(seg.t as u64);
                self.replay.push(seg);
                if let Some(c) = ctx {
                    trace::finish_span(
                        c, c.span_id, "learner_consume", "learner", t0, rows,
                    );
                }
            }
        }
    }

    fn artifact(&self, kind: &str) -> String {
        match kind {
            "train" => format!("train_{}_{}", self.cfg.algo, self.cfg.env),
            "grad" => format!("grad_{}_{}", self.cfg.algo, self.cfg.env),
            "apply" => format!("apply_adam_{}", self.cfg.env),
            _ => unreachable!(),
        }
    }

    fn parse_stats(&mut self, stats: &[f32]) {
        self.last_stats = TrainStats {
            loss: stats[0],
            pol_loss: stats[1],
            v_loss: stats[2],
            entropy: stats[3],
            approx_kl: stats[4],
            grad_norm: *stats.get(8).unwrap_or(&0.0),
            steps: self.steps,
        };
    }

    /// One training step; Ok(false) if there wasn't enough data yet.
    pub fn train_once(&mut self) -> Result<bool> {
        self.ingest();
        let m = self.engine.manifest.env(&self.cfg.env)?.clone();
        let Some(segs) = self.replay.sample(m.train_b) else {
            std::thread::sleep(Duration::from_millis(2));
            return Ok(false);
        };
        let lag = segs
            .iter()
            .map(|s| self.key.version.saturating_sub(s.model_key.version) as f64)
            .sum::<f64>()
            / segs.len().max(1) as f64;
        self.staleness.push(lag);
        let batch = replay::assemble(&segs, m.obs_dim)?;
        let frames = batch.frames;
        if self.group.is_none() || self.group.as_ref().unwrap().participants() == 1 {
            // fused path: grads + Adam in one artifact call
            let mut inputs = vec![
                Tensor::F32(std::mem::take(&mut self.params)),
                Tensor::F32(std::mem::take(&mut self.adam_m)),
                Tensor::F32(std::mem::take(&mut self.adam_v)),
                Tensor::F32(std::mem::take(&mut self.opt_step)),
                Tensor::F32(self.hp.clone()),
            ];
            inputs.extend(batch.tensors());
            let out = self
                .engine
                .run(&self.cfg.env, &self.artifact("train"), &inputs)?;
            let mut it = out.into_iter();
            self.params = it.next().context("params")?.into_f32()?;
            self.adam_m = it.next().context("m")?.into_f32()?;
            self.adam_v = it.next().context("v")?.into_f32()?;
            self.opt_step = it.next().context("step")?.into_f32()?;
            let stats = it.next().context("stats")?.into_f32()?;
            self.parse_stats(&stats);
        } else {
            // split path: grad -> allreduce -> apply (Horovod design point)
            let mut inputs = vec![
                Tensor::F32(self.params.clone()),
                Tensor::F32(self.hp.clone()),
            ];
            inputs.extend(batch.tensors());
            let out = self
                .engine
                .run(&self.cfg.env, &self.artifact("grad"), &inputs)?;
            let mut it = out.into_iter();
            let mut grads = it.next().context("grads")?.into_f32()?;
            let stats = it.next().context("stats")?.into_f32()?;
            anyhow::ensure!(
                self.group.as_ref().unwrap().reduce(&mut grads),
                "allreduce poisoned (a peer learner died)"
            );
            let inputs = vec![
                Tensor::F32(std::mem::take(&mut self.params)),
                Tensor::F32(std::mem::take(&mut self.adam_m)),
                Tensor::F32(std::mem::take(&mut self.adam_v)),
                Tensor::F32(std::mem::take(&mut self.opt_step)),
                Tensor::F32(self.hp.clone()),
                Tensor::F32(grads),
            ];
            let out = self
                .engine
                .run(&self.cfg.env, &self.artifact("apply"), &inputs)?;
            let mut it = out.into_iter();
            self.params = it.next().context("params")?.into_f32()?;
            self.adam_m = it.next().context("m")?.into_f32()?;
            self.adam_v = it.next().context("v")?.into_f32()?;
            self.opt_step = it.next().context("step")?.into_f32()?;
            self.parse_stats(&stats);
        }
        self.steps += 1;
        self.cfps.add(frames);

        if self.cfg.rank == 0 && self.steps % self.cfg.publish_every == 0 {
            self.publish(false)?;
        }
        if self.steps % self.cfg.period_steps == 0 {
            self.end_period()?;
        }
        Ok(true)
    }

    /// Learning-period boundary: freeze the model into the pool, fetch
    /// the next version + possibly-PBT-perturbed hyper-parameters.
    fn end_period(&mut self) -> Result<()> {
        if self.cfg.rank == 0 {
            self.publish(true)?;
            self.league.notify_period_done(self.key)?;
        }
        // group barrier so non-rank-0 learners see the bumped version
        if let Some(g) = &self.group {
            let mut token = vec![0.0f32];
            anyhow::ensure!(
                g.reduce(&mut token),
                "allreduce poisoned (a peer learner died)"
            );
        }
        let task = self.league.request_learner_task(self.cfg.agent)?;
        self.key = task.learner_key;
        self.hp = task.hp;
        if self.cfg.rank == 0 {
            self.publish(false)?; // make the new version visible to actors
        }
        Ok(())
    }

    /// Train until `target_steps` or `stop`; returns steps done.
    pub fn run(&mut self, target_steps: u64, stop: &AtomicBool) -> Result<u64> {
        let start = self.steps;
        while self.steps - start < target_steps && !stop.load(Ordering::Relaxed) {
            self.train_once()?;
        }
        Ok(self.steps - start)
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }
    pub fn replay_len(&self) -> usize {
        self.replay.len()
    }
    pub fn rfps_count(&self) -> u64 {
        self.replay.received
    }
    pub fn cfps_count(&self) -> u64 {
        self.replay.consumed
    }
    /// Undecodable frames dropped by this learner's data port (a nonzero
    /// rate means an actor speaks a different protocol version).
    pub fn decode_errors(&self) -> u64 {
        self.data.decode_errors.count()
    }
}
