//! Vectorized-rollout tests at the service level: a real ModelPool and
//! PullServer over TCP, a LeagueMgr-protocol stub that logs every task
//! issue / outcome report, and a stub inference server so the Actor's
//! Remote backend runs WITHOUT PJRT artifacts (the stub answers every
//! `InferReq` with zero logits of the right shape, i.e. a uniform
//! policy).  Everything is deterministic: fixed seeds, fixed-length
//! `synthetic:<len>` episodes, so segment discount patterns and
//! per-slot outcome counts are asserted exactly.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use tleague::actor::{Actor, ActorConfig, PolicyBackend};
use tleague::envs;
use tleague::model_pool::{ModelPoolClient, ModelPoolServer};
use tleague::proto::{MatchOutcome, ModelBlob, ModelKey, Msg, TaskSpec};
use tleague::transport::{PullServer, RepServer, ReqClient};

const LEARNER: ModelKey = ModelKey { agent: 0, version: 1 };
const OPPONENT: ModelKey = ModelKey { agent: 0, version: 0 };

#[derive(Clone, Debug)]
enum Event {
    TaskReq,
    Outcome(MatchOutcome),
}

/// LeagueMgr-protocol stub: unique task ids, fixed learner/opponent
/// keys, and a log of every message in arrival order.
fn stub_league(log: Arc<Mutex<Vec<Event>>>) -> RepServer {
    let next = AtomicU64::new(1);
    RepServer::serve("127.0.0.1:0", move |msg| match msg {
        Msg::RequestActorTask { .. } => {
            log.lock().unwrap().push(Event::TaskReq);
            Msg::Task(TaskSpec {
                task_id: next.fetch_add(1, Ordering::Relaxed),
                learner_key: LEARNER,
                opponents: vec![OPPONENT],
                hp: vec![],
            })
        }
        Msg::ReportOutcome(o) => {
            log.lock().unwrap().push(Event::Outcome(o));
            Msg::Ok
        }
        other => Msg::Err(format!("stub league: unexpected {other:?}")),
    })
    .unwrap()
}

/// InfServer-protocol stub: zero logits (uniform policy), no engine.
fn stub_inf(act_dim: usize) -> RepServer {
    RepServer::serve("127.0.0.1:0", move |msg| match msg {
        Msg::InferReq { rows, .. } => Msg::InferResp {
            logits: vec![0.0; rows as usize * act_dim],
            value: vec![0.0; rows as usize],
        },
        other => Msg::Err(format!("stub inf: unexpected {other:?}")),
    })
    .unwrap()
}

fn pool_with_models() -> ModelPoolServer {
    let pool = ModelPoolServer::start("127.0.0.1:0").unwrap();
    let pc = ModelPoolClient::connect(&[pool.addr.clone()]);
    pc.put(ModelBlob {
        key: OPPONENT,
        params: vec![0.0; 8],
        hp: vec![],
        frozen: true,
    })
    .unwrap();
    pc.put(ModelBlob {
        key: LEARNER,
        params: vec![0.0; 8],
        hp: vec![],
        frozen: false,
    })
    .unwrap();
    pool
}

struct Rollout {
    segs: Vec<tleague::proto::TrajSegment>,
    events: Vec<Event>,
}

fn outcomes(events: &[Event]) -> Vec<&MatchOutcome> {
    events
        .iter()
        .filter_map(|e| match e {
            Event::Outcome(o) => Some(o),
            _ => None,
        })
        .collect()
}

/// Run one vectorized actor for exactly `frames` env steps (summed over
/// slots) and collect every pushed segment + league event.
fn run_rollout(
    env: &str,
    n_slots: usize,
    train_t: usize,
    frames: u64,
    gamma: f32,
) -> Rollout {
    let log = Arc::new(Mutex::new(Vec::new()));
    let league = stub_league(log.clone());
    let act_dim = envs::make(env, 0).unwrap().act_dim();
    let inf = stub_inf(act_dim);
    let pool = pool_with_models();
    let sink = PullServer::bind("127.0.0.1:0", 4096).unwrap();
    let mut actor = Actor::new_vec(
        ActorConfig {
            env: env.into(),
            actor_id: "0/vec".into(),
            seed: 9,
            gamma,
            refresh_every: 1,
            train_t,
            trace_sample: 0.0,
        },
        n_slots,
        PolicyBackend::Remote(ReqClient::connect(&inf.addr)),
        &league.addr,
        &[pool.addr.clone()],
        &sink.addr,
    )
    .unwrap();
    assert_eq!(actor.n_slots(), n_slots);
    let stop = AtomicBool::new(false);
    let done = actor.run(frames, &stop).unwrap();
    assert_eq!(done, frames, "tick = one step per slot");
    let mut segs = Vec::new();
    while let Some(msg) = sink.recv_timeout(Duration::from_millis(300)) {
        match msg {
            Msg::Traj(seg) => segs.push(seg),
            other => panic!("sink got {other:?}"),
        }
    }
    let events = log.lock().unwrap().clone();
    Rollout { segs, events }
}

/// Satellite: a segment spanning an episode boundary carries the exact
/// discount/reward split.  `synthetic:4` episodes are exactly 4 steps,
/// train_t = 6, so boundaries land mid-segment at known offsets.
#[test]
fn single_slot_segments_cross_episode_boundaries() {
    let g = 0.9f32;
    let r = run_rollout("synthetic:4", 1, 6, 24, g);
    // 24 steps = 4 full segments; episode ends (discount 0.0) at global
    // steps 3, 7, 11, 15, 19, 23
    assert_eq!(r.segs.len(), 4);
    let expect: [Vec<f32>; 4] = [
        vec![g, g, g, 0.0, g, g],
        vec![g, 0.0, g, g, g, 0.0],
        vec![g, g, g, 0.0, g, g],
        vec![g, 0.0, g, g, g, 0.0],
    ];
    for (k, (seg, want)) in r.segs.iter().zip(&expect).enumerate() {
        assert_eq!(seg.t, 6, "segment {k}");
        assert_eq!(seg.n_agents, 1);
        assert_eq!(seg.model_key, LEARNER);
        assert_eq!(&seg.discounts, want, "segment {k} boundary split");
        assert_eq!(seg.rewards.len(), 6);
        assert_eq!(seg.actions.len(), 6);
        assert_eq!(seg.behavior_logp.len(), 6);
        // (T+1) bootstrap rows of the learner slot's 1024-dim obs
        assert_eq!(seg.obs.len(), 7 * 1024);
        assert!(seg.behavior_logp.iter().all(|lp| *lp < 0.0));
        // synthetic step rewards are exactly 0.0 or +/-0.01
        assert!(seg
            .rewards
            .iter()
            .all(|&r| r == 0.0 || r == 0.01 || r == -0.01));
    }
    // six episodes completed and reported, each exactly 4 steps
    let outs = outcomes(&r.events);
    assert_eq!(outs.len(), 6);
    for o in &outs {
        assert_eq!(o.episode_len, 4);
        assert_eq!(o.frames, 4);
        assert!([0.0, 0.5, 1.0].contains(&o.outcome));
        assert_eq!(o.learner_key, LEARNER);
        assert_eq!(o.opponents, vec![OPPONENT]);
    }
}

/// Satellite (multi-slot case): every slot carries its own cross-episode
/// segment stream with the correct boundary pattern, interleaved in
/// deterministic slot order and independently seeded.
#[test]
fn multi_slot_segments_interleave_with_correct_boundaries() {
    let g = 0.99f32;
    let r = run_rollout("synthetic:6", 2, 4, 48, g);
    // 48 frames over 2 slots = 24 ticks/slot -> 6 segments per slot,
    // pushed as (slot0, slot1) pairs at the same tick
    assert_eq!(r.segs.len(), 12);
    // per-slot boundaries at steps 5, 11, 17, 23 (6-step episodes)
    let expect: Vec<Vec<f32>> = (0..6)
        .map(|k| {
            (0..4)
                .map(|i| if (k * 4 + i + 1) % 6 == 0 { 0.0 } else { g })
                .collect()
        })
        .collect();
    for k in 0..6 {
        let a = &r.segs[2 * k];
        let b = &r.segs[2 * k + 1];
        assert_eq!(&a.discounts, &expect[k], "slot0 segment {k}");
        assert_eq!(&b.discounts, &expect[k], "slot1 segment {k}");
        assert_eq!(a.t, 4);
        assert_eq!(b.t, 4);
        // slots are independently seeded: observation streams differ
        assert_ne!(a.obs, b.obs, "segment pair {k} identical");
    }
    // segment 1 (steps 4..8) crosses the step-5 boundary mid-segment
    assert_eq!(expect[1], vec![g, 0.0, g, g]);
    // 4 episodes per slot, every episode exactly 6 steps
    let outs = outcomes(&r.events);
    assert_eq!(outs.len(), 8);
    assert!(outs.iter().all(|o| o.episode_len == 6 && o.frames == 6));
}

/// Acceptance: one actor drives N concurrent episodes — N tasks in
/// flight before any outcome, per-slot outcomes each paired with a
/// distinct issued task, exact per-episode lengths.
#[test]
fn vectorized_actor_runs_n_concurrent_episodes() {
    let g = 0.99f32;
    let r = run_rollout("synthetic:5", 4, 5, 60, g);
    // first tick: all four slots request tasks before anything else
    assert!(r.events.len() >= 4);
    assert!(
        r.events[..4].iter().all(|e| matches!(e, Event::TaskReq)),
        "all slots must open tasks concurrently: {:?}",
        &r.events[..6.min(r.events.len())]
    );
    // 60 frames / 4 slots = 15 ticks/slot = 3 episodes/slot
    let outs = outcomes(&r.events);
    assert_eq!(outs.len(), 12);
    for o in &outs {
        assert_eq!(o.episode_len, 5, "fixed-length episodes");
        assert_eq!(o.frames, 5);
        assert!([0.0, 0.5, 1.0].contains(&o.outcome));
    }
    // every outcome pairs a distinct issued task (per-slot reporting
    // never mixes tasks up or double-reports)
    let mut ids: Vec<u64> = outs.iter().map(|o| o.task_id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 12, "12 distinct task ids");
    let issued = r
        .events
        .iter()
        .filter(|e| matches!(e, Event::TaskReq))
        .count() as u64;
    assert!(ids.iter().all(|&id| id >= 1 && id <= issued));
    // train_t == episode_len: every slot pushes 3 aligned segments
    assert_eq!(r.segs.len(), 12);
    for seg in &r.segs {
        assert_eq!(seg.t, 5);
        assert_eq!(&seg.discounts, &[g, g, g, g, 0.0]);
    }
}

/// `envs_per_actor = 1` on a variable-length env behaves like the
/// classic actor: segments flow, outcomes report, nothing panics.
#[test]
fn single_slot_pong_rollout_smoke() {
    let r = run_rollout("pong2p", 1, 8, 200, 0.99);
    assert_eq!(r.segs.len(), 25);
    for seg in &r.segs {
        assert_eq!(seg.t, 8);
        assert!(seg
            .discounts
            .iter()
            .all(|&d| d == 0.99 || d == 0.0));
    }
    let outs = outcomes(&r.events);
    let boundaries: usize = r
        .segs
        .iter()
        .flat_map(|s| s.discounts.iter())
        .filter(|&&d| d == 0.0)
        .count();
    // 200 steps = 25 full segments, nothing in flight: every completed
    // (reported) episode shows up as exactly one 0-discount row
    assert_eq!(outs.len(), boundaries);
}
