//! Chaos drills: deterministic fault injection + scheduled kills over a
//! real multi-process league, asserting the run completes with no lost
//! league counters and no hung thread.
//!
//! Needs `make artifacts` (workers run PJRT); the tests skip otherwise.

use std::io::Read;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tleague::config::RunConfig;
use tleague::orchestrator::controller::Controller;
use tleague::runtime::Engine;

const BIN: &str = env!("CARGO_BIN_EXE_tleague");

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(dir)
}

fn spawn_worker(role: &str, ctrl_addr: &str, artifacts: &Path) -> Child {
    Command::new(BIN)
        .args(["worker", "--role", role, "--controller", ctrl_addr])
        .args(["--artifacts", artifacts.to_str().unwrap()])
        .spawn()
        .expect("spawn worker")
}

/// Kills any still-running children on drop so a failing assert never
/// leaks orphan processes into the test host.
struct Reap(Vec<Child>);

impl Drop for Reap {
    fn drop(&mut self) {
        for c in &mut self.0 {
            c.kill().ok();
            c.wait().ok();
        }
    }
}

impl Reap {
    fn expect_clean_exit(&mut self, timeout: Duration) {
        let deadline = Instant::now() + timeout;
        for (i, c) in self.0.iter_mut().enumerate() {
            loop {
                match c.try_wait().expect("try_wait") {
                    Some(status) => {
                        assert!(status.success(), "worker {i} exited {status}");
                        break;
                    }
                    None if Instant::now() > deadline => {
                        panic!("worker {i} did not exit after stop")
                    }
                    None => std::thread::sleep(Duration::from_millis(50)),
                }
            }
        }
        self.0.clear();
    }
}

/// A scratch dir that cleans up after itself even on panic.
struct TmpDir(PathBuf);

impl TmpDir {
    fn new(tag: &str) -> TmpDir {
        let p = std::env::temp_dir()
            .join(format!("tleague-chaos-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&p).unwrap();
        TmpDir(p)
    }
}

impl Drop for TmpDir {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.0).ok();
    }
}

/// The full chaos drill through the CLI: a procs-mode league with two
/// pool replicas and an inf-server, a low-grade deterministic fault
/// plan on every transport site, and a kill schedule that takes down
/// the inf-server, one pool replica, and the learner mid-run.  The run
/// must still complete (slots reassigned, clients failed over) and say
/// so on stdout.
#[test]
fn chaos_schedule_kills_workers_and_run_completes() {
    let Some(dir) = artifacts() else { return };
    let tmp = TmpDir::new("cli");
    let spec = tmp.0.join("spec.json");
    std::fs::write(
        &spec,
        r#"{
        "env": "rps", "mode": "procs", "seed": 7,
        "total_steps": 12, "period_steps": 2,
        "actors_per_learner": 1, "model_pools": 2, "inf_servers": 1,
        "heartbeat_ms": 100, "heartbeat_timeout_ms": 1000,
        "stats_every_secs": 1
    }"#,
    )
    .unwrap();
    let mut child = Command::new(BIN)
        .args(["run", "--config", spec.to_str().unwrap()])
        .args(["--chaos", "kill:inf-server@300,kill:pool@600,kill:learner@900"])
        .args(["--faults", "delay:*@0.02+2"])
        .args(["--fault-seed", "7"])
        .args(["--artifacts", dir.to_str().unwrap()])
        .stdout(Stdio::piped())
        .spawn()
        .expect("run --mode procs --chaos");
    // poll with a deadline so a hung drill fails the suite instead of
    // wedging it (output() would block forever)
    let deadline = Instant::now() + Duration::from_secs(300);
    let status = loop {
        if let Some(s) = child.try_wait().expect("try_wait") {
            break s;
        }
        if Instant::now() > deadline {
            child.kill().ok();
            child.wait().ok();
            panic!("chaos run timed out (hung thread?)");
        }
        std::thread::sleep(Duration::from_millis(100));
    };
    let mut stdout = String::new();
    child.stdout.take().unwrap().read_to_string(&mut stdout).unwrap();
    assert!(status.success(), "exit {status}\nstdout:\n{stdout}");
    assert!(stdout.contains("done:"), "no completion line:\n{stdout}");
    // the schedule actually fired (worker spawn alone outlasts 300ms)
    assert!(stdout.contains("chaos["), "schedule never fired:\n{stdout}");
    // kill:pool is a real failover now: the survivor re-owns the dead
    // replica's shards and the rebalanced contents are bit-exact
    assert!(
        stdout.contains("bit-exact=true"),
        "pool failover not bit-exact:\n{stdout}"
    );
}

/// Kill-the-controller drill: snapshot, SIGKILL-equivalent crash of the
/// whole control plane (league + pools + controller service, no clean
/// final save), restart resumed on the SAME port.  The live worker
/// processes — never touched — must re-register against the successor,
/// the run must complete, and the resumed league counters must carry
/// the pre-crash totals forward.
#[test]
fn controller_crash_recovers_workers_and_league_totals() {
    let Some(dir) = artifacts() else { return };
    let engine = Arc::new(Engine::load(&dir).unwrap());
    let tmp = TmpDir::new("ckpt");
    // a fixed port the successor can rebind (probe-and-release)
    let port = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let mut cfg = RunConfig::default();
    cfg.env = "rps".into();
    cfg.mode = "procs".into();
    cfg.seed = 7;
    cfg.total_steps = 12;
    cfg.period_steps = 2;
    cfg.actors_per_learner = 1;
    cfg.heartbeat_ms = 100;
    cfg.heartbeat_timeout_ms = 1_000;
    cfg.controller_bind = format!("127.0.0.1:{port}");
    cfg.checkpoint_dir = Some(tmp.0.to_str().unwrap().to_string());
    let restart_cfg = cfg.clone();
    let start = |cfg: RunConfig| -> Controller {
        Controller::start(
            cfg,
            engine.manifest.hp_layout.clone(),
            engine.manifest.default_hp(),
        )
        .unwrap()
    };
    let mut ctrl = start(cfg);
    let mut kids = Reap(vec![
        spawn_worker("learner", &ctrl.addr, &dir),
        spawn_worker("actor", &ctrl.addr, &dir),
    ]);

    // let the league make real progress first
    let deadline = Instant::now() + Duration::from_secs(120);
    while ctrl.deploy_stats().learner_steps < 2 {
        assert!(Instant::now() < deadline, "league never started");
        std::thread::sleep(Duration::from_millis(50));
    }
    let pre = ctrl.league_stats();

    // crash-consistent restart: pin the recovery point, then die hard
    ctrl.snapshot_now().unwrap();
    ctrl.crash();
    let mut cfg2 = restart_cfg;
    cfg2.resume = cfg2.checkpoint_dir.clone();
    ctrl = start(cfg2);

    // the surviving workers notice (failed heartbeat / unknown-worker)
    // and re-register against the successor
    let deadline = Instant::now() + Duration::from_secs(60);
    while ctrl.deploy_stats().workers < 2 {
        assert!(Instant::now() < deadline, "workers never re-registered");
        std::thread::sleep(Duration::from_millis(50));
    }

    assert!(ctrl.wait(Duration::from_secs(180)), "run did not recover");
    assert_eq!(ctrl.deploy_stats().learner_steps, 12);
    // no lost counters: the resumed league can only have grown
    let post = ctrl.league_stats();
    assert!(
        post.episodes >= pre.episodes,
        "episodes lost across crash: {} -> {}",
        pre.episodes,
        post.episodes
    );
    assert!(
        post.pool_size >= pre.pool_size,
        "pool shrank across crash: {} -> {}",
        pre.pool_size,
        post.pool_size
    );
    ctrl.shutdown();
    kids.expect_clean_exit(Duration::from_secs(30));
}
