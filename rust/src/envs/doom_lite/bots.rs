//! Scripted doom_lite opponents.
//!
//! - [`BuiltinBot`]: the "builtin bots" of ViZDoom's CIG deathmatch —
//!   wander the maze, engage on sight (Table 1's opposition).
//! - [`F1Bot`]: stand-in for "F1", the CIG-2016 track-1 champion
//!   (closed checkpoint): better aim (leads the target), keeps
//!   preferred range, strafes around incoming rockets, retreats when
//!   outnumbered.  Table 2's opposition.

use super::{
    DoomLite, ACT_BACK, ACT_FIRE, ACT_FWD, ACT_IDLE, ACT_TURN_L, ACT_TURN_R,
    FOV, ROCKET_SPEED,
};
use crate::util::rng::Pcg32;

fn norm_angle(mut a: f32) -> f32 {
    while a > std::f32::consts::PI {
        a -= std::f32::consts::TAU;
    }
    while a < -std::f32::consts::PI {
        a += std::f32::consts::TAU;
    }
    a
}

/// Nearest visible enemy: (index, distance, bearing error).
fn nearest_visible(env: &DoomLite, who: usize) -> Option<(usize, f32, f32)> {
    let me = &env.players[who];
    let mut best: Option<(usize, f32, f32)> = None;
    for (i, p) in env.players.iter().enumerate() {
        if i == who || !p.alive {
            continue;
        }
        let rel = (p.pos.0 - me.pos.0, p.pos.1 - me.pos.1);
        let dist = (rel.0 * rel.0 + rel.1 * rel.1).sqrt();
        let bearing = norm_angle(rel.1.atan2(rel.0) - me.angle);
        if bearing.abs() > FOV {
            continue; // outside (generous) field of view
        }
        // line-of-sight check
        let (d, hit) = env.raycast(me.pos, me.angle + bearing, who);
        if hit == Some(i) || d >= dist - 0.5 {
            if best.map_or(true, |(_, bd, _)| dist < bd) {
                best = Some((i, dist, bearing));
            }
        }
    }
    best
}

pub trait DoomPolicy: Send {
    fn act(&mut self, env: &DoomLite, who: usize) -> usize;
    fn name(&self) -> &'static str;
}

pub struct BuiltinBot {
    rng: Pcg32,
    wander_turn: i32,
    wander_dir: i32,
}

impl BuiltinBot {
    pub fn new(seed: u64) -> Self {
        BuiltinBot {
            rng: Pcg32::from_label(seed, "doom-bot"),
            wander_turn: 0,
            wander_dir: 1,
        }
    }
}

impl DoomPolicy for BuiltinBot {
    fn act(&mut self, env: &DoomLite, who: usize) -> usize {
        let me = &env.players[who];
        if !me.alive {
            return ACT_IDLE;
        }
        if let Some((_, dist, bearing)) = nearest_visible(env, who) {
            // threshold > TURN_SPEED/2, else aim oscillates forever
            if bearing.abs() > 0.2 {
                return if bearing > 0.0 { ACT_TURN_R } else { ACT_TURN_L };
            }
            if me.cooldown == 0 && dist < 9.0 {
                return ACT_FIRE;
            }
            return ACT_FWD;
        }
        // wander: forward unless blocked, occasional random turns
        let (d, _) = env.raycast(me.pos, me.angle, who);
        if d < 1.2 || self.wander_turn > 0 {
            if self.wander_turn == 0 {
                self.wander_turn = 2 + self.rng.below(4) as i32;
                self.wander_dir = if self.rng.chance(0.5) { 1 } else { -1 };
            }
            self.wander_turn -= 1;
            return if self.wander_dir > 0 { ACT_TURN_R } else { ACT_TURN_L };
        }
        if self.rng.chance(0.05) {
            self.wander_turn = 1 + self.rng.below(3) as i32;
        }
        ACT_FWD
    }

    fn name(&self) -> &'static str {
        "builtin"
    }
}

pub struct F1Bot {
    rng: Pcg32,
    strafe_dir: i32,
    wander_turn: i32,
}

impl F1Bot {
    pub fn new(seed: u64) -> Self {
        F1Bot {
            rng: Pcg32::from_label(seed, "doom-f1"),
            strafe_dir: 1,
            wander_turn: 0,
        }
    }
}

impl DoomPolicy for F1Bot {
    fn act(&mut self, env: &DoomLite, who: usize) -> usize {
        let me = &env.players[who];
        if !me.alive {
            return ACT_IDLE;
        }
        // rocket evasion: an incoming rocket about to arrive -> burst
        // forward to leave the splash zone (turning alone cannot dodge)
        for r in &env.rockets {
            if r.owner == who {
                continue;
            }
            let rel = (me.pos.0 - r.pos.0, me.pos.1 - r.pos.1);
            let dist = (rel.0 * rel.0 + rel.1 * rel.1).sqrt();
            if dist < 2.2 {
                let heading = r.vel.1.atan2(r.vel.0);
                let to_me = rel.1.atan2(rel.0);
                if norm_angle(heading - to_me).abs() < 0.35 {
                    let (d, _) = env.raycast(me.pos, me.angle, who);
                    return if d > 1.0 { ACT_FWD } else { ACT_BACK };
                }
            }
        }
        if let Some((e, dist, bearing)) = nearest_visible(env, who) {
            // lead the target: aim where the enemy will be
            let enemy = &env.players[e];
            let tof = dist / ROCKET_SPEED;
            // half-lead: bots alternate moving/turning, full lead overshoots
            let ev = (enemy.angle.cos() * 0.08, enemy.angle.sin() * 0.08);
            let future = (enemy.pos.0 + ev.0 * tof, enemy.pos.1 + ev.1 * tof);
            let lead_bearing = norm_angle(
                (future.1 - me.pos.1).atan2(future.0 - me.pos.0) - me.angle,
            );
            if lead_bearing.abs() > 0.2 {
                return if lead_bearing > 0.0 { ACT_TURN_R } else { ACT_TURN_L };
            }
            if me.cooldown == 0 && dist < 10.0 {
                return ACT_FIRE;
            }
            // range keeping while reloading: close if far, back off
            // point-blank, otherwise hold the aim (don't break it)
            if dist > 6.0 {
                return ACT_FWD;
            }
            if dist < 2.5 {
                return ACT_BACK;
            }
            let _ = bearing;
            return ACT_IDLE;
        }
        // patrol like the builtin, slightly less random
        let (d, _) = env.raycast(me.pos, me.angle, who);
        if d < 1.5 || self.wander_turn > 0 {
            if self.wander_turn == 0 {
                self.wander_turn = 2 + self.rng.below(3) as i32;
            }
            self.wander_turn -= 1;
            return ACT_TURN_R;
        }
        if self.rng.chance(0.03) {
            self.wander_turn = 1 + self.rng.below(2) as i32;
        }
        ACT_FWD
    }

    fn name(&self) -> &'static str {
        "f1"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::MultiAgentEnv;

    /// Run one full match with the given per-player policies, return FRAGs.
    pub fn run_match(
        env: &mut DoomLite,
        pols: &mut [Box<dyn DoomPolicy>],
        steps: usize,
    ) -> Vec<i32> {
        env.reset();
        for _ in 0..steps {
            let acts: Vec<usize> =
                (0..env.n_agents()).map(|i| pols[i].act(env, i)).collect();
            let s = env.step(&acts);
            if s.done {
                break;
            }
        }
        env.frags()
    }

    #[test]
    fn bots_score_frags_against_idlers() {
        let mut env = DoomLite::new(11, 4);
        let mut pols: Vec<Box<dyn DoomPolicy>> = vec![
            Box::new(BuiltinBot::new(1)),
            Box::new(Idle),
            Box::new(Idle),
            Box::new(Idle),
        ];
        let frags = run_match(&mut env, &mut pols, 800);
        assert!(frags[0] > 0, "bot should frag idlers: {frags:?}");
    }

    #[test]
    fn f1_outperforms_builtin_on_average() {
        let mut total_f1 = 0i32;
        let mut total_bot = 0i32;
        for seed in 0..4 {
            let mut env = DoomLite::new(100 + seed, 4);
            let mut pols: Vec<Box<dyn DoomPolicy>> = vec![
                Box::new(F1Bot::new(seed)),
                Box::new(BuiltinBot::new(seed + 10)),
                Box::new(BuiltinBot::new(seed + 20)),
                Box::new(BuiltinBot::new(seed + 30)),
            ];
            let frags = run_match(&mut env, &mut pols, 1200);
            total_f1 += frags[0];
            total_bot += frags[1] + frags[2] + frags[3];
        }
        let avg_bot = total_bot as f64 / 12.0;
        assert!(
            total_f1 as f64 / 4.0 >= avg_bot,
            "F1 avg {} < builtin avg {avg_bot}",
            total_f1 as f64 / 4.0
        );
    }

    struct Idle;
    impl DoomPolicy for Idle {
        fn act(&mut self, _e: &DoomLite, _w: usize) -> usize {
            ACT_IDLE
        }
        fn name(&self) -> &'static str {
            "idle"
        }
    }
}
