//! tleague CLI: launch a league run, individual services, or evals.
//!
//! Subcommands:
//!   run        --config <spec.json> [--artifacts DIR]   full league (kube-lite)
//!              [--mode thread|procs]                    threads or one OS
//!                                                       process per role
//!              [--checkpoint-dir D] [--resume D]        durable / resumed runs
//!   controller                                          procs-mode control plane
//!   worker     --role learner|actor|inf-server          one league role,
//!              --controller host:port                   controller-directed
//!   stats      --controller host:port [--deploy] [--json] merged league telemetry
//!   trace      --controller host:port [--trace-out F]   flight-recorder export
//!                                                       (Chrome trace JSON)
//!   eval-doom  --checkpoint <f32 file> --setting 1|2a|2b|2c --games N
//!   eval-rps   --artifacts DIR                           exploitability demo
//!   league-mgr / model-pool                              standalone services
//!   info       --artifacts DIR                           manifest summary

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::process::{Child, Command};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};
use tleague::config::RunConfig;
use tleague::model_pool::PoolOptions;
use tleague::orchestrator::controller::Controller;
use tleague::orchestrator::Deployment;
use tleague::runtime::manifest::Manifest;
use tleague::runtime::Engine;
use tleague::telemetry::{self, JsonlSink};
use tleague::util::cli::Args;
use tleague::util::signal;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.str_or("artifacts", "artifacts")
}

fn engine(args: &Args) -> Result<Arc<Engine>> {
    Ok(Arc::new(Engine::load(artifacts_dir(args))?))
}

fn run() -> Result<()> {
    let args = Args::from_env();
    if args.bool("help") {
        println!("{}", tleague::util::cli::USAGE);
        return Ok(());
    }
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("controller") => cmd_controller(&args),
        Some("worker") => cmd_worker(&args),
        Some("stats") => cmd_stats(&args),
        Some("trace") => cmd_trace(&args),
        Some("info") => cmd_info(&args),
        Some("eval-doom") => cmd_eval_doom(&args),
        Some("eval-rps") => cmd_eval_rps(&args),
        Some("model-pool") => cmd_model_pool(&args),
        Some("league-mgr") => cmd_league_mgr(&args),
        Some(other) => anyhow::bail!("unknown subcommand '{other}'"),
        None => {
            println!("{}", tleague::util::cli::USAGE);
            Ok(())
        }
    }
}

// ---- standalone services ------------------------------------------------

/// Serve until SIGINT/SIGTERM or a wire `Shutdown` request, then return
/// so the server drops (accept loop joined, sockets drained) instead of
/// dying inside an infinite sleep.
fn serve_until_stopped(name: &str, stop_requested: impl Fn() -> bool) {
    let sig = signal::install();
    while !sig.load(Ordering::Relaxed) && !stop_requested() {
        std::thread::sleep(Duration::from_millis(100));
    }
    println!("{name}: shutting down");
}

fn cmd_model_pool(args: &Args) -> Result<()> {
    let mem_budget_mb = args.f64_or("mem-budget-mb", 0.0)?;
    // a negative value would saturate to budget 0 (= unbounded) in the
    // cast below — reject it instead of silently disabling the budget
    anyhow::ensure!(
        mem_budget_mb >= 0.0 && mem_budget_mb.is_finite(),
        "--mem-budget-mb must be a finite value >= 0, got {mem_budget_mb}"
    );
    let opts = PoolOptions {
        spill_dir: args.get("spill-dir").map(PathBuf::from),
        mem_budget: (mem_budget_mb * (1u64 << 20) as f64) as usize,
    };
    // same rule as RunConfig: a budget with nowhere to spill would
    // silently never evict
    anyhow::ensure!(
        opts.mem_budget == 0 || opts.spill_dir.is_some(),
        "--mem-budget-mb requires --spill-dir"
    );
    let mut s = tleague::model_pool::ModelPoolServer::start_with(
        &args.str_or("bind", "127.0.0.1:9001"),
        opts,
    )?;
    println!("model-pool listening on {}", s.addr);
    serve_until_stopped("model-pool", || s.stop_requested());
    s.shutdown();
    Ok(())
}

fn cmd_league_mgr(args: &Args) -> Result<()> {
    let eng = engine(args)?;
    let s = tleague::league::LeagueMgrServer::start(
        &args.str_or("bind", "127.0.0.1:9003"),
        tleague::league::LeagueConfig {
            n_agents: args.usize_or("n-agents", 1)? as u32,
            n_opponents: args.usize_or("n-opponents", 1)?,
            game_mgr: args.str_or("game-mgr", "uniform"),
            hp_layout: eng.manifest.hp_layout.clone(),
            hp_default: eng.manifest.default_hp(),
            seed: args.u64_or("seed", 0)?,
        },
    )?;
    println!("league-mgr listening on {}", s.addr);
    serve_until_stopped("league-mgr", || s.stop_requested());
    Ok(())
}

// ---- league runs --------------------------------------------------------

/// Build the RunConfig shared by `run` and `controller` (spec file +
/// flag overrides).
fn build_run_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(path)?,
        None => RunConfig {
            env: args.str_or("env", "rps"),
            total_steps: args.u64_or("total-steps", 100)?,
            period_steps: args.u64_or("period-steps", 25)?,
            actors_per_learner: args.usize_or("actors", 2)?,
            game_mgr: args.str_or("game-mgr", "uniform"),
            ..RunConfig::default()
        },
    };
    // vectorized rollouts: episodes per actor (flag overrides the file)
    cfg.envs_per_actor = args.usize_or("envs-per-actor", cfg.envs_per_actor)?;
    // durability flags override the config file either way
    if let Some(dir) = args.get("checkpoint-dir") {
        cfg.checkpoint_dir = Some(dir.to_string());
    }
    if let Some(dir) = args.get("resume") {
        cfg.resume = Some(dir.to_string());
        // a resumed run keeps checkpointing into the same dir by default
        if cfg.checkpoint_dir.is_none() {
            cfg.checkpoint_dir = Some(dir.to_string());
        }
    }
    cfg.checkpoint_every_secs =
        args.u64_or("checkpoint-every", cfg.checkpoint_every_secs)?;
    // data-plane knobs (see USAGE): flags override the config file
    cfg.refresh_every =
        args.u64_or("refresh-every", cfg.refresh_every as u64)? as u32;
    cfg.infer_max_wait_us =
        args.u64_or("infer-max-wait-us", cfg.infer_max_wait_us)?;
    cfg.infer_refresh_ms = args.u64_or("infer-refresh-ms", cfg.infer_refresh_ms)?;
    // transport knobs: lane policy, ring directory, event-loop threads
    cfg.local_lanes = args.str_or("local-lanes", &cfg.local_lanes);
    if let Some(d) = args.get("shm-dir") {
        cfg.shm_dir = Some(d.to_string());
    }
    cfg.net_threads = args.u64_or("net-threads", cfg.net_threads as u64)? as usize;
    // deployment-mode knobs
    cfg.mode = args.str_or("mode", &cfg.mode);
    cfg.controller_bind = args.str_or("controller-bind", &cfg.controller_bind);
    if let Some(h) = args.get("advertise-host") {
        cfg.advertise_host = Some(h.to_string());
    }
    cfg.heartbeat_ms = args.u64_or("heartbeat-ms", cfg.heartbeat_ms)?;
    cfg.heartbeat_timeout_ms =
        args.u64_or("heartbeat-timeout-ms", cfg.heartbeat_timeout_ms)?;
    // telemetry knobs
    cfg.stats_every_secs = args.u64_or("stats-every", cfg.stats_every_secs)?;
    if let Some(p) = args.get("stats-jsonl") {
        cfg.stats_jsonl = Some(p.to_string());
    }
    cfg.trace_sample = args.f64_or("trace-sample", cfg.trace_sample)?;
    cfg.trace_slow_ms = args.u64_or("trace-slow-ms", cfg.trace_slow_ms)?;
    // elasticity / pool-sharding knobs: flags override the config file
    cfg.model_pools = args.usize_or("model-pools", cfg.model_pools)?;
    cfg.pool_replication =
        args.usize_or("pool-replication", cfg.pool_replication)?;
    if args.bool("autoscale") {
        cfg.autoscale = true;
    }
    cfg.scale_every_secs = args.u64_or("scale-every", cfg.scale_every_secs)?;
    cfg.min_actor_slots = args.usize_or("min-actor-slots", cfg.min_actor_slots)?;
    cfg.max_actor_slots = args.usize_or("max-actor-slots", cfg.max_actor_slots)?;
    cfg.min_inf_slots = args.usize_or("min-inf-slots", cfg.min_inf_slots)?;
    cfg.max_inf_slots = args.usize_or("max-inf-slots", cfg.max_inf_slots)?;
    // fault-injection / chaos knobs: flags override the config file
    cfg.fault_seed = args.u64_or("fault-seed", cfg.fault_seed)?;
    if let Some(s) = args.get("faults") {
        cfg.faults = Some(s.to_string());
    }
    if let Some(s) = args.get("chaos") {
        cfg.chaos = Some(s.to_string());
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Write the run's recorded spans as Chrome trace-event JSON
/// (`--trace-out`): open in chrome://tracing or Perfetto.
fn export_trace(path: &str, spans: &[tleague::proto::SpanRec]) -> Result<()> {
    std::fs::write(path, tleague::telemetry::trace::chrome_trace_json(spans))
        .with_context(|| format!("write trace {path}"))?;
    println!("wrote {} spans to {path} (chrome://tracing format)", spans.len());
    Ok(())
}

/// Open the `--stats-jsonl` sink when configured.
fn open_jsonl(path: &Option<String>) -> Result<Option<JsonlSink>> {
    match path {
        Some(p) => {
            println!("appending league telemetry to {p}");
            Ok(Some(JsonlSink::open(p)?))
        }
        None => Ok(None),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = build_run_config(args)?;
    println!(
        "launching league: env={} M_G={} M_L={} M_A={} sampler={} mode={}",
        cfg.env, cfg.n_agents, cfg.learners_per_agent, cfg.actors_per_learner,
        cfg.game_mgr, cfg.mode
    );
    if let Some(dir) = &cfg.resume {
        println!("resuming from latest snapshot in {dir}");
    }
    if let Some(dir) = &cfg.checkpoint_dir {
        println!(
            "checkpointing to {dir} every {}s (keep {})",
            cfg.checkpoint_every_secs, cfg.checkpoint_keep
        );
    }
    if cfg.mode == "procs" {
        return cmd_run_procs(cfg, args);
    }
    let eng = engine(args)?;
    let mut dep = Deployment::start(cfg, eng)?;
    let interval = Duration::from_secs(dep.cfg.stats_every_secs.max(1));
    let mut jsonl = open_jsonl(&dep.cfg.stats_jsonl)?;
    let mut last = 0;
    while !dep.learners_done() {
        std::thread::sleep(interval);
        let steps = dep.total_learner_steps();
        let stats = dep.league_stats();
        let s0 = &dep.learner_status[0];
        let ts = s0.stats.lock().unwrap().clone();
        println!(
            "steps={steps} (+{}) pool={} episodes={} frames={} loss={:.4} ent={:.3}",
            steps - last, stats.pool_size, stats.episodes, stats.frames,
            ts.loss, ts.entropy
        );
        last = steps;
        let tele = dep.telemetry_report();
        println!("league: {}", telemetry::summary_line(&tele));
        if let Some(sink) = jsonl.as_mut() {
            sink.append(&tele, stats.episodes, stats.frames);
        }
    }
    // stop the roles FIRST, then write the final telemetry row: with
    // every actor quiesced the drained run totals and the league
    // counters describe the same finished run (and a run shorter than
    // one report interval still emits at least this one JSONL row)
    dep.shutdown();
    let tele = dep.telemetry_report();
    let stats = dep.league_stats();
    println!("league: {}", telemetry::summary_line(&tele));
    if let Some(sink) = jsonl.as_mut() {
        sink.append(&tele, stats.episodes, stats.frames);
    }
    println!(
        "done: pool={} episodes={} frames={} actor restarts={}",
        stats.pool_size,
        stats.episodes,
        stats.frames,
        dep.restarts.load(Ordering::Relaxed)
    );
    if let Some(path) = args.get("trace-out") {
        export_trace(path, &dep.trace_spans())?;
    }
    Ok(())
}

// ---- procs mode ---------------------------------------------------------

fn spawn_worker(exe: &Path, role: &str, ctrl_addr: &str, artifacts: &str) -> Result<Child> {
    Command::new(exe)
        .args(["worker", "--role", role, "--controller", ctrl_addr])
        .args(["--artifacts", artifacts])
        .spawn()
        .with_context(|| format!("spawn {role} worker"))
}

/// Shared progress monitor for procs-mode runs: prints stats every
/// `--stats-every` seconds until the learners finish, the run drains
/// (covers an operator's wire `Msg::Shutdown` — learners deregister
/// before ever reporting done, so waiting on learners_done alone would
/// spin forever), or the process is signalled.  `tick` runs each
/// interval before the stats line (cmd_run_procs supervises its child
/// processes there).  Returns the JSONL sink so the caller can write
/// the FINAL row after `ctrl.shutdown()` — only once the workers have
/// drained (and flushed their last heartbeat snapshots) do the merged
/// run totals and the league counters describe the same finished run.
fn monitor_controller(
    ctrl: &Controller,
    mut tick: impl FnMut() -> Result<()>,
) -> Result<Option<JsonlSink>> {
    let sig = signal::install();
    let interval = Duration::from_secs(ctrl.cfg.stats_every_secs.max(1));
    let mut jsonl = open_jsonl(&ctrl.cfg.stats_jsonl)?;
    let mut last = 0u64;
    while !ctrl.learners_done()
        && !ctrl.deploy_stats().draining
        && !sig.load(Ordering::Relaxed)
    {
        std::thread::sleep(interval);
        tick()?;
        let ds = ctrl.deploy_stats();
        let ls = ctrl.league_stats();
        println!(
            "steps={} (+{}) pool={} episodes={} workers={} lost={} reassigned={}",
            ds.learner_steps,
            ds.learner_steps.saturating_sub(last),
            ls.pool_size,
            ls.episodes,
            ds.workers,
            ds.lost,
            ds.reassigned
        );
        last = ds.learner_steps;
        // league-wide telemetry merged from worker heartbeat snapshots
        // + the controller's in-process pool replicas
        let tele = ctrl.telemetry_report();
        println!("league: {}", telemetry::summary_line(&tele));
        if let Some(sink) = jsonl.as_mut() {
            sink.append(&tele, ls.episodes, ls.frames);
        }
    }
    Ok(jsonl)
}

/// The post-shutdown telemetry row: complete run totals (every worker
/// flushed its final heartbeat snapshot during the drain) + final
/// league counters.  Also guarantees sub-interval runs emit at least
/// one JSONL row.
fn final_stats_row(ctrl: &Controller, jsonl: &mut Option<JsonlSink>) {
    let tele = ctrl.telemetry_report();
    let ls = ctrl.league_stats();
    println!("league: {}", telemetry::summary_line(&tele));
    if let Some(sink) = jsonl.as_mut() {
        sink.append(&tele, ls.episodes, ls.frames);
    }
}

/// Autoscale follow-through for the one-command procs runner: when the
/// controller has grown the slot table past the live worker count of a
/// role, spawn workers for the new slots (the controller admits them as
/// late joiners).  Scale-downs need no action here — the drained
/// worker finishes its episode and exits 0 on its own.
fn fill_grown_slots(
    ctrl: &Controller,
    children: &mut Vec<(&'static str, Child)>,
    exe: &Path,
    artifacts: &str,
) -> Result<()> {
    if !ctrl.cfg.autoscale {
        return Ok(());
    }
    let (mut actors, mut infs) = (0usize, 0usize);
    for (role, child) in children.iter_mut() {
        if matches!(child.try_wait(), Ok(None)) {
            match *role {
                "actor" => actors += 1,
                "inf-server" => infs += 1,
                _ => {}
            }
        }
    }
    let ds = ctrl.deploy_stats();
    for _ in actors..ds.actor_slots as usize {
        println!("autoscale: spawning actor worker for grown slot");
        children.push(("actor", spawn_worker(exe, "actor", &ctrl.addr, artifacts)?));
    }
    for _ in infs..ds.inf_slots as usize {
        println!("autoscale: spawning inf-server worker for grown slot");
        children
            .push(("inf-server", spawn_worker(exe, "inf-server", &ctrl.addr, artifacts)?));
    }
    Ok(())
}

/// `--chaos` supervision: the plain monitor loop plus a deterministic
/// kill schedule.  Worker kills ride the existing respawn + slot
/// reassignment path; `kill:pool` retires one in-process replica so
/// clients must fail over; `kill:controller` forces a snapshot, tears
/// the control plane down WITHOUT the clean-shutdown save (SIGKILL
/// semantics), and restarts it resumed from that snapshot on the same
/// bind — live workers re-register against the successor.
#[allow(clippy::too_many_arguments)]
fn chaos_supervise(
    ctrl: &mut Controller,
    restart_cfg: &RunConfig,
    hp_layout: &[String],
    hp_default: &[f32],
    children: &mut Vec<(&'static str, Child)>,
    events: &[tleague::orchestrator::chaos::ChaosEvent],
    exe: &Path,
    artifacts: &str,
    respawns: &mut u64,
    respawn_cap: u64,
) -> Result<Option<JsonlSink>> {
    let sig = signal::install();
    let start = Instant::now();
    let stats_every = Duration::from_secs(ctrl.cfg.stats_every_secs.max(1));
    let mut jsonl = open_jsonl(&ctrl.cfg.stats_jsonl)?;
    let mut next_stats = Instant::now() + stats_every;
    let mut last = 0u64;
    let mut fired = 0usize;
    while !ctrl.learners_done()
        && !ctrl.deploy_stats().draining
        && !sig.load(Ordering::Relaxed)
    {
        // finer tick than the stats interval so kill times are honored
        std::thread::sleep(Duration::from_millis(50));
        while fired < events.len()
            && start.elapsed() >= Duration::from_millis(events[fired].at_ms)
        {
            let ev = &events[fired];
            fired += 1;
            match ev.role.as_str() {
                "controller" => {
                    // pin the recovery point: a real crash resumes from
                    // the last periodic snapshot; the drill forces one
                    // so recovery is exercised, not snapshot timing
                    ctrl.snapshot_now()?;
                    ctrl.crash();
                    println!(
                        "chaos[{}ms]: controller crashed; restarting from snapshot",
                        ev.at_ms
                    );
                    let mut cfg2 = restart_cfg.clone();
                    cfg2.resume = cfg2.checkpoint_dir.clone();
                    *ctrl =
                        Controller::start(cfg2, hp_layout.to_vec(), hp_default.to_vec())?;
                    println!("chaos[{}ms]: controller back on {}", ev.at_ms, ctrl.addr);
                }
                "pool" => match ctrl.chaos_kill_pool() {
                    Some((addr, moved, bit_exact)) => println!(
                        "chaos[{}ms]: model-pool replica {addr} down; rebalanced \
                         {} blobs / {} bytes across {} agents ({} already in place), \
                         bit-exact={bit_exact}",
                        ev.at_ms,
                        moved.blobs_moved,
                        moved.bytes_moved,
                        moved.agents,
                        moved.blobs_skipped
                    ),
                    None => {
                        println!("chaos[{}ms]: no pool replica to spare", ev.at_ms)
                    }
                },
                role => {
                    // SIGKILL the first live child of that role; the
                    // supervisor below respawns it and the controller
                    // reassigns the freed slot
                    let mut killed = false;
                    for (r, child) in children.iter_mut() {
                        if *r == role && matches!(child.try_wait(), Ok(None)) {
                            println!(
                                "chaos[{}ms]: SIGKILL {role} worker pid {}",
                                ev.at_ms,
                                child.id()
                            );
                            child.kill().ok();
                            killed = true;
                            break;
                        }
                    }
                    if !killed {
                        println!("chaos[{}ms]: no live {role} worker", ev.at_ms);
                    }
                }
            }
        }
        // supervise: chaos victims and organic deaths alike respawn
        for (role, child) in children.iter_mut() {
            if let Some(status) = child.try_wait()? {
                if ctrl.learners_done() || sig.load(Ordering::Relaxed) {
                    break;
                }
                if ctrl.cfg.autoscale && status.success() {
                    // a clean mid-run exit is a drained slot, not a death
                    continue;
                }
                anyhow::ensure!(
                    *respawns < respawn_cap,
                    "{role} worker keeps dying ({respawns} respawns); aborting"
                );
                eprintln!("{role} worker exited ({status}); respawning");
                *child = spawn_worker(exe, *role, &ctrl.addr, artifacts)?;
                *respawns += 1;
            }
        }
        fill_grown_slots(ctrl, children, exe, artifacts)?;
        if Instant::now() >= next_stats {
            next_stats += stats_every;
            let ds = ctrl.deploy_stats();
            let ls = ctrl.league_stats();
            println!(
                "steps={} (+{}) pool={} episodes={} workers={} lost={} reassigned={}",
                ds.learner_steps,
                ds.learner_steps.saturating_sub(last),
                ls.pool_size,
                ls.episodes,
                ds.workers,
                ds.lost,
                ds.reassigned
            );
            last = ds.learner_steps;
            let tele = ctrl.telemetry_report();
            println!("league: {}", telemetry::summary_line(&tele));
            if let Some(sink) = jsonl.as_mut() {
                sink.append(&tele, ls.episodes, ls.frames);
            }
        }
    }
    Ok(jsonl)
}

/// `run --mode procs`: embed the controller, spawn one OS process per
/// role worker, supervise them (respawn on unexpected exit — the
/// cross-process analogue of the thread supervisor), and drain
/// everything when the learners finish.
fn cmd_run_procs(cfg: RunConfig, args: &Args) -> Result<()> {
    let artifacts = artifacts_dir(args);
    // the parent only needs the manifest (hp layout); PJRT stays in the
    // worker processes
    let manifest = Manifest::load(Path::new(&artifacts))?;
    let hp_layout = manifest.hp_layout.clone();
    let hp_default = manifest.default_hp();
    let n_learner_workers = cfg.n_agents as usize;
    let n_actor_workers =
        cfg.n_agents as usize * cfg.learners_per_agent * cfg.actors_per_learner;
    let n_inf_workers = cfg.inf_servers;
    // deterministic chaos schedule (grammar validated with the config)
    let chaos_events = match &cfg.chaos {
        Some(spec) => tleague::orchestrator::chaos::parse_chaos(spec)?,
        None => Vec::new(),
    };
    // the parent embeds the control plane and the pool replicas, so it
    // participates in the fault plan as role "controller"; workers get
    // the same plan with their assignment slice
    if let Some(spec) = &cfg.faults {
        tleague::transport::fault::set_role("controller");
        tleague::transport::fault::install_spec(cfg.fault_seed, spec)?;
    }
    let restart_cfg = cfg.clone();
    let mut ctrl = Controller::start(cfg, hp_layout.clone(), hp_default.clone())?;
    println!("controller on {}", ctrl.addr);

    let exe = std::env::current_exe()?;
    let mut children: Vec<(&'static str, Child)> = Vec::new();
    for _ in 0..n_learner_workers {
        children.push(("learner", spawn_worker(&exe, "learner", &ctrl.addr, &artifacts)?));
    }
    for _ in 0..n_inf_workers {
        children.push(("inf-server", spawn_worker(&exe, "inf-server", &ctrl.addr, &artifacts)?));
    }
    for _ in 0..n_actor_workers {
        children.push(("actor", spawn_worker(&exe, "actor", &ctrl.addr, &artifacts)?));
    }
    println!(
        "spawned {} workers ({n_learner_workers} learner / {n_inf_workers} inf / {n_actor_workers} actor)",
        children.len()
    );

    let sig = signal::install();
    let mut respawns = 0u64;
    // a persistently-failing worker (the worker itself gives up after 10
    // consecutive failures) must abort the run loudly, not respawn forever
    let respawn_cap = 10 * children.len() as u64;
    let supervised = if chaos_events.is_empty() {
        monitor_controller(&ctrl, || {
            // supervise: a worker process that died mid-run is respawned;
            // the controller hands it back its freed slot.  Not after
            // Ctrl-C: the signal hit the whole process group, and the dead
            // children are the signal's work, not failures.
            for (role, child) in children.iter_mut() {
                if let Some(status) = child.try_wait()? {
                    if ctrl.learners_done() || sig.load(Ordering::Relaxed) {
                        break;
                    }
                    if ctrl.cfg.autoscale && status.success() {
                        // a clean mid-run exit is a drained slot
                        continue;
                    }
                    anyhow::ensure!(
                        respawns < respawn_cap,
                        "{role} worker keeps dying ({respawns} respawns); aborting"
                    );
                    eprintln!("{role} worker exited ({status}); respawning");
                    *child = spawn_worker(&exe, *role, &ctrl.addr, &artifacts)?;
                    respawns += 1;
                }
            }
            fill_grown_slots(&ctrl, &mut children, &exe, &artifacts)?;
            Ok(())
        })
    } else {
        chaos_supervise(
            &mut ctrl,
            &restart_cfg,
            &hp_layout,
            &hp_default,
            &mut children,
            &chaos_events,
            &exe,
            &artifacts,
            &mut respawns,
            respawn_cap,
        )
    };

    // graceful drain (even when supervision aborted): actors first, then
    // learners/inf, final snapshot
    ctrl.shutdown();
    let deadline = Instant::now() + Duration::from_secs(15);
    for (role, child) in children.iter_mut() {
        loop {
            if child.try_wait()?.is_some() {
                break;
            }
            if Instant::now() > deadline {
                eprintln!("{role} worker ignored stop; killing");
                child.kill().ok();
                child.wait().ok();
                break;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }
    // children are reaped: now a supervision error can surface
    let mut jsonl = supervised?;
    final_stats_row(&ctrl, &mut jsonl);
    let ds = ctrl.deploy_stats();
    let ls = ctrl.league_stats();
    println!(
        "done: pool={} episodes={} frames={} worker respawns={respawns} lost={} reassigned={}",
        ls.pool_size, ls.episodes, ls.frames, ds.lost, ds.reassigned
    );
    // spans merged at the controller: each worker's final heartbeat
    // carried its flight-recorder drain during the shutdown above
    if let Some(path) = args.get("trace-out") {
        export_trace(path, &ctrl.trace_spans())?;
    }
    Ok(())
}

/// Hand-launched control plane (`tleague controller`): same core as
/// `run --mode procs` but workers are started by the operator (other
/// boxes, a compose file — see examples/procs_league.yaml).
fn cmd_controller(args: &Args) -> Result<()> {
    // the controller subcommand IS procs mode; default the flag before
    // validation so e.g. --autoscale (procs-only) passes without the
    // operator spelling --mode procs (an explicit --mode still wins)
    let mut args = args.clone();
    args.flags
        .entry("mode".into())
        .or_insert_with(|| "procs".into());
    let args = &args;
    let mut cfg = build_run_config(args)?;
    cfg.mode = "procs".into();
    // --bind wins; otherwise keep --controller-bind / the config file's
    // value, upgrading only the ephemeral run-mode default to the
    // documented stable controller port
    if let Some(bind) = args.get("bind") {
        cfg.controller_bind = bind.to_string();
    } else if cfg.controller_bind == "127.0.0.1:0" {
        cfg.controller_bind = "127.0.0.1:9100".into();
    }
    cfg.validate()?;
    if let Some(spec) = &cfg.faults {
        tleague::transport::fault::set_role("controller");
        tleague::transport::fault::install_spec(cfg.fault_seed, spec)?;
    }
    let manifest = Manifest::load(Path::new(&artifacts_dir(args)))?;
    let hp_layout = manifest.hp_layout.clone();
    let hp_default = manifest.default_hp();
    let mut ctrl = Controller::start(cfg, hp_layout, hp_default)?;
    println!("controller listening on {}", ctrl.addr);
    println!(
        "waiting for workers: tleague worker --role learner|actor|inf-server \
         --controller {}",
        ctrl.addr
    );
    let mut jsonl = monitor_controller(&ctrl, || Ok(()))?;
    ctrl.shutdown();
    final_stats_row(&ctrl, &mut jsonl);
    let ls = ctrl.league_stats();
    println!("done: pool={} episodes={} frames={}", ls.pool_size, ls.episodes, ls.frames);
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    let role = args.get("role").context("--role learner|actor|inf-server required")?;
    let ctrl_addr = args
        .get("controller")
        .context("--controller host:port required")?;
    let net = tleague::orchestrator::worker::WorkerNet {
        bind_host: args.str_or("bind-host", "127.0.0.1"),
        advertise_host: args.get("advertise-host").map(str::to_string),
    };
    let eng = engine(args)?;
    let stop = signal::install();
    tleague::orchestrator::worker::run_worker(role, ctrl_addr, eng, &net, stop)
}

/// Probe a running controller for the merged league telemetry
/// (`tleague stats --controller host:port [--deploy]`).
fn cmd_stats(args: &Args) -> Result<()> {
    use tleague::proto::Msg;
    let addr = args
        .get("controller")
        .context("--controller host:port required")?;
    let c = tleague::transport::ReqClient::connect(addr);
    if args.bool("deploy") {
        match c.request(&Msg::DeployStats)? {
            Msg::DeployStatsReply {
                workers,
                lost,
                reassigned,
                learners_done,
                learner_steps,
                draining,
            } => println!(
                "deploy: workers={workers} lost={lost} reassigned={reassigned} \
                 learners_done={learners_done} steps={learner_steps} \
                 draining={draining}"
            ),
            other => anyhow::bail!("DeployStats: unexpected reply {other:?}"),
        }
    }
    // per-replica shard map + storage counters (aggregated PoolStats
    // would hide which replica holds what — the shard view shows both)
    let shards = match c.request(&Msg::PoolShardQuery)? {
        Msg::PoolShardReply(infos) => infos,
        other => anyhow::bail!("PoolShardQuery: unexpected reply {other:?}"),
    };
    match c.request(&Msg::StatsQuery)? {
        Msg::StatsReply(r) => {
            if args.bool("json") {
                println!("{}", pool_json(telemetry::report_json(&r), &shards));
                return Ok(());
            }
            println!("league: {}", telemetry::summary_line(&r));
            for role in &r.roles {
                let totals: Vec<String> = role
                    .totals
                    .iter()
                    .map(|(k, v)| format!("{k}={v}"))
                    .collect();
                println!(
                    "  {}[{}] totals: {}",
                    role.role,
                    role.slots,
                    if totals.is_empty() {
                        "-".to_string()
                    } else {
                        totals.join(" ")
                    }
                );
            }
            print_pool_section(&shards);
            Ok(())
        }
        other => anyhow::bail!("StatsQuery: unexpected reply {other:?}"),
    }
}

/// Human-readable pool section for `stats`: one line per live replica
/// with its shard ownership and storage counters, plus the aggregate.
fn print_pool_section(shards: &[tleague::proto::PoolShardInfo]) {
    if shards.is_empty() {
        return;
    }
    let ver = shards.iter().map(|s| s.map_version).max().unwrap_or(0);
    println!("  pool[{}] shard map v{ver}:", shards.len());
    let hit_pct = |hits: u64, reads: u64| {
        if reads == 0 { 0.0 } else { 100.0 * hits as f64 / reads as f64 }
    };
    for s in shards {
        println!(
            "    replica {} @ {}: agents={:?} models={} resident={}B \
             spilled={} reads={} frame-hit={:.0}%",
            s.replica,
            s.addr,
            s.owned_agents,
            s.models,
            s.resident_bytes,
            s.spilled,
            s.reads,
            hit_pct(s.frame_hits, s.reads)
        );
    }
    let (models, resident, spilled, reads, hits) = shards.iter().fold(
        (0u64, 0u64, 0u64, 0u64, 0u64),
        |(m, b, sp, rd, fh), s| {
            (
                m + s.models as u64,
                b + s.resident_bytes,
                sp + s.spilled as u64,
                rd + s.reads,
                fh + s.frame_hits,
            )
        },
    );
    println!(
        "    total: models={models} resident={resident}B spilled={spilled} \
         reads={reads} frame-hit={:.0}%",
        hit_pct(hits, reads)
    );
}

/// Splice the pool shard view into the `stats --json` payload as a
/// `pool` array alongside the telemetry `roles` object.
fn pool_json(
    report: tleague::util::json::Json,
    shards: &[tleague::proto::PoolShardInfo],
) -> tleague::util::json::Json {
    use tleague::util::json::Json;
    let arr: Vec<Json> = shards
        .iter()
        .map(|s| {
            Json::obj()
                .set("replica", s.replica as usize)
                .set("addr", s.addr.as_str())
                .set(
                    "owned_agents",
                    s.owned_agents.iter().map(|a| *a as usize).collect::<Vec<_>>(),
                )
                .set("models", s.models as usize)
                .set("resident_bytes", s.resident_bytes as f64)
                .set("spilled", s.spilled as usize)
                .set("reads", s.reads as f64)
                .set("frame_hits", s.frame_hits as f64)
                .set("map_version", s.map_version as f64)
        })
        .collect();
    report.set("pool", arr)
}

/// Drain the flight recorder of a running league (`tleague trace
/// --controller host:port [--trace-out <path>]`): the controller
/// replies with the spans merged into its league view (worker heartbeat
/// drains + its own in-process roles), exported as Chrome trace JSON.
fn cmd_trace(args: &Args) -> Result<()> {
    use tleague::proto::Msg;
    let addr = args
        .get("controller")
        .context("--controller host:port required")?;
    let c = tleague::transport::ReqClient::connect(addr);
    match c.request(&Msg::TraceQuery)? {
        Msg::TraceReply(spans) => {
            anyhow::ensure!(
                !spans.is_empty(),
                "controller has no recorded spans yet (run with --trace-sample > 0, \
                 or wait for requests slower than --trace-slow-ms)"
            );
            export_trace(&args.str_or("trace-out", "trace.json"), &spans)
        }
        other => anyhow::bail!("TraceQuery: unexpected reply {other:?}"),
    }
}

// ---- info / eval --------------------------------------------------------

fn cmd_info(args: &Args) -> Result<()> {
    let eng = engine(args)?;
    println!("hp layout: {:?}", eng.manifest.hp_layout);
    for (name, m) in &eng.manifest.envs {
        println!(
            "env {name}: obs={} act={} hidden={:?} team={} P={} T={} B={} artifacts={}",
            m.obs_dim, m.act_dim, m.hidden, m.team, m.param_count, m.train_t,
            m.train_b, m.artifacts.len()
        );
    }
    Ok(())
}

fn load_checkpoint(path: &str, expected: usize) -> Result<Vec<f32>> {
    let raw = std::fs::read(path).with_context(|| format!("read {path}"))?;
    anyhow::ensure!(
        raw.len() == expected * 4,
        "checkpoint has {} bytes, want {}",
        raw.len(),
        expected * 4
    );
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Tables 1 & 2: FRAG matches in doom_lite.
fn cmd_eval_doom(args: &Args) -> Result<()> {
    use tleague::envs::doom_lite::bots::{BuiltinBot, DoomPolicy, F1Bot};
    use tleague::eval::{doom_match, NnPolicy};
    let eng = engine(args)?;
    let m = eng.manifest.env("doom_lite")?.clone();
    let params = match args.get("checkpoint") {
        Some(p) => load_checkpoint(p, m.param_count)?,
        None => eng.init_params("doom_lite")?,
    };
    let games = args.u64_or("games", 5)?;
    let setting = args.str_or("setting", "1");
    // (n_my, n_f1, n_bots) per Table 1 / Table 2 rows
    let (n_my, n_f1, n_bots) = match setting.as_str() {
        "1" => (1, 0, 7),
        "2a" => (1, 1, 6),
        "2b" => (2, 2, 4),
        "2c" => (4, 4, 0),
        s => anyhow::bail!("setting must be 1|2a|2b|2c, got {s}"),
    };
    println!("setting {setting}: {n_my} MyPlayer + {n_f1} F1 + {n_bots} bots, {games} matches");
    let mut my_best = Vec::new();
    let mut f1_best = Vec::new();
    for g in 0..games {
        let mut nn: Vec<NnPolicy> = (0..n_my)
            .map(|i| NnPolicy::new(eng.clone(), "doom_lite", params.clone(), g * 10 + i))
            .collect();
        let mut bots: Vec<Box<dyn DoomPolicy>> = Vec::new();
        for i in 0..n_f1 {
            bots.push(Box::new(F1Bot::new(g * 20 + i)));
        }
        for i in 0..n_bots {
            bots.push(Box::new(BuiltinBot::new(g * 30 + i)));
        }
        let frags = doom_match(g, &mut nn, &mut bots)?;
        let my = frags[..n_my as usize].iter().max().copied().unwrap_or(0);
        my_best.push(my);
        if n_f1 > 0 {
            let f1 = frags[n_my as usize..(n_my + n_f1) as usize]
                .iter()
                .max()
                .copied()
                .unwrap();
            f1_best.push(f1);
        }
        println!("  match {}: frags {:?}", g + 1, frags);
    }
    let avg = |v: &[i32]| v.iter().sum::<i32>() as f64 / v.len().max(1) as f64;
    println!("MyPlayer best-FRAG per match: {my_best:?}  avg {:.1}", avg(&my_best));
    if !f1_best.is_empty() {
        println!("F1       best-FRAG per match: {f1_best:?}  avg {:.1}", avg(&f1_best));
    }
    Ok(())
}

/// Experiment V1: league-trained RPS pool exploitability.
fn cmd_eval_rps(args: &Args) -> Result<()> {
    use tleague::envs::matrix::MatrixGame;
    use tleague::eval::{rps_pool_exploitability, rps_strategy, NnPolicy};
    let eng = engine(args)?;
    let params = eng.init_params("rps")?;
    let mut nn = NnPolicy::new(eng, "rps", params, 0);
    let s = rps_strategy(&mut nn)?;
    let game = MatrixGame::rps(0);
    println!("seed policy strategy: {s:?}");
    println!("exploitability: {:.4}", rps_pool_exploitability(&game, &[s]));
    println!("(run examples/rps_league for the full FSP-vs-selfplay curve)");
    Ok(())
}
