//! doom_lite: ViZDoom CIG-2016 track-1 stand-in (DESIGN.md substitution 1).
//!
//! A 2-D tile-maze deathmatch: 8 players, rockets with splash damage
//! (suicides are possible, so FRAG = kills − suicides is meaningful),
//! respawns, fixed-length match, ranked by FRAG — the protocol of the
//! paper's §4.2.  Observations are egocentric ray casts (depth + entity
//! channels), the stand-in for the first-person RGB screen.  Actions (6):
//! idle, turn-left, turn-right, move-forward, move-backward, fire.
//!
//! All simulation is synchronous (the paper's fairness note): every
//! agent acts, then the world ticks once.

pub mod bots;

use super::{Info, MultiAgentEnv, Step};
use crate::util::rng::Pcg32;

pub const MAZE: usize = 24;
pub const N_RAYS: usize = 24;
pub const RAY_CH: usize = 5;
pub const OBS_DIM: usize = N_RAYS * RAY_CH + 8;
pub const FOV: f32 = 1.6; // radians (~92 deg)
pub const MAX_DEPTH: f32 = 12.0;
pub const MOVE_SPEED: f32 = 0.22;
pub const TURN_SPEED: f32 = 0.35;
pub const ROCKET_SPEED: f32 = 0.8;
pub const SPLASH_RADIUS: f32 = 1.1;
pub const FIRE_COOLDOWN: i32 = 6;
pub const RESPAWN_DELAY: i32 = 12;
pub const MATCH_STEPS: usize = 2100; // ≙ 10 min at 17.5 eff. fps / 5

pub const ACT_IDLE: usize = 0;
pub const ACT_TURN_L: usize = 1;
pub const ACT_TURN_R: usize = 2;
pub const ACT_FWD: usize = 3;
pub const ACT_BACK: usize = 4;
pub const ACT_FIRE: usize = 5;

#[derive(Clone, Debug)]
pub struct Player {
    pub pos: (f32, f32),
    pub angle: f32,
    pub alive: bool,
    pub respawn_in: i32,
    pub cooldown: i32,
    pub kills: i32,
    pub suicides: i32,
    pub deaths: i32,
}

impl Player {
    pub fn frag(&self) -> i32 {
        self.kills - self.suicides
    }
}

#[derive(Clone, Debug)]
pub struct Rocket {
    pub pos: (f32, f32),
    pub vel: (f32, f32),
    pub owner: usize,
}

pub struct DoomLite {
    rng: Pcg32,
    pub walls: Vec<bool>, // MAZE*MAZE
    pub players: Vec<Player>,
    pub rockets: Vec<Rocket>,
    pub steps: usize,
    n_players: usize,
    done: bool,
    /// navigation-stage reward shaping (stage 1 of the paper's two-stage
    /// training): exploration bonus, firing disabled
    pub nav_mode: bool,
    visited: Vec<Vec<bool>>, // per player, per cell
}

fn widx(x: i32, y: i32) -> usize {
    y as usize * MAZE + x as usize
}

impl DoomLite {
    pub fn new(seed: u64, n_players: usize) -> Self {
        assert!((2..=8).contains(&n_players));
        let mut env = DoomLite {
            rng: Pcg32::from_label(seed, "doom"),
            walls: vec![false; MAZE * MAZE],
            players: Vec::new(),
            rockets: Vec::new(),
            steps: 0,
            n_players,
            done: true,
            nav_mode: false,
            visited: vec![vec![false; MAZE * MAZE]; n_players],
        };
        env.gen_maze();
        env
    }

    fn gen_maze(&mut self) {
        // border walls + random interior pillars/segments, with a
        // connectivity pass that knocks holes until the maze is connected
        self.walls.fill(false);
        for i in 0..MAZE as i32 {
            for &(x, y) in &[(i, 0), (i, MAZE as i32 - 1), (0, i), (MAZE as i32 - 1, i)] {
                self.walls[widx(x, y)] = true;
            }
        }
        for _ in 0..42 {
            let x = 2 + self.rng.below(MAZE as u32 - 4) as i32;
            let y = 2 + self.rng.below(MAZE as u32 - 4) as i32;
            let horiz = self.rng.chance(0.5);
            let len = 2 + self.rng.below(4) as i32;
            for k in 0..len {
                let (wx, wy) = if horiz { (x + k, y) } else { (x, y + k) };
                if wx < MAZE as i32 - 1 && wy < MAZE as i32 - 1 {
                    self.walls[widx(wx, wy)] = true;
                }
            }
        }
        // connectivity: flood fill from first free cell, open walls
        // adjacent to unreached regions until all free cells reachable
        loop {
            let mut seen = vec![false; MAZE * MAZE];
            let start = (0..MAZE * MAZE).find(|&i| !self.walls[i]);
            let Some(start) = start else { break };
            let mut q = std::collections::VecDeque::from([start]);
            seen[start] = true;
            while let Some(i) = q.pop_front() {
                let (x, y) = ((i % MAZE) as i32, (i / MAZE) as i32);
                for (dx, dy) in [(1, 0), (-1, 0), (0, 1), (0, -1)] {
                    let (nx, ny) = (x + dx, y + dy);
                    if (0..MAZE as i32).contains(&nx)
                        && (0..MAZE as i32).contains(&ny)
                    {
                        let ni = widx(nx, ny);
                        if !self.walls[ni] && !seen[ni] {
                            seen[ni] = true;
                            q.push_back(ni);
                        }
                    }
                }
            }
            // find an unreached free cell adjacent to a reached one via a wall
            let mut fixed = false;
            'outer: for y in 1..MAZE as i32 - 1 {
                for x in 1..MAZE as i32 - 1 {
                    let i = widx(x, y);
                    if self.walls[i] {
                        let near_seen = [(1, 0), (-1, 0), (0, 1), (0, -1)]
                            .iter()
                            .any(|(dx, dy)| {
                                let ni = widx(x + dx, y + dy);
                                !self.walls[ni] && seen[ni]
                            });
                        let near_unseen = [(1, 0), (-1, 0), (0, 1), (0, -1)]
                            .iter()
                            .any(|(dx, dy)| {
                                let ni = widx(x + dx, y + dy);
                                !self.walls[ni] && !seen[ni]
                            });
                        if near_seen && near_unseen {
                            self.walls[i] = false;
                            fixed = true;
                            break 'outer;
                        }
                    }
                }
            }
            if !fixed {
                break;
            }
        }
    }

    pub fn is_wall_at(&self, x: f32, y: f32) -> bool {
        let (cx, cy) = (x.floor() as i32, y.floor() as i32);
        if !(0..MAZE as i32).contains(&cx) || !(0..MAZE as i32).contains(&cy) {
            return true;
        }
        self.walls[widx(cx, cy)]
    }

    fn free_spawn(&mut self) -> (f32, f32) {
        loop {
            let x = 1.5 + self.rng.next_f32() * (MAZE as f32 - 3.0);
            let y = 1.5 + self.rng.next_f32() * (MAZE as f32 - 3.0);
            if !self.is_wall_at(x, y) {
                return (x, y);
            }
        }
    }

    fn spawn_players(&mut self) {
        self.players.clear();
        for _ in 0..self.n_players {
            let pos = self.free_spawn();
            let angle = self.rng.next_f32() * std::f32::consts::TAU;
            self.players.push(Player {
                pos,
                angle,
                alive: true,
                respawn_in: 0,
                cooldown: 0,
                kills: 0,
                suicides: 0,
                deaths: 0,
            });
        }
    }

    /// Cast a ray from `pos` along `angle`; returns (depth, hit_player).
    pub fn raycast(&self, pos: (f32, f32), angle: f32, skip: usize) -> (f32, Option<usize>) {
        let (dx, dy) = (angle.cos(), angle.sin());
        let step = 0.1f32;
        let mut t = step;
        while t < MAX_DEPTH {
            let (x, y) = (pos.0 + dx * t, pos.1 + dy * t);
            if self.is_wall_at(x, y) {
                return (t, None);
            }
            for (i, p) in self.players.iter().enumerate() {
                if i != skip && p.alive {
                    let d2 = (p.pos.0 - x) * (p.pos.0 - x)
                        + (p.pos.1 - y) * (p.pos.1 - y);
                    if d2 < 0.25 {
                        return (t, Some(i));
                    }
                }
            }
            t += step;
        }
        (MAX_DEPTH, None)
    }

    pub fn encode_obs(&self, who: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; OBS_DIM];
        let me = &self.players[who];
        for r in 0..N_RAYS {
            let frac = r as f32 / (N_RAYS - 1) as f32 - 0.5;
            let angle = me.angle + frac * FOV;
            let (depth, hit) = self.raycast(me.pos, angle, who);
            let base = r * RAY_CH;
            out[base] = 1.0 - depth / MAX_DEPTH; // wall proximity
            if let Some(e) = hit {
                out[base + 1] = 1.0; // enemy visible on this ray
                out[base + 2] = 1.0 - depth / MAX_DEPTH; // enemy proximity
                let _ = e;
            }
            // rockets along this ray
            for rk in &self.rockets {
                let rel = (rk.pos.0 - me.pos.0, rk.pos.1 - me.pos.1);
                let dist = (rel.0 * rel.0 + rel.1 * rel.1).sqrt();
                if dist < MAX_DEPTH {
                    let ra = rel.1.atan2(rel.0);
                    let mut da = ra - angle;
                    while da > std::f32::consts::PI {
                        da -= std::f32::consts::TAU;
                    }
                    while da < -std::f32::consts::PI {
                        da += std::f32::consts::TAU;
                    }
                    if da.abs() < FOV / N_RAYS as f32 {
                        out[base + 3] = (1.0 - dist / MAX_DEPTH).max(out[base + 3]);
                    }
                }
            }
            // wall-normal-ish: depth gradient helps steering
            out[base + 4] = depth / MAX_DEPTH;
        }
        let base = N_RAYS * RAY_CH;
        out[base] = me.alive as u8 as f32;
        out[base + 1] = (me.cooldown as f32 / FIRE_COOLDOWN as f32).min(1.0);
        out[base + 2] = me.pos.0 / MAZE as f32;
        out[base + 3] = me.pos.1 / MAZE as f32;
        out[base + 4] = (me.angle / std::f32::consts::TAU).rem_euclid(1.0);
        out[base + 5] = self.steps as f32 / MATCH_STEPS as f32;
        out[base + 6] = me.frag() as f32 / 30.0;
        out[base + 7] = if self.nav_mode { 1.0 } else { 0.0 };
        out
    }

    fn all_obs(&self) -> Vec<Vec<f32>> {
        (0..self.n_players).map(|i| self.encode_obs(i)).collect()
    }

    pub fn frags(&self) -> Vec<i32> {
        self.players.iter().map(|p| p.frag()).collect()
    }
}

impl MultiAgentEnv for DoomLite {
    fn n_agents(&self) -> usize {
        self.n_players
    }
    fn obs_dim(&self) -> usize {
        OBS_DIM
    }
    fn act_dim(&self) -> usize {
        6
    }
    fn max_steps(&self) -> usize {
        MATCH_STEPS
    }

    fn reset(&mut self) -> Vec<Vec<f32>> {
        self.gen_maze();
        self.spawn_players();
        self.rockets.clear();
        self.steps = 0;
        self.done = false;
        for v in self.visited.iter_mut() {
            v.fill(false);
        }
        self.all_obs()
    }

    fn step(&mut self, actions: &[usize]) -> Step {
        assert!(!self.done, "step after done");
        assert_eq!(actions.len(), self.n_players);
        self.steps += 1;
        let mut rewards = vec![0.0f32; self.n_players];

        // respawns + cooldowns
        for i in 0..self.n_players {
            let p = &mut self.players[i];
            if p.cooldown > 0 {
                p.cooldown -= 1;
            }
            if !p.alive {
                p.respawn_in -= 1;
                if p.respawn_in <= 0 {
                    let pos = self.free_spawn();
                    let p = &mut self.players[i];
                    p.pos = pos;
                    p.alive = true;
                }
            }
        }

        // actions
        for i in 0..self.n_players {
            if !self.players[i].alive {
                continue;
            }
            match actions[i] {
                ACT_TURN_L => self.players[i].angle -= TURN_SPEED,
                ACT_TURN_R => self.players[i].angle += TURN_SPEED,
                ACT_FWD | ACT_BACK => {
                    let sgn = if actions[i] == ACT_FWD { 1.0 } else { -0.6 };
                    let p = &self.players[i];
                    let nx = p.pos.0 + p.angle.cos() * MOVE_SPEED * sgn;
                    let ny = p.pos.1 + p.angle.sin() * MOVE_SPEED * sgn;
                    if !self.is_wall_at(nx, ny) {
                        self.players[i].pos = (nx, ny);
                    } else if !self.is_wall_at(nx, p.pos.1) {
                        self.players[i].pos.0 = nx; // wall slide
                    } else if !self.is_wall_at(p.pos.0, ny) {
                        self.players[i].pos.1 = ny;
                    }
                }
                ACT_FIRE if !self.nav_mode => {
                    let p = &mut self.players[i];
                    if p.cooldown == 0 {
                        p.cooldown = FIRE_COOLDOWN;
                        let vel = (p.angle.cos() * ROCKET_SPEED,
                                   p.angle.sin() * ROCKET_SPEED);
                        let pos = (p.pos.0 + vel.0, p.pos.1 + vel.1);
                        self.rockets.push(Rocket { pos, vel, owner: i });
                    }
                }
                _ => {}
            }
            // nav-mode exploration bonus (stage-1 reward shaping, §4.2)
            if self.nav_mode {
                let p = &self.players[i];
                let ci = widx(p.pos.0.floor() as i32, p.pos.1.floor() as i32);
                if !self.visited[i][ci] {
                    self.visited[i][ci] = true;
                    rewards[i] += 0.1;
                }
            }
        }

        // rocket flight + detonation (sub-stepped to avoid tunneling)
        let mut exploded: Vec<((f32, f32), usize)> = Vec::new();
        let walls = &self.walls;
        let players_snapshot: Vec<(bool, (f32, f32))> =
            self.players.iter().map(|p| (p.alive, p.pos)).collect();
        let mut live_rockets = Vec::with_capacity(self.rockets.len());
        'rockets: for mut r in self.rockets.drain(..) {
            for substep in 0..3 {
                // check-then-advance: a rocket spawned inside a wall
                // detonates at its spawn point (point-blank suicide)
                if substep > 0 {
                    r.pos.0 += r.vel.0 / 2.0;
                    r.pos.1 += r.vel.1 / 2.0;
                }
                let (cx, cy) = (r.pos.0.floor() as i32, r.pos.1.floor() as i32);
                let in_wall = !(0..MAZE as i32).contains(&cx)
                    || !(0..MAZE as i32).contains(&cy)
                    || walls[widx(cx, cy)];
                let direct_hit = players_snapshot.iter().enumerate().any(
                    |(i, (alive, pos))| {
                        *alive
                            && i != r.owner
                            && (pos.0 - r.pos.0).powi(2)
                                + (pos.1 - r.pos.1).powi(2)
                                < 0.3
                    },
                );
                if in_wall || direct_hit {
                    exploded.push((r.pos, r.owner));
                    continue 'rockets;
                }
            }
            live_rockets.push(r);
        }
        self.rockets = live_rockets;

        // splash damage (single-hit kill within radius — incl. the owner:
        // that's where suicides come from)
        for (pos, owner) in exploded {
            for i in 0..self.n_players {
                let p = &self.players[i];
                if !p.alive {
                    continue;
                }
                let d2 = (p.pos.0 - pos.0).powi(2) + (p.pos.1 - pos.1).powi(2);
                if d2 < SPLASH_RADIUS * SPLASH_RADIUS {
                    let p = &mut self.players[i];
                    p.alive = false;
                    p.respawn_in = RESPAWN_DELAY;
                    p.deaths += 1;
                    if i == owner {
                        self.players[owner].suicides += 1;
                        rewards[owner] -= 1.0;
                    } else {
                        self.players[owner].kills += 1;
                        rewards[owner] += 1.0;
                        rewards[i] -= 0.2;
                    }
                }
            }
        }

        let done = self.steps >= MATCH_STEPS;
        self.done = done;
        let info = if done {
            // rank by FRAG: winner(s) get 1.0, last 0.0, linear between
            let frags = self.frags();
            let max = *frags.iter().max().unwrap();
            let min = *frags.iter().min().unwrap();
            let outcome = frags
                .iter()
                .map(|&f| {
                    if max == min {
                        0.5
                    } else {
                        (f - min) as f32 / (max - min) as f32
                    }
                })
                .collect();
            Info { outcome: Some(outcome), frags: Some(frags) }
        } else {
            Info::default()
        };
        Step { obs: self.all_obs(), rewards, done, info }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maze_is_connected() {
        for seed in 0..5 {
            let env = DoomLite::new(seed, 8);
            let free: Vec<usize> =
                (0..MAZE * MAZE).filter(|&i| !env.walls[i]).collect();
            let mut seen = vec![false; MAZE * MAZE];
            let mut q = std::collections::VecDeque::from([free[0]]);
            seen[free[0]] = true;
            let mut count = 1;
            while let Some(i) = q.pop_front() {
                let (x, y) = ((i % MAZE) as i32, (i / MAZE) as i32);
                for (dx, dy) in [(1, 0), (-1, 0), (0, 1), (0, -1)] {
                    let (nx, ny) = (x + dx, y + dy);
                    if (0..MAZE as i32).contains(&nx)
                        && (0..MAZE as i32).contains(&ny)
                    {
                        let ni = widx(nx, ny);
                        if !env.walls[ni] && !seen[ni] {
                            seen[ni] = true;
                            count += 1;
                            q.push_back(ni);
                        }
                    }
                }
            }
            assert_eq!(count, free.len(), "seed {seed}: maze disconnected");
        }
    }

    #[test]
    fn players_stay_in_maze() {
        let mut env = DoomLite::new(1, 8);
        env.reset();
        for t in 0..300 {
            let acts: Vec<usize> = (0..8).map(|i| (t + i) % 6).collect();
            env.step(&acts);
            for p in &env.players {
                assert!(!env.is_wall_at(p.pos.0, p.pos.1));
            }
        }
    }

    #[test]
    fn firing_kills_and_scores_frag() {
        let mut env = DoomLite::new(2, 2);
        env.reset();
        // place shooter facing victim point-blank in open space
        env.walls.fill(false);
        for i in 0..MAZE as i32 {
            for &(x, y) in
                &[(i, 0), (i, MAZE as i32 - 1), (0, i), (MAZE as i32 - 1, i)]
            {
                env.walls[widx(x, y)] = true;
            }
        }
        env.players[0].pos = (5.0, 5.0);
        env.players[0].angle = 0.0;
        env.players[1].pos = (8.0, 5.0);
        let mut killed = false;
        for _ in 0..20 {
            let s = env.step(&vec![ACT_FIRE, ACT_IDLE]);
            if !env.players[1].alive || env.players[1].deaths > 0 {
                killed = true;
                assert_eq!(env.players[0].kills, 1);
                assert!(s.rewards[0] > 0.9);
                break;
            }
        }
        assert!(killed, "point-blank rocket must kill");
    }

    #[test]
    fn suicide_counts_negative_frag() {
        let mut env = DoomLite::new(3, 2);
        env.reset();
        env.walls.fill(false);
        for i in 0..MAZE as i32 {
            for &(x, y) in
                &[(i, 0), (i, MAZE as i32 - 1), (0, i), (MAZE as i32 - 1, i)]
            {
                env.walls[widx(x, y)] = true;
            }
        }
        // face a wall point-blank: splash catches the shooter
        env.players[0].pos = (1.6, 5.0);
        env.players[0].angle = std::f32::consts::PI; // toward x=0 wall
        env.players[1].pos = (20.0, 20.0);
        for _ in 0..5 {
            env.step(&vec![ACT_FIRE, ACT_IDLE]);
            if env.players[0].suicides > 0 {
                break;
            }
        }
        assert!(env.players[0].suicides >= 1, "wall-blast suicide expected");
        assert!(env.players[0].frag() < 0);
    }

    #[test]
    fn respawn_after_delay() {
        let mut env = DoomLite::new(4, 2);
        env.reset();
        env.players[1].alive = false;
        env.players[1].respawn_in = 2;
        env.step(&vec![ACT_IDLE; 2]);
        assert!(!env.players[1].alive);
        env.step(&vec![ACT_IDLE; 2]);
        assert!(env.players[1].alive, "must respawn after delay");
    }

    #[test]
    fn nav_mode_rewards_exploration_and_blocks_fire() {
        let mut env = DoomLite::new(5, 2);
        env.nav_mode = true;
        env.reset();
        let s = env.step(&vec![ACT_FWD, ACT_FIRE]);
        assert!(env.rockets.is_empty(), "fire disabled in nav mode");
        assert!(s.rewards[0] >= 0.0);
        // moving into fresh cells pays out
        let mut total = 0.0;
        for _ in 0..50 {
            let s = env.step(&vec![ACT_FWD, ACT_IDLE]);
            total += s.rewards[0];
        }
        assert!(total > 0.0, "exploration must earn reward");
    }

    #[test]
    fn obs_dim_matches_spec() {
        let mut env = DoomLite::new(6, 8);
        let obs = env.reset();
        assert_eq!(obs[0].len(), OBS_DIM);
        assert_eq!(OBS_DIM, 24 * 5 + 8);
    }

    #[test]
    fn raycast_sees_walls_and_players() {
        let mut env = DoomLite::new(7, 2);
        env.reset();
        env.walls.fill(false);
        for i in 0..MAZE as i32 {
            for &(x, y) in
                &[(i, 0), (i, MAZE as i32 - 1), (0, i), (MAZE as i32 - 1, i)]
            {
                env.walls[widx(x, y)] = true;
            }
        }
        env.players[0].pos = (5.0, 5.0);
        env.players[1].pos = (9.0, 5.0);
        let (d, hit) = env.raycast((5.0, 5.0), 0.0, 0);
        assert!(hit == Some(1), "should see player 1, got {hit:?}");
        assert!((d - 4.0).abs() < 0.6, "depth ~4, got {d}");
        let (d, hit) = env.raycast((5.0, 5.0), std::f32::consts::PI, 0);
        assert!(hit.is_none());
        assert!(d < 5.0, "wall within depth, got {d}");
    }
}
