//! GameMgr: opponent-sampling algorithms (paper §3.1 + §3.2).
//!
//! Each implementation answers one question per task request: given the
//! current learning model and the frozen pool M (with its payoff
//! matrix), which opponent(s) should this episode be played against?
//!
//! Shipped samplers (mirroring the paper's list):
//!  - [`SelfPlay`]         — always the current model (the naive baseline
//!                           that circulates on RPS; §3.1)
//!  - [`UniformRecent`]    — uniform over the most recent K frozen models
//!                           (the ViZDoom §4.2 setting, K = 50)
//!  - [`Pfsp`]             — Prioritized FSP: weight ∝ f(winrate), the
//!                           AlphaStar f_hard weighting
//!  - [`SpPfspMix`]        — 35% pure self-play + 65% PFSP (the Pommerman
//!                           §4.3 / AlphaStar Main-Agent setting)
//!  - [`EloMatch`]         — Gaussian Elo matchmaking (Quake-III PBT)
//!  - [`AgentExploiter`]   — AlphaStar league roles: main agents mix
//!                           SP+PFSP, exploiters target the main agent

use super::payoff::PayoffMatrix;
use crate::proto::ModelKey;
use crate::util::rng::Pcg32;

pub trait GameMgr: Send {
    /// Sample `n_opponents` opponents for the learning agent `learner`.
    fn sample_opponents(
        &mut self,
        learner: ModelKey,
        n_opponents: usize,
        pool: &[ModelKey],
        payoff: &PayoffMatrix,
        rng: &mut Pcg32,
    ) -> Vec<ModelKey>;

    fn name(&self) -> &'static str;
}

/// Always play the current model against itself.
pub struct SelfPlay;

impl GameMgr for SelfPlay {
    fn sample_opponents(
        &mut self,
        learner: ModelKey,
        n: usize,
        _pool: &[ModelKey],
        _payoff: &PayoffMatrix,
        _rng: &mut Pcg32,
    ) -> Vec<ModelKey> {
        vec![learner; n]
    }
    fn name(&self) -> &'static str {
        "selfplay"
    }
}

/// Uniform over the most recent `k` frozen models.
pub struct UniformRecent {
    pub k: usize,
}

impl GameMgr for UniformRecent {
    fn sample_opponents(
        &mut self,
        learner: ModelKey,
        n: usize,
        pool: &[ModelKey],
        _payoff: &PayoffMatrix,
        rng: &mut Pcg32,
    ) -> Vec<ModelKey> {
        if pool.is_empty() {
            return vec![learner; n];
        }
        let start = pool.len().saturating_sub(self.k);
        let recent = &pool[start..];
        (0..n).map(|_| *rng.choose(recent)).collect()
    }
    fn name(&self) -> &'static str {
        "uniform"
    }
}

/// PFSP weighting functions (AlphaStar supplementary).
#[derive(Clone, Copy, Debug)]
pub enum PfspWeight {
    /// f_hard(p) = (1-p)^2 — focus on opponents we lose to
    Hard,
    /// f_var(p) = p(1-p) — focus on even matches
    Var,
    /// uniform
    Flat,
}

impl PfspWeight {
    pub fn weight(self, winrate: f64) -> f64 {
        match self {
            PfspWeight::Hard => (1.0 - winrate).powi(2),
            PfspWeight::Var => winrate * (1.0 - winrate),
            PfspWeight::Flat => 1.0,
        }
    }
}

/// Prioritized Fictitious Self-Play.
pub struct Pfsp {
    pub weighting: PfspWeight,
}

impl GameMgr for Pfsp {
    fn sample_opponents(
        &mut self,
        learner: ModelKey,
        n: usize,
        pool: &[ModelKey],
        payoff: &PayoffMatrix,
        rng: &mut Pcg32,
    ) -> Vec<ModelKey> {
        if pool.is_empty() {
            return vec![learner; n];
        }
        let weights: Vec<f64> = pool
            .iter()
            .map(|&op| self.weighting.weight(payoff.winrate(learner, op)) + 1e-3)
            .collect();
        (0..n).map(|_| pool[rng.weighted(&weights)]).collect()
    }
    fn name(&self) -> &'static str {
        // mirrors the factory key so stats/logs name the actual sampler
        match self.weighting {
            PfspWeight::Var => "pfsp_var",
            _ => "pfsp",
        }
    }
}

/// p_sp self-play + (1 - p_sp) PFSP — the paper's Pommerman sampler
/// ("35% pure self-play and 65% PFSP", §4.3).
pub struct SpPfspMix {
    pub p_sp: f64,
    pub pfsp: Pfsp,
}

impl SpPfspMix {
    pub fn paper() -> Self {
        SpPfspMix { p_sp: 0.35, pfsp: Pfsp { weighting: PfspWeight::Hard } }
    }
}

impl GameMgr for SpPfspMix {
    fn sample_opponents(
        &mut self,
        learner: ModelKey,
        n: usize,
        pool: &[ModelKey],
        payoff: &PayoffMatrix,
        rng: &mut Pcg32,
    ) -> Vec<ModelKey> {
        (0..n)
            .map(|_| {
                if pool.is_empty() || rng.chance(self.p_sp) {
                    learner
                } else {
                    self.pfsp
                        .sample_opponents(learner, 1, pool, payoff, rng)[0]
                }
            })
            .collect()
    }
    fn name(&self) -> &'static str {
        "sp_pfsp"
    }
}

/// Gaussian Elo matchmaking (Quake III / PBT): opponents whose Elo is
/// within ~sigma of the learner are preferred.
pub struct EloMatch {
    pub sigma: f64,
}

impl GameMgr for EloMatch {
    fn sample_opponents(
        &mut self,
        learner: ModelKey,
        n: usize,
        pool: &[ModelKey],
        payoff: &PayoffMatrix,
        rng: &mut Pcg32,
    ) -> Vec<ModelKey> {
        if pool.is_empty() {
            return vec![learner; n];
        }
        let my_elo = payoff.elo(learner);
        let weights: Vec<f64> = pool
            .iter()
            .map(|&op| {
                let d = (payoff.elo(op) - my_elo) / self.sigma;
                (-0.5 * d * d).exp() + 1e-6
            })
            .collect();
        (0..n).map(|_| pool[rng.weighted(&weights)]).collect()
    }
    fn name(&self) -> &'static str {
        "elo_match"
    }
}

/// AlphaStar-style league roles.  Agent id 0 is the Main Agent
/// (SP+PFSP); odd agent ids are Main Exploiters (always target the main
/// agent's CURRENT model); other even ids are League Exploiters (PFSP
/// over the whole pool).
pub struct AgentExploiter {
    main: SpPfspMix,
    league: Pfsp,
}

impl Default for AgentExploiter {
    fn default() -> Self {
        Self::new()
    }
}

impl AgentExploiter {
    pub fn new() -> Self {
        AgentExploiter {
            main: SpPfspMix::paper(),
            league: Pfsp { weighting: PfspWeight::Hard },
        }
    }

    pub fn role(agent: u32) -> &'static str {
        if agent == 0 {
            "main"
        } else if agent % 2 == 1 {
            "main_exploiter"
        } else {
            "league_exploiter"
        }
    }
}

impl GameMgr for AgentExploiter {
    fn sample_opponents(
        &mut self,
        learner: ModelKey,
        n: usize,
        pool: &[ModelKey],
        payoff: &PayoffMatrix,
        rng: &mut Pcg32,
    ) -> Vec<ModelKey> {
        match Self::role(learner.agent) {
            "main" => self.main.sample_opponents(learner, n, pool, payoff, rng),
            "main_exploiter" => {
                // latest model of agent 0 (current main), falling back to
                // the most recent frozen main model
                let main_latest = pool
                    .iter()
                    .rev()
                    .find(|k| k.agent == 0)
                    .copied()
                    .unwrap_or(learner);
                vec![main_latest; n]
            }
            _ => self.league.sample_opponents(learner, n, pool, payoff, rng),
        }
    }
    fn name(&self) -> &'static str {
        "agent_exploiter"
    }
}

/// Build a sampler by config name.
/// Every name [`make_game_mgr`] accepts.  `util::cli::USAGE` documents
/// this exact list; a test asserts the two never drift apart.
pub const GAME_MGR_NAMES: &[&str] = &[
    "selfplay",
    "uniform",
    "pfsp",
    "pfsp_var",
    "sp_pfsp",
    "elo_match",
    "agent_exploiter",
];

pub fn make_game_mgr(name: &str) -> anyhow::Result<Box<dyn GameMgr>> {
    Ok(match name {
        "selfplay" => Box::new(SelfPlay),
        "uniform" => Box::new(UniformRecent { k: 50 }),
        "pfsp" => Box::new(Pfsp { weighting: PfspWeight::Hard }),
        "pfsp_var" => Box::new(Pfsp { weighting: PfspWeight::Var }),
        "sp_pfsp" => Box::new(SpPfspMix::paper()),
        "elo_match" => Box::new(EloMatch { sigma: 200.0 }),
        "agent_exploiter" => Box::new(AgentExploiter::new()),
        other => anyhow::bail!("unknown game_mgr '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    fn k(v: u32) -> ModelKey {
        ModelKey::new(0, v)
    }

    /// The factory accepts exactly the names in [`GAME_MGR_NAMES`]: every
    /// listed name constructs, and the registered name() matches the
    /// factory key (so stats/snapshots stay round-trippable).
    #[test]
    fn factory_accepts_exactly_the_registered_names() {
        for name in GAME_MGR_NAMES {
            let mgr = make_game_mgr(name)
                .unwrap_or_else(|e| panic!("'{name}' must construct: {e}"));
            assert_eq!(&mgr.name(), name, "factory key != sampler name()");
        }
        for bad in ["", "pfsp2", "uniform ", "exploiter", "sp-pfsp"] {
            assert!(make_game_mgr(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn selfplay_returns_learner() {
        let mut g = SelfPlay;
        let mut rng = Pcg32::new(1, 1);
        let pool = vec![k(1), k(2)];
        let ops = g.sample_opponents(k(9), 3, &pool, &PayoffMatrix::new(), &mut rng);
        assert_eq!(ops, vec![k(9); 3]);
    }

    #[test]
    fn uniform_restricts_to_recent_k() {
        let mut g = UniformRecent { k: 3 };
        let mut rng = Pcg32::new(2, 1);
        let pool: Vec<ModelKey> = (0..10).map(k).collect();
        for _ in 0..200 {
            let ops = g.sample_opponents(k(10), 1, &pool, &PayoffMatrix::new(), &mut rng);
            assert!(ops[0].version >= 7, "sampled {:?}", ops[0]);
        }
    }

    #[test]
    fn pfsp_hard_prefers_hard_opponents() {
        let mut payoff = PayoffMatrix::new();
        // learner k(10) crushes k(1), loses to k(2)
        for _ in 0..30 {
            payoff.record(k(10), k(1), 1.0);
            payoff.record(k(10), k(2), 0.0);
        }
        let mut g = Pfsp { weighting: PfspWeight::Hard };
        let mut rng = Pcg32::new(3, 1);
        let pool = vec![k(1), k(2)];
        let mut hard = 0;
        for _ in 0..300 {
            if g.sample_opponents(k(10), 1, &pool, &payoff, &mut rng)[0] == k(2) {
                hard += 1;
            }
        }
        assert!(hard > 270, "hard opponent sampled only {hard}/300");
    }

    #[test]
    fn mix_ratio_is_respected() {
        let mut g = SpPfspMix::paper();
        let mut rng = Pcg32::new(4, 1);
        let pool = vec![k(1), k(2), k(3)];
        let payoff = PayoffMatrix::new();
        let mut sp = 0;
        let n = 2000;
        for _ in 0..n {
            if g.sample_opponents(k(10), 1, &pool, &payoff, &mut rng)[0] == k(10) {
                sp += 1;
            }
        }
        let frac = sp as f64 / n as f64;
        assert!((frac - 0.35).abs() < 0.05, "self-play fraction {frac}");
    }

    #[test]
    fn elo_match_prefers_close_elo() {
        let mut payoff = PayoffMatrix::new();
        payoff.add_model(k(1));
        payoff.add_model(k(2));
        payoff.add_model(k(10));
        // k(2) beats k(1) a lot: large Elo gap
        for _ in 0..60 {
            payoff.record(k(2), k(1), 1.0);
        }
        // learner plays k(2) evenly: learner Elo ≈ k(2)'s
        for _ in 0..30 {
            payoff.record(k(10), k(2), 1.0);
            payoff.record(k(10), k(2), 0.0);
        }
        let mut g = EloMatch { sigma: 100.0 };
        let mut rng = Pcg32::new(5, 1);
        let pool = vec![k(1), k(2)];
        let mut close = 0;
        for _ in 0..300 {
            if g.sample_opponents(k(10), 1, &pool, &payoff, &mut rng)[0] == k(2) {
                close += 1;
            }
        }
        assert!(close > 200, "close-Elo opponent sampled {close}/300");
    }

    #[test]
    fn exploiter_targets_main() {
        let mut g = AgentExploiter::new();
        let mut rng = Pcg32::new(6, 1);
        let pool = vec![
            ModelKey::new(0, 1),
            ModelKey::new(1, 1),
            ModelKey::new(0, 2),
        ];
        let payoff = PayoffMatrix::new();
        let ops = g.sample_opponents(ModelKey::new(1, 5), 2, &pool, &payoff, &mut rng);
        assert_eq!(ops, vec![ModelKey::new(0, 2); 2], "exploiter must hit latest main");
    }

    #[test]
    fn samplers_never_panic_on_any_pool() {
        forall(100, "gamemgr-total", |rng| {
            let pool: Vec<ModelKey> = (0..rng.below(8))
                .map(|i| ModelKey::new(rng.below(3), i))
                .collect();
            let payoff = PayoffMatrix::new();
            for name in ["selfplay", "uniform", "pfsp", "sp_pfsp", "elo_match",
                         "agent_exploiter"] {
                let mut g = make_game_mgr(name).unwrap();
                let n = 1 + rng.below(7) as usize;
                let ops = g.sample_opponents(
                    ModelKey::new(0, 99), n, &pool, &payoff, rng);
                crate::prop_assert!(ops.len() == n, "{name} wrong count");
            }
            Ok(())
        });
    }
}
