//! Experiment V1 — the §3.1 claim: naive independent self-play
//! circulates on Rock-Paper-Scissors while Fictitious Self-Play
//! (opponent sampling over the frozen pool) converges toward the Nash
//! equilibrium.
//!
//! Two identical league runs, differing ONLY in the GameMgr:
//!   - "selfplay": always play the current model (independent RL)
//!   - "uniform":  uniform mixture over all frozen models (FSP)
//!
//! For each, we log the exploitability of (a) the current policy and
//! (b) the pool-average policy over training.  Expected shape: the FSP
//! pool-average exploitability decays; the self-play current policy
//! stays exploitable (it chases cycles).
//!
//!     cargo run --release --example rps_league

use std::sync::Arc;
use std::time::Duration;
use tleague::config::RunConfig;
use tleague::envs::matrix::MatrixGame;
use tleague::eval::{rps_pool_exploitability, NnPolicy};
use tleague::model_pool::ModelPoolClient;
use tleague::orchestrator::Deployment;
use tleague::proto::ModelKey;
use tleague::runtime::Engine;

fn run_league(engine: Arc<Engine>, game_mgr: &str) -> anyhow::Result<Vec<(u64, f64, f64)>> {
    let mut cfg = RunConfig::default();
    cfg.env = "rps".into();
    cfg.game_mgr = game_mgr.into();
    cfg.actors_per_learner = 3;
    cfg.total_steps = 400;
    cfg.period_steps = 5; // many short best-response periods: FSP averaging needs a deep pool
    cfg.publish_every = 2;
    cfg.hp_overrides.insert("lr".into(), 3e-3);
    cfg.hp_overrides.insert("ent_coef".into(), 0.01);
    cfg.seed = 11;

    let game = MatrixGame::rps(0);
    let dep = Deployment::start(cfg, engine.clone())?;
    let pool_client = ModelPoolClient::connect(dep.pool_addrs());
    let mut curve = Vec::new();
    let mut seen_versions = 0usize;
    while !dep.learners_done() {
        std::thread::sleep(Duration::from_millis(300));
        let frozen = dep.league().pool();
        if frozen.len() >= seen_versions + 8 {
            seen_versions = frozen.len();
            // pool-average strategy (the FSP mixture)
            let mut strategies = Vec::new();
            for key in &frozen {
                if let Some(blob) = pool_client.get(*key)? {
                    let mut nn = NnPolicy::new(engine.clone(), "rps", blob.params, 5);
                    strategies.push(nn.distribution(&[1.0, 0.0, 0.0, 0.0])?);
                }
            }
            let pool_expl = rps_pool_exploitability(&game, &strategies);
            // current policy exploitability
            let cur_expl = match pool_client.get_latest(0)? {
                Some(blob) => {
                    let mut nn = NnPolicy::new(engine.clone(), "rps", blob.params, 5);
                    let s = nn.distribution(&[1.0, 0.0, 0.0, 0.0])?;
                    game.exploitability(&s)
                }
                None => f64::NAN,
            };
            let steps = dep.total_learner_steps();
            curve.push((steps, cur_expl, pool_expl));
            println!(
                "  [{game_mgr:8}] steps={steps:4} pool={:2} expl(current)={cur_expl:.3} expl(pool-avg)={pool_expl:.3}",
                frozen.len()
            );
        }
    }
    let mut dep = dep;
    dep.shutdown();
    Ok(curve)
}

fn main() -> anyhow::Result<()> {
    let engine = Arc::new(Engine::load("artifacts")?);
    println!("== V1: FSP vs naive self-play on RPS (paper 3.1) ==\n");
    println!("-- naive independent self-play --");
    let sp = run_league(engine.clone(), "selfplay")?;
    println!("\n-- fictitious self-play (uniform pool sampling) --");
    let fsp = run_league(engine.clone(), "uniform")?;

    println!("\n== summary (exploitability of pool-average strategy) ==");
    println!("{:>8} {:>12} {:>12}", "steps", "selfplay", "fsp");
    for i in 0..sp.len().max(fsp.len()) {
        let s = sp.get(i).map(|x| format!("{:.3}", x.2)).unwrap_or_default();
        let f = fsp.get(i).map(|x| format!("{:.3}", x.2)).unwrap_or_default();
        let steps = sp.get(i).or(fsp.get(i)).map(|x| x.0).unwrap_or(0);
        println!("{steps:>8} {s:>12} {f:>12}");
    }
    let last_sp = sp.last().map(|x| x.2).unwrap_or(f64::NAN);
    let last_fsp = fsp.last().map(|x| x.2).unwrap_or(f64::NAN);
    println!(
        "\nfinal pool-average exploitability: selfplay={last_sp:.3} fsp={last_fsp:.3}"
    );
    if last_fsp < last_sp {
        println!("=> FSP mixture is less exploitable, as the paper's 3.1 argues");
    }
    let _ = ModelKey::new(0, 0);
    Ok(())
}
