//! Multi-process deployment integration: real worker subprocesses (the
//! `tleague worker` subcommand) driven by an embedded controller.
//!
//! The league tests need `make artifacts` (workers run PJRT); they skip
//! otherwise.  The CLI/standalone-service tests run everywhere.

use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tleague::config::RunConfig;
use tleague::model_pool::ModelPoolClient;
use tleague::orchestrator::controller::Controller;
use tleague::orchestrator::Deployment;
use tleague::proto::{ModelKey, Msg};
use tleague::runtime::Engine;
use tleague::transport::ReqClient;

const BIN: &str = env!("CARGO_BIN_EXE_tleague");

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(dir)
}

fn spawn_worker(role: &str, ctrl_addr: &str, artifacts: &Path) -> Child {
    Command::new(BIN)
        .args(["worker", "--role", role, "--controller", ctrl_addr])
        .args(["--artifacts", artifacts.to_str().unwrap()])
        .spawn()
        .expect("spawn worker")
}

/// Kills any still-running children on drop so a failing assert never
/// leaks orphan processes into the test host.
struct Reap(Vec<Child>);

impl Drop for Reap {
    fn drop(&mut self) {
        for c in &mut self.0 {
            c.kill().ok();
            c.wait().ok();
        }
    }
}

impl Reap {
    /// Wait for every child to exit on its own (clean-stop path) and
    /// assert success.
    fn expect_clean_exit(&mut self, timeout: Duration) {
        let deadline = Instant::now() + timeout;
        for (i, c) in self.0.iter_mut().enumerate() {
            loop {
                match c.try_wait().expect("try_wait") {
                    Some(status) => {
                        assert!(status.success(), "worker {i} exited {status}");
                        break;
                    }
                    None if Instant::now() > deadline => {
                        panic!("worker {i} did not exit after stop")
                    }
                    None => std::thread::sleep(Duration::from_millis(50)),
                }
            }
        }
        self.0.clear();
    }
}

fn procs_cfg(total_steps: u64, actors_per_learner: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.env = "rps".into();
    cfg.mode = "procs".into();
    cfg.seed = 7;
    cfg.total_steps = total_steps;
    cfg.period_steps = 2;
    cfg.actors_per_learner = actors_per_learner;
    cfg.heartbeat_ms = 100;
    cfg.heartbeat_timeout_ms = 1_000;
    cfg
}

fn controller(cfg: RunConfig, engine: &Engine) -> Controller {
    Controller::start(
        cfg,
        engine.manifest.hp_layout.clone(),
        engine.manifest.default_hp(),
    )
    .unwrap()
}

/// A small rps league runs end-to-end with every role in its own OS
/// process: learner + 2 actors register, train, freeze models, drain.
#[test]
fn procs_league_end_to_end() {
    let Some(dir) = artifacts() else { return };
    let engine = Arc::new(Engine::load(&dir).unwrap());
    let mut ctrl = controller(procs_cfg(4, 2), &engine);
    let mut kids = Reap(vec![
        spawn_worker("learner", &ctrl.addr, &dir),
        spawn_worker("actor", &ctrl.addr, &dir),
        spawn_worker("actor", &ctrl.addr, &dir),
    ]);
    assert!(ctrl.wait(Duration::from_secs(180)), "learners never finished");
    let ds = ctrl.deploy_stats();
    assert_eq!(ds.learner_steps, 4);
    let ls = ctrl.league_stats();
    assert!(ls.episodes > 0, "no episodes reported");
    // seed + 2 period freezes
    assert!(ls.pool_size >= 3, "pool {}", ls.pool_size);
    // the telemetry plane merged the workers' heartbeat snapshots into
    // a league-wide view: actors reported env frames, the learner its
    // consumed frames, and the in-process pool replicas their reads
    let tele = ctrl.telemetry_report();
    let total = |role: &str, k: &str| {
        tele.roles
            .iter()
            .find(|r| r.role == role)
            .and_then(|r| r.totals.iter().find(|(n, _)| n == k))
            .map(|(_, v)| *v)
            .unwrap_or(0)
    };
    assert!(total("actor", "env_frames") > 0, "{tele:?}");
    assert!(total("learner", "consumed_frames") > 0, "{tele:?}");
    assert!(total("model-pool", "reads") > 0, "{tele:?}");
    ctrl.shutdown();
    kids.expect_clean_exit(Duration::from_secs(30));
}

/// Kill an actor worker mid-run: the controller must detect the lost
/// heartbeat, free the slot, hand it to a replacement worker, and the
/// run must still finish.
#[test]
fn killed_actor_worker_is_detected_and_slot_reassigned() {
    let Some(dir) = artifacts() else { return };
    let engine = Arc::new(Engine::load(&dir).unwrap());
    let mut ctrl = controller(procs_cfg(12, 1), &engine);
    let mut kids = Reap(vec![
        spawn_worker("learner", &ctrl.addr, &dir),
        spawn_worker("actor", &ctrl.addr, &dir),
    ]);

    // let the league make some progress first
    let deadline = Instant::now() + Duration::from_secs(120);
    while ctrl.deploy_stats().learner_steps < 2 {
        assert!(Instant::now() < deadline, "league never started");
        std::thread::sleep(Duration::from_millis(50));
    }

    // SIGKILL the actor: no goodbye, only silence
    kids.0[1].kill().unwrap();
    kids.0[1].wait().unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    while ctrl.deploy_stats().lost < 1 {
        assert!(Instant::now() < deadline, "lost heartbeat never detected");
        std::thread::sleep(Duration::from_millis(50));
    }

    // a replacement registers and inherits the freed slot
    kids.0.push(spawn_worker("actor", &ctrl.addr, &dir));
    let deadline = Instant::now() + Duration::from_secs(30);
    while ctrl.deploy_stats().reassigned < 1 {
        assert!(Instant::now() < deadline, "slot never reassigned");
        std::thread::sleep(Duration::from_millis(50));
    }

    assert!(ctrl.wait(Duration::from_secs(180)), "run did not recover");
    assert_eq!(ctrl.deploy_stats().learner_steps, 12);
    ctrl.shutdown();
    // kids.0[1] is the killed actor (already waited); remove it so the
    // clean-exit check covers the survivors only
    let killed = kids.0.remove(1);
    drop(killed);
    kids.expect_clean_exit(Duration::from_secs(30));
}

/// Elastic slot table end-to-end: a surplus actor worker parks in the
/// registration retry loop until the operator grows a slot, then is
/// admitted mid-run; draining the table back down stops exactly one
/// actor, which finishes its episode, deregisters, and exits 0 — the
/// run completes with every learner step and no lost episodes.
#[test]
fn grown_actor_slot_admits_late_worker_then_drains_cleanly() {
    let Some(dir) = artifacts() else { return };
    let engine = Arc::new(Engine::load(&dir).unwrap());
    let mut ctrl = controller(procs_cfg(16, 1), &engine);
    let mut kids = Reap(vec![
        spawn_worker("learner", &ctrl.addr, &dir),
        spawn_worker("actor", &ctrl.addr, &dir),
    ]);

    // let the league make real progress first
    let deadline = Instant::now() + Duration::from_secs(120);
    while ctrl.deploy_stats().learner_steps < 2 {
        assert!(Instant::now() < deadline, "league never started");
        std::thread::sleep(Duration::from_millis(50));
    }

    // a late joiner with no free slot parks in the retry loop; growing
    // the table admits it
    kids.0.push(spawn_worker("actor", &ctrl.addr, &dir));
    std::thread::sleep(Duration::from_millis(300));
    assert_eq!(ctrl.deploy_stats().workers, 2, "admitted without a slot");
    assert_eq!(ctrl.request_scale("actor", 1), 1);
    assert_eq!(ctrl.deploy_stats().actor_slots, 2);
    let deadline = Instant::now() + Duration::from_secs(30);
    while ctrl.deploy_stats().workers < 3 {
        assert!(Instant::now() < deadline, "late joiner never admitted");
        std::thread::sleep(Duration::from_millis(50));
    }

    // drain back down: the occupant of the drained slot acks stop,
    // finishes its episode, deregisters, and exits on its own
    let pre_episodes = ctrl.league_stats().episodes;
    assert_eq!(ctrl.request_scale("actor", -1), 1);
    assert_eq!(ctrl.deploy_stats().actor_slots, 1);
    let deadline = Instant::now() + Duration::from_secs(60);
    while ctrl.deploy_stats().workers > 2 {
        assert!(Instant::now() < deadline, "drained actor never left");
        std::thread::sleep(Duration::from_millis(50));
    }
    // the drained worker exited 0 (not killed, not crashed)
    let drained = kids
        .0
        .iter_mut()
        .position(|c| matches!(c.try_wait(), Ok(Some(_))))
        .expect("one worker exited");
    let status = kids.0.remove(drained).wait().unwrap();
    assert!(status.success(), "drained actor exited {status}");

    // the survivors finish the run; nothing was lost in the drain
    assert!(ctrl.wait(Duration::from_secs(180)), "run did not finish");
    assert_eq!(ctrl.deploy_stats().learner_steps, 16);
    assert!(
        ctrl.league_stats().episodes >= pre_episodes,
        "episodes lost across drain"
    );
    ctrl.shutdown();
    kids.expect_clean_exit(Duration::from_secs(30));
}

/// Same seed, same spec → thread mode and procs mode produce the same
/// pool: identical frozen league keys and identical ModelPool contents
/// (model count per agent).  Equivalence smoke for the two launch paths.
#[test]
fn thread_and_procs_modes_agree_on_pool_contents() {
    let Some(dir) = artifacts() else { return };
    let engine = Arc::new(Engine::load(&dir).unwrap());

    // thread mode
    let mut tcfg = procs_cfg(4, 2);
    tcfg.mode = "thread".into();
    let mut dep = Deployment::start(tcfg, engine.clone()).unwrap();
    assert!(dep.wait(Duration::from_secs(180)), "thread run stuck");
    let thread_pool: Vec<ModelKey> = dep.league().pool();
    let tclient = ModelPoolClient::connect(dep.pool_addrs());
    let (_, thread_models, _) = tclient.stats().unwrap();
    dep.shutdown();
    drop(dep);

    // procs mode, same seed/spec
    let mut ctrl = controller(procs_cfg(4, 2), &engine);
    let mut kids = Reap(vec![
        spawn_worker("learner", &ctrl.addr, &dir),
        spawn_worker("actor", &ctrl.addr, &dir),
        spawn_worker("actor", &ctrl.addr, &dir),
    ]);
    assert!(ctrl.wait(Duration::from_secs(180)), "procs run stuck");
    let procs_pool: Vec<ModelKey> = ctrl.league().pool();
    let pclient = ModelPoolClient::connect(ctrl.pool_addrs());
    let (_, procs_models, _) = pclient.stats().unwrap();
    ctrl.shutdown();
    kids.expect_clean_exit(Duration::from_secs(30));

    assert_eq!(thread_pool, procs_pool, "frozen league pools differ");
    assert_eq!(thread_models, procs_models, "ModelPool contents differ");
}

/// The one-command path: `tleague run --mode procs` embeds the
/// controller, spawns + supervises its own worker processes, and
/// drains everything at the end.
#[test]
fn run_subcommand_mode_procs_completes() {
    let Some(dir) = artifacts() else { return };
    let mut child = Command::new(BIN)
        .args(["run", "--mode", "procs", "--env", "rps"])
        .args(["--total-steps", "4", "--period-steps", "2", "--actors", "1"])
        .args(["--heartbeat-ms", "100", "--heartbeat-timeout-ms", "1000"])
        .args(["--artifacts", dir.to_str().unwrap()])
        .stdout(Stdio::piped())
        .spawn()
        .expect("run --mode procs");
    // the run prints a handful of lines, far below the pipe buffer, so
    // polling with a deadline (instead of output()) cannot deadlock and
    // a regression cannot hang the suite
    let deadline = Instant::now() + Duration::from_secs(240);
    let status = loop {
        if let Some(s) = child.try_wait().expect("try_wait") {
            break s;
        }
        if Instant::now() > deadline {
            child.kill().ok();
            child.wait().ok();
            panic!("run --mode procs timed out");
        }
        std::thread::sleep(Duration::from_millis(100));
    };
    let mut stdout = String::new();
    use std::io::Read;
    child
        .stdout
        .take()
        .unwrap()
        .read_to_string(&mut stdout)
        .unwrap();
    assert!(status.success(), "exit {status}\nstdout:\n{stdout}");
    assert!(stdout.contains("done:"), "no completion line:\n{stdout}");
}

// ---- CLI / standalone services (no artifacts needed) --------------------

/// The standalone model-pool must exit 0 on a wire Shutdown instead of
/// sleeping forever, and must honor the spill knobs' validation.
#[test]
fn standalone_model_pool_shuts_down_cleanly() {
    let mut child = Command::new(BIN)
        .args(["model-pool", "--bind", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .spawn()
        .unwrap();
    let mut line = String::new();
    std::io::BufReader::new(child.stdout.take().unwrap())
        .read_line(&mut line)
        .unwrap();
    let addr = line
        .trim()
        .rsplit(' ')
        .next()
        .unwrap_or_else(|| panic!("no addr in {line:?}"))
        .to_string();

    let c = ReqClient::connect(&addr);
    assert_eq!(c.request(&Msg::Ping).unwrap(), Msg::Pong);
    assert_eq!(c.request(&Msg::Shutdown).unwrap(), Msg::Ok);
    let deadline = Instant::now() + Duration::from_secs(10);
    let status = loop {
        if let Some(s) = child.try_wait().unwrap() {
            break s;
        }
        if Instant::now() > deadline {
            child.kill().ok();
            panic!("model-pool ignored Shutdown");
        }
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(status.success(), "exited {status}");
}

/// A spill budget with nowhere to spill is rejected at startup (parity
/// with the orchestrated replicas' RunConfig rule).
#[test]
fn standalone_model_pool_rejects_budget_without_spill_dir() {
    let out = Command::new(BIN)
        .args(["model-pool", "--bind", "127.0.0.1:0", "--mem-budget-mb", "64"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--spill-dir"), "unhelpful error: {err}");
}

/// Malformed numeric flags abort the process with an error naming the
/// flag and value — the old parser silently fell back to defaults.
#[test]
fn malformed_numeric_flags_abort() {
    for (args, flag, value) in [
        (vec!["run", "--total-steps", "10k"], "--total-steps", "10k"),
        (vec!["model-pool", "--mem-budget-mb", "64MB"], "--mem-budget-mb", "64MB"),
        (vec!["run", "--heartbeat-ms", "1s"], "--heartbeat-ms", "1s"),
    ] {
        let out = Command::new(BIN).args(&args).output().unwrap();
        assert!(!out.status.success(), "{args:?} must fail");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains(flag), "{args:?}: flag not named: {err}");
        assert!(err.contains(value), "{args:?}: value not shown: {err}");
    }
}
