//! ReplayMem: trajectory buffer + batch assembly (paper §3.2, §4.4).
//!
//! Two consumption modes matching the paper's rfps/cfps discussion:
//!  - `Blocking`: FIFO, every segment learned ~once — cfps ≈ rfps, best
//!    on-policyness (the "blocking queue" the paper mentions).
//!  - `Ratio { max_reuse }`: segments may be re-sampled up to max_reuse
//!    times while fresh data trickles in — cfps/rfps ≈ reuse factor.
//!
//! Batch assembly converts B equally-shaped segments into the flat
//! time-major buffers the train artifact expects.

use crate::proto::TrajSegment;
use crate::runtime::Tensor;
use crate::util::rng::Pcg32;
use std::collections::VecDeque;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ReplayMode {
    Blocking,
    Ratio { max_reuse: u32 },
}

impl ReplayMode {
    /// The one (strict) parser for the config/wire grammar: "blocking"
    /// or "ratio:<positive int>".  Used by `RunConfig::validate` and by
    /// procs-mode workers decoding a `RunSlice` off the wire, so a
    /// version-skewed controller fails loudly instead of silently
    /// training with a default reuse count.
    pub fn parse(s: &str) -> anyhow::Result<ReplayMode> {
        match s.strip_prefix("ratio:") {
            Some(n) => match n.parse::<u32>() {
                Ok(v) if v >= 1 => Ok(ReplayMode::Ratio { max_reuse: v }),
                _ => anyhow::bail!(
                    "replay_mode ratio must be a positive int, got '{s}'"
                ),
            },
            None if s == "blocking" => Ok(ReplayMode::Blocking),
            None => anyhow::bail!(
                "replay_mode must be 'blocking' or 'ratio:<n>', got '{s}'"
            ),
        }
    }
}

pub struct ReplayMem {
    mode: ReplayMode,
    cap: usize,
    segs: VecDeque<(TrajSegment, u32)>, // (segment, times consumed)
    rng: Pcg32,
    pub received: u64,
    pub consumed: u64,
}

impl ReplayMem {
    pub fn new(mode: ReplayMode, cap: usize, seed: u64) -> Self {
        ReplayMem {
            mode,
            cap: cap.max(1),
            segs: VecDeque::new(),
            rng: Pcg32::from_label(seed, "replay"),
            received: 0,
            consumed: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.segs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.segs.is_empty()
    }

    pub fn push(&mut self, seg: TrajSegment) {
        self.received += 1;
        if self.segs.len() >= self.cap {
            self.segs.pop_front(); // drop oldest under backpressure
        }
        self.segs.push_back((seg, 0));
    }

    /// Try to take `n` segments for a batch; None if not enough data.
    pub fn sample(&mut self, n: usize) -> Option<Vec<TrajSegment>> {
        match self.mode {
            ReplayMode::Blocking => {
                if self.segs.len() < n {
                    return None;
                }
                self.consumed += n as u64;
                Some(
                    (0..n)
                        .map(|_| self.segs.pop_front().unwrap().0)
                        .collect(),
                )
            }
            ReplayMode::Ratio { max_reuse } => {
                if self.segs.is_empty() {
                    return None;
                }
                let mut out = Vec::with_capacity(n);
                for _ in 0..n {
                    if self.segs.is_empty() {
                        return if out.is_empty() { None } else { Some(out) };
                    }
                    let i = self.rng.below(self.segs.len() as u32) as usize;
                    let (seg, used) = &mut self.segs[i];
                    out.push(seg.clone());
                    *used += 1;
                    if *used >= max_reuse {
                        self.segs.remove(i);
                    }
                }
                self.consumed += out.len() as u64;
                Some(out)
            }
        }
    }
}

/// Flat time-major training batch (artifact input order).
pub struct Batch {
    pub obs: Vec<f32>,           // (T+1) * B * n_agents * D
    pub actions: Vec<i32>,       // T * B * n_agents
    pub behavior_logp: Vec<f32>, // T * B * n_agents
    pub rewards: Vec<f32>,       // T * B
    pub discounts: Vec<f32>,     // T * B
    pub t: usize,
    pub b: usize,
    pub n_agents: usize,
    pub frames: u64,
}

impl Batch {
    pub fn tensors(&self) -> Vec<Tensor> {
        vec![
            Tensor::F32(self.obs.clone()),
            Tensor::I32(self.actions.clone()),
            Tensor::F32(self.behavior_logp.clone()),
            Tensor::F32(self.rewards.clone()),
            Tensor::F32(self.discounts.clone()),
        ]
    }
}

/// Interleave B segments (each time-major) into one time-major batch:
/// out[t][b] = seg_b[t].  All segments must agree on (t, n_agents) and
/// per-step sizes.
pub fn assemble(segs: &[TrajSegment], obs_dim: usize) -> anyhow::Result<Batch> {
    anyhow::ensure!(!segs.is_empty(), "empty batch");
    let t = segs[0].t as usize;
    let na = segs[0].n_agents as usize;
    let b = segs.len();
    for s in segs {
        anyhow::ensure!(
            s.t as usize == t && s.n_agents as usize == na,
            "heterogeneous segments in batch"
        );
        anyhow::ensure!(
            s.obs.len() == (t + 1) * na * obs_dim,
            "segment obs len {} != {}",
            s.obs.len(),
            (t + 1) * na * obs_dim
        );
    }
    let row = na * obs_dim;
    let mut obs = vec![0.0f32; (t + 1) * b * row];
    let mut actions = vec![0i32; t * b * na];
    let mut behavior_logp = vec![0.0f32; t * b * na];
    let mut rewards = vec![0.0f32; t * b];
    let mut discounts = vec![0.0f32; t * b];
    for (bi, s) in segs.iter().enumerate() {
        for ti in 0..=t {
            let dst = (ti * b + bi) * row;
            let src = ti * row;
            obs[dst..dst + row].copy_from_slice(&s.obs[src..src + row]);
        }
        for ti in 0..t {
            let dst = (ti * b + bi) * na;
            let src = ti * na;
            actions[dst..dst + na].copy_from_slice(&s.actions[src..src + na]);
            behavior_logp[dst..dst + na]
                .copy_from_slice(&s.behavior_logp[src..src + na]);
            rewards[ti * b + bi] = s.rewards[ti];
            discounts[ti * b + bi] = s.discounts[ti];
        }
    }
    Ok(Batch {
        obs,
        actions,
        behavior_logp,
        rewards,
        discounts,
        t,
        b,
        n_agents: na,
        frames: (t * b) as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::ModelKey;

    fn seg(t: usize, na: usize, d: usize, fill: f32) -> TrajSegment {
        TrajSegment {
            model_key: ModelKey::new(0, 1),
            t: t as u32,
            n_agents: na as u32,
            obs: (0..(t + 1) * na * d).map(|i| fill + i as f32).collect(),
            actions: (0..t * na).map(|i| i as i32).collect(),
            behavior_logp: vec![-1.0; t * na],
            rewards: (0..t).map(|i| fill * i as f32).collect(),
            discounts: vec![0.99; t],
            trace: None,
        }
    }

    #[test]
    fn blocking_is_fifo_exactly_once() {
        let mut r = ReplayMem::new(ReplayMode::Blocking, 100, 0);
        assert!(r.sample(1).is_none());
        r.push(seg(2, 1, 3, 1.0));
        r.push(seg(2, 1, 3, 2.0));
        assert!(r.sample(3).is_none(), "insufficient data blocks");
        let got = r.sample(2).unwrap();
        assert_eq!(got[0].rewards[1], 1.0);
        assert_eq!(got[1].rewards[1], 2.0);
        assert!(r.is_empty());
        assert_eq!(r.received, 2);
        assert_eq!(r.consumed, 2);
    }

    #[test]
    fn ratio_reuses_then_evicts() {
        let mut r = ReplayMem::new(ReplayMode::Ratio { max_reuse: 3 }, 100, 1);
        r.push(seg(2, 1, 3, 1.0));
        let mut total = 0;
        while r.sample(1).is_some() {
            total += 1;
            assert!(total <= 3, "reuse cap exceeded");
        }
        assert_eq!(total, 3);
        assert_eq!(r.consumed, 3);
    }

    #[test]
    fn capacity_drops_oldest() {
        let mut r = ReplayMem::new(ReplayMode::Blocking, 2, 2);
        r.push(seg(1, 1, 2, 1.0));
        r.push(seg(1, 1, 2, 2.0));
        r.push(seg(1, 1, 2, 3.0));
        assert_eq!(r.len(), 2);
        let got = r.sample(2).unwrap();
        assert_eq!(got[0].obs[0], 2.0, "oldest dropped");
    }

    #[test]
    fn assemble_interleaves_time_major() {
        let d = 3;
        let segs = vec![seg(2, 1, d, 100.0), seg(2, 1, d, 200.0)];
        let batch = assemble(&segs, d).unwrap();
        assert_eq!(batch.t, 2);
        assert_eq!(batch.b, 2);
        // obs[t=0][b=0] == seg0.obs[0..3], obs[t=0][b=1] == seg1.obs[0..3]
        assert_eq!(&batch.obs[0..3], &[100.0, 101.0, 102.0]);
        assert_eq!(&batch.obs[3..6], &[200.0, 201.0, 202.0]);
        // obs[t=1][b=0] == seg0.obs[3..6]
        assert_eq!(&batch.obs[6..9], &[103.0, 104.0, 105.0]);
        // rewards [t=1][b=1] = 200*1
        assert_eq!(batch.rewards[1 * 2 + 1], 200.0);
        assert_eq!(batch.frames, 4);
    }

    #[test]
    fn assemble_team_layout() {
        let d = 2;
        let segs = vec![seg(1, 2, d, 0.0)];
        let batch = assemble(&segs, d).unwrap();
        assert_eq!(batch.n_agents, 2);
        assert_eq!(batch.obs.len(), 2 * 1 * 2 * 2);
        assert_eq!(batch.actions.len(), 2);
    }

    #[test]
    fn assemble_rejects_mismatched() {
        let segs = vec![seg(2, 1, 3, 0.0), seg(3, 1, 3, 0.0)];
        assert!(assemble(&segs, 3).is_err());
        let segs = vec![seg(2, 1, 4, 0.0)];
        assert!(assemble(&segs, 3).is_err());
    }

    #[test]
    fn fuzz_assemble_roundtrip() {
        crate::util::proptest::forall(50, "assemble-roundtrip", |rng| {
            let t = 1 + rng.below(8) as usize;
            let na = 1 + rng.below(2) as usize;
            let d = 1 + rng.below(6) as usize;
            let b = 1 + rng.below(5) as usize;
            let segs: Vec<TrajSegment> =
                (0..b).map(|i| seg(t, na, d, i as f32 * 1000.0)).collect();
            let batch = assemble(&segs, d).map_err(|e| e.to_string())?;
            // spot-check: every segment's step-0 obs appears at [0][bi]
            for (bi, s) in segs.iter().enumerate() {
                let row = na * d;
                let dst = bi * row;
                crate::prop_assert!(
                    batch.obs[dst..dst + row] == s.obs[0..row],
                    "b={bi} t=0 mismatch"
                );
            }
            Ok(())
        });
    }
}
