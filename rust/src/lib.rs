//! # TLeague — Competitive Self-Play Distributed MARL (reproduction)
//!
//! Rust coordinator (L3) for the TLeague framework (Sun et al., 2020):
//! LeagueMgr / GameMgr / HyperMgr / ModelPool / Actor / Learner /
//! InfServer, plus the environments and orchestration substrate.  Neural
//! compute (L2 JAX model + L1 Pallas kernels) is AOT-compiled to HLO
//! text by `make artifacts` and executed through [`runtime::Engine`]
//! (PJRT); Python is never on the training path.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod actor;
pub mod checkpoint;
pub mod config;
pub mod envs;
pub mod eval;
pub mod inference;
pub mod league;
pub mod learner;
pub mod lint;
pub mod model_pool;
pub mod orchestrator;
pub mod proto;
pub mod runtime;
pub mod telemetry;
pub mod transport;
pub mod util;
