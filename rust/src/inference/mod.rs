//! InfServer: batched remote inference (paper §3.2).
//!
//! Actors delegate their neural-net forward passes here; the server
//! collects observations from many actors into one batch (size- or
//! timeout-triggered) and runs the `infer_<env>_b{B}` artifact — the
//! SEED-RL design point the paper adopts: batch-32 forward passes are
//! far cheaper per row than 32 batch-1 passes (ablation A2).
//!
//! Parameters are fetched from the ModelPool and cached: frozen models
//! forever, the in-training model with a short TTL so actors follow the
//! learner's updates.

use crate::model_pool::ModelPoolClient;
use crate::proto::{ModelKey, Msg};
use crate::runtime::{Engine, Tensor};
use crate::transport::RepServer;
use crate::util::metrics::Meter;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

struct Pending {
    key: ModelKey,
    obs: Vec<f32>,
    reply: mpsc::Sender<Msg>,
    enqueued: Instant,
}

#[derive(Default)]
struct Queue {
    items: Vec<Pending>,
}

pub struct InfServerConfig {
    pub env: String,
    /// slots per forward pass (manifest infer_b)
    pub batch: usize,
    /// max time the oldest request waits before a partial batch runs
    pub max_wait: Duration,
    /// TTL for the non-frozen (learning) model's cached params
    pub refresh: Duration,
}

pub struct InfServer {
    pub addr: String,
    stop: Arc<AtomicBool>,
    batcher: Option<std::thread::JoinHandle<()>>,
    _server: RepServer,
    /// rows served / batches run — exposes the batching efficiency
    pub rows_meter: Arc<Meter>,
    pub batch_meter: Arc<Meter>,
}

struct CacheEntry {
    params: Arc<Vec<f32>>,
    /// device-buffer cache id (bumped on every refetch)
    buf_id: u64,
    frozen: bool,
    fetched: Instant,
}

impl InfServer {
    pub fn start(
        bind: &str,
        cfg: InfServerConfig,
        engine: Arc<Engine>,
        pool_addrs: &[String],
    ) -> Result<InfServer> {
        let queue = Arc::new((Mutex::new(Queue::default()), Condvar::new()));
        let q2 = queue.clone();
        let server = RepServer::serve(bind, move |msg| match msg {
            Msg::InferReq { key, obs, rows } => {
                let (tx, rx) = mpsc::channel();
                {
                    let (lock, cv) = &*q2;
                    lock.lock().unwrap().items.push(Pending {
                        key,
                        obs,
                        reply: tx,
                        enqueued: Instant::now(),
                    });
                    cv.notify_one();
                }
                let _ = rows;
                rx.recv_timeout(Duration::from_secs(30))
                    .unwrap_or(Msg::Err("infserver timeout".into()))
            }
            Msg::Ping => Msg::Pong,
            other => Msg::Err(format!("infserver: unexpected {other:?}")),
        })?;

        let stop = Arc::new(AtomicBool::new(false));
        let rows_meter = Arc::new(Meter::new());
        let batch_meter = Arc::new(Meter::new());
        let pool = ModelPoolClient::connect(pool_addrs);
        let stop2 = stop.clone();
        let rm = rows_meter.clone();
        let bm = batch_meter.clone();
        let addr = server.addr.clone();
        let batcher = std::thread::Builder::new()
            .name("infserver-batcher".into())
            .spawn(move || {
                let mut cache: HashMap<ModelKey, CacheEntry> = HashMap::new();
                while !stop2.load(Ordering::Relaxed) {
                    let batch = {
                        let (lock, cv) = &*queue;
                        let mut q = lock.lock().unwrap();
                        while q.items.is_empty() && !stop2.load(Ordering::Relaxed)
                        {
                            let (g, _t) = cv
                                .wait_timeout(q, Duration::from_millis(20))
                                .unwrap();
                            q = g;
                        }
                        if q.items.is_empty() {
                            continue;
                        }
                        // run when full OR the oldest request is stale
                        let oldest = q.items[0].enqueued.elapsed();
                        if q.items.len() < cfg.batch && oldest < cfg.max_wait {
                            drop(q);
                            std::thread::sleep(Duration::from_micros(300));
                            continue;
                        }
                        // take up to `batch` items of the majority key
                        let key = q.items[0].key;
                        let mut taken = Vec::new();
                        let mut rest = Vec::new();
                        for item in q.items.drain(..) {
                            if item.key == key && taken.len() < cfg.batch {
                                taken.push(item);
                            } else {
                                rest.push(item);
                            }
                        }
                        q.items = rest;
                        taken
                    };
                    if batch.is_empty() {
                        continue;
                    }
                    let key = batch[0].key;
                    let params = Self::params_for(
                        &mut cache, &pool, &engine, key, cfg.refresh,
                    );
                    let reply_err = |items: &[Pending], e: &str| {
                        for it in items {
                            let _ = it.reply.send(Msg::Err(e.to_string()));
                        }
                    };
                    let Some((params, buf_id)) = params else {
                        reply_err(&batch, "model not found");
                        continue;
                    };
                    match Self::run_batch(&engine, &cfg, &params, buf_id, &batch) {
                        Ok(()) => {
                            rm.add(batch.len() as u64);
                            bm.add(1);
                        }
                        Err(e) => reply_err(&batch, &format!("{e}")),
                    }
                }
            })?;

        Ok(InfServer {
            addr,
            stop,
            batcher: Some(batcher),
            _server: server,
            rows_meter,
            batch_meter,
        })
    }

    fn params_for(
        cache: &mut HashMap<ModelKey, CacheEntry>,
        pool: &ModelPoolClient,
        engine: &Engine,
        key: ModelKey,
        ttl: Duration,
    ) -> Option<(Arc<Vec<f32>>, u64)> {
        if let Some(e) = cache.get(&key) {
            if e.frozen || e.fetched.elapsed() < ttl {
                return Some((e.params.clone(), e.buf_id));
            }
        }
        match pool.get(key) {
            Ok(Some(blob)) => {
                let params = Arc::new(blob.params);
                let buf_id = crate::runtime::new_cache_id();
                if let Some(old) = cache.insert(
                    key,
                    CacheEntry {
                        params: params.clone(),
                        buf_id,
                        frozen: blob.frozen,
                        fetched: Instant::now(),
                    },
                ) {
                    engine.evict_cached(old.buf_id);
                }
                Some((params, buf_id))
            }
            _ => cache.get(&key).map(|e| (e.params.clone(), e.buf_id)),
        }
    }

    fn run_batch(
        engine: &Engine,
        cfg: &InfServerConfig,
        params: &[f32],
        buf_id: u64,
        batch: &[Pending],
    ) -> Result<()> {
        let slot = batch[0].obs.len(); // rows-per-slot * D
        let mut obs = vec![0.0f32; cfg.batch * slot];
        for (i, p) in batch.iter().enumerate() {
            obs[i * slot..(i + 1) * slot].copy_from_slice(&p.obs);
        }
        let (logits, value) =
            engine.infer_cached(&cfg.env, cfg.batch, buf_id, params, &obs)?;
        let lslot = logits.len() / cfg.batch;
        let vslot = value.len() / cfg.batch;
        for (i, p) in batch.iter().enumerate() {
            let _ = p.reply.send(Msg::InferResp {
                logits: logits[i * lslot..(i + 1) * lslot].to_vec(),
                value: value[i * vslot..(i + 1) * vslot].to_vec(),
            });
        }
        Ok(())
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.batcher.take() {
            h.join().ok();
        }
    }
}

impl Drop for InfServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// used by tests and the actor's remote backend
pub fn infer_remote(
    client: &crate::transport::ReqClient,
    key: ModelKey,
    obs: &[f32],
    rows: u32,
) -> Result<(Vec<f32>, Vec<f32>)> {
    match client.request(&Msg::InferReq { key, obs: obs.to_vec(), rows })? {
        Msg::InferResp { logits, value } => Ok((logits, value)),
        other => anyhow::bail!("infer: unexpected reply {other:?}"),
    }
}

#[allow(unused_imports)]
use Tensor as _TensorUnused;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model_pool::ModelPoolServer;
    use crate::proto::ModelBlob;
    use crate::transport::ReqClient;
    use std::path::PathBuf;

    fn engine() -> Option<Arc<Engine>> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Arc::new(Engine::load(dir).unwrap()))
    }

    #[test]
    fn batched_inference_matches_local() {
        let Some(engine) = engine() else { return };
        let pool = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let pc = ModelPoolClient::connect(&[pool.addr.clone()]);
        let params = engine.init_params("rps").unwrap();
        let key = ModelKey::new(0, 1);
        pc.put(ModelBlob { key, params: params.clone(), hp: vec![], frozen: true })
            .unwrap();

        let m = engine.manifest.env("rps").unwrap().clone();
        let server = InfServer::start(
            "127.0.0.1:0",
            InfServerConfig {
                env: "rps".into(),
                batch: m.infer_b,
                max_wait: Duration::from_millis(2),
                refresh: Duration::from_millis(50),
            },
            engine.clone(),
            &[pool.addr.clone()],
        )
        .unwrap();

        let client = ReqClient::connect(&server.addr);
        let obs = vec![1.0f32, 0.0, 0.0, 0.0];
        let (logits, value) = infer_remote(&client, key, &obs, 1).unwrap();
        let (l_local, v_local) = engine.infer("rps", 1, &params, &obs).unwrap();
        assert_eq!(logits.len(), m.act_dim);
        for (a, b) in logits.iter().zip(&l_local) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        assert!((value[0] - v_local[0]).abs() < 1e-4);
    }

    #[test]
    fn many_concurrent_clients_get_batched() {
        let Some(engine) = engine() else { return };
        let pool = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let pc = ModelPoolClient::connect(&[pool.addr.clone()]);
        let params = engine.init_params("rps").unwrap();
        let key = ModelKey::new(0, 1);
        pc.put(ModelBlob { key, params, hp: vec![], frozen: true }).unwrap();
        let m = engine.manifest.env("rps").unwrap().clone();
        let server = InfServer::start(
            "127.0.0.1:0",
            InfServerConfig {
                env: "rps".into(),
                batch: m.infer_b,
                max_wait: Duration::from_millis(5),
                refresh: Duration::from_millis(50),
            },
            engine,
            &[pool.addr.clone()],
        )
        .unwrap();
        let addr = server.addr.clone();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let c = ReqClient::connect(&addr);
                    for _ in 0..12 {
                        let (l, _) =
                            infer_remote(&c, key, &[1.0, 0.0, 0.0, 0.0], 1)
                                .unwrap();
                        assert_eq!(l.len(), 3);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let rows = server.rows_meter.count();
        let batches = server.batch_meter.count();
        assert_eq!(rows, 96);
        assert!(batches < rows, "some batching must happen: {batches} batches");
    }

    #[test]
    fn unknown_model_reports_error() {
        let Some(engine) = engine() else { return };
        let pool = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let server = InfServer::start(
            "127.0.0.1:0",
            InfServerConfig {
                env: "rps".into(),
                batch: 4,
                max_wait: Duration::from_millis(1),
                refresh: Duration::from_millis(50),
            },
            engine,
            &[pool.addr.clone()],
        )
        .unwrap();
        let c = ReqClient::connect(&server.addr);
        let reply = c
            .request(&Msg::InferReq {
                key: ModelKey::new(9, 9),
                obs: vec![0.0; 4],
                rows: 1,
            })
            .unwrap();
        assert!(matches!(reply, Msg::Err(_)));
    }
}
