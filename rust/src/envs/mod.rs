//! Multi-agent environments (the Arena toolbox of the paper, §3.5).
//!
//! The trait mirrors the paper's OpenAI-gym-compatible multi-agent
//! contract (§3.2):
//!
//! ```text
//! l_obs = env.reset()                           # episode beginning
//! l_obs, l_rwd, done, info = env.step(l_act)    # in-episode stepping
//! ```
//!
//! Environments: `matrix` (RPS & friends — FSP validation), `pong2p`
//! (the paper's extension example), `pommerman` (NeurIPS-18 Team mode),
//! `doom_lite` (ViZDoom CIG-2016 track-1 stand-in), `synthetic`
//! (calibrated step cost for the Table-3 throughput harness).

pub mod doom_lite;
pub mod matrix;
pub mod pommerman;
pub mod pong2p;
pub mod synthetic;

use anyhow::{bail, Result};

/// Extra episode info (the paper's `info` dict).  `outcome` is set at
/// episode end: per-agent 1.0 win / 0.5 tie / 0.0 loss.
#[derive(Clone, Debug, Default)]
pub struct Info {
    pub outcome: Option<Vec<f32>>,
    /// per-agent FRAG (kills - suicides), doom_lite only
    pub frags: Option<Vec<i32>>,
}

pub struct Step {
    pub obs: Vec<Vec<f32>>,
    pub rewards: Vec<f32>,
    pub done: bool,
    pub info: Info,
}

pub trait MultiAgentEnv: Send {
    fn n_agents(&self) -> usize;
    fn obs_dim(&self) -> usize;
    fn act_dim(&self) -> usize;
    /// Hard cap on episode length (steps) — used for buffer sizing.
    fn max_steps(&self) -> usize;
    fn reset(&mut self) -> Vec<Vec<f32>>;
    fn step(&mut self, actions: &[usize]) -> Step;
}

/// Instantiate an env by manifest name.  `seed` drives all env
/// randomness (map layout, spawn order, ...).
pub fn make(name: &str, seed: u64) -> Result<Box<dyn MultiAgentEnv>> {
    Ok(match name {
        "rps" => Box::new(matrix::MatrixGame::rps(seed)),
        "pong2p" => Box::new(pong2p::Pong2p::new(seed)),
        "pommerman" => Box::new(pommerman::Pommerman::team(seed)),
        "pommerman_ffa" => Box::new(pommerman::Pommerman::ffa(seed)),
        "doom_lite" => Box::new(doom_lite::DoomLite::new(seed, 8)),
        "synthetic" => Box::new(synthetic::Synthetic::new(seed)),
        other => bail!("unknown env '{other}'"),
    })
}

/// The manifest env name an env maps to (pommerman_ffa shares the
/// pommerman artifacts).
pub fn manifest_name(env: &str) -> &str {
    match env {
        "pommerman_ffa" => "pommerman",
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_every_env() {
        for name in ["rps", "pong2p", "pommerman", "pommerman_ffa",
                     "doom_lite", "synthetic"] {
            let mut env = make(name, 7).unwrap();
            let obs = env.reset();
            assert_eq!(obs.len(), env.n_agents(), "{name}");
            for o in &obs {
                assert_eq!(o.len(), env.obs_dim(), "{name}");
                assert!(o.iter().all(|x| x.is_finite()), "{name}");
            }
        }
        assert!(make("nope", 0).is_err());
    }

    #[test]
    fn episodes_terminate_and_emit_outcome() {
        for name in ["rps", "pong2p", "pommerman", "doom_lite"] {
            let mut env = make(name, 3).unwrap();
            env.reset();
            let mut steps = 0;
            loop {
                let acts: Vec<usize> = (0..env.n_agents())
                    .map(|i| (steps + i) % env.act_dim())
                    .collect();
                let s = env.step(&acts);
                steps += 1;
                assert!(steps <= env.max_steps(), "{name} overran max_steps");
                assert_eq!(s.rewards.len(), env.n_agents(), "{name}");
                if s.done {
                    let out = s.info.outcome.expect("outcome at episode end");
                    assert_eq!(out.len(), env.n_agents(), "{name}");
                    for &o in &out {
                        assert!((0.0..=1.0).contains(&o), "{name}: {o}");
                    }
                    break;
                }
            }
        }
    }

    #[test]
    fn same_seed_same_rollout() {
        for name in ["pommerman", "doom_lite", "pong2p"] {
            let mut a = make(name, 42).unwrap();
            let mut b = make(name, 42).unwrap();
            assert_eq!(a.reset(), b.reset(), "{name}");
            for t in 0..50 {
                let acts: Vec<usize> =
                    (0..a.n_agents()).map(|i| (t * 3 + i) % a.act_dim()).collect();
                let sa = a.step(&acts);
                let sb = b.step(&acts);
                assert_eq!(sa.obs, sb.obs, "{name} diverged at {t}");
                assert_eq!(sa.rewards, sb.rewards, "{name}");
                if sa.done {
                    break;
                }
            }
        }
    }
}
