//! Wire protocol: every message that crosses a module boundary.
//!
//! The paper defines its inter-process API in native Python over ZeroMQ
//! (§3.3); here the equivalent contract is the `Msg` enum + `Wire` codec.
//! One enum covers all four services (LeagueMgr, ModelPool, Learner data
//! port, InfServer) so a single framed-socket layer serves everything.

use crate::util::codec::{Cursor, Enc, Wire};
use crate::util::metrics::HistDelta;
use anyhow::{bail, Result};

// lint: proto-registry — league-lint checks this const table against
// the `Msg::encode`/`Msg::decode` arms below: tag values must be
// unique, every const must appear on both sides, and neither side may
// use a literal tag byte.  Add new tags HERE, never inline.
//
// Tag ranges: 0-4 control, 10-14 league, 20-29 model pool, 30 data
// port, 31-39 deployment, 40-41 inference, 42-45 stats/trace, 46 shm
// lanes, 47-51 pool sharding.
pub const TAG_OK: u8 = 0;
pub const TAG_ERR: u8 = 1;
pub const TAG_PING: u8 = 2;
pub const TAG_PONG: u8 = 3;
pub const TAG_SHUTDOWN: u8 = 4;
pub const TAG_REQUEST_ACTOR_TASK: u8 = 10;
pub const TAG_TASK: u8 = 11;
pub const TAG_REPORT_OUTCOME: u8 = 12;
pub const TAG_REQUEST_LEARNER_TASK: u8 = 13;
pub const TAG_NOTIFY_PERIOD_DONE: u8 = 14;
pub const TAG_PUT_MODEL: u8 = 20;
pub const TAG_GET_MODEL: u8 = 21;
pub const TAG_GET_LATEST: u8 = 22;
/// Wire tag of `Msg::Model`.  The ModelPool frame cache prepends this
/// to a pre-encoded `ModelBlob` without re-encoding the params (see
/// `transport::Reply::Framed`).
pub const TAG_MODEL: u8 = 23;
pub const TAG_NOT_FOUND: u8 = 24;
pub const TAG_POOL_STATS: u8 = 25;
pub const TAG_POOL_STATS_REPLY: u8 = 26;
pub const TAG_GET_MODEL_IF_NEWER: u8 = 27;
/// Wire tag of `Msg::ModelRev` (same frame-cache trick, plus a rev head).
pub const TAG_MODEL_REV: u8 = 28;
pub const TAG_NOT_MODIFIED: u8 = 29;
pub const TAG_TRAJ: u8 = 30;
pub const TAG_REGISTER: u8 = 31;
pub const TAG_ASSIGN: u8 = 32;
pub const TAG_RETRY: u8 = 33;
pub const TAG_HEARTBEAT: u8 = 34;
pub const TAG_HEARTBEAT_ACK: u8 = 35;
pub const TAG_WORKER_READY: u8 = 36;
pub const TAG_DEREGISTER: u8 = 37;
pub const TAG_DEPLOY_STATS: u8 = 38;
pub const TAG_DEPLOY_STATS_REPLY: u8 = 39;
pub const TAG_INFER_REQ: u8 = 40;
pub const TAG_INFER_RESP: u8 = 41;
pub const TAG_STATS_QUERY: u8 = 42;
pub const TAG_STATS_REPLY: u8 = 43;
pub const TAG_TRACE_QUERY: u8 = 44;
pub const TAG_TRACE_REPLY: u8 = 45;
pub const TAG_SHM_HELLO: u8 = 46;
pub const TAG_GET_SHARD_MAP: u8 = 47;
pub const TAG_SHARD_MAP: u8 = 48;
pub const TAG_WRONG_SHARD: u8 = 49;
pub const TAG_POOL_SHARD_QUERY: u8 = 50;
pub const TAG_POOL_SHARD_REPLY: u8 = 51;

/// Identifies a model: which learning agent produced it + version number.
/// Version 0 is the seed (random init or imitation-learned) policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ModelKey {
    pub agent: u32,
    pub version: u32,
}

impl ModelKey {
    pub fn new(agent: u32, version: u32) -> Self {
        ModelKey { agent, version }
    }
}

impl std::fmt::Display for ModelKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "agt{:02}:{:04}", self.agent, self.version)
    }
}

/// Trace context propagated along the request path (actor → inf-server,
/// actor → learner data port, client → model-pool).  Carried as an
/// *optional* trailing field on the messages that cross those hops:
/// absent = untraced, so the hot path pays nothing when sampling is off.
/// `trace_id` names one sampled rollout row end-to-end; `span_id` names
/// the sender-side span the receiver should parent its own spans under.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct TraceCtx {
    pub trace_id: u64,
    pub span_id: u64,
}

/// One completed span in the flight recorder: a named stage of the
/// request path with wall-clock start (unix epoch micros) and duration.
/// `parent` = 0 means root.  `rows` is the batch-row payload the span
/// covered (0 when not applicable).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct SpanRec {
    pub trace_id: u64,
    pub span_id: u64,
    pub parent: u64,
    /// stage name: actor_gather | actor_infer | inf_queue_wait |
    /// inf_compute | inf_reply | learner_consume | pool_get
    pub name: String,
    /// role that recorded it: actor | inf-server | learner | model-pool
    pub role: String,
    /// span start, microseconds since the unix epoch
    pub ts_us: u64,
    pub dur_us: u64,
    pub rows: u32,
}

/// A task handed to an Actor at episode begin (§3.2): the learning
/// policy, the sampled opponent(s), and the hyper-parameters attached to
/// the learning model by the HyperMgr.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskSpec {
    pub task_id: u64,
    pub learner_key: ModelKey,
    /// Opponent model keys; empty for single-agent tasks, one for 1v1,
    /// seven for doom_lite 8-player FFA, etc.
    pub opponents: Vec<ModelKey>,
    pub hp: Vec<f32>,
}

/// Episode result reported back to the LeagueMgr at episode end.
#[derive(Clone, Debug, PartialEq)]
pub struct MatchOutcome {
    pub task_id: u64,
    pub learner_key: ModelKey,
    pub opponents: Vec<ModelKey>,
    /// 1.0 win / 0.5 tie / 0.0 loss from the learning agent's view.
    pub outcome: f32,
    pub episode_len: u32,
    pub frames: u64,
}

/// One trajectory segment (eq. 1 in the paper): L contiguous steps plus
/// the bootstrap observation.  All tensors are flattened f32/i32 vectors;
/// shapes are implied by the env manifest (T, obs_dim, n_agents).
#[derive(Clone, Debug, PartialEq)]
pub struct TrajSegment {
    pub model_key: ModelKey,
    /// number of time steps T (obs holds T+1 rows)
    pub t: u32,
    /// agents contributing observations per step (2 for team mode else 1)
    pub n_agents: u32,
    pub obs: Vec<f32>,          // (T+1) * n_agents * D
    pub actions: Vec<i32>,      // T * n_agents
    pub behavior_logp: Vec<f32>, // T * n_agents
    pub rewards: Vec<f32>,      // T
    pub discounts: Vec<f32>,    // T
    /// set when the pushing actor sampled this row for tracing
    pub trace: Option<TraceCtx>,
}

/// Versioned parameters + attached hyperparams stored in the ModelPool.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelBlob {
    pub key: ModelKey,
    pub params: Vec<f32>,
    pub hp: Vec<f32>,
    /// true once the LeagueMgr froze this version into the opponent pool
    pub frozen: bool,
}

/// Versioned placement map for the sharded ModelPool: which replica
/// slots exist, and how many copies of each agent's models the ring
/// keeps.  Placement hashes replica *slot indices* (not addresses), so
/// every process derives the identical ring from the same map and a
/// retired replica leaves a tombstone (`""`) instead of shifting the
/// survivors' slots — removal moves only the victim's keys (see
/// `model_pool::shard`).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct ShardMap {
    /// bumped on every membership change; clients replace any older map
    pub version: u64,
    /// replica address per slot; `""` marks a retired (dead) slot
    pub replicas: Vec<String>,
    /// copies kept per agent (effective R = min(replication, live slots))
    pub replication: u32,
}

impl ShardMap {
    /// Slot indices still serving (non-tombstone).
    pub fn live(&self) -> Vec<u32> {
        (0..self.replicas.len() as u32)
            .filter(|&i| !self.replicas[i as usize].is_empty())
            .collect()
    }
}

/// One replica's slice of the `stats` CLI pool section: shard ownership
/// plus the storage/read counters the operator tunes against.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct PoolShardInfo {
    pub replica: u32,
    pub addr: String,
    /// distinct agents with at least one model resident on this replica
    pub owned_agents: Vec<u32>,
    pub resident_bytes: u64,
    pub models: u32,
    pub spilled: u32,
    pub reads: u64,
    pub frame_hits: u64,
    pub map_version: u64,
}

/// One role instance's delta-based metric snapshot for a reporting
/// interval (the telemetry plane's wire unit, see DESIGN.md §Telemetry
/// plane).  `counters` are event deltas accumulated over `interval_ms`
/// of wall clock — NOT lifetime totals — so the receiver derives
/// current rates and running totals without ever seeing a misleading
/// lifetime average.  `gauges` are current rolling-window values
/// (means), meaningful only for the instant of the snapshot.
///
/// Procs mode piggybacks one of these on every `Msg::Heartbeat`;
/// thread mode feeds the identical struct into the same merge code.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct RoleStats {
    /// "learner" | "actor" | "inf-server" | "model-pool"
    pub role: String,
    /// role-local slot index (the merge key together with `role`)
    pub slot: u32,
    /// per-worker snapshot sequence number: deltas ride `ReqClient`,
    /// which retransmits on connection breaks, so the controller
    /// dedupes repeated deliveries of the same snapshot by (worker,
    /// seq).  0 = no dedupe (in-process ingests that never retransmit).
    pub seq: u64,
    /// wall clock the counter deltas were collected over
    pub interval_ms: u64,
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    /// latency histogram deltas: name → sparse (bucket, count-delta)
    /// pairs accumulated over `interval_ms` (same telescoping-delta
    /// contract as `counters`)
    pub hists: Vec<(String, HistDelta)>,
    /// recent spans drained from the role's flight recorder
    pub spans: Vec<SpanRec>,
}

/// One role's slice of the merged league view: per-interval rates
/// summed over live slots, cumulative totals over the whole run
/// (reaped slots keep their contribution), and gauge means.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct RoleReport {
    pub role: String,
    /// slots contributing live rates this window
    pub slots: u32,
    /// counter → events/s summed over live slots
    pub rates: Vec<(String, f64)>,
    /// counter → cumulative events since league start
    pub totals: Vec<(String, u64)>,
    /// gauge → mean over live slots
    pub gauges: Vec<(String, f64)>,
}

/// League-wide telemetry: the controller's merged per-role view, also
/// what thread mode reports (identical merge path).  Served as
/// `Msg::StatsReply` for the `stats` CLI subcommand.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct LeagueReport {
    pub roles: Vec<RoleReport>,
}

/// The slice of the RunConfig a role worker needs — handed out by the
/// controller with every assignment so worker processes never read the
/// spec file themselves (one source of truth per run).
#[derive(Clone, Debug, PartialEq)]
pub struct RunSlice {
    pub env: String,
    pub algo: String,
    pub replay_mode: String,
    pub seed: u64,
    pub gamma: f32,
    pub total_steps: u64,
    pub period_steps: u64,
    pub publish_every: u64,
    pub learners_per_agent: u32,
    pub envs_per_actor: u32,
    pub refresh_every: u32,
    pub infer_max_wait_us: u64,
    pub infer_refresh_ms: u64,
    /// cadence the worker must heartbeat at (the controller's timeout is
    /// a multiple of this)
    pub heartbeat_ms: u64,
    /// fraction of rollout rows the actor traces end-to-end (0 = off)
    pub trace_sample: f64,
    /// spans slower than this land in the flight recorder's slow log
    pub trace_slow_ms: u64,
    /// seed of the run-wide deterministic fault-injection plan
    pub fault_seed: u64,
    /// fault-injection spec (empty = injection disabled)
    pub fault_spec: String,
    /// shared-memory lane policy for colocated REQ/REP pairs:
    /// "auto" | "on" | "off"
    pub local_lanes: String,
    /// directory for lane ring files ("" = /dev/shm or the temp dir)
    pub shm_dir: String,
    /// event-loop threads per transport server (0 = auto)
    pub net_threads: u32,
    /// ModelPool copies kept per agent (consistent-hash ring, see
    /// `model_pool::shard`); workers build their bootstrap shard map
    /// from this + `pool_addrs`
    pub pool_replication: u32,
}

/// A role slot granted to a worker process: which role instance it is,
/// plus every address it needs to do the job.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerAssignment {
    pub worker_id: u64,
    /// "learner" | "actor" | "inf-server"
    pub role: String,
    /// role-local slot index (stable across worker restarts)
    pub slot: u32,
    /// learning agent this slot serves (learner/actor roles)
    pub agent: u32,
    /// actor: global learner index whose data port it feeds
    pub li: u32,
    pub league_addr: String,
    pub pool_addrs: Vec<String>,
    /// actor: trajectory PULL endpoint of its learner ("" otherwise)
    pub data_addr: String,
    /// actor: InfServer endpoint; "" = local PJRT inference
    pub inf_addr: String,
    pub run: RunSlice,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    // -- generic ---------------------------------------------------------
    Ok,
    Err(String),
    Ping,
    Pong,
    Shutdown,
    // -- LeagueMgr service ------------------------------------------------
    RequestActorTask { actor_id: String },
    Task(TaskSpec),
    ReportOutcome(MatchOutcome),
    RequestLearnerTask { learner_id: u32 },
    /// Learner finished its learning period; LeagueMgr freezes the model.
    NotifyPeriodDone { key: ModelKey },
    // -- ModelPool service --------------------------------------------------
    PutModel(ModelBlob),
    GetModel { key: ModelKey, trace: Option<TraceCtx> },
    GetLatest { agent: u32 },
    Model(ModelBlob),
    NotFound,
    /// Delta-aware read: "send the latest model for `agent` unless I
    /// already hold it".  `have_rev` is the replica-local put counter
    /// returned by the last `ModelRev` reply (0 = hold nothing), which
    /// catches same-version re-puts of the in-training model.
    GetModelIfNewer { agent: u32, have_version: u32, have_rev: u64, trace: Option<TraceCtx> },
    /// Reply to `GetModelIfNewer` when the pool has something newer.
    ModelRev { rev: u64, blob: ModelBlob },
    /// Reply to `GetModelIfNewer` when the requester is current: O(1)
    /// bytes instead of the params payload.
    NotModified,
    /// Observability probe: resident memory + spill state of a replica.
    PoolStats,
    PoolStatsReply {
        resident_bytes: u64,
        models: u32,
        spilled: u32,
        /// lifetime read requests served (GetModel/GetLatest/IfNewer)
        reads: u64,
        /// reads answered from the pre-encoded frame cache
        frame_hits: u64,
    },
    /// Ask any replica for the current shard map (client bootstrap /
    /// refresh after marking a replica dead — off the read hot path).
    GetShardMap,
    ShardMapMsg(ShardMap),
    /// Write/read landed on a non-owner replica that has no data for the
    /// key: the reply piggybacks the current map so the client corrects
    /// its cached placement without a coordinator round-trip.
    WrongShard(ShardMap),
    /// Controller probe: per-replica shard ownership + storage counters
    /// (the `stats` CLI pool section).
    PoolShardQuery,
    PoolShardReply(Vec<PoolShardInfo>),
    // -- Controller service (multi-process deployment) -----------------------
    /// A worker process announces itself.  `slot_hint` is the slot it is
    /// already running (controller-restart re-adopt) or last held
    /// (respawn after a crash); -1 = no preference.
    Register { role: String, slot_hint: i64 },
    Assign(WorkerAssignment),
    /// No assignable slot right now (e.g. an actor registering before
    /// its learner's data port is known) — try again in `backoff_ms`.
    Retry { backoff_ms: u32, reason: String },
    /// `stats` piggybacks the worker's telemetry snapshot (None when
    /// the role has produced nothing since the last beat).
    Heartbeat { worker_id: u64, steps: u64, done: bool, stats: Option<RoleStats> },
    /// `stop = true`: wind the role down and exit cleanly.
    HeartbeatAck { stop: bool },
    /// Endpoints the worker serves (learner: data ports in rank order;
    /// inf-server: its serving address).  Gates dependent assignments.
    WorkerReady { worker_id: u64, addrs: Vec<String> },
    /// Clean goodbye: frees the slot without waiting out a heartbeat
    /// timeout (and without counting as a loss).
    Deregister { worker_id: u64 },
    DeployStats,
    DeployStatsReply {
        workers: u32,
        lost: u32,
        reassigned: u32,
        learners_done: u32,
        learner_steps: u64,
        draining: bool,
    },
    /// Telemetry probe: ask the controller for the merged league view.
    StatsQuery,
    StatsReply(LeagueReport),
    /// Tracing probe: drain the merged flight recorder (recent spans +
    /// slow-request log) from the controller.
    TraceQuery,
    TraceReply(Vec<SpanRec>),
    // -- Learner data port ---------------------------------------------------
    Traj(TrajSegment),
    // -- InfServer -------------------------------------------------------
    InferReq { key: ModelKey, obs: Vec<f32>, rows: u32, trace: Option<TraceCtx> },
    InferResp { logits: Vec<f32>, value: Vec<f32> },
    // -- Transport core ---------------------------------------------------
    /// Shared-memory lane offer: `path` is the ring-pair base path the
    /// client created (`<base>.c2s` / `<base>.s2c`).  Answered by the
    /// transport core itself (Ok = lane attached, Err = stay on TCP) —
    /// handlers never see it.
    ShmHello { path: String },
}

impl Wire for ModelKey {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.put_u32(self.agent);
        buf.put_u32(self.version);
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        Ok(ModelKey { agent: cur.u32()?, version: cur.u32()? })
    }
}

impl Wire for TraceCtx {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.put_u64(self.trace_id);
        buf.put_u64(self.span_id);
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        Ok(TraceCtx { trace_id: cur.u64()?, span_id: cur.u64()? })
    }
}

/// Optional-TraceCtx presence byte (precedent: `Heartbeat.stats`).  Both
/// ends of a connection run the same binary, so the byte is always
/// written; "wire-compatible" means untraced traffic costs one zero
/// byte, not that old binaries can decode new frames.
fn put_trace(buf: &mut Vec<u8>, t: &Option<TraceCtx>) {
    match t {
        Some(c) => {
            buf.put_u8(1);
            c.encode(buf);
        }
        None => buf.put_u8(0),
    }
}

fn get_trace(cur: &mut Cursor) -> Result<Option<TraceCtx>> {
    Ok(match cur.u8()? {
        0 => None,
        _ => Some(TraceCtx::decode(cur)?),
    })
}

impl Wire for SpanRec {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.put_u64(self.trace_id);
        buf.put_u64(self.span_id);
        buf.put_u64(self.parent);
        buf.put_str(&self.name);
        buf.put_str(&self.role);
        buf.put_u64(self.ts_us);
        buf.put_u64(self.dur_us);
        buf.put_u32(self.rows);
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        Ok(SpanRec {
            trace_id: cur.u64()?,
            span_id: cur.u64()?,
            parent: cur.u64()?,
            name: cur.str()?,
            role: cur.str()?,
            ts_us: cur.u64()?,
            dur_us: cur.u64()?,
            rows: cur.u32()?,
        })
    }
}

fn put_spans(buf: &mut Vec<u8>, v: &[SpanRec]) {
    buf.put_u32(v.len() as u32);
    for s in v {
        s.encode(buf);
    }
}

fn get_spans(cur: &mut Cursor) -> Result<Vec<SpanRec>> {
    let n = cur.u32()? as usize;
    (0..n).map(|_| SpanRec::decode(cur)).collect()
}

fn put_keys(buf: &mut Vec<u8>, keys: &[ModelKey]) {
    buf.put_u32(keys.len() as u32);
    for k in keys {
        k.encode(buf);
    }
}

fn get_keys(cur: &mut Cursor) -> Result<Vec<ModelKey>> {
    let n = cur.u32()? as usize;
    (0..n).map(|_| ModelKey::decode(cur)).collect()
}

impl Wire for TaskSpec {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.put_u64(self.task_id);
        self.learner_key.encode(buf);
        put_keys(buf, &self.opponents);
        buf.put_f32s(&self.hp);
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        Ok(TaskSpec {
            task_id: cur.u64()?,
            learner_key: ModelKey::decode(cur)?,
            opponents: get_keys(cur)?,
            hp: cur.f32s()?,
        })
    }
}

impl Wire for MatchOutcome {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.put_u64(self.task_id);
        self.learner_key.encode(buf);
        put_keys(buf, &self.opponents);
        buf.put_f32(self.outcome);
        buf.put_u32(self.episode_len);
        buf.put_u64(self.frames);
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        Ok(MatchOutcome {
            task_id: cur.u64()?,
            learner_key: ModelKey::decode(cur)?,
            opponents: get_keys(cur)?,
            outcome: cur.f32()?,
            episode_len: cur.u32()?,
            frames: cur.u64()?,
        })
    }
}

impl Wire for TrajSegment {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.model_key.encode(buf);
        buf.put_u32(self.t);
        buf.put_u32(self.n_agents);
        buf.put_f32s(&self.obs);
        buf.put_i32s(&self.actions);
        buf.put_f32s(&self.behavior_logp);
        buf.put_f32s(&self.rewards);
        buf.put_f32s(&self.discounts);
        put_trace(buf, &self.trace);
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        Ok(TrajSegment {
            model_key: ModelKey::decode(cur)?,
            t: cur.u32()?,
            n_agents: cur.u32()?,
            obs: cur.f32s()?,
            actions: cur.i32s()?,
            behavior_logp: cur.f32s()?,
            rewards: cur.f32s()?,
            discounts: cur.f32s()?,
            trace: get_trace(cur)?,
        })
    }
}

impl Wire for ModelBlob {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.key.encode(buf);
        buf.put_f32s(&self.params);
        buf.put_f32s(&self.hp);
        buf.put_u8(self.frozen as u8);
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        Ok(ModelBlob {
            key: ModelKey::decode(cur)?,
            params: cur.f32s()?,
            hp: cur.f32s()?,
            frozen: cur.u8()? != 0,
        })
    }
}

fn put_strs(buf: &mut Vec<u8>, strs: &[String]) {
    buf.put_u32(strs.len() as u32);
    for s in strs {
        buf.put_str(s);
    }
}

fn get_strs(cur: &mut Cursor) -> Result<Vec<String>> {
    let n = cur.u32()? as usize;
    (0..n).map(|_| cur.str()).collect()
}

impl Wire for ShardMap {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.put_u64(self.version);
        put_strs(buf, &self.replicas);
        buf.put_u32(self.replication);
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        Ok(ShardMap {
            version: cur.u64()?,
            replicas: get_strs(cur)?,
            replication: cur.u32()?,
        })
    }
}

fn put_u32s(buf: &mut Vec<u8>, v: &[u32]) {
    buf.put_u32(v.len() as u32);
    for x in v {
        buf.put_u32(*x);
    }
}

fn get_u32s(cur: &mut Cursor) -> Result<Vec<u32>> {
    let n = cur.u32()? as usize;
    (0..n).map(|_| cur.u32()).collect()
}

impl Wire for PoolShardInfo {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.put_u32(self.replica);
        buf.put_str(&self.addr);
        put_u32s(buf, &self.owned_agents);
        buf.put_u64(self.resident_bytes);
        buf.put_u32(self.models);
        buf.put_u32(self.spilled);
        buf.put_u64(self.reads);
        buf.put_u64(self.frame_hits);
        buf.put_u64(self.map_version);
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        Ok(PoolShardInfo {
            replica: cur.u32()?,
            addr: cur.str()?,
            owned_agents: get_u32s(cur)?,
            resident_bytes: cur.u64()?,
            models: cur.u32()?,
            spilled: cur.u32()?,
            reads: cur.u64()?,
            frame_hits: cur.u64()?,
            map_version: cur.u64()?,
        })
    }
}

fn put_counters(buf: &mut Vec<u8>, v: &[(String, u64)]) {
    buf.put_u32(v.len() as u32);
    for (k, n) in v {
        buf.put_str(k);
        buf.put_u64(*n);
    }
}

fn get_counters(cur: &mut Cursor) -> Result<Vec<(String, u64)>> {
    let n = cur.u32()? as usize;
    (0..n).map(|_| Ok((cur.str()?, cur.u64()?))).collect()
}

fn put_gauges(buf: &mut Vec<u8>, v: &[(String, f64)]) {
    buf.put_u32(v.len() as u32);
    for (k, g) in v {
        buf.put_str(k);
        buf.put_f64(*g);
    }
}

fn get_gauges(cur: &mut Cursor) -> Result<Vec<(String, f64)>> {
    let n = cur.u32()? as usize;
    (0..n).map(|_| Ok((cur.str()?, cur.f64()?))).collect()
}

fn put_hists(buf: &mut Vec<u8>, v: &[(String, HistDelta)]) {
    buf.put_u32(v.len() as u32);
    for (k, d) in v {
        buf.put_str(k);
        buf.put_u32(d.len() as u32);
        for (idx, n) in d {
            buf.put_u8(*idx);
            buf.put_u64(*n);
        }
    }
}

fn get_hists(cur: &mut Cursor) -> Result<Vec<(String, HistDelta)>> {
    let n = cur.u32()? as usize;
    (0..n)
        .map(|_| {
            let k = cur.str()?;
            let m = cur.u32()? as usize;
            let d = (0..m)
                .map(|_| Ok((cur.u8()?, cur.u64()?)))
                .collect::<Result<HistDelta>>()?;
            Ok((k, d))
        })
        .collect()
}

impl Wire for RoleStats {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.put_str(&self.role);
        buf.put_u32(self.slot);
        buf.put_u64(self.seq);
        buf.put_u64(self.interval_ms);
        put_counters(buf, &self.counters);
        put_gauges(buf, &self.gauges);
        put_hists(buf, &self.hists);
        put_spans(buf, &self.spans);
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        Ok(RoleStats {
            role: cur.str()?,
            slot: cur.u32()?,
            seq: cur.u64()?,
            interval_ms: cur.u64()?,
            counters: get_counters(cur)?,
            gauges: get_gauges(cur)?,
            hists: get_hists(cur)?,
            spans: get_spans(cur)?,
        })
    }
}

impl Wire for RoleReport {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.put_str(&self.role);
        buf.put_u32(self.slots);
        put_gauges(buf, &self.rates);
        put_counters(buf, &self.totals);
        put_gauges(buf, &self.gauges);
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        Ok(RoleReport {
            role: cur.str()?,
            slots: cur.u32()?,
            rates: get_gauges(cur)?,
            totals: get_counters(cur)?,
            gauges: get_gauges(cur)?,
        })
    }
}

impl Wire for LeagueReport {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.put_u32(self.roles.len() as u32);
        for r in &self.roles {
            r.encode(buf);
        }
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        let n = cur.u32()? as usize;
        Ok(LeagueReport {
            roles: (0..n).map(|_| RoleReport::decode(cur)).collect::<Result<_>>()?,
        })
    }
}

impl Wire for RunSlice {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.put_str(&self.env);
        buf.put_str(&self.algo);
        buf.put_str(&self.replay_mode);
        buf.put_u64(self.seed);
        buf.put_f32(self.gamma);
        buf.put_u64(self.total_steps);
        buf.put_u64(self.period_steps);
        buf.put_u64(self.publish_every);
        buf.put_u32(self.learners_per_agent);
        buf.put_u32(self.envs_per_actor);
        buf.put_u32(self.refresh_every);
        buf.put_u64(self.infer_max_wait_us);
        buf.put_u64(self.infer_refresh_ms);
        buf.put_u64(self.heartbeat_ms);
        buf.put_f64(self.trace_sample);
        buf.put_u64(self.trace_slow_ms);
        buf.put_u64(self.fault_seed);
        buf.put_str(&self.fault_spec);
        buf.put_str(&self.local_lanes);
        buf.put_str(&self.shm_dir);
        buf.put_u32(self.net_threads);
        buf.put_u32(self.pool_replication);
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        Ok(RunSlice {
            env: cur.str()?,
            algo: cur.str()?,
            replay_mode: cur.str()?,
            seed: cur.u64()?,
            gamma: cur.f32()?,
            total_steps: cur.u64()?,
            period_steps: cur.u64()?,
            publish_every: cur.u64()?,
            learners_per_agent: cur.u32()?,
            envs_per_actor: cur.u32()?,
            refresh_every: cur.u32()?,
            infer_max_wait_us: cur.u64()?,
            infer_refresh_ms: cur.u64()?,
            heartbeat_ms: cur.u64()?,
            trace_sample: cur.f64()?,
            trace_slow_ms: cur.u64()?,
            fault_seed: cur.u64()?,
            fault_spec: cur.str()?,
            local_lanes: cur.str()?,
            shm_dir: cur.str()?,
            net_threads: cur.u32()?,
            pool_replication: cur.u32()?,
        })
    }
}

impl Wire for WorkerAssignment {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.put_u64(self.worker_id);
        buf.put_str(&self.role);
        buf.put_u32(self.slot);
        buf.put_u32(self.agent);
        buf.put_u32(self.li);
        buf.put_str(&self.league_addr);
        put_strs(buf, &self.pool_addrs);
        buf.put_str(&self.data_addr);
        buf.put_str(&self.inf_addr);
        self.run.encode(buf);
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        Ok(WorkerAssignment {
            worker_id: cur.u64()?,
            role: cur.str()?,
            slot: cur.u32()?,
            agent: cur.u32()?,
            li: cur.u32()?,
            league_addr: cur.str()?,
            pool_addrs: get_strs(cur)?,
            data_addr: cur.str()?,
            inf_addr: cur.str()?,
            run: RunSlice::decode(cur)?,
        })
    }
}

impl Wire for Msg {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Msg::Ok => buf.put_u8(TAG_OK),
            Msg::Err(s) => {
                buf.put_u8(TAG_ERR);
                buf.put_str(s);
            }
            Msg::Ping => buf.put_u8(TAG_PING),
            Msg::Pong => buf.put_u8(TAG_PONG),
            Msg::Shutdown => buf.put_u8(TAG_SHUTDOWN),
            Msg::RequestActorTask { actor_id } => {
                buf.put_u8(TAG_REQUEST_ACTOR_TASK);
                buf.put_str(actor_id);
            }
            Msg::Task(t) => {
                buf.put_u8(TAG_TASK);
                t.encode(buf);
            }
            Msg::ReportOutcome(o) => {
                buf.put_u8(TAG_REPORT_OUTCOME);
                o.encode(buf);
            }
            Msg::RequestLearnerTask { learner_id } => {
                buf.put_u8(TAG_REQUEST_LEARNER_TASK);
                buf.put_u32(*learner_id);
            }
            Msg::NotifyPeriodDone { key } => {
                buf.put_u8(TAG_NOTIFY_PERIOD_DONE);
                key.encode(buf);
            }
            Msg::PutModel(b) => {
                buf.put_u8(TAG_PUT_MODEL);
                b.encode(buf);
            }
            Msg::GetModel { key, trace } => {
                buf.put_u8(TAG_GET_MODEL);
                key.encode(buf);
                put_trace(buf, trace);
            }
            Msg::GetLatest { agent } => {
                buf.put_u8(TAG_GET_LATEST);
                buf.put_u32(*agent);
            }
            Msg::Model(b) => {
                buf.put_u8(TAG_MODEL);
                b.encode(buf);
            }
            Msg::NotFound => buf.put_u8(TAG_NOT_FOUND),
            Msg::GetModelIfNewer { agent, have_version, have_rev, trace } => {
                buf.put_u8(TAG_GET_MODEL_IF_NEWER);
                buf.put_u32(*agent);
                buf.put_u32(*have_version);
                buf.put_u64(*have_rev);
                put_trace(buf, trace);
            }
            Msg::ModelRev { rev, blob } => {
                buf.put_u8(TAG_MODEL_REV);
                buf.put_u64(*rev);
                blob.encode(buf);
            }
            Msg::NotModified => buf.put_u8(TAG_NOT_MODIFIED),
            Msg::PoolStats => buf.put_u8(TAG_POOL_STATS),
            Msg::PoolStatsReply { resident_bytes, models, spilled, reads, frame_hits } => {
                buf.put_u8(TAG_POOL_STATS_REPLY);
                buf.put_u64(*resident_bytes);
                buf.put_u32(*models);
                buf.put_u32(*spilled);
                buf.put_u64(*reads);
                buf.put_u64(*frame_hits);
            }
            Msg::GetShardMap => buf.put_u8(TAG_GET_SHARD_MAP),
            Msg::ShardMapMsg(m) => {
                buf.put_u8(TAG_SHARD_MAP);
                m.encode(buf);
            }
            Msg::WrongShard(m) => {
                buf.put_u8(TAG_WRONG_SHARD);
                m.encode(buf);
            }
            Msg::PoolShardQuery => buf.put_u8(TAG_POOL_SHARD_QUERY),
            Msg::PoolShardReply(infos) => {
                buf.put_u8(TAG_POOL_SHARD_REPLY);
                buf.put_u32(infos.len() as u32);
                for i in infos {
                    i.encode(buf);
                }
            }
            Msg::Register { role, slot_hint } => {
                buf.put_u8(TAG_REGISTER);
                buf.put_str(role);
                buf.put_u64(*slot_hint as u64);
            }
            Msg::Assign(a) => {
                buf.put_u8(TAG_ASSIGN);
                a.encode(buf);
            }
            Msg::Retry { backoff_ms, reason } => {
                buf.put_u8(TAG_RETRY);
                buf.put_u32(*backoff_ms);
                buf.put_str(reason);
            }
            Msg::Heartbeat { worker_id, steps, done, stats } => {
                buf.put_u8(TAG_HEARTBEAT);
                buf.put_u64(*worker_id);
                buf.put_u64(*steps);
                buf.put_u8(*done as u8);
                buf.put_u8(stats.is_some() as u8);
                if let Some(s) = stats {
                    s.encode(buf);
                }
            }
            Msg::HeartbeatAck { stop } => {
                buf.put_u8(TAG_HEARTBEAT_ACK);
                buf.put_u8(*stop as u8);
            }
            Msg::WorkerReady { worker_id, addrs } => {
                buf.put_u8(TAG_WORKER_READY);
                buf.put_u64(*worker_id);
                put_strs(buf, addrs);
            }
            Msg::Deregister { worker_id } => {
                buf.put_u8(TAG_DEREGISTER);
                buf.put_u64(*worker_id);
            }
            Msg::DeployStats => buf.put_u8(TAG_DEPLOY_STATS),
            Msg::DeployStatsReply {
                workers,
                lost,
                reassigned,
                learners_done,
                learner_steps,
                draining,
            } => {
                buf.put_u8(TAG_DEPLOY_STATS_REPLY);
                buf.put_u32(*workers);
                buf.put_u32(*lost);
                buf.put_u32(*reassigned);
                buf.put_u32(*learners_done);
                buf.put_u64(*learner_steps);
                buf.put_u8(*draining as u8);
            }
            Msg::Traj(t) => {
                buf.put_u8(TAG_TRAJ);
                t.encode(buf);
            }
            Msg::StatsQuery => buf.put_u8(TAG_STATS_QUERY),
            Msg::StatsReply(r) => {
                buf.put_u8(TAG_STATS_REPLY);
                r.encode(buf);
            }
            Msg::TraceQuery => buf.put_u8(TAG_TRACE_QUERY),
            Msg::TraceReply(spans) => {
                buf.put_u8(TAG_TRACE_REPLY);
                put_spans(buf, spans);
            }
            Msg::InferReq { key, obs, rows, trace } => {
                buf.put_u8(TAG_INFER_REQ);
                key.encode(buf);
                buf.put_f32s(obs);
                buf.put_u32(*rows);
                put_trace(buf, trace);
            }
            Msg::InferResp { logits, value } => {
                buf.put_u8(TAG_INFER_RESP);
                buf.put_f32s(logits);
                buf.put_f32s(value);
            }
            Msg::ShmHello { path } => {
                buf.put_u8(TAG_SHM_HELLO);
                buf.put_str(path);
            }
        }
    }

    fn decode(cur: &mut Cursor) -> Result<Self> {
        let tag = cur.u8()?;
        Ok(match tag {
            TAG_OK => Msg::Ok,
            TAG_ERR => Msg::Err(cur.str()?),
            TAG_PING => Msg::Ping,
            TAG_PONG => Msg::Pong,
            TAG_SHUTDOWN => Msg::Shutdown,
            TAG_REQUEST_ACTOR_TASK => Msg::RequestActorTask { actor_id: cur.str()? },
            TAG_TASK => Msg::Task(TaskSpec::decode(cur)?),
            TAG_REPORT_OUTCOME => Msg::ReportOutcome(MatchOutcome::decode(cur)?),
            TAG_REQUEST_LEARNER_TASK => Msg::RequestLearnerTask { learner_id: cur.u32()? },
            TAG_NOTIFY_PERIOD_DONE => Msg::NotifyPeriodDone { key: ModelKey::decode(cur)? },
            TAG_PUT_MODEL => Msg::PutModel(ModelBlob::decode(cur)?),
            TAG_GET_MODEL => Msg::GetModel { key: ModelKey::decode(cur)?, trace: get_trace(cur)? },
            TAG_GET_LATEST => Msg::GetLatest { agent: cur.u32()? },
            TAG_MODEL => Msg::Model(ModelBlob::decode(cur)?),
            TAG_NOT_FOUND => Msg::NotFound,
            TAG_GET_MODEL_IF_NEWER => Msg::GetModelIfNewer {
                agent: cur.u32()?,
                have_version: cur.u32()?,
                have_rev: cur.u64()?,
                trace: get_trace(cur)?,
            },
            TAG_MODEL_REV => {
                Msg::ModelRev { rev: cur.u64()?, blob: ModelBlob::decode(cur)? }
            }
            TAG_NOT_MODIFIED => Msg::NotModified,
            TAG_POOL_STATS => Msg::PoolStats,
            TAG_POOL_STATS_REPLY => Msg::PoolStatsReply {
                resident_bytes: cur.u64()?,
                models: cur.u32()?,
                spilled: cur.u32()?,
                reads: cur.u64()?,
                frame_hits: cur.u64()?,
            },
            TAG_GET_SHARD_MAP => Msg::GetShardMap,
            TAG_SHARD_MAP => Msg::ShardMapMsg(ShardMap::decode(cur)?),
            TAG_WRONG_SHARD => Msg::WrongShard(ShardMap::decode(cur)?),
            TAG_POOL_SHARD_QUERY => Msg::PoolShardQuery,
            TAG_POOL_SHARD_REPLY => {
                let n = cur.u32()? as usize;
                Msg::PoolShardReply(
                    (0..n).map(|_| PoolShardInfo::decode(cur)).collect::<Result<_>>()?,
                )
            }
            TAG_TRAJ => Msg::Traj(TrajSegment::decode(cur)?),
            TAG_REGISTER => Msg::Register { role: cur.str()?, slot_hint: cur.u64()? as i64 },
            TAG_ASSIGN => Msg::Assign(WorkerAssignment::decode(cur)?),
            TAG_RETRY => Msg::Retry { backoff_ms: cur.u32()?, reason: cur.str()? },
            TAG_HEARTBEAT => Msg::Heartbeat {
                worker_id: cur.u64()?,
                steps: cur.u64()?,
                done: cur.u8()? != 0,
                stats: match cur.u8()? {
                    0 => None,
                    _ => Some(RoleStats::decode(cur)?),
                },
            },
            TAG_HEARTBEAT_ACK => Msg::HeartbeatAck { stop: cur.u8()? != 0 },
            TAG_WORKER_READY => Msg::WorkerReady { worker_id: cur.u64()?, addrs: get_strs(cur)? },
            TAG_DEREGISTER => Msg::Deregister { worker_id: cur.u64()? },
            TAG_DEPLOY_STATS => Msg::DeployStats,
            TAG_DEPLOY_STATS_REPLY => Msg::DeployStatsReply {
                workers: cur.u32()?,
                lost: cur.u32()?,
                reassigned: cur.u32()?,
                learners_done: cur.u32()?,
                learner_steps: cur.u64()?,
                draining: cur.u8()? != 0,
            },
            TAG_STATS_QUERY => Msg::StatsQuery,
            TAG_STATS_REPLY => Msg::StatsReply(LeagueReport::decode(cur)?),
            TAG_TRACE_QUERY => Msg::TraceQuery,
            TAG_TRACE_REPLY => Msg::TraceReply(get_spans(cur)?),
            TAG_INFER_REQ => Msg::InferReq {
                key: ModelKey::decode(cur)?,
                obs: cur.f32s()?,
                rows: cur.u32()?,
                trace: get_trace(cur)?,
            },
            TAG_INFER_RESP => Msg::InferResp { logits: cur.f32s()?, value: cur.f32s()? },
            TAG_SHM_HELLO => Msg::ShmHello { path: cur.str()? },
            t => bail!("unknown msg tag {t}"),
        })
    }
}

#[doc(hidden)]
pub mod testkit {
    //! Deterministic sample constructors covering every `Msg` variant.
    //! Not test-gated: shared by the proto unit tests, the lint
    //! cross-check test (`rust/tests/lint_invariants.rs`), and the
    //! `lint` bench group.
    use super::*;
    use crate::util::rng::Pcg32;

    pub fn sample_traj(rng: &mut Pcg32) -> TrajSegment {
        let t = 1 + rng.below(8);
        let na = 1 + rng.below(2);
        let d = 1 + rng.below(16) as usize;
        let f = |rng: &mut Pcg32, n: usize| {
            (0..n).map(|_| rng.next_f32()).collect::<Vec<_>>()
        };
        TrajSegment {
            model_key: ModelKey::new(rng.below(4), rng.below(100)),
            t,
            n_agents: na,
            obs: f(rng, (t as usize + 1) * na as usize * d),
            actions: (0..t * na).map(|_| rng.below(6) as i32).collect(),
            behavior_logp: f(rng, (t * na) as usize),
            rewards: f(rng, t as usize),
            discounts: f(rng, t as usize),
            trace: match rng.below(2) {
                0 => None,
                _ => Some(TraceCtx {
                    trace_id: rng.next_u32() as u64,
                    span_id: rng.next_u32() as u64,
                }),
            },
        }
    }

    /// At least one instance of every `Msg` variant (optional fields
    /// covered both present and absent).
    pub fn sample_msgs() -> Vec<Msg> {
        let mut rng = Pcg32::new(3, 1);
        let traj = sample_traj(&mut rng);
        let blob = ModelBlob {
            key: ModelKey::new(1, 7),
            params: vec![1.0, -2.0],
            hp: vec![3e-4],
            frozen: true,
        };
        let msgs = vec![
            Msg::Ok,
            Msg::Err("boom".into()),
            Msg::Ping,
            Msg::Pong,
            Msg::Shutdown,
            Msg::RequestActorTask { actor_id: "a0".into() },
            Msg::Task(TaskSpec {
                task_id: 9,
                learner_key: ModelKey::new(0, 3),
                opponents: vec![ModelKey::new(0, 1), ModelKey::new(0, 2)],
                hp: vec![0.1, 0.2],
            }),
            Msg::ReportOutcome(MatchOutcome {
                task_id: 9,
                learner_key: ModelKey::new(0, 3),
                opponents: vec![ModelKey::new(0, 1)],
                outcome: 0.5,
                episode_len: 100,
                frames: 800,
            }),
            Msg::RequestLearnerTask { learner_id: 2 },
            Msg::NotifyPeriodDone { key: ModelKey::new(0, 4) },
            Msg::PutModel(blob.clone()),
            Msg::GetModel { key: ModelKey::new(1, 7), trace: None },
            Msg::GetModel {
                key: ModelKey::new(1, 7),
                trace: Some(TraceCtx { trace_id: 0xfeed, span_id: 2 }),
            },
            Msg::GetLatest { agent: 1 },
            Msg::Model(blob.clone()),
            Msg::NotFound,
            Msg::GetModelIfNewer { agent: 1, have_version: 7, have_rev: 3, trace: None },
            Msg::GetModelIfNewer {
                agent: 1,
                have_version: 7,
                have_rev: 3,
                trace: Some(TraceCtx { trace_id: 5, span_id: 6 }),
            },
            Msg::ModelRev { rev: 4, blob },
            Msg::NotModified,
            Msg::PoolStats,
            Msg::PoolStatsReply {
                resident_bytes: 1 << 30,
                models: 120,
                spilled: 40,
                reads: 9_001,
                frame_hits: 8_000,
            },
            Msg::GetShardMap,
            Msg::ShardMapMsg(ShardMap {
                version: 3,
                replicas: vec![
                    "127.0.0.1:9001".into(),
                    String::new(), // tombstone: retired slot 1
                    "127.0.0.1:9003".into(),
                ],
                replication: 2,
            }),
            Msg::WrongShard(ShardMap {
                version: 4,
                replicas: vec!["127.0.0.1:9001".into()],
                replication: 1,
            }),
            Msg::PoolShardQuery,
            Msg::PoolShardReply(vec![
                PoolShardInfo {
                    replica: 0,
                    addr: "127.0.0.1:9001".into(),
                    owned_agents: vec![0, 2],
                    resident_bytes: 1 << 20,
                    models: 12,
                    spilled: 3,
                    reads: 400,
                    frame_hits: 350,
                    map_version: 3,
                },
                PoolShardInfo::default(),
            ]),
            Msg::Register { role: "actor".into(), slot_hint: -1 },
            Msg::Register { role: "learner".into(), slot_hint: 3 },
            Msg::Assign(WorkerAssignment {
                worker_id: 12,
                role: "actor".into(),
                slot: 5,
                agent: 1,
                li: 2,
                league_addr: "127.0.0.1:9003".into(),
                pool_addrs: vec!["127.0.0.1:9001".into(), "127.0.0.1:9002".into()],
                data_addr: "127.0.0.1:41000".into(),
                inf_addr: String::new(),
                run: RunSlice {
                    env: "rps".into(),
                    algo: "ppo".into(),
                    replay_mode: "blocking".into(),
                    seed: 7,
                    gamma: 0.99,
                    total_steps: 100,
                    period_steps: 25,
                    publish_every: 4,
                    learners_per_agent: 2,
                    envs_per_actor: 4,
                    refresh_every: 1,
                    infer_max_wait_us: 2_000,
                    infer_refresh_ms: 50,
                    heartbeat_ms: 1_000,
                    trace_sample: 0.01,
                    trace_slow_ms: 50,
                    fault_seed: 99,
                    fault_spec: "drop:actor@0.25".into(),
                    local_lanes: "auto".into(),
                    shm_dir: "/dev/shm".into(),
                    net_threads: 2,
                    pool_replication: 2,
                },
            }),
            Msg::Retry { backoff_ms: 500, reason: "no free slot".into() },
            Msg::Heartbeat { worker_id: 12, steps: 42, done: false, stats: None },
            Msg::Heartbeat {
                worker_id: 12,
                steps: 42,
                done: true,
                stats: Some(RoleStats {
                    role: "actor".into(),
                    slot: 5,
                    seq: 3,
                    interval_ms: 1_000,
                    counters: vec![
                        ("env_frames".into(), 4_096),
                        ("episodes".into(), 7),
                    ],
                    gauges: vec![("staleness".into(), 0.5)],
                    hists: vec![
                        ("row_e2e_us".into(), vec![(10, 3), (12, 1), (63, 2)]),
                        ("queue_wait_us".into(), vec![(0, 1)]),
                    ],
                    spans: vec![SpanRec {
                        trace_id: 0xabcd,
                        span_id: 1,
                        parent: 0,
                        name: "actor_infer".into(),
                        role: "actor".into(),
                        ts_us: 1_700_000_000_000_000,
                        dur_us: 850,
                        rows: 4,
                    }],
                }),
            },
            Msg::HeartbeatAck { stop: true },
            Msg::WorkerReady {
                worker_id: 12,
                addrs: vec!["127.0.0.1:41000".into()],
            },
            Msg::Deregister { worker_id: 12 },
            Msg::DeployStats,
            Msg::DeployStatsReply {
                workers: 8,
                lost: 1,
                reassigned: 1,
                learners_done: 2,
                learner_steps: 640,
                draining: false,
            },
            Msg::StatsQuery,
            Msg::StatsReply(LeagueReport {
                roles: vec![
                    RoleReport {
                        role: "actor".into(),
                        slots: 8,
                        rates: vec![("env_frames".into(), 1234.5)],
                        totals: vec![("env_frames".into(), 99_000)],
                        gauges: vec![],
                    },
                    RoleReport {
                        role: "learner".into(),
                        slots: 1,
                        rates: vec![("consumed_frames".into(), 900.0)],
                        totals: vec![("consumed_frames".into(), 10_000)],
                        gauges: vec![("staleness".into(), 0.25)],
                    },
                ],
            }),
            Msg::Traj(traj),
            Msg::TraceQuery,
            Msg::TraceReply(vec![
                SpanRec {
                    trace_id: 7,
                    span_id: 8,
                    parent: 1,
                    name: "inf_queue_wait".into(),
                    role: "inf-server".into(),
                    ts_us: 123,
                    dur_us: 456,
                    rows: 32,
                },
                SpanRec::default(),
            ]),
            Msg::InferReq {
                key: ModelKey::new(0, 0),
                obs: vec![0.5; 8],
                rows: 1,
                trace: None,
            },
            Msg::InferReq {
                key: ModelKey::new(0, 0),
                obs: vec![0.5; 8],
                rows: 1,
                trace: Some(TraceCtx { trace_id: u64::MAX, span_id: 9 }),
            },
            Msg::InferResp { logits: vec![1.0, 2.0], value: vec![0.3] },
            Msg::ShmHello { path: "/dev/shm/tleague-lane-1-0".into() },
        ];
        msgs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_roundtrip_all_variants() {
        for m in testkit::sample_msgs() {
            let bytes = m.to_bytes();
            let back = Msg::from_bytes(&bytes).unwrap();
            assert_eq!(m, back);
        }
    }

    #[test]
    fn traj_roundtrip_fuzz() {
        crate::util::proptest::forall(200, "traj-roundtrip", |rng| {
            let t = testkit::sample_traj(rng);
            let back = TrajSegment::from_bytes(&t.to_bytes())
                .map_err(|e| e.to_string())?;
            crate::prop_assert_eq!(t, back);
            Ok(())
        });
    }

    /// Satellite: trace-context codec roundtrip, standalone and embedded
    /// as the optional trailing field of every message that carries it.
    #[test]
    fn trace_ctx_roundtrip_fuzz() {
        crate::util::proptest::forall(200, "trace-ctx-roundtrip", |rng| {
            let ctx = TraceCtx {
                trace_id: ((rng.next_u32() as u64) << 32) | rng.next_u32() as u64,
                span_id: ((rng.next_u32() as u64) << 32) | rng.next_u32() as u64,
            };
            let back = TraceCtx::from_bytes(&ctx.to_bytes()).map_err(|e| e.to_string())?;
            crate::prop_assert_eq!(ctx, back);
            let trace = match rng.below(2) {
                0 => None,
                _ => Some(ctx),
            };
            let req = Msg::InferReq {
                key: ModelKey::new(rng.below(4), rng.below(100)),
                obs: vec![0.25; 4],
                rows: 1,
                trace,
            };
            let back = Msg::from_bytes(&req.to_bytes()).map_err(|e| e.to_string())?;
            crate::prop_assert_eq!(req, back);
            Ok(())
        });
    }

    /// An untraced InferReq costs exactly one presence byte over the
    /// pre-trace wire format — the hot path stays compact.
    #[test]
    fn untraced_req_costs_one_byte() {
        let traced = Msg::InferReq {
            key: ModelKey::new(0, 0),
            obs: vec![0.5; 8],
            rows: 1,
            trace: Some(TraceCtx { trace_id: 1, span_id: 2 }),
        };
        let bare = Msg::InferReq {
            key: ModelKey::new(0, 0),
            obs: vec![0.5; 8],
            rows: 1,
            trace: None,
        };
        assert_eq!(bare.to_bytes().len() + 16, traced.to_bytes().len());
    }

    #[test]
    fn decode_rejects_unknown_tag() {
        assert!(Msg::from_bytes(&[99]).is_err());
    }

    #[test]
    fn decode_rejects_trailing() {
        let mut b = Msg::Ok.to_bytes();
        b.push(0);
        assert!(Msg::from_bytes(&b).is_err());
    }
}
