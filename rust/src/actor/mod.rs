//! Actor: produces trajectories (paper §3.2).
//!
//! Embeds the Env and the Agents.  At each episode beginning it
//! requests a task from the LeagueMgr (which learning policy, which
//! opponent(s)); at episode end it reports the outcome.  During the
//! loop, the learning agent's trajectory segments (length L = the
//! manifest's train_t, spanning episode boundaries IMPALA-style) are
//! pushed to the Learner, and policy parameters are pulled from the
//! ModelPool.  Forward passes run either on a local PJRT engine or are
//! delegated to a remote InfServer.

use crate::envs::{self, MultiAgentEnv};
use crate::inference::infer_remote;
use crate::league::LeagueClient;
use crate::model_pool::{LatestFetch, ModelPoolClient};
use crate::proto::{MatchOutcome, ModelKey, TaskSpec, TrajSegment};
use crate::runtime::Engine;
use crate::transport::{PushClient, ReqClient};
use crate::util::metrics::Meter;
use crate::util::rng::{log_softmax_at, Pcg32};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// How this actor evaluates policies.
pub enum PolicyBackend {
    Local(Arc<Engine>),
    Remote(ReqClient),
}

/// Which env slots the learning (meta-)agent controls and how the
/// opponents group.  E.g. Pommerman Team: learner = [0, 2] acting as
/// one meta-agent, one opponent controlling [1, 3].
#[derive(Clone, Debug)]
pub struct RoleLayout {
    pub learner_slots: Vec<usize>,
    pub opponent_groups: Vec<Vec<usize>>,
}

pub fn role_layout(env_name: &str, n_agents: usize) -> RoleLayout {
    match env_name {
        "pommerman" => RoleLayout {
            learner_slots: vec![0, 2],
            opponent_groups: vec![vec![1, 3]],
        },
        "pommerman_ffa" => RoleLayout {
            learner_slots: vec![0],
            opponent_groups: (1..4).map(|i| vec![i]).collect(),
        },
        _ => RoleLayout {
            learner_slots: vec![0],
            opponent_groups: (1..n_agents).map(|i| vec![i]).collect(),
        },
    }
}

pub struct ActorConfig {
    /// env factory name (envs::make)
    pub env: String,
    /// "<agent>/<name>" — the prefix routes LeagueMgr tasks
    pub actor_id: String,
    pub seed: u64,
    pub gamma: f32,
    /// pull fresh learning-model params every N episodes
    pub refresh_every: u32,
    /// trajectory segment length; 0 = read from the local engine's
    /// manifest (required explicitly for the Remote backend)
    pub train_t: usize,
}

impl Default for ActorConfig {
    fn default() -> Self {
        ActorConfig {
            env: "rps".into(),
            actor_id: "0/actor".into(),
            seed: 0,
            gamma: 0.99,
            refresh_every: 1,
            train_t: 0,
        }
    }
}

struct SegBuffer {
    obs: Vec<f32>,
    actions: Vec<i32>,
    logp: Vec<f32>,
    rewards: Vec<f32>,
    discounts: Vec<f32>,
    steps: usize,
}

impl SegBuffer {
    fn new() -> Self {
        SegBuffer {
            obs: Vec::new(),
            actions: Vec::new(),
            logp: Vec::new(),
            rewards: Vec::new(),
            discounts: Vec::new(),
            steps: 0,
        }
    }
    fn clear(&mut self) {
        self.obs.clear();
        self.actions.clear();
        self.logp.clear();
        self.rewards.clear();
        self.discounts.clear();
        self.steps = 0;
    }
}

pub struct Actor {
    pub cfg: ActorConfig,
    env: Box<dyn MultiAgentEnv>,
    layout: RoleLayout,
    backend: PolicyBackend,
    league: LeagueClient,
    pool: ModelPoolClient,
    push: PushClient,
    manifest_env: String,
    train_t: usize,
    obs_dim: usize,
    act_dim: usize,
    /// host params + device-buffer cache id (bumped on refresh)
    params: HashMap<ModelKey, (Arc<Vec<f32>>, u64)>,
    /// per-agent (version, rev) held from the last if-newer refresh, so
    /// steady-state refreshes transfer O(1) bytes (NotModified)
    latest_have: HashMap<u32, (u32, u64)>,
    task: Option<TaskSpec>,
    seg: SegBuffer,
    cur_obs: Vec<Vec<f32>>,
    episode_steps: u32,
    episodes_done: u32,
    rng: Pcg32,
    pub frames: Meter,
    pub episodes: Meter,
}

impl Actor {
    pub fn new(
        cfg: ActorConfig,
        backend: PolicyBackend,
        league_addr: &str,
        pool_addrs: &[String],
        learner_data_addr: &str,
    ) -> Result<Actor> {
        let env = envs::make(&cfg.env, cfg.seed)?;
        let layout = role_layout(&cfg.env, env.n_agents());
        let manifest_env = envs::manifest_name(&cfg.env).to_string();
        let (train_t, obs_dim, act_dim) = match &backend {
            PolicyBackend::Local(engine) => {
                let m = engine.manifest.env(&manifest_env)?;
                let t = if cfg.train_t > 0 { cfg.train_t } else { m.train_t };
                (t, m.obs_dim, m.act_dim)
            }
            PolicyBackend::Remote(_) => {
                anyhow::ensure!(
                    cfg.train_t > 0,
                    "ActorConfig.train_t must be set for the Remote backend"
                );
                (cfg.train_t, env.obs_dim(), env.act_dim())
            }
        };
        anyhow::ensure!(
            obs_dim == env.obs_dim() && act_dim == env.act_dim(),
            "env/manifest shape mismatch for {}: {}x{} vs {}x{}",
            cfg.env, obs_dim, act_dim, env.obs_dim(), env.act_dim()
        );
        let rng = Pcg32::from_label(cfg.seed, &cfg.actor_id);
        Ok(Actor {
            env,
            layout,
            backend,
            league: LeagueClient::connect(league_addr),
            pool: ModelPoolClient::connect(pool_addrs),
            push: PushClient::connect(learner_data_addr),
            manifest_env,
            train_t,
            obs_dim,
            act_dim,
            params: HashMap::new(),
            latest_have: HashMap::new(),
            task: None,
            seg: SegBuffer::new(),
            cur_obs: Vec::new(),
            episode_steps: 0,
            episodes_done: 0,
            rng,
            frames: Meter::new(),
            episodes: Meter::new(),
            cfg,
        })
    }

    /// Override the segment length (tests / throughput harness).
    pub fn set_train_t(&mut self, t: usize) {
        self.train_t = t;
    }

    /// Install fetched params under `key` (the key requests are pinned
    /// to), evicting the predecessor's device buffer and bounding the
    /// cache.
    fn install_params(&mut self, key: ModelKey, params: Vec<f32>) -> Arc<Vec<f32>> {
        let p = Arc::new(params);
        let id = crate::runtime::new_cache_id();
        if let Some((_, old_id)) = self.params.insert(key, (p.clone(), id)) {
            if let PolicyBackend::Local(engine) = &self.backend {
                engine.evict_cached(old_id);
            }
        }
        // bound the cache (frozen models accumulate over a long run)
        if self.params.len() > 64 {
            let drop_key = *self.params.keys().next().unwrap();
            if let Some((_, old_id)) = self.params.remove(&drop_key) {
                if let PolicyBackend::Local(engine) = &self.backend {
                    engine.evict_cached(old_id);
                }
            }
        }
        p
    }

    fn fetch_params(&mut self, key: ModelKey, force: bool) -> Result<Arc<Vec<f32>>> {
        if !force {
            if let Some((p, _)) = self.params.get(&key) {
                return Ok(p.clone());
            }
        }
        let blob = self
            .pool
            .get(key)?
            .or_else(|| self.pool.get_latest(key.agent).ok().flatten())
            .with_context(|| format!("model {key} not in pool"))?;
        Ok(self.install_params(key, blob.params))
    }

    /// Delta-aware learner refresh: echo the (version, rev) we hold so
    /// an unchanged in-training model costs a NotModified instead of a
    /// full params transfer.
    fn refresh_learner(&mut self, key: ModelKey) -> Result<()> {
        let (hv, hr) =
            self.latest_have.get(&key.agent).copied().unwrap_or((0, 0));
        match self.pool.get_latest_if_newer(key.agent, hv, hr) {
            Ok(LatestFetch::NotModified) if self.params.contains_key(&key) => {
                return Ok(());
            }
            Ok(LatestFetch::New { rev, blob }) => {
                self.latest_have.insert(key.agent, (blob.key.version, rev));
                self.install_params(key, blob.params);
                return Ok(());
            }
            // NotFound, transport error, or NotModified without a local
            // copy under this task's key: take the legacy full fetch
            _ => {}
        }
        self.fetch_params(key, true)?;
        Ok(())
    }

    fn begin_task(&mut self) -> Result<()> {
        let task = self.league.request_actor_task(&self.cfg.actor_id)?;
        let refresh = self.episodes_done % self.cfg.refresh_every.max(1) == 0;
        if refresh {
            self.refresh_learner(task.learner_key)?;
        } else {
            self.fetch_params(task.learner_key, false)?;
        }
        for &op in &task.opponents {
            self.fetch_params(op, false)?;
        }
        self.task = Some(task);
        Ok(())
    }

    /// Forward pass for `rows` observations under `key`'s policy.
    fn infer(&mut self, key: ModelKey, obs: &[f32], rows: u32) -> Result<Vec<f32>> {
        match &self.backend {
            PolicyBackend::Local(engine) => {
                let (params, id) =
                    self.params.get(&key).context("params not cached")?;
                let (logits, _value) =
                    engine.infer_cached(&self.manifest_env, 1, *id, params, obs)?;
                let _ = rows;
                Ok(logits)
            }
            PolicyBackend::Remote(client) => {
                let (logits, _value) = infer_remote(client, key, obs, rows)?;
                Ok(logits)
            }
        }
    }

    /// Sample actions for a group of slots sharing one policy; returns
    /// (actions per slot, logp per slot).
    fn act_group(
        &mut self,
        key: ModelKey,
        slots: &[usize],
    ) -> Result<(Vec<usize>, Vec<f32>)> {
        let mut obs = Vec::with_capacity(slots.len() * self.obs_dim);
        for &s in slots {
            obs.extend_from_slice(&self.cur_obs[s]);
        }
        let logits = self.infer(key, &obs, slots.len() as u32)?;
        let a = self.act_dim;
        let mut actions = Vec::with_capacity(slots.len());
        let mut logps = Vec::with_capacity(slots.len());
        for (i, _) in slots.iter().enumerate() {
            let row = &logits[i * a..(i + 1) * a];
            let act = self.rng.sample_logits(row);
            actions.push(act);
            logps.push(log_softmax_at(row, act));
        }
        Ok((actions, logps))
    }

    fn push_segment(&mut self) -> Result<()> {
        let task = self.task.as_ref().unwrap();
        let na = self.layout.learner_slots.len() as u32;
        // bootstrap obs = current learner-slot observations
        let mut obs = std::mem::take(&mut self.seg.obs);
        for &s in &self.layout.learner_slots {
            obs.extend_from_slice(&self.cur_obs[s]);
        }
        let seg = TrajSegment {
            model_key: task.learner_key,
            t: self.seg.steps as u32,
            n_agents: na,
            obs,
            actions: std::mem::take(&mut self.seg.actions),
            behavior_logp: std::mem::take(&mut self.seg.logp),
            rewards: std::mem::take(&mut self.seg.rewards),
            discounts: std::mem::take(&mut self.seg.discounts),
        };
        self.seg.clear();
        self.push.push(&crate::proto::Msg::Traj(seg))
    }

    /// Advance the env by one step (all agents act).  Returns true at
    /// episode end.
    pub fn step_once(&mut self) -> Result<bool> {
        if self.task.is_none() {
            self.begin_task()?;
            self.cur_obs = self.env.reset();
            self.episode_steps = 0;
        }
        let task = self.task.as_ref().unwrap().clone();
        let n = self.env.n_agents();
        let mut actions = vec![0usize; n];

        // learning meta-agent
        let (l_acts, l_logps) =
            self.act_group(task.learner_key, &self.layout.learner_slots.clone())?;
        for (i, &s) in self.layout.learner_slots.iter().enumerate() {
            actions[s] = l_acts[i];
        }
        // opponents
        for (gi, group) in self.layout.opponent_groups.clone().iter().enumerate() {
            let key = task.opponents.get(gi).copied().unwrap_or(task.learner_key);
            let (o_acts, _) = self.act_group(key, group)?;
            for (i, &s) in group.iter().enumerate() {
                actions[s] = o_acts[i];
            }
        }

        // record obs+action+logp for the learning agent BEFORE stepping
        for &s in &self.layout.learner_slots {
            self.seg.obs.extend_from_slice(&self.cur_obs[s]);
        }
        for (i, _) in self.layout.learner_slots.iter().enumerate() {
            self.seg.actions.push(l_acts[i] as i32);
            self.seg.logp.push(l_logps[i]);
        }

        let step = self.env.step(&actions);
        self.episode_steps += 1;
        self.frames.add(1);

        // team reward = mean over learner slots
        let r: f32 = self
            .layout
            .learner_slots
            .iter()
            .map(|&s| step.rewards[s])
            .sum::<f32>()
            / self.layout.learner_slots.len() as f32;
        self.seg.rewards.push(r);
        self.seg.discounts.push(if step.done {
            0.0
        } else {
            self.cfg.gamma
        });
        self.seg.steps += 1;
        self.cur_obs = step.obs;

        if self.seg.steps >= self.train_t {
            self.push_segment()?;
        }

        if step.done {
            let outcome = step
                .info
                .outcome
                .as_ref()
                .map(|o| {
                    self.layout
                        .learner_slots
                        .iter()
                        .map(|&s| o[s])
                        .sum::<f32>()
                        / self.layout.learner_slots.len() as f32
                })
                .unwrap_or(0.5);
            self.league.report_outcome(MatchOutcome {
                task_id: task.task_id,
                learner_key: task.learner_key,
                opponents: task.opponents.clone(),
                outcome,
                episode_len: self.episode_steps,
                frames: self.episode_steps as u64,
            })?;
            self.episodes.add(1);
            self.episodes_done += 1;
            self.task = None; // next step_once() starts a fresh task
            return Ok(true);
        }
        Ok(false)
    }

    /// Run until `stop` or `max_frames` env steps.
    pub fn run(&mut self, max_frames: u64, stop: &AtomicBool) -> Result<u64> {
        let start = self.frames.count();
        while self.frames.count() - start < max_frames
            && !stop.load(Ordering::Relaxed)
        {
            self.step_once()?;
        }
        Ok(self.frames.count() - start)
    }
}
