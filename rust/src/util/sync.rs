//! Concurrency-correctness primitives: poison recovery + lock-order
//! checking.
//!
//! `lock_recover` replaces panic-on-poison `.lock().unwrap()` on server
//! request paths: a worker thread that panicked while holding a mutex
//! poisons it, and without recovery every subsequent request into that
//! mutex panics too, wedging the whole server.  The data under our
//! mutexes is always left consistent at panic sites (inserts and reads
//! are atomic at the Store level), so recovery is `into_inner` plus a
//! once-logged process-wide counter.
//!
//! `OrderedMutex` is the runtime half of the league-lint concurrency
//! harness: in debug builds every acquisition records a held-before
//! edge between lock *classes* (the `&'static str` name passed to
//! `new`, not the instance) into a process-global acquisition graph,
//! and an acquisition that would close a cycle — a lock-order
//! inversion, i.e. a potential deadlock — panics with both orders
//! spelled out, even if the schedule that would actually deadlock was
//! never hit.  Release builds compile down to a plain `Mutex` +
//! `lock_recover` with zero tracking.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, Once, WaitTimeoutResult};
use std::time::Duration;

static POISON_RECOVERIES: AtomicU64 = AtomicU64::new(0);
static POISON_LOG: Once = Once::new();

/// Lock `m`, recovering from poisoning instead of panicking.  The first
/// recovery in the process logs to stderr; every recovery bumps the
/// [`poison_recoveries`] counter so telemetry can surface it.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            note_poison();
            poisoned.into_inner()
        }
    }
}

fn note_poison() {
    POISON_RECOVERIES.fetch_add(1, Ordering::Relaxed);
    POISON_LOG.call_once(|| {
        eprintln!(
            "warn: recovered a poisoned lock (a thread panicked while holding it); \
             further recoveries are counted silently"
        );
    });
}

/// Process-wide count of poisoned-lock recoveries (0 in a healthy run).
pub fn poison_recoveries() -> u64 {
    POISON_RECOVERIES.load(Ordering::Relaxed)
}

#[cfg(debug_assertions)]
mod order {
    //! The global lock-acquisition graph.  Nodes are lock classes; a
    //! directed edge a→b is recorded the first time some thread
    //! acquires b while holding a.  Acquiring `b` while holding `a`
    //! when a path b→…→a already exists would make the order cyclic,
    //! so it panics before blocking on the inner mutex (reporting the
    //! inversion even on schedules that would not deadlock today).

    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};

    struct Graph {
        names: Vec<&'static str>,
        ids: HashMap<&'static str, usize>,
        /// edges[a] = classes observed acquired while a was held.
        edges: Vec<Vec<usize>>,
    }

    impl Graph {
        /// Is `to` reachable from `from` over recorded edges?
        fn reaches(&self, from: usize, to: usize) -> bool {
            let mut seen = vec![false; self.names.len()];
            let mut stack = vec![from];
            while let Some(n) = stack.pop() {
                if n == to {
                    return true;
                }
                if seen[n] {
                    continue;
                }
                seen[n] = true;
                stack.extend(self.edges[n].iter().copied());
            }
            false
        }
    }

    fn graph() -> &'static Mutex<Graph> {
        static G: OnceLock<Mutex<Graph>> = OnceLock::new();
        G.get_or_init(|| {
            Mutex::new(Graph { names: Vec::new(), ids: HashMap::new(), edges: Vec::new() })
        })
    }

    thread_local! {
        /// Classes held by this thread, in acquisition order.
        static HELD: RefCell<Vec<usize>> = const { RefCell::new(Vec::new()) };
    }

    pub fn class_id(name: &'static str) -> usize {
        let mut g = super::lock_recover(graph());
        if let Some(&id) = g.ids.get(name) {
            return id;
        }
        let id = g.names.len();
        g.names.push(name);
        g.ids.insert(name, id);
        g.edges.push(Vec::new());
        id
    }

    /// Record held→class edges; panic if one would create a cycle.
    /// Called BEFORE blocking on the inner mutex so the inversion is
    /// reported instead of deadlocking.
    pub fn on_acquire(class: usize) {
        let held: Vec<usize> = HELD.with(|h| h.borrow().clone());
        if !held.is_empty() {
            let mut g = super::lock_recover(graph());
            for &hc in &held {
                if hc == class || g.edges[hc].contains(&class) {
                    continue;
                }
                if g.reaches(class, hc) {
                    let (a, b) = (g.names[hc], g.names[class]);
                    drop(g);
                    panic!(
                        "lock-order inversion: acquiring '{b}' while holding '{a}', \
                         but the recorded global order already requires '{b}' before '{a}'"
                    );
                }
                g.edges[hc].push(class);
            }
        }
        HELD.with(|h| h.borrow_mut().push(class));
    }

    pub fn on_release(class: usize) {
        HELD.with(|h| {
            let mut v = h.borrow_mut();
            if let Some(pos) = v.iter().rposition(|&c| c == class) {
                v.remove(pos);
            }
        });
    }
}

/// A mutex with (debug-only) global lock-order checking and built-in
/// poison recovery.  `name` identifies the lock *class* — every
/// instance created with the same name shares one node in the
/// acquisition graph, so per-slot or per-shard instances don't blow the
/// graph up.
pub struct OrderedMutex<T> {
    name: &'static str,
    #[cfg(debug_assertions)]
    class: usize,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    pub fn new(name: &'static str, value: T) -> OrderedMutex<T> {
        OrderedMutex {
            name,
            #[cfg(debug_assertions)]
            class: order::class_id(name),
            inner: Mutex::new(value),
        }
    }

    /// Acquire, recovering from poisoning.  In debug builds, panics if
    /// this acquisition inverts the recorded global lock order.
    pub fn lock(&self) -> OrderedGuard<'_, T> {
        #[cfg(debug_assertions)]
        order::on_acquire(self.class);
        OrderedGuard {
            guard: Some(lock_recover(&self.inner)),
            #[cfg(debug_assertions)]
            class: self.class,
        }
    }

    /// `Condvar::wait_timeout` against this mutex.  The wait re-acquires
    /// the same class it released, so the held-set bookkeeping carries
    /// through unchanged.
    pub fn wait_timeout<'a>(
        &self,
        cv: &Condvar,
        mut g: OrderedGuard<'a, T>,
        dur: Duration,
    ) -> (OrderedGuard<'a, T>, WaitTimeoutResult) {
        let inner = g.guard.take().expect("guard already consumed");
        let (inner, res) = match cv.wait_timeout(inner, dur) {
            Ok(pair) => pair,
            Err(poisoned) => {
                note_poison();
                poisoned.into_inner()
            }
        };
        g.guard = Some(inner);
        (g, res)
    }

    pub fn name(&self) -> &'static str {
        self.name
    }
}

impl<T: fmt::Debug> fmt::Debug for OrderedMutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "OrderedMutex({})", self.name)
    }
}

/// Guard returned by [`OrderedMutex::lock`]; releases the class from the
/// thread's held set on drop.
pub struct OrderedGuard<'a, T> {
    /// `Option` only so `wait_timeout` can hand the inner guard to the
    /// condvar and put it back; always `Some` outside that window.
    guard: Option<MutexGuard<'a, T>>,
    #[cfg(debug_assertions)]
    class: usize,
}

impl<T> Deref for OrderedGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.guard.as_ref().expect("guard consumed")
    }
}

impl<T> DerefMut for OrderedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.guard.as_mut().expect("guard consumed")
    }
}

impl<T> Drop for OrderedGuard<'_, T> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        order::on_release(self.class);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recover_recovers_poison() {
        let m = Arc::new(Mutex::new(5u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock_recover(&m), 5);
        assert!(poison_recoveries() >= 1);
    }

    #[test]
    fn ordered_mutex_basic() {
        let m = OrderedMutex::new("sync-test-basic", 1u32);
        *m.lock() += 1;
        assert_eq!(*m.lock(), 2);
        assert_eq!(m.name(), "sync-test-basic");
    }

    #[test]
    fn consistent_order_is_fine() {
        let a = OrderedMutex::new("sync-test-co-a", ());
        let b = OrderedMutex::new("sync-test-co-b", ());
        for _ in 0..3 {
            let _ga = a.lock();
            let _gb = b.lock();
        }
    }

    #[test]
    fn same_class_instances_do_not_self_edge() {
        // Two instances of one class held together must not create a
        // self-loop (per-shard locks of the same kind).
        let a = OrderedMutex::new("sync-test-same", 0u8);
        let b = OrderedMutex::new("sync-test-same", 0u8);
        let _ga = a.lock();
        let _gb = b.lock();
    }

    #[test]
    fn detects_lock_order_inversion() {
        let a = Arc::new(OrderedMutex::new("sync-test-inv-a", ()));
        let b = Arc::new(OrderedMutex::new("sync-test-inv-b", ()));
        // Establish a→b on another thread.
        {
            let (a, b) = (Arc::clone(&a), Arc::clone(&b));
            std::thread::spawn(move || {
                let _ga = a.lock();
                let _gb = b.lock();
            })
            .join()
            .unwrap();
        }
        // b→a closes the cycle: must panic in debug builds.
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _gb = b.lock();
            let _ga = a.lock();
        }));
        if cfg!(debug_assertions) {
            assert!(res.is_err(), "inversion went undetected");
        } else {
            assert!(res.is_ok());
        }
    }

    #[test]
    fn wait_timeout_round_trips_guard() {
        let m = OrderedMutex::new("sync-test-cv", 0u32);
        let cv = Condvar::new();
        let g = m.lock();
        let (mut g, res) = m.wait_timeout(&cv, g, Duration::from_millis(5));
        assert!(res.timed_out());
        *g += 1;
        drop(g);
        assert_eq!(*m.lock(), 1);
    }
}
