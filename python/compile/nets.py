"""Policy-value networks with a FLAT parameter vector.

All parameters live in a single f32 vector ``params_flat[P]``; the apply
functions unflatten with static slices.  This is the contract that keeps
the Rust side fully generic (DESIGN.md "Parameter representation"): the
ModelPool stores one Vec<f32> per version, allreduce is a vector average,
and artifact I/O is a fixed literal list.

Two architectures (mirroring the paper's TPolicies use):
  - solo net: shared MLP torso -> policy head (logits) + value head.
  - team net (Pommerman 4.3): per-agent shared-weight torso -> per-agent
    policy head; CENTRALIZED value head over the concatenated teammate
    torso embeddings (the paper's cooperation mechanism).
"""

import numpy as np
import jax.numpy as jnp


def param_specs(obs_dim, act_dim, hidden, team=False):
    """Ordered list of (name, shape) defining the flat layout."""
    specs = []
    d = obs_dim
    for i, h in enumerate(hidden):
        specs.append((f"torso{i}/w", (d, h)))
        specs.append((f"torso{i}/b", (h,)))
        d = h
    specs.append(("policy/w", (d, act_dim)))
    specs.append(("policy/b", (act_dim,)))
    if team:
        # centralized value: input = concat of the 2 teammates' embeddings
        specs.append(("value0/w", (2 * d, d)))
        specs.append(("value0/b", (d,)))
    specs.append(("value/w", (d, 1)))
    specs.append(("value/b", (1,)))
    return specs


def param_count(specs):
    return int(sum(int(np.prod(s)) for _, s in specs))


def init_params(seed, specs):
    """He-scaled gaussian init, numpy only (runs once at build time)."""
    rng = np.random.RandomState(seed)
    chunks = []
    for name, shape in specs:
        if name.endswith("/b"):
            chunks.append(np.zeros(shape, np.float32))
        else:
            fan_in = shape[0]
            scale = np.sqrt(2.0 / fan_in)
            if name.startswith(("policy", "value")):
                scale *= 0.1  # small heads: near-uniform initial policy
            chunks.append(
                (rng.randn(*shape) * scale).astype(np.float32))
    return np.concatenate([c.reshape(-1) for c in chunks])


def unflatten(flat, specs):
    out = {}
    off = 0
    for name, shape in specs:
        n = int(np.prod(shape))
        out[name] = flat[off:off + n].reshape(shape)
        off += n
    return out


def _torso(p, obs, hidden):
    h = obs
    for i in range(len(hidden)):
        h = jnp.maximum(h @ p[f"torso{i}/w"] + p[f"torso{i}/b"], 0.0)
    return h


def apply_solo(flat, obs, spec):
    """obs [..., D] -> (logits [..., A], value [...])."""
    specs = param_specs(spec["obs_dim"], spec["act_dim"], spec["hidden"])
    p = unflatten(flat, specs)
    h = _torso(p, obs, spec["hidden"])
    logits = h @ p["policy/w"] + p["policy/b"]
    value = (h @ p["value/w"] + p["value/b"])[..., 0]
    return logits, value


def apply_team(flat, obs, spec):
    """obs [..., 2, D] -> (logits [..., 2, A], value [...]).

    Policy is decentralized (shared weights, own observation); value is
    centralized over both teammates' embeddings.
    """
    specs = param_specs(spec["obs_dim"], spec["act_dim"], spec["hidden"],
                        team=True)
    p = unflatten(flat, specs)
    h = _torso(p, obs, spec["hidden"])            # [..., 2, H]
    logits = h @ p["policy/w"] + p["policy/b"]    # [..., 2, A]
    hc = jnp.concatenate([h[..., 0, :], h[..., 1, :]], axis=-1)
    hv = jnp.maximum(hc @ p["value0/w"] + p["value0/b"], 0.0)
    value = (hv @ p["value/w"] + p["value/b"])[..., 0]
    return logits, value


def make_apply(spec):
    if spec["team"]:
        return lambda flat, obs: apply_team(flat, obs, spec)
    return lambda flat, obs: apply_solo(flat, obs, spec)


def specs_for(spec):
    return param_specs(spec["obs_dim"], spec["act_dim"], spec["hidden"],
                       team=spec["team"])
