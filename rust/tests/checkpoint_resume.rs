//! Kill-and-resume integration: snapshot a live league, tear everything
//! down, restore from disk, and verify the restored state is bit-exact —
//! including model blobs that were spilled out of memory.
//!
//! The Deployment-level test needs `make artifacts` (PJRT); it skips
//! otherwise.  The service-level tests run everywhere.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use tleague::checkpoint::CheckpointMgr;
use tleague::config::RunConfig;
use tleague::league::{LeagueClient, LeagueConfig, LeagueMgrServer};
use tleague::model_pool::{ModelPoolClient, ModelPoolServer, PoolOptions};
use tleague::orchestrator::Deployment;
use tleague::proto::{MatchOutcome, ModelBlob, ModelKey};
use tleague::runtime::Engine;
use tleague::util::codec::Wire;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("tleague-resume-{}-{tag}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn frozen_blob(version: u32, n: usize) -> ModelBlob {
    ModelBlob {
        key: ModelKey::new(0, version),
        params: (0..n).map(|i| (i as f32).sin() + version as f32).collect(),
        hp: vec![3e-4],
        frozen: true,
    }
}

/// Run a short league over real TCP, snapshot it, tear it down, restore,
/// and require a bit-exact round trip of pool/payoff/Elo/hyper state.
#[test]
fn league_and_pool_roundtrip_bit_exact() {
    let ckpt_dir = tmp_dir("svc");
    let spill_dir = ckpt_dir.join("spill-0");
    let league = LeagueMgrServer::start(
        "127.0.0.1:0",
        LeagueConfig {
            n_agents: 1,
            n_opponents: 1,
            game_mgr: "pfsp".into(),
            hp_layout: vec!["lr".into(), "ent_coef".into()],
            hp_default: vec![3e-4, 0.01],
            seed: 11,
        },
    )
    .unwrap();
    let pool = ModelPoolServer::start_with(
        "127.0.0.1:0",
        PoolOptions { spill_dir: Some(spill_dir), mem_budget: 36 * 1024 },
    )
    .unwrap();
    let lc = LeagueClient::connect(&league.addr);
    let pc = ModelPoolClient::connect(&[pool.addr.clone()]);

    // ~10 learning periods: outcomes, freezes, model publications
    pc.put(frozen_blob(0, 2000)).unwrap();
    for v in 1..=10u32 {
        let me = ModelKey::new(0, v);
        for g in 0..4 {
            lc.report_outcome(MatchOutcome {
                task_id: 0,
                learner_key: me,
                opponents: vec![ModelKey::new(0, g % v)],
                outcome: [1.0, 0.0, 0.5, 1.0][g as usize % 4],
                episode_len: 7,
                frames: 7,
            })
            .unwrap();
        }
        pc.put(frozen_blob(v, 2000)).unwrap();
        lc.notify_period_done(me).unwrap();
    }
    let _ = lc.request_actor_task("0/a").unwrap(); // advance rng + task ids
    assert!(pool.spilled_count() > 0, "budget never forced a spill");

    // ---- snapshot, then kill everything ----------------------------
    let mut snap = league.snapshot();
    snap.models = pool.all_blobs();
    assert_eq!(snap.models.len(), 11);
    let mgr = CheckpointMgr::open(&ckpt_dir, 3).unwrap();
    mgr.save(&snap).unwrap();

    let stats = league.stats();
    let pool_keys = league.pool();
    let elos: Vec<u64> =
        pool_keys.iter().map(|&k| league.elo(k).to_bits()).collect();
    let winrates: Vec<u64> = pool_keys
        .iter()
        .map(|&k| league.winrate(ModelKey::new(0, 10), k).to_bits())
        .collect();
    let hp = lc.request_learner_task(0).unwrap().hp;
    drop(lc);
    drop(league);
    drop(pool);

    // ---- restore from disk -----------------------------------------
    let loaded = CheckpointMgr::open(&ckpt_dir, 3)
        .unwrap()
        .load_latest()
        .unwrap()
        .expect("snapshot on disk");
    assert_eq!(snap.to_bytes(), loaded.to_bytes(), "round trip not bit-exact");

    let league2 = LeagueMgrServer::start_with(
        "127.0.0.1:0",
        LeagueConfig {
            n_agents: 1,
            n_opponents: 1,
            game_mgr: "uniform".into(), // snapshot's sampler must win
            hp_layout: vec!["lr".into(), "ent_coef".into()],
            hp_default: vec![1.0, 1.0],
            seed: 999,
        },
        Some(&loaded),
    )
    .unwrap();
    let pool2 = ModelPoolServer::start_with(
        "127.0.0.1:0",
        PoolOptions {
            spill_dir: Some(ckpt_dir.join("spill-restored")),
            mem_budget: 36 * 1024,
        },
    )
    .unwrap();
    pool2.preload(&loaded.models);

    let rstats = league2.stats();
    assert_eq!(rstats.pool_size, stats.pool_size);
    assert_eq!(rstats.episodes, stats.episodes);
    assert_eq!(rstats.frames, stats.frames);
    assert_eq!(rstats.total_matches, stats.total_matches);
    assert_eq!(rstats.current, stats.current);
    assert_eq!(league2.pool(), pool_keys);
    for (i, &k) in pool_keys.iter().enumerate() {
        assert_eq!(league2.elo(k).to_bits(), elos[i], "Elo drift at {k}");
        assert_eq!(
            league2.winrate(ModelKey::new(0, 10), k).to_bits(),
            winrates[i],
            "winrate drift at {k}"
        );
    }
    let lc2 = LeagueClient::connect(&league2.addr);
    assert_eq!(lc2.request_learner_task(0).unwrap().hp, hp, "hyper drift");

    // every blob — resident or spilled — must be served, bit-identical
    let pc2 = ModelPoolClient::connect(&[pool2.addr.clone()]);
    assert!(pool2.resident_bytes() <= 36 * 1024, "budget violated on restore");
    for v in 0..=10u32 {
        let b = pc2
            .get(ModelKey::new(0, v))
            .unwrap()
            .unwrap_or_else(|| panic!("NotFound for restored blob v{v}"));
        assert_eq!(b.params, frozen_blob(v, 2000).params, "blob v{v} corrupted");
    }
    std::fs::remove_dir_all(&ckpt_dir).ok();
}

/// Long-run memory bound: a pool fed far more frozen models than the
/// budget admits must stay under it while serving every blob.
#[test]
fn model_pool_stays_bounded_over_long_run() {
    let dir = tmp_dir("bound");
    let budget = 64 * 1024;
    let pool = ModelPoolServer::start_with(
        "127.0.0.1:0",
        PoolOptions { spill_dir: Some(dir.clone()), mem_budget: budget },
    )
    .unwrap();
    let pc = ModelPoolClient::connect(&[pool.addr.clone()]);
    for v in 0..100u32 {
        pc.put(frozen_blob(v, 2000)).unwrap();
        assert!(
            pool.resident_bytes() <= budget,
            "resident {} > budget {budget} after v{v}",
            pool.resident_bytes()
        );
        // interleave reads of old versions to exercise fault-in mid-run
        if v % 7 == 0 && v > 0 {
            assert!(pc.get(ModelKey::new(0, v / 2)).unwrap().is_some());
        }
    }
    assert_eq!(pool.model_count(), 100);
    for v in 0..100u32 {
        assert!(
            pc.get(ModelKey::new(0, v)).unwrap().is_some(),
            "v{v} lost"
        );
        assert!(pool.resident_bytes() <= budget);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Full-stack kill-and-resume through the orchestrator (needs PJRT
/// artifacts): train a short league with checkpointing on, kill the
/// deployment, resume, and require identical league state plus a usable
/// (spill-backed) model pool.
#[test]
fn deployment_kill_and_resume() {
    let art = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !art.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Arc::new(Engine::load(&art).unwrap());
    let ckpt_dir = tmp_dir("deploy");

    let mut cfg = RunConfig::default();
    cfg.env = "rps".into();
    cfg.total_steps = 6;
    cfg.period_steps = 3;
    cfg.actors_per_learner = 2;
    cfg.checkpoint_dir = Some(ckpt_dir.to_string_lossy().into_owned());
    cfg.checkpoint_every_secs = 3600; // only the shutdown snapshot matters
    cfg.pool_mem_budget_bytes = 1; // spill everything spillable
    let mut dep = Deployment::start(cfg.clone(), engine.clone()).unwrap();
    assert!(dep.wait(Duration::from_secs(120)), "did not finish");
    dep.shutdown(); // snapshotter writes the final snapshot here

    let stats = dep.league_stats();
    let pool_keys = dep.league().pool();
    let elos: Vec<u64> =
        pool_keys.iter().map(|&k| dep.league().elo(k).to_bits()).collect();
    drop(dep);

    let mut cfg2 = cfg.clone();
    cfg2.resume = Some(ckpt_dir.to_string_lossy().into_owned());
    cfg2.checkpoint_dir = None;
    cfg2.total_steps = 0; // freeze the resumed state for comparison
    cfg2.actors_per_learner = 0;
    let mut dep2 = Deployment::start(cfg2, engine).unwrap();

    let rstats = dep2.league_stats();
    assert_eq!(rstats.pool_size, stats.pool_size, "pool size drift");
    assert_eq!(rstats.episodes, stats.episodes, "episode counter drift");
    assert_eq!(rstats.frames, stats.frames, "frame counter drift");
    assert_eq!(rstats.current, stats.current, "learner keys drift");
    assert_eq!(dep2.league().pool(), pool_keys);
    for (i, &k) in pool_keys.iter().enumerate() {
        assert_eq!(dep2.league().elo(k).to_bits(), elos[i], "Elo drift at {k}");
    }
    // every frozen model must be served from the resumed pool (spilled
    // blobs fault back in; none may be NotFound)
    let pc = ModelPoolClient::connect(&[dep2.pool_addrs()[0].clone()]);
    let m = engine.manifest.env("rps").unwrap();
    for &k in &pool_keys {
        let blob = pc
            .get(k)
            .unwrap()
            .unwrap_or_else(|| panic!("NotFound for {k} after resume"));
        assert_eq!(blob.params.len(), m.param_count);
    }
    dep2.shutdown();
    std::fs::remove_dir_all(&ckpt_dir).ok();
}
