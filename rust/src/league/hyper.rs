//! HyperMgr: per-model hyper-parameters + PBT perturbation (§3.2).
//!
//! Each model version carries its own hp vector (layout =
//! manifest.hp_layout).  On freeze, the next version inherits the hp;
//! with PBT enabled, underperforming agents copy the best agent's hp
//! ("exploit") and jitter the continuous entries ("explore"), as in the
//! Quake-III population-based training the paper cites.

use crate::proto::ModelKey;
use crate::util::codec::{Cursor, Enc, Wire};
use crate::util::rng::Pcg32;
use anyhow::Result;
use std::collections::BTreeMap;

#[derive(Clone)]
pub struct HyperMgr {
    pub layout: Vec<String>,
    hp: BTreeMap<ModelKey, Vec<f32>>,
    default: Vec<f32>,
    /// indices of entries PBT is allowed to perturb (e.g. lr, ent_coef)
    pub perturbable: Vec<usize>,
    pub pbt_enabled: bool,
    rng: Pcg32,
}

impl HyperMgr {
    pub fn new(layout: Vec<String>, default: Vec<f32>, seed: u64) -> Self {
        assert_eq!(layout.len(), default.len());
        let perturbable = layout
            .iter()
            .enumerate()
            .filter(|(_, k)| matches!(k.as_str(), "lr" | "ent_coef" | "lam"))
            .map(|(i, _)| i)
            .collect();
        HyperMgr {
            layout,
            hp: BTreeMap::new(),
            default,
            perturbable,
            pbt_enabled: false,
            rng: Pcg32::from_label(seed, "hyper"),
        }
    }

    pub fn get(&self, key: ModelKey) -> Vec<f32> {
        self.hp.get(&key).cloned().unwrap_or_else(|| self.default.clone())
    }

    pub fn set(&mut self, key: ModelKey, hp: Vec<f32>) {
        assert_eq!(hp.len(), self.layout.len());
        self.hp.insert(key, hp);
    }

    pub fn override_named(&mut self, key: ModelKey, name: &str, value: f32) {
        let mut hp = self.get(key);
        if let Some(i) = self.layout.iter().position(|k| k == name) {
            hp[i] = value;
            self.set(key, hp);
        }
    }

    /// New version inherits its predecessor's hp.
    pub fn inherit(&mut self, from: ModelKey, to: ModelKey) {
        let hp = self.get(from);
        self.set(to, hp);
    }

    /// PBT step for `key`: if its score is in the bottom fraction of
    /// `population` (scored by `score_of`), copy the best member's hp
    /// and perturb (x0.8 / x1.2) the perturbable entries.
    /// Returns true if the hp changed.
    pub fn pbt_step<F: Fn(ModelKey) -> f64>(
        &mut self,
        key: ModelKey,
        population: &[ModelKey],
        score_of: F,
    ) -> bool {
        if !self.pbt_enabled || population.len() < 2 {
            return false;
        }
        let my = score_of(key);
        let best = population
            .iter()
            .copied()
            .max_by(|a, b| score_of(*a).total_cmp(&score_of(*b)))
            .unwrap();
        let best_score = score_of(best);
        // exploit if clearly dominated
        if best == key || best_score - my < 0.1 {
            return false;
        }
        let mut hp = self.get(best);
        for &i in &self.perturbable {
            let f = if self.rng.chance(0.5) { 0.8 } else { 1.2 };
            hp[i] *= f;
        }
        self.set(key, hp);
        true
    }
}

/// Snapshot codec: covers the per-model hp table, PBT switches, and the
/// perturbation RNG stream so restored runs perturb identically.
impl Wire for HyperMgr {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.put_u32(self.layout.len() as u32);
        for name in &self.layout {
            buf.put_str(name);
        }
        buf.put_f32s(&self.default);
        buf.put_u32(self.perturbable.len() as u32);
        for &i in &self.perturbable {
            buf.put_u32(i as u32);
        }
        buf.put_u8(self.pbt_enabled as u8);
        let (state, inc) = self.rng.state_parts();
        buf.put_u64(state);
        buf.put_u64(inc);
        buf.put_u32(self.hp.len() as u32);
        for (key, hp) in &self.hp {
            key.encode(buf);
            buf.put_f32s(hp);
        }
    }

    fn decode(cur: &mut Cursor) -> Result<Self> {
        let n_layout = cur.u32()? as usize;
        let layout: Vec<String> =
            (0..n_layout).map(|_| cur.str()).collect::<Result<_>>()?;
        let default = cur.f32s()?;
        let n_pert = cur.u32()? as usize;
        let mut perturbable = Vec::with_capacity(n_pert);
        for _ in 0..n_pert {
            perturbable.push(cur.u32()? as usize);
        }
        let pbt_enabled = cur.u8()? != 0;
        let state = cur.u64()?;
        let inc = cur.u64()?;
        let n_hp = cur.u32()? as usize;
        let mut hp = BTreeMap::new();
        for _ in 0..n_hp {
            let key = ModelKey::decode(cur)?;
            let v = cur.f32s()?;
            hp.insert(key, v);
        }
        Ok(HyperMgr {
            layout,
            hp,
            default,
            perturbable,
            pbt_enabled,
            rng: Pcg32::from_state_parts(state, inc),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> HyperMgr {
        HyperMgr::new(
            vec!["lr".into(), "clip_eps".into(), "ent_coef".into()],
            vec![3e-4, 0.2, 0.01],
            7,
        )
    }

    fn k(a: u32, v: u32) -> ModelKey {
        ModelKey::new(a, v)
    }

    #[test]
    fn default_and_set() {
        let mut m = mgr();
        assert_eq!(m.get(k(0, 0)), vec![3e-4, 0.2, 0.01]);
        m.set(k(0, 1), vec![1e-3, 0.1, 0.02]);
        assert_eq!(m.get(k(0, 1))[0], 1e-3);
    }

    #[test]
    fn inherit_copies() {
        let mut m = mgr();
        m.set(k(0, 3), vec![5e-4, 0.3, 0.05]);
        m.inherit(k(0, 3), k(0, 4));
        assert_eq!(m.get(k(0, 4)), vec![5e-4, 0.3, 0.05]);
    }

    #[test]
    fn override_named_works() {
        let mut m = mgr();
        m.override_named(k(1, 0), "ent_coef", 0.5);
        assert_eq!(m.get(k(1, 0))[2], 0.5);
        assert_eq!(m.get(k(1, 0))[0], 3e-4, "others untouched");
    }

    #[test]
    fn pbt_copies_winner_and_perturbs() {
        let mut m = mgr();
        m.pbt_enabled = true;
        m.set(k(0, 0), vec![9e-4, 0.2, 0.03]);
        m.set(k(1, 0), vec![1e-5, 0.2, 0.0]);
        let pop = vec![k(0, 0), k(1, 0)];
        let changed = m.pbt_step(k(1, 0), &pop, |key| {
            if key.agent == 0 {
                0.9
            } else {
                0.2
            }
        });
        assert!(changed);
        let hp = m.get(k(1, 0));
        // lr copied from winner then x0.8 or x1.2
        assert!(
            (hp[0] - 9e-4 * 0.8).abs() < 1e-9 || (hp[0] - 9e-4 * 1.2).abs() < 1e-9,
            "lr {}",
            hp[0]
        );
        // clip_eps not perturbable: exact copy
        assert_eq!(hp[1], 0.2);
    }

    #[test]
    fn wire_roundtrip_preserves_state_and_rng() {
        let mut m = mgr();
        m.pbt_enabled = true;
        m.set(k(0, 1), vec![1e-3, 0.1, 0.02]);
        m.set(k(2, 5), vec![2e-3, 0.3, 0.04]);
        let bytes = m.to_bytes();
        let mut back = HyperMgr::from_bytes(&bytes).unwrap();
        assert_eq!(bytes, back.to_bytes(), "re-encode must be identical");
        assert_eq!(back.get(k(0, 1)), vec![1e-3, 0.1, 0.02]);
        assert_eq!(back.get(k(9, 9)), vec![3e-4, 0.2, 0.01], "default kept");
        assert!(back.pbt_enabled);
        // the perturbation RNG continues the same stream
        let pop = vec![k(0, 1), k(2, 5)];
        let score = |key: ModelKey| if key.agent == 2 { 0.9 } else { 0.1 };
        m.pbt_step(k(0, 1), &pop, score);
        back.pbt_step(k(0, 1), &pop, score);
        assert_eq!(m.get(k(0, 1)), back.get(k(0, 1)), "PBT rng diverged");
    }

    #[test]
    fn pbt_noop_for_winner_or_disabled() {
        let mut m = mgr();
        let pop = vec![k(0, 0), k(1, 0)];
        assert!(!m.pbt_step(k(1, 0), &pop, |_| 0.5), "disabled: noop");
        m.pbt_enabled = true;
        assert!(!m.pbt_step(k(0, 0), &pop, |key| {
            if key.agent == 0 { 0.9 } else { 0.1 }
        }), "winner keeps its hp");
    }
}
