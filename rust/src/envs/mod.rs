//! Multi-agent environments (the Arena toolbox of the paper, §3.5).
//!
//! The trait mirrors the paper's OpenAI-gym-compatible multi-agent
//! contract (§3.2):
//!
//! ```text
//! l_obs = env.reset()                           # episode beginning
//! l_obs, l_rwd, done, info = env.step(l_act)    # in-episode stepping
//! ```
//!
//! Environments: `matrix` (RPS & friends — FSP validation), `pong2p`
//! (the paper's extension example), `pommerman` (NeurIPS-18 Team mode),
//! `doom_lite` (ViZDoom CIG-2016 track-1 stand-in), `synthetic`
//! (calibrated step cost for the Table-3 throughput harness).

pub mod doom_lite;
pub mod matrix;
pub mod pommerman;
pub mod pong2p;
pub mod synthetic;
pub mod vec;

pub use vec::{SlotStep, VecEnv};

use anyhow::{bail, Result};

/// Extra episode info (the paper's `info` dict).  `outcome` is set at
/// episode end: per-agent 1.0 win / 0.5 tie / 0.0 loss.
#[derive(Clone, Debug, Default)]
pub struct Info {
    pub outcome: Option<Vec<f32>>,
    /// per-agent FRAG (kills - suicides), doom_lite only
    pub frags: Option<Vec<i32>>,
}

pub struct Step {
    pub obs: Vec<Vec<f32>>,
    pub rewards: Vec<f32>,
    pub done: bool,
    pub info: Info,
}

pub trait MultiAgentEnv: Send {
    fn n_agents(&self) -> usize;
    fn obs_dim(&self) -> usize;
    fn act_dim(&self) -> usize;
    /// Hard cap on episode length (steps) — used for buffer sizing.
    fn max_steps(&self) -> usize;
    fn reset(&mut self) -> Vec<Vec<f32>>;
    fn step(&mut self, actions: &[usize]) -> Step;
}

/// Canonical environment registry: every base name [`make`] accepts.
/// `doom_lite` and `synthetic` also take a `:<n>` parameter (see
/// [`make`]); the registry lists base names only.
pub const ALL: &[&str] = &[
    "rps",
    "pong2p",
    "pommerman",
    "pommerman_ffa",
    "doom_lite",
    "synthetic",
];

/// Split an env spec into `(base_name, optional ":<param>" value)`,
/// e.g. `"doom_lite:4"` → `("doom_lite", Some("4"))`.
pub fn spec(name: &str) -> (&str, Option<&str>) {
    match name.split_once(':') {
        Some((base, p)) => (base, Some(p)),
        None => (name, None),
    }
}

fn parse_param(base: &str, p: Option<&str>) -> Result<Option<usize>> {
    match p {
        None => Ok(None),
        Some(s) => match s.parse::<usize>() {
            Ok(v) => Ok(Some(v)),
            Err(_) => bail!("env '{base}': bad parameter '{s}' (want an integer)"),
        },
    }
}

/// Instantiate an env by spec name.  `seed` drives all env randomness
/// (map layout, spawn order, ...).  Parameterized specs:
///
/// - `doom_lite:<players>` — FFA player count (2..=8; default 8)
/// - `synthetic:<episode_len>` — fixed episode length (default 256)
pub fn make(name: &str, seed: u64) -> Result<Box<dyn MultiAgentEnv>> {
    let (base, p) = spec(name);
    if !ALL.contains(&base) {
        bail!("unknown env '{base}' (known: {ALL:?})");
    }
    let param = parse_param(base, p)?;
    anyhow::ensure!(
        param.is_none() || matches!(base, "doom_lite" | "synthetic"),
        "env '{base}' takes no ':<n>' parameter"
    );
    Ok(match base {
        "rps" => Box::new(matrix::MatrixGame::rps(seed)),
        "pong2p" => Box::new(pong2p::Pong2p::new(seed)),
        "pommerman" => Box::new(pommerman::Pommerman::team(seed)),
        "pommerman_ffa" => Box::new(pommerman::Pommerman::ffa(seed)),
        "doom_lite" => {
            let n = param.unwrap_or(8);
            anyhow::ensure!(
                (2..=8).contains(&n),
                "doom_lite:<players> wants 2..=8, got {n}"
            );
            Box::new(doom_lite::DoomLite::new(seed, n))
        }
        "synthetic" => match param {
            None => Box::new(synthetic::Synthetic::new(seed)),
            Some(len) => {
                anyhow::ensure!(len >= 1, "synthetic:<episode_len> wants >= 1");
                Box::new(synthetic::Synthetic::with_cost(seed, 2_000, len))
            }
        },
        _ => unreachable!("envs::ALL and the make dispatch must agree"),
    })
}

/// The manifest env name an env spec maps to (pommerman_ffa shares the
/// pommerman artifacts; `:<n>` parameters never change the net shapes).
pub fn manifest_name(env: &str) -> &str {
    match spec(env).0 {
        "pommerman_ffa" => "pommerman",
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_every_env() {
        for &name in ALL {
            let mut env = make(name, 7).unwrap();
            let obs = env.reset();
            assert_eq!(obs.len(), env.n_agents(), "{name}");
            for o in &obs {
                assert_eq!(o.len(), env.obs_dim(), "{name}");
                assert!(o.iter().all(|x| x.is_finite()), "{name}");
            }
        }
        assert!(make("nope", 0).is_err());
    }

    #[test]
    fn episodes_terminate_and_emit_outcome() {
        for &name in ALL {
            let mut env = make(name, 3).unwrap();
            env.reset();
            let mut steps = 0;
            loop {
                let acts: Vec<usize> = (0..env.n_agents())
                    .map(|i| (steps + i) % env.act_dim())
                    .collect();
                let s = env.step(&acts);
                steps += 1;
                assert!(steps <= env.max_steps(), "{name} overran max_steps");
                assert_eq!(s.rewards.len(), env.n_agents(), "{name}");
                if s.done {
                    let out = s.info.outcome.expect("outcome at episode end");
                    assert_eq!(out.len(), env.n_agents(), "{name}");
                    for &o in &out {
                        assert!((0.0..=1.0).contains(&o), "{name}: {o}");
                    }
                    break;
                }
            }
        }
    }

    #[test]
    fn same_seed_same_rollout() {
        for &name in ALL {
            let mut a = make(name, 42).unwrap();
            let mut b = make(name, 42).unwrap();
            assert_eq!(a.reset(), b.reset(), "{name}");
            for t in 0..50 {
                let acts: Vec<usize> =
                    (0..a.n_agents()).map(|i| (t * 3 + i) % a.act_dim()).collect();
                let sa = a.step(&acts);
                let sb = b.step(&acts);
                assert_eq!(sa.obs, sb.obs, "{name} diverged at {t}");
                assert_eq!(sa.rewards, sb.rewards, "{name}");
                if sa.done {
                    break;
                }
            }
        }
    }

    #[test]
    fn parameterized_specs() {
        let mut d = make("doom_lite:4", 1).unwrap();
        assert_eq!(d.n_agents(), 4);
        assert_eq!(d.reset().len(), 4);
        let mut s = make("synthetic:8", 1).unwrap();
        s.reset();
        for t in 0..8 {
            let st = s.step(&[0, 1]);
            assert_eq!(st.done, t == 7, "episode_len param must hold");
        }
        assert!(make("doom_lite:1", 0).is_err());
        assert!(make("doom_lite:9", 0).is_err());
        assert!(make("doom_lite:x", 0).is_err());
        assert!(make("synthetic:0", 0).is_err());
        assert!(make("rps:3", 0).is_err(), "rps takes no parameter");
        assert_eq!(manifest_name("doom_lite:4"), "doom_lite");
        assert_eq!(manifest_name("pommerman_ffa"), "pommerman");
        assert_eq!(spec("synthetic:64"), ("synthetic", Some("64")));
        assert_eq!(spec("rps"), ("rps", None));
    }
}
