//! Controller: the league's control plane for multi-process deployment.
//!
//! Owns the [`CoreServices`] (LeagueMgr + ModelPool replicas +
//! CheckpointMgr snapshotter) and a slot registry derived from the
//! [`RunConfig`] topology: one learner slot per learning agent (the
//! agent's whole allreduce group runs as threads inside one worker —
//! gradient allreduce is intra-process), one actor slot per
//! (agent, rank, M_A) tuple, one slot per InfServer.
//!
//! Workers register over the existing `transport` REQ/REP layer
//! (`Register` → `Assign`/`Retry`), report the endpoints they serve
//! (`WorkerReady`), and heartbeat.  A worker silent for longer than
//! `heartbeat_timeout_ms` is declared dead: its slot is freed and
//! handed to the next registrant (typically the supervisor's respawn of
//! the same process), which is how actors keep the auto-restart
//! semantics that thread-mode `Deployment` gives them.  A controller
//! restart re-adopts live workers: their next heartbeat is answered
//! with an unknown-worker error, they re-register with their old slot
//! as a hint, and restart their role against the resumed services.

use crate::config::RunConfig;
use crate::league::LeagueStats;
use crate::model_pool::MoveStats;
use crate::orchestrator::CoreServices;
use crate::proto::{LeagueReport, Msg, RoleStats, RunSlice, WorkerAssignment};
use crate::telemetry::{snapshot_role, trace, LeagueView};
use crate::transport::RepServer;
use crate::util::metrics::MetricsHub;
use crate::util::sync::OrderedMutex;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

pub const ROLE_LEARNER: &str = "learner";
pub const ROLE_ACTOR: &str = "actor";
pub const ROLE_INF: &str = "inf-server";

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum Role {
    Learner,
    Actor,
    Inf,
}

impl Role {
    fn parse(s: &str) -> Option<Role> {
        match s {
            ROLE_LEARNER => Some(Role::Learner),
            ROLE_ACTOR => Some(Role::Actor),
            ROLE_INF => Some(Role::Inf),
            _ => None,
        }
    }
    fn as_str(self) -> &'static str {
        match self {
            Role::Learner => ROLE_LEARNER,
            Role::Actor => ROLE_ACTOR,
            Role::Inf => ROLE_INF,
        }
    }
}

struct WorkerInfo {
    role: Role,
    slot: usize,
    last_seen: Instant,
}

/// One learner slot = one learning agent's whole allreduce group.
#[derive(Default)]
struct LearnerSlot {
    worker: Option<u64>,
    /// data ports in rank order, reported via WorkerReady; empty until
    /// then (gates dependent actor assignments)
    data_addrs: Vec<String>,
    steps: u64,
    done: bool,
    was_lost: bool,
}

struct ActorSlot {
    worker: Option<u64>,
    agent: u32,
    rank: usize,
    was_lost: bool,
    /// scale-down in progress: the occupant's next heartbeat acks
    /// stop=true; it finishes its episode, flushes segments, and
    /// deregisters — which retires the slot
    draining: bool,
    /// out of the capacity pool; kept in the table so slot indices (and
    /// telemetry keys) stay stable.  A later scale-up resurrects it.
    retired: bool,
}

#[derive(Default)]
struct InfSlot {
    worker: Option<u64>,
    addr: Option<String>,
    was_lost: bool,
    draining: bool,
    retired: bool,
}

struct CtrlState {
    learners: Vec<LearnerSlot>, // index = agent
    actors: Vec<ActorSlot>,
    infs: Vec<InfSlot>,
    workers: HashMap<u64, WorkerInfo>,
    /// last telemetry snapshot seq ingested per slot — heartbeats ride
    /// `ReqClient` (retransmits on connection breaks) and a worker
    /// retries an unconfirmed snapshot verbatim after re-registering,
    /// so delta merging must be idempotent per (slot, seq).  Keyed by
    /// slot, not worker id, so the dedupe survives the respawn path;
    /// bounded by the slot table.
    stats_seq: HashMap<(Role, usize), u64>,
    next_worker: u64,
    lost: u64,
    reassigned: u64,
    /// learners all done → actors are being told to stop
    draining: bool,
    /// everything is being told to stop
    stop_all: bool,
}

/// Point-in-time controller statistics (also served as
/// `Msg::DeployStatsReply` for remote probes).
#[derive(Clone, Debug, Default)]
pub struct DeployStatsSnap {
    pub workers: u32,
    pub lost: u32,
    pub reassigned: u32,
    pub learners_done: u32,
    pub learner_steps: u64,
    pub draining: bool,
    /// current actor capacity: slots neither retired nor draining
    pub actor_slots: u32,
    /// current inf-server capacity: slots neither retired nor draining
    pub inf_slots: u32,
}

fn stats_of(st: &CtrlState) -> DeployStatsSnap {
    DeployStatsSnap {
        workers: st.workers.len() as u32,
        lost: st.lost as u32,
        reassigned: st.reassigned as u32,
        learners_done: st.learners.iter().filter(|l| l.done).count() as u32,
        learner_steps: st.learners.iter().map(|l| l.steps).sum(),
        draining: st.draining,
        actor_slots: actor_capacity(st) as u32,
        inf_slots: inf_capacity(st) as u32,
    }
}

// ---- elastic slot table ------------------------------------------------

/// Slots currently counted as capacity (not retired, not draining).
fn actor_capacity(st: &CtrlState) -> usize {
    st.actors.iter().filter(|s| !s.retired && !s.draining).count()
}

fn inf_capacity(st: &CtrlState) -> usize {
    st.infs.iter().filter(|s| !s.retired && !s.draining).count()
}

/// Open up to `n` actor slots without exceeding `max` capacity.
/// Retired slots are resurrected first (stable indices); genuinely new
/// slots attach to the least-loaded (agent, rank) pair so scale-ups
/// spread evenly across learners.  Returns how many slots opened.
fn grow_actor_slots(
    st: &mut CtrlState,
    n: usize,
    max: usize,
    lpa: usize,
) -> usize {
    let lpa = lpa.max(1);
    let mut opened = 0;
    for _ in 0..n {
        if actor_capacity(st) >= max {
            break;
        }
        if let Some(i) = st.actors.iter().position(|s| s.retired) {
            let s = &mut st.actors[i];
            s.retired = false;
            s.draining = false;
            s.was_lost = false;
            opened += 1;
            continue;
        }
        let lanes = st.learners.len().max(1) * lpa;
        let mut counts = vec![0usize; lanes];
        for s in st.actors.iter().filter(|s| !s.retired) {
            let li = s.agent as usize * lpa + s.rank;
            if li < lanes {
                counts[li] += 1;
            }
        }
        let li = counts
            .iter()
            .enumerate()
            .min_by_key(|&(_, c)| *c)
            .map(|(i, _)| i)
            .unwrap_or(0);
        st.actors.push(ActorSlot {
            worker: None,
            agent: (li / lpa) as u32,
            rank: li % lpa,
            was_lost: false,
            draining: false,
            retired: false,
        });
        opened += 1;
    }
    opened
}

/// Drain up to `n` actor slots, never dropping capacity below `min`.
/// Empty slots retire immediately; an occupied one (highest index
/// first) is marked draining — its worker's next heartbeat acks
/// stop=true, the actor finishes its episode and flushes segments, and
/// its Deregister retires the slot.  Returns how many slots changed.
fn drain_actor_slots(st: &mut CtrlState, n: usize, min: usize) -> usize {
    let mut drained = 0;
    for _ in 0..n {
        if actor_capacity(st) <= min {
            break;
        }
        if let Some(i) = st
            .actors
            .iter()
            .rposition(|s| !s.retired && !s.draining && s.worker.is_none())
        {
            st.actors[i].retired = true;
        } else if let Some(i) =
            st.actors.iter().rposition(|s| !s.retired && !s.draining)
        {
            st.actors[i].draining = true;
        } else {
            break;
        }
        drained += 1;
    }
    drained
}

fn grow_inf_slots(st: &mut CtrlState, n: usize, max: usize) -> usize {
    let mut opened = 0;
    for _ in 0..n {
        if inf_capacity(st) >= max {
            break;
        }
        if let Some(i) = st.infs.iter().position(|s| s.retired) {
            st.infs[i] = InfSlot::default();
        } else {
            st.infs.push(InfSlot::default());
        }
        opened += 1;
    }
    opened
}

fn drain_inf_slots(st: &mut CtrlState, n: usize, min: usize) -> usize {
    let mut drained = 0;
    for _ in 0..n {
        if inf_capacity(st) <= min {
            break;
        }
        if let Some(i) = st
            .infs
            .iter()
            .rposition(|s| !s.retired && !s.draining && s.worker.is_none())
        {
            st.infs[i].retired = true;
        } else if let Some(i) =
            st.infs.iter().rposition(|s| !s.retired && !s.draining)
        {
            st.infs[i].draining = true;
        } else {
            break;
        }
        drained += 1;
    }
    drained
}

// ---- scaling policy ----------------------------------------------------

/// Inf-server batch occupancy above which the serving tier is
/// saturated (actors queue on inference) and below which it is idle.
pub const INF_GROW_FILL: f64 = 0.8;
pub const INF_SHRINK_FILL: f64 = 0.2;
/// Learner staleness (model versions behind) above which actors
/// out-produce training, and below which the learner is starved.
pub const ACTOR_SHRINK_STALENESS: f64 = 3.0;
pub const ACTOR_GROW_STALENESS: f64 = 1.0;

/// Capacity bounds for one scalable role.
#[derive(Clone, Copy, Debug)]
pub struct ScaleBounds {
    pub min: usize,
    pub max: usize,
}

/// One policy evaluation, pure for unit testing: league-view signals in,
/// per-role deltas out (each in {-1, 0, +1}).  A missing signal (no
/// live slot reporting the gauge yet) never triggers a move.
pub fn policy_decide(
    staleness: Option<f64>,
    batch_fill: Option<f64>,
    actor_cap: usize,
    inf_cap: usize,
    actor_bounds: ScaleBounds,
    inf_bounds: ScaleBounds,
) -> (i64, i64) {
    let mut actor = 0i64;
    let mut inf = 0i64;
    if let Some(f) = batch_fill {
        if f > INF_GROW_FILL && inf_cap < inf_bounds.max {
            inf = 1;
        } else if f < INF_SHRINK_FILL && inf_cap > inf_bounds.min {
            inf = -1;
        }
    }
    if let Some(s) = staleness {
        if s > ACTOR_SHRINK_STALENESS && actor_cap > actor_bounds.min {
            actor = -1;
        } else if s < ACTOR_GROW_STALENESS && actor_cap < actor_bounds.max {
            actor = 1;
        }
    }
    (actor, inf)
}

/// Publish one scaling decision into the league view as role
/// "autoscaler" — it rides the same merge path as worker snapshots, so
/// every decision shows up in `--stats-jsonl` rows and the `stats` CLI.
fn note_scale(
    view: &LeagueView,
    seq: &AtomicU64,
    st: &CtrlState,
    up_a: u64,
    down_a: u64,
    up_i: u64,
    down_i: u64,
) {
    let counters: Vec<(String, u64)> = [
        ("scale_up_actor", up_a),
        ("scale_down_actor", down_a),
        ("scale_up_inf", up_i),
        ("scale_down_inf", down_i),
    ]
    .iter()
    .filter(|(_, v)| *v > 0)
    .map(|(k, v)| (k.to_string(), *v))
    .collect();
    view.ingest(&RoleStats {
        role: "autoscaler".into(),
        slot: 0,
        seq: seq.fetch_add(1, Ordering::Relaxed),
        interval_ms: 1_000,
        counters,
        gauges: vec![
            ("actor_slots".into(), actor_capacity(st) as f64),
            ("inf_slots".into(), inf_capacity(st) as f64),
        ],
        ..Default::default()
    });
}

/// Remove `id` and free its slot.  `lost = true` marks the slot so the
/// next assignment counts as a reassignment (heartbeat-timeout path);
/// a clean `Deregister` frees silently.  The slot's telemetry entry is
/// dropped either way — a dead worker's gauges must not freeze at their
/// last reported value in the league view.
fn free_slot(st: &mut CtrlState, id: u64, lost: bool, view: &LeagueView) {
    let Some(w) = st.workers.remove(&id) else { return };
    view.drop_slot(w.role.as_str(), w.slot as u32);
    match w.role {
        Role::Learner => {
            let s = &mut st.learners[w.slot];
            if s.worker == Some(id) {
                s.worker = None;
                // endpoints die with the process: actors holding the
                // old data addr will fail, re-register, and pick up the
                // replacement's addresses
                s.data_addrs.clear();
                if lost {
                    s.was_lost = true;
                }
            }
        }
        Role::Actor => {
            let s = &mut st.actors[w.slot];
            if s.worker == Some(id) {
                s.worker = None;
                if lost {
                    s.was_lost = true;
                }
                // scale-down completes when the draining occupant goes
                // away (cleanly or not): the slot leaves the capacity
                // pool instead of being re-handed out
                if s.draining {
                    s.draining = false;
                    s.retired = true;
                }
            }
        }
        Role::Inf => {
            let s = &mut st.infs[w.slot];
            if s.worker == Some(id) {
                s.worker = None;
                s.addr = None;
                if lost {
                    s.was_lost = true;
                }
                if s.draining {
                    s.draining = false;
                    s.retired = true;
                }
            }
        }
    }
}

/// Static per-register context captured by the service handler.
struct Ctx {
    league_addr: String,
    pool_addrs: Vec<String>,
    slice: RunSlice,
    learners_per_agent: usize,
    inf_servers: usize,
    /// with the scaling loop on, surplus workers park in Retry instead
    /// of being rejected — a later scale-up admits them
    autoscale: bool,
}

fn retry(backoff_ms: u32, reason: &str) -> Msg {
    Msg::Retry { backoff_ms, reason: reason.to_string() }
}

/// Hint-or-scan slot selection shared by every role: the hinted slot
/// wins when it is in range and eligible (a respawned worker gets its
/// old slot back), else the first eligible slot.
fn pick_slot(
    slot_hint: i64,
    n: usize,
    eligible: impl Fn(usize) -> bool,
) -> Option<usize> {
    usize::try_from(slot_hint)
        .ok()
        .filter(|&s| s < n && eligible(s))
        .or_else(|| (0..n).find(|&s| eligible(s)))
}

fn admit(st: &mut CtrlState, role: Role, slot: usize) -> u64 {
    let id = st.next_worker;
    st.next_worker += 1;
    st.workers.insert(id, WorkerInfo { role, slot, last_seen: Instant::now() });
    id
}

/// Note on idempotency: Register rides `ReqClient`, which re-sends
/// after a write-succeeded/read-failed connection break, so one
/// registration can transiently admit two worker ids.  The orphan never
/// heartbeats and is reaped after `heartbeat_timeout_ms` (counted as
/// lost), freeing its slot — self-healing, at the cost of briefly
/// skewed deploy stats on an exactly-sized fleet.
fn handle_register(
    st: &mut CtrlState,
    ctx: &Ctx,
    role: &str,
    slot_hint: i64,
) -> Msg {
    let Some(role) = Role::parse(role) else {
        return Msg::Err(format!(
            "unknown role '{role}' (want {ROLE_LEARNER}|{ROLE_ACTOR}|{ROLE_INF})"
        ));
    };
    if st.stop_all || st.draining {
        // the run is over for new registrants: tell them to exit
        // cleanly instead of parking them in a forever-Retry loop
        return Msg::Shutdown;
    }
    match role {
        Role::Learner => {
            // a slot whose learner already finished must not be handed
            // out again — the replacement would retrain total_steps from
            // scratch and freeze a second set of models
            let slot = pick_slot(slot_hint, st.learners.len(), |s| {
                st.learners[s].worker.is_none() && !st.learners[s].done
            });
            let Some(slot) = slot else {
                let only_done_left =
                    st.learners.iter().any(|l| l.worker.is_none() && l.done);
                return if only_done_left {
                    Msg::Shutdown // that training is complete; exit cleanly
                } else {
                    retry(1_000, "no free learner slot")
                };
            };
            let id = admit(st, Role::Learner, slot);
            let s = &mut st.learners[slot];
            s.worker = Some(id);
            s.steps = 0;
            s.done = false;
            if std::mem::take(&mut s.was_lost) {
                st.reassigned += 1;
            }
            Msg::Assign(WorkerAssignment {
                worker_id: id,
                role: ROLE_LEARNER.into(),
                slot: slot as u32,
                agent: slot as u32,
                li: (slot * ctx.learners_per_agent) as u32,
                league_addr: ctx.league_addr.clone(),
                pool_addrs: ctx.pool_addrs.clone(),
                data_addr: String::new(),
                inf_addr: String::new(),
                run: ctx.slice.clone(),
            })
        }
        Role::Inf => {
            if st.infs.is_empty() && !ctx.autoscale {
                return Msg::Err("this run declares no inf-servers".into());
            }
            let slot = pick_slot(slot_hint, st.infs.len(), |s| {
                let i = &st.infs[s];
                i.worker.is_none() && !i.retired && !i.draining
            });
            let Some(slot) = slot else {
                // under autoscale this parks the worker in the idle
                // pool: the next scale-up opens a slot and its retry
                // lands in it
                return retry(1_000, "no free inf-server slot");
            };
            let id = admit(st, Role::Inf, slot);
            let s = &mut st.infs[slot];
            s.worker = Some(id);
            if std::mem::take(&mut s.was_lost) {
                st.reassigned += 1;
            }
            Msg::Assign(WorkerAssignment {
                worker_id: id,
                role: ROLE_INF.into(),
                slot: slot as u32,
                agent: 0,
                li: 0,
                league_addr: ctx.league_addr.clone(),
                pool_addrs: ctx.pool_addrs.clone(),
                data_addr: String::new(),
                inf_addr: String::new(),
                run: ctx.slice.clone(),
            })
        }
        Role::Actor => {
            // actors need their learner's data port and, when the run
            // declares inf-servers, the FULL declared set of serving
            // addresses — assigning against a partial set would pile
            // every actor onto whichever inf-server reported ready
            // first.  Slots opened beyond the declared count by the
            // autoscaler do NOT gate (a freshly grown, still-empty slot
            // must not stall actor admission); actors spread over
            // whatever is ready once the new server reports in.
            let inf_ready: Vec<String> = st
                .infs
                .iter()
                .filter(|s| !s.retired && !s.draining)
                .filter_map(|s| s.addr.clone())
                .collect();
            let need = ctx.inf_servers.min(
                st.infs.iter().filter(|s| !s.retired && !s.draining).count(),
            );
            if inf_ready.len() < need {
                return retry(300, "waiting for inf-server endpoints");
            }
            let slot = pick_slot(slot_hint, st.actors.len(), |i| {
                let s = &st.actors[i];
                s.worker.is_none()
                    && !s.retired
                    && !s.draining
                    && st.learners[s.agent as usize].data_addrs.len() > s.rank
            });
            let Some(slot) = slot else {
                return if st
                    .actors
                    .iter()
                    .any(|s| s.worker.is_none() && !s.retired && !s.draining)
                {
                    retry(300, "waiting for learner data endpoints")
                } else {
                    retry(1_000, "no free actor slot")
                };
            };
            let id = admit(st, Role::Actor, slot);
            let (agent, rank) = {
                let s = &mut st.actors[slot];
                s.worker = Some(id);
                if std::mem::take(&mut s.was_lost) {
                    st.reassigned += 1;
                }
                (s.agent, s.rank)
            };
            let data_addr = st.learners[agent as usize].data_addrs[rank].clone();
            // slot-stable mapping over every ready server (declared or
            // autoscaled), mirroring thread mode's
            // `id % inf_addrs.len()` balance
            let inf_addr = if inf_ready.is_empty() {
                String::new()
            } else {
                inf_ready[slot % inf_ready.len()].clone()
            };
            Msg::Assign(WorkerAssignment {
                worker_id: id,
                role: ROLE_ACTOR.into(),
                slot: slot as u32,
                agent,
                li: (agent as usize * ctx.learners_per_agent + rank) as u32,
                league_addr: ctx.league_addr.clone(),
                pool_addrs: ctx.pool_addrs.clone(),
                data_addr,
                inf_addr,
                run: ctx.slice.clone(),
            })
        }
    }
}

/// Merge the controller's local service hubs (ModelPool replicas run
/// in-process) into the league view, then derive the merged report —
/// the single code path behind the periodic summary, the JSONL
/// trajectory, and the `StatsQuery` wire probe.
fn merged_report(view: &LeagueView, pool_hubs: &[Arc<MetricsHub>]) -> LeagueReport {
    for (i, h) in pool_hubs.iter().enumerate() {
        view.ingest(&snapshot_role(h, "model-pool", i as u32));
    }
    // services sharing the controller process (pool replicas) record
    // into its flight recorder; fold those spans into the view too
    view.ingest_spans(&trace::recorder().drain(1024));
    view.report()
}

/// The multi-process control plane: CoreServices + worker registry +
/// (optionally) the closed-loop autoscaler.
pub struct Controller {
    pub addr: String,
    pub cfg: RunConfig,
    core: CoreServices,
    state: Arc<OrderedMutex<CtrlState>>,
    /// merged telemetry (worker heartbeat snapshots + local pool hubs)
    view: Arc<LeagueView>,
    pool_hubs: Vec<Arc<MetricsHub>>,
    server: RepServer,
    reaper_stop: Arc<AtomicBool>,
    reaper: Option<std::thread::JoinHandle<()>>,
    autoscaler: Option<std::thread::JoinHandle<()>>,
    actor_bounds: ScaleBounds,
    inf_bounds: ScaleBounds,
    /// sequence for "autoscaler" RoleStats rows (shared with the policy
    /// thread; seq 0 is reserved for "no dedupe")
    scale_seq: Arc<AtomicU64>,
}

impl Controller {
    /// Start CoreServices and the controller protocol server on
    /// `cfg.controller_bind`.  `hp_layout`/`hp_default` come from the
    /// artifact manifest (the controller itself never touches PJRT).
    pub fn start(
        cfg: RunConfig,
        hp_layout: Vec<String>,
        hp_default: Vec<f32>,
    ) -> Result<Controller> {
        cfg.validate()?;
        let bind_host = cfg
            .controller_bind
            .rsplit_once(':')
            .map(|(h, _)| h)
            .filter(|h| !h.is_empty())
            .unwrap_or("127.0.0.1")
            .to_string();
        let core = CoreServices::start(&cfg, &bind_host, hp_layout, hp_default)?;
        if matches!(bind_host.as_str(), "0.0.0.0" | "::" | "[::]")
            && cfg.advertise_host.is_none()
        {
            eprintln!(
                "controller: binding {bind_host} without --advertise-host — \
                 remote workers will receive unroutable {bind_host}:port \
                 endpoints"
            );
        }

        let mut actors = Vec::new();
        for agent in 0..cfg.n_agents {
            for rank in 0..cfg.learners_per_agent {
                for _ in 0..cfg.actors_per_learner {
                    actors.push(ActorSlot {
                        worker: None,
                        agent,
                        rank,
                        was_lost: false,
                        draining: false,
                        retired: false,
                    });
                }
            }
        }
        // scaling bounds: explicit knobs win; 0 derives min=1 (an inf
        // tier only exists when declared) and max = 4x the declared size
        let initial_actors = cfg.n_agents as usize
            * cfg.learners_per_agent
            * cfg.actors_per_learner;
        let actor_bounds = ScaleBounds {
            min: if cfg.min_actor_slots > 0 { cfg.min_actor_slots } else { 1 },
            max: if cfg.max_actor_slots > 0 {
                cfg.max_actor_slots
            } else {
                initial_actors.max(1) * 4
            },
        };
        let inf_bounds = ScaleBounds {
            min: if cfg.min_inf_slots > 0 {
                cfg.min_inf_slots
            } else {
                usize::from(cfg.inf_servers > 0)
            },
            max: if cfg.max_inf_slots > 0 {
                cfg.max_inf_slots
            } else {
                cfg.inf_servers * 4
            },
        };
        let state = Arc::new(OrderedMutex::new(
            "controller.state",
            CtrlState {
                learners: (0..cfg.n_agents).map(|_| LearnerSlot::default()).collect(),
                actors,
                infs: (0..cfg.inf_servers).map(|_| InfSlot::default()).collect(),
                workers: HashMap::new(),
                stats_seq: HashMap::new(),
                next_worker: 1,
                lost: 0,
                reassigned: 0,
                draining: false,
                stop_all: false,
            },
        ));
        if cfg.autoscale {
            // honour explicit minimums from the start — a run declaring
            // min_inf_slots=2 should open both before any signal fires
            let mut st = state.lock();
            let cur = actor_capacity(&st);
            if cur < actor_bounds.min {
                grow_actor_slots(
                    &mut st,
                    actor_bounds.min - cur,
                    actor_bounds.max,
                    cfg.learners_per_agent,
                );
            }
            let cur = inf_capacity(&st);
            if cur < inf_bounds.min {
                grow_inf_slots(&mut st, inf_bounds.min - cur, inf_bounds.max);
            }
        }

        let adv = cfg.advertise_host.as_deref();
        let ctx = Arc::new(Ctx {
            league_addr: super::advertised(&core.league.addr, adv),
            pool_addrs: core
                .pool_addrs
                .iter()
                .map(|a| super::advertised(a, adv))
                .collect(),
            slice: cfg.slice(),
            learners_per_agent: cfg.learners_per_agent,
            inf_servers: cfg.inf_servers,
            autoscale: cfg.autoscale,
        });
        // a slot whose last snapshot predates the heartbeat timeout is
        // stale even before the reaper frees it
        let view = Arc::new(LeagueView::new(Duration::from_millis(
            cfg.heartbeat_timeout_ms.max(1_000),
        )));
        let pool_hubs: Vec<Arc<MetricsHub>> =
            core.pools.iter().map(|p| p.hub().clone()).collect();
        let shard_fns: Vec<_> =
            core.pools.iter().map(|p| p.shard_info_fn()).collect();
        let pool_live = core.pool_live.clone();
        let s2 = state.clone();
        let v2 = view.clone();
        let lpa = cfg.learners_per_agent;
        let server = RepServer::serve(&cfg.controller_bind, move |msg| {
            let mut st = s2.lock();
            match msg {
                Msg::Register { role, slot_hint } => {
                    handle_register(&mut st, &ctx, &role, slot_hint)
                }
                Msg::WorkerReady { worker_id, addrs } => {
                    let Some(w) = st.workers.get(&worker_id) else {
                        return Msg::Err(format!(
                            "unknown worker {worker_id} (re-register)"
                        ));
                    };
                    let (role, slot) = (w.role, w.slot);
                    match role {
                        Role::Learner => {
                            if addrs.len() != lpa {
                                return Msg::Err(format!(
                                    "learner must report {lpa} data ports, got {}",
                                    addrs.len()
                                ));
                            }
                            st.learners[slot].data_addrs = addrs;
                        }
                        Role::Inf => st.infs[slot].addr = addrs.first().cloned(),
                        Role::Actor => {}
                    }
                    Msg::Ok
                }
                Msg::Heartbeat { worker_id, steps, done, stats } => {
                    let stop = st.stop_all;
                    let draining = st.draining;
                    match st.workers.get_mut(&worker_id) {
                        None => Msg::Err(format!(
                            "unknown worker {worker_id} (re-register)"
                        )),
                        Some(w) => {
                            w.last_seen = Instant::now();
                            let (role, slot) = (w.role, w.slot);
                            // merge the piggybacked telemetry snapshot
                            // under the REGISTRY's (role, slot) — the
                            // worker's own claim is not authoritative —
                            // skipping redeliveries of an already-merged
                            // snapshot (same non-zero seq for this slot)
                            if let Some(mut s) = stats {
                                let key = (role, slot);
                                let dup = s.seq != 0
                                    && st.stats_seq.get(&key)
                                        == Some(&s.seq);
                                if !dup {
                                    if s.seq != 0 {
                                        st.stats_seq.insert(key, s.seq);
                                    }
                                    s.role = role.as_str().to_string();
                                    s.slot = slot as u32;
                                    v2.ingest(&s);
                                }
                            }
                            if role == Role::Learner {
                                st.learners[slot].steps = steps;
                                st.learners[slot].done = done;
                            }
                            // per-slot drain: a scale-down stops just
                            // this occupant, not the whole role
                            let slot_draining = match role {
                                Role::Actor => {
                                    let s = &st.actors[slot];
                                    s.draining || s.retired
                                }
                                Role::Inf => {
                                    let s = &st.infs[slot];
                                    s.draining || s.retired
                                }
                                Role::Learner => false,
                            };
                            Msg::HeartbeatAck {
                                stop: stop
                                    || (draining && role == Role::Actor)
                                    || slot_draining,
                            }
                        }
                    }
                }
                Msg::Deregister { worker_id } => {
                    free_slot(&mut st, worker_id, false, &v2);
                    Msg::Ok
                }
                // read-only: the wire probe must not drain the pool
                // hubs' snapshot intervals out from under the periodic
                // reporter (pool rates in the JSONL would otherwise
                // jitter with external probe timing); pool figures are
                // as of the last periodic report
                Msg::StatsQuery => Msg::StatsReply(v2.report()),
                // read-only for the same reason: the trace probe copies
                // the view's span ring + slow log without draining them
                Msg::TraceQuery => Msg::TraceReply(v2.spans()),
                // per-replica shard ownership + store stats for the
                // `stats` CLI pool section; dead replicas are elided
                Msg::PoolShardQuery => Msg::PoolShardReply(
                    shard_fns
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| pool_live[*i].load(Ordering::Relaxed))
                        .map(|(_, f)| f())
                        .collect(),
                ),
                Msg::DeployStats => {
                    let s = stats_of(&st);
                    Msg::DeployStatsReply {
                        workers: s.workers,
                        lost: s.lost,
                        reassigned: s.reassigned,
                        learners_done: s.learners_done,
                        learner_steps: s.learner_steps,
                        draining: s.draining,
                    }
                }
                Msg::Shutdown => {
                    st.draining = true;
                    st.stop_all = true;
                    Msg::Ok
                }
                Msg::Ping => Msg::Pong,
                other => Msg::Err(format!("controller: unexpected {other:?}")),
            }
        })?;

        // ---- reaper: heartbeat timeouts + completion state machine -----
        let reaper_stop = Arc::new(AtomicBool::new(false));
        let rs2 = reaper_stop.clone();
        let s3 = state.clone();
        let v3 = view.clone();
        let timeout = Duration::from_millis(cfg.heartbeat_timeout_ms);
        let reaper = std::thread::Builder::new()
            .name("ctrl-reaper".into())
            .spawn(move || {
                while !rs2.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(
                        (timeout.as_millis() as u64 / 10).clamp(10, 250),
                    ));
                    let mut st = s3.lock();
                    let dead: Vec<u64> = st
                        .workers
                        .iter()
                        .filter(|(_, w)| w.last_seen.elapsed() > timeout)
                        .map(|(&id, _)| id)
                        .collect();
                    for id in dead {
                        let (role, slot) = {
                            let w = &st.workers[&id];
                            (w.role, w.slot)
                        };
                        eprintln!(
                            "controller: worker {id} ({} slot {slot}) lost \
                             heartbeat; freeing slot for reassignment",
                            role.as_str()
                        );
                        free_slot(&mut st, id, true, &v3);
                        st.lost += 1;
                    }
                    // learners all done → drain actors; actors gone →
                    // stop everything (draining latches)
                    if !st.draining
                        && !st.learners.is_empty()
                        && st.learners.iter().all(|l| l.done)
                    {
                        st.draining = true;
                    }
                    if st.draining
                        && !st.stop_all
                        && !st.workers.values().any(|w| w.role == Role::Actor)
                    {
                        st.stop_all = true;
                    }
                }
            })?;

        // ---- closed-loop autoscaler ------------------------------------
        // every scale_every_secs: read the league view's learner
        // staleness and inf-server batch_fill (slot means), decide via
        // the pure policy, apply at most one slot move per role, with a
        // 2x cadence cooldown so a decision's effect is observed before
        // the next one.
        let scale_seq = Arc::new(AtomicU64::new(1));
        let autoscaler = if cfg.autoscale {
            let s4 = state.clone();
            let v4 = view.clone();
            let stop4 = reaper_stop.clone();
            let seq4 = scale_seq.clone();
            let every = Duration::from_secs(cfg.scale_every_secs.max(1));
            let cooldown = every * 2;
            let lpa2 = cfg.learners_per_agent;
            Some(
                std::thread::Builder::new()
                    .name("ctrl-autoscaler".into())
                    .spawn(move || {
                        let mut last_eval = Instant::now();
                        let mut last_actor: Option<Instant> = None;
                        let mut last_inf: Option<Instant> = None;
                        while !stop4.load(Ordering::Relaxed) {
                            std::thread::sleep(Duration::from_millis(50));
                            if last_eval.elapsed() < every {
                                continue;
                            }
                            last_eval = Instant::now();
                            let r = v4.report();
                            let gauge = |role: &str, k: &str| {
                                r.roles
                                    .iter()
                                    .find(|x| x.role == role)
                                    .and_then(|x| {
                                        x.gauges.iter().find(|(n, _)| n == k)
                                    })
                                    .map(|(_, v)| *v)
                            };
                            let staleness = gauge("learner", "staleness");
                            let fill = gauge("inf-server", "batch_fill");
                            let mut st = s4.lock();
                            if st.stop_all || st.draining {
                                continue;
                            }
                            let (da, di) = policy_decide(
                                staleness,
                                fill,
                                actor_capacity(&st),
                                inf_capacity(&st),
                                actor_bounds,
                                inf_bounds,
                            );
                            let cooled = |t: &Option<Instant>| {
                                t.map_or(true, |t| t.elapsed() >= cooldown)
                            };
                            let (mut up_a, mut down_a) = (0u64, 0u64);
                            let (mut up_i, mut down_i) = (0u64, 0u64);
                            if da != 0 && cooled(&last_actor) {
                                let n = if da > 0 {
                                    grow_actor_slots(
                                        &mut st,
                                        1,
                                        actor_bounds.max,
                                        lpa2,
                                    )
                                } else {
                                    drain_actor_slots(&mut st, 1, actor_bounds.min)
                                };
                                if n > 0 {
                                    if da > 0 {
                                        up_a = n as u64;
                                    } else {
                                        down_a = n as u64;
                                    }
                                    last_actor = Some(Instant::now());
                                }
                            }
                            if di != 0 && cooled(&last_inf) {
                                let n = if di > 0 {
                                    grow_inf_slots(&mut st, 1, inf_bounds.max)
                                } else {
                                    drain_inf_slots(&mut st, 1, inf_bounds.min)
                                };
                                if n > 0 {
                                    if di > 0 {
                                        up_i = n as u64;
                                    } else {
                                        down_i = n as u64;
                                    }
                                    last_inf = Some(Instant::now());
                                }
                            }
                            if up_a + down_a + up_i + down_i > 0 {
                                note_scale(
                                    &v4, &seq4, &st, up_a, down_a, up_i, down_i,
                                );
                                eprintln!(
                                    "controller: autoscale actors {:+} infs \
                                     {:+} -> {} actor / {} inf slots \
                                     (staleness {} batch_fill {})",
                                    up_a as i64 - down_a as i64,
                                    up_i as i64 - down_i as i64,
                                    actor_capacity(&st),
                                    inf_capacity(&st),
                                    staleness
                                        .map(|v| format!("{v:.2}"))
                                        .unwrap_or_else(|| "n/a".into()),
                                    fill.map(|v| format!("{v:.2}"))
                                        .unwrap_or_else(|| "n/a".into()),
                                );
                            }
                        }
                    })?,
            )
        } else {
            None
        };

        Ok(Controller {
            addr: server.addr.clone(),
            cfg,
            core,
            state,
            view,
            pool_hubs,
            server,
            reaper_stop,
            reaper: Some(reaper),
            autoscaler,
            actor_bounds,
            inf_bounds,
            scale_seq,
        })
    }

    /// Operator/test entry into the elastic slot table: grow
    /// (`delta > 0`) or drain (`delta < 0`) `|delta|` slots of `role`
    /// ("actor" | "inf-server"), clamped to the configured bounds.  The
    /// learner topology is fixed by `n_agents` and cannot be scaled.
    /// Returns how many slots actually changed state; every applied
    /// change is published as an "autoscaler" telemetry row.
    pub fn request_scale(&self, role: &str, delta: i64) -> usize {
        let Some(role) = Role::parse(role) else { return 0 };
        let mut st = self.state.lock();
        let n = delta.unsigned_abs() as usize;
        let applied = match (role, delta >= 0) {
            (Role::Actor, true) => grow_actor_slots(
                &mut st,
                n,
                self.actor_bounds.max,
                self.cfg.learners_per_agent,
            ),
            (Role::Actor, false) => {
                drain_actor_slots(&mut st, n, self.actor_bounds.min)
            }
            (Role::Inf, true) => grow_inf_slots(&mut st, n, self.inf_bounds.max),
            (Role::Inf, false) => {
                drain_inf_slots(&mut st, n, self.inf_bounds.min)
            }
            (Role::Learner, _) => 0,
        };
        if applied > 0 {
            let a = applied as u64;
            let (up_a, down_a, up_i, down_i) = match (role, delta >= 0) {
                (Role::Actor, true) => (a, 0, 0, 0),
                (Role::Actor, false) => (0, a, 0, 0),
                (Role::Inf, true) => (0, 0, a, 0),
                (Role::Inf, false) => (0, 0, 0, a),
                (Role::Learner, _) => (0, 0, 0, 0),
            };
            note_scale(
                &self.view, &self.scale_seq, &st, up_a, down_a, up_i, down_i,
            );
            eprintln!(
                "controller: scale {} {delta:+} applied {applied} -> {} actor \
                 / {} inf slots",
                role.as_str(),
                actor_capacity(&st),
                inf_capacity(&st),
            );
        }
        applied
    }

    pub fn league(&self) -> &crate::league::LeagueMgrServer {
        &self.core.league
    }

    pub fn pool_addrs(&self) -> &[String] {
        &self.core.pool_addrs
    }

    pub fn league_stats(&self) -> LeagueStats {
        self.core.league.stats()
    }

    pub fn deploy_stats(&self) -> DeployStatsSnap {
        stats_of(&self.state.lock())
    }

    /// Merged league telemetry: worker heartbeat snapshots plus the
    /// in-process ModelPool hubs (same path `Msg::StatsQuery` serves).
    pub fn telemetry_report(&self) -> LeagueReport {
        merged_report(&self.view, &self.pool_hubs)
    }

    /// Recent + slow request spans accumulated in the league view (same
    /// data `Msg::TraceQuery` serves over the wire).
    pub fn trace_spans(&self) -> Vec<crate::proto::SpanRec> {
        self.view.spans()
    }

    pub fn learners_done(&self) -> bool {
        let st = self.state.lock();
        !st.learners.is_empty() && st.learners.iter().all(|l| l.done)
    }

    /// Block until every learner slot reports done (or `timeout`).
    pub fn wait(&self, timeout: Duration) -> bool {
        let start = Instant::now();
        while !self.learners_done() {
            if start.elapsed() > timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        true
    }

    fn wait_workers(&self, pred: impl Fn(&CtrlState) -> bool, grace: Duration) {
        let start = Instant::now();
        while start.elapsed() < grace {
            if pred(&self.state.lock()) {
                return;
            }
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    /// Graceful stop: drain actors via heartbeat acks, then learners +
    /// inf-servers, then take the final snapshot.  Worker processes exit
    /// on their own; a grace period bounds each phase.  Idempotent —
    /// Drop re-invokes this after an explicit call, and a second run
    /// (reaper already joined, stuck entries unclearable) must not sit
    /// out the grace periods again.
    pub fn shutdown(&mut self) {
        if self.reaper.is_none() {
            return; // already shut down
        }
        self.state.lock().draining = true;
        self.wait_workers(
            |st| !st.workers.values().any(|w| w.role == Role::Actor),
            Duration::from_secs(10),
        );
        self.state.lock().stop_all = true;
        self.wait_workers(|st| st.workers.is_empty(), Duration::from_secs(10));
        self.reaper_stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.reaper.take() {
            h.join().ok();
        }
        if let Some(h) = self.autoscaler.take() {
            h.join().ok();
        }
        self.server.shutdown();
        // every worker is gone (or timed out): pools hold everything the
        // learners will ever publish, so the final snapshot is complete
        self.core.shutdown();
    }

    /// Force a league + pool snapshot right now (chaos drills take one
    /// before crashing the controller so recovery has something to
    /// resume from).  Requires `cfg.checkpoint_dir`.
    pub fn snapshot_now(&self) -> Result<std::path::PathBuf> {
        self.core.snapshot_now(&self.cfg)
    }

    /// Chaos drill: SIGKILL-equivalent death of the control plane.  No
    /// draining, no stop acks, no final snapshot — ports simply close.
    /// Workers discover it via failed heartbeats and re-register against
    /// the successor started from the last snapshot on the same bind.
    /// After this, `shutdown()` (and Drop) are no-ops — the crashed
    /// value can be overwritten with a restarted Controller in place.
    pub fn crash(&mut self) {
        if self.reaper.is_none() {
            return; // already crashed / shut down
        }
        self.reaper_stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.reaper.take() {
            h.join().ok();
        }
        if let Some(h) = self.autoscaler.take() {
            h.join().ok();
        }
        self.server.shutdown();
        self.core.crash();
    }

    /// Chaos drill: kill one in-process ModelPool replica (they live
    /// inside the controller process, so the schedule can't SIGKILL
    /// them individually) and run the real failover — tombstone the
    /// shard map, rebalance the survivors back to R owners per agent,
    /// and verify the union of live stores is bit-exact with the
    /// pre-kill state.  Stops the highest-index live replica — never
    /// replica 0, whose spill dir may back a resume.  Returns the
    /// downed address, the rebalance transfer stats, and the
    /// bit-exactness verdict; None if no replica can be spared.
    pub fn chaos_kill_pool(&mut self) -> Option<(String, MoveStats, bool)> {
        self.core.kill_pool()
    }
}

impl Drop for Controller {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::ReqClient;

    /// A controller for protocol tests: no engine, no PJRT — rps league
    /// topology.  The generous default timeout keeps tests that don't
    /// exercise reaping immune to CI scheduling stalls.
    fn ctrl_with(
        n_actors: usize,
        inf_servers: usize,
        timeout_ms: u64,
    ) -> Controller {
        let mut cfg = RunConfig::default();
        cfg.env = "rps".into();
        cfg.mode = "procs".into();
        cfg.actors_per_learner = n_actors;
        cfg.inf_servers = inf_servers;
        cfg.heartbeat_ms = 50;
        cfg.heartbeat_timeout_ms = timeout_ms;
        Controller::start(cfg, vec!["lr".into()], vec![3e-4]).unwrap()
    }

    fn ctrl(n_actors: usize, inf_servers: usize) -> Controller {
        ctrl_with(n_actors, inf_servers, 3_000)
    }

    fn register(c: &ReqClient, role: &str, hint: i64) -> Msg {
        c.request(&Msg::Register { role: role.into(), slot_hint: hint })
            .unwrap()
    }

    #[test]
    fn assignment_flow_and_dependency_gating() {
        let ctrl = ctrl(2, 0);
        let c = ReqClient::connect(&ctrl.addr);

        // actor before any learner endpoint: must be told to retry
        match register(&c, ROLE_ACTOR, -1) {
            Msg::Retry { .. } => {}
            other => panic!("expected Retry, got {other:?}"),
        }

        // learner registers and reports its data port
        let asn = match register(&c, ROLE_LEARNER, -1) {
            Msg::Assign(a) => a,
            other => panic!("expected Assign, got {other:?}"),
        };
        assert_eq!(asn.role, ROLE_LEARNER);
        assert_eq!(asn.agent, 0);
        assert!(!asn.pool_addrs.is_empty());
        assert!(!asn.league_addr.is_empty());
        assert_eq!(asn.run.env, "rps");
        let reply = c
            .request(&Msg::WorkerReady {
                worker_id: asn.worker_id,
                addrs: vec!["127.0.0.1:40001".into()],
            })
            .unwrap();
        assert_eq!(reply, Msg::Ok);

        // both actor slots now assign, with the learner's data addr
        for slot in 0..2u32 {
            let a = match register(&c, ROLE_ACTOR, -1) {
                Msg::Assign(a) => a,
                other => panic!("expected Assign, got {other:?}"),
            };
            assert_eq!(a.slot, slot);
            assert_eq!(a.data_addr, "127.0.0.1:40001");
            assert_eq!(a.inf_addr, "", "no inf-servers declared");
        }
        // a third actor has no slot
        match register(&c, ROLE_ACTOR, -1) {
            Msg::Retry { reason, .. } => {
                assert!(reason.contains("no free actor slot"), "{reason}")
            }
            other => panic!("expected Retry, got {other:?}"),
        }
        // registering an undeclared role fails loudly
        assert!(matches!(register(&c, "inf-server", -1), Msg::Err(_)));
        assert!(matches!(register(&c, "driver", -1), Msg::Err(_)));
    }

    /// With an advertise host, every address handed to workers carries
    /// it (binding 0.0.0.0 would otherwise publish unroutable
    /// endpoints to remote machines).
    #[test]
    fn advertise_host_rewrites_assignment_addresses() {
        let mut cfg = RunConfig::default();
        cfg.env = "rps".into();
        cfg.mode = "procs".into();
        cfg.advertise_host = Some("ctrl.example".into());
        let ctrl = Controller::start(cfg, vec!["lr".into()], vec![3e-4]).unwrap();
        let c = ReqClient::connect(&ctrl.addr);
        let asn = match register(&c, ROLE_LEARNER, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        assert!(
            asn.league_addr.starts_with("ctrl.example:"),
            "league addr {}",
            asn.league_addr
        );
        for p in &asn.pool_addrs {
            assert!(p.starts_with("ctrl.example:"), "pool addr {p}");
        }
    }

    #[test]
    fn heartbeat_timeout_frees_slot_and_reassigns() {
        let ctrl = ctrl_with(1, 0, 300);
        let c = ReqClient::connect(&ctrl.addr);
        let learner = match register(&c, ROLE_LEARNER, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        c.request(&Msg::WorkerReady {
            worker_id: learner.worker_id,
            addrs: vec!["127.0.0.1:40002".into()],
        })
        .unwrap();
        let actor = match register(&c, ROLE_ACTOR, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };

        // keep the learner alive; let the actor go silent
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            assert!(Instant::now() < deadline, "loss never detected");
            c.request(&Msg::Heartbeat {
                worker_id: learner.worker_id,
                steps: 1,
                done: false,
                stats: None,
            })
            .unwrap();
            if ctrl.deploy_stats().lost >= 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        // the dead actor's heartbeat now gets unknown-worker
        match c
            .request(&Msg::Heartbeat {
                worker_id: actor.worker_id,
                steps: 0,
                done: false,
                stats: None,
            })
            .unwrap()
        {
            Msg::Err(e) => assert!(e.contains("unknown worker"), "{e}"),
            other => panic!("expected Err, got {other:?}"),
        }
        // a respawned worker re-registers with its old slot as a hint
        // and gets the same slot back
        let again = match register(&c, ROLE_ACTOR, actor.slot as i64) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        assert_eq!(again.slot, actor.slot);
        assert_ne!(again.worker_id, actor.worker_id);
        // >=: the learner may also get reaped if this thread stalls
        let stats = ctrl.deploy_stats();
        assert!(stats.lost >= 1, "lost {}", stats.lost);
        assert!(stats.reassigned >= 1, "reassigned {}", stats.reassigned);
    }

    #[test]
    fn drain_stops_actors_after_learners_finish() {
        let ctrl = ctrl(1, 0);
        let c = ReqClient::connect(&ctrl.addr);
        let learner = match register(&c, ROLE_LEARNER, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        c.request(&Msg::WorkerReady {
            worker_id: learner.worker_id,
            addrs: vec!["127.0.0.1:40003".into()],
        })
        .unwrap();
        let actor = match register(&c, ROLE_ACTOR, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };

        // learner reports done; the reaper flips to draining and actor
        // heartbeats start acking stop=true.  Keep both heartbeating so
        // neither gets reaped while we wait.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            assert!(Instant::now() < deadline, "never told to stop");
            c.request(&Msg::Heartbeat {
                worker_id: learner.worker_id,
                steps: 100,
                done: true,
                stats: None,
            })
            .unwrap();
            match c
                .request(&Msg::Heartbeat {
                    worker_id: actor.worker_id,
                    steps: 0,
                    done: false,
                    stats: None,
                })
                .unwrap()
            {
                Msg::HeartbeatAck { stop: true } => break,
                Msg::HeartbeatAck { stop: false } => {
                    std::thread::sleep(Duration::from_millis(25))
                }
                other => panic!("{other:?}"),
            }
        }
        assert!(ctrl.learners_done());
        // both obey and deregister cleanly: no loss counted
        c.request(&Msg::Deregister { worker_id: actor.worker_id }).unwrap();
        c.request(&Msg::Deregister { worker_id: learner.worker_id }).unwrap();
        assert_eq!(ctrl.deploy_stats().lost, 0);
        // a new registration during drain is told to exit, not parked
        assert!(matches!(register(&c, ROLE_ACTOR, -1), Msg::Shutdown));
    }

    /// A learner slot whose training already finished must never be
    /// handed to a replacement (it would retrain total_steps from
    /// scratch and freeze duplicate models): with only done slots free,
    /// the registrant is told to exit.
    #[test]
    fn finished_learner_slot_is_not_reassigned() {
        let mut cfg = RunConfig::default();
        cfg.env = "rps".into();
        cfg.mode = "procs".into();
        cfg.n_agents = 2; // second agent keeps the drain latch open
        cfg.heartbeat_ms = 50;
        cfg.heartbeat_timeout_ms = 3_000;
        let ctrl = Controller::start(cfg, vec!["lr".into()], vec![3e-4]).unwrap();
        let c = ReqClient::connect(&ctrl.addr);
        let l0 = match register(&c, ROLE_LEARNER, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        let _l1 = match register(&c, ROLE_LEARNER, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        // agent 0 finishes, then its worker goes away cleanly
        c.request(&Msg::Heartbeat {
            worker_id: l0.worker_id,
            steps: 100,
            done: true,
            stats: None,
        })
        .unwrap();
        c.request(&Msg::Deregister { worker_id: l0.worker_id }).unwrap();
        // the respawned worker asks for its old slot back: told to exit
        // (agent 1's slot is occupied, agent 0's is complete)
        assert!(matches!(
            register(&c, ROLE_LEARNER, l0.slot as i64),
            Msg::Shutdown
        ));
        assert!(!ctrl.learners_done(), "agent 1 still training");
    }

    use crate::proto::RoleStats;

    fn stats(
        counters: &[(&str, u64)],
        gauges: &[(&str, f64)],
    ) -> Option<RoleStats> {
        // each canned snapshot gets a fresh sequence number, mirroring
        // the worker heartbeat thread (equal seqs are retransmits)
        static SEQ: std::sync::atomic::AtomicU64 =
            std::sync::atomic::AtomicU64::new(1);
        Some(RoleStats {
            role: String::new(), // controller overrides from its registry
            slot: 9999,
            seq: SEQ.fetch_add(1, Ordering::Relaxed),
            interval_ms: 1_000,
            counters: counters
                .iter()
                .map(|(k, v)| (k.to_string(), *v))
                .collect(),
            gauges: gauges.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
            ..Default::default()
        })
    }

    fn beat(c: &ReqClient, worker_id: u64, stats: Option<RoleStats>) {
        match c
            .request(&Msg::Heartbeat { worker_id, steps: 0, done: false, stats })
            .unwrap()
        {
            Msg::HeartbeatAck { .. } => {}
            other => panic!("expected ack, got {other:?}"),
        }
    }

    fn role<'a>(
        r: &'a crate::proto::LeagueReport,
        name: &str,
    ) -> &'a crate::proto::RoleReport {
        r.roles
            .iter()
            .find(|x| x.role == name)
            .unwrap_or_else(|| panic!("role {name} missing from {r:?}"))
    }

    fn rate(r: &crate::proto::RoleReport, k: &str) -> f64 {
        r.rates
            .iter()
            .find(|(n, _)| n == k)
            .map(|(_, v)| *v)
            .unwrap_or(f64::NAN)
    }

    fn total(r: &crate::proto::RoleReport, k: &str) -> u64 {
        r.totals
            .iter()
            .find(|(n, _)| n == k)
            .map(|(_, v)| *v)
            .unwrap_or(u64::MAX)
    }

    /// Canned worker snapshots merge into a league-wide view: per-role
    /// rates sum over slots, totals accumulate, and a worker joining
    /// mid-window contributes from its first heartbeat.
    #[test]
    fn telemetry_merges_role_snapshots() {
        let ctrl = ctrl(2, 0);
        let c = ReqClient::connect(&ctrl.addr);
        let learner = match register(&c, ROLE_LEARNER, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        c.request(&Msg::WorkerReady {
            worker_id: learner.worker_id,
            addrs: vec!["127.0.0.1:40010".into()],
        })
        .unwrap();
        let a0 = match register(&c, ROLE_ACTOR, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };

        beat(
            &c,
            learner.worker_id,
            stats(&[("consumed_frames", 50)], &[("staleness", 1.0)]),
        );
        // the worker's own role/slot claim is NOT authoritative — the
        // registry's assignment wins (this one lies about being a
        // learner in slot 9999)
        beat(&c, a0.worker_id, stats(&[("env_frames", 100)], &[]));
        let r = ctrl.telemetry_report();
        assert_eq!(role(&r, "actor").slots, 1);
        assert!((rate(role(&r, "actor"), "env_frames") - 100.0).abs() < 1e-9);
        assert_eq!(total(role(&r, "actor"), "env_frames"), 100);
        assert!(
            (rate(role(&r, "learner"), "consumed_frames") - 50.0).abs() < 1e-9
        );
        assert_eq!(
            role(&r, "learner").gauges,
            vec![("staleness".into(), 1.0)]
        );
        // the controller's in-process pool replicas report too
        assert_eq!(role(&r, "model-pool").slots, 1);

        // a second actor joins mid-window: the next report includes it
        let a1 = match register(&c, ROLE_ACTOR, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        beat(&c, a0.worker_id, stats(&[("env_frames", 60)], &[]));
        beat(&c, a1.worker_id, stats(&[("env_frames", 300)], &[]));
        let r = ctrl.telemetry_report();
        assert_eq!(role(&r, "actor").slots, 2);
        assert!((rate(role(&r, "actor"), "env_frames") - 360.0).abs() < 1e-9);
        assert_eq!(total(role(&r, "actor"), "env_frames"), 460);

        // the wire probe serves the same merged view
        match c.request(&Msg::StatsQuery).unwrap() {
            Msg::StatsReply(wire) => {
                assert_eq!(total(role(&wire, "actor"), "env_frames"), 460);
            }
            other => panic!("expected StatsReply, got {other:?}"),
        }

        // a retransmitted snapshot (same worker, same seq — ReqClient
        // re-sends after a connection break) must not double-count the
        // deltas in the run totals
        let dup = stats(&[("env_frames", 1_000)], &[]);
        beat(&c, a0.worker_id, dup.clone());
        beat(&c, a0.worker_id, dup);
        let r = ctrl.telemetry_report();
        assert_eq!(
            total(role(&r, "actor"), "env_frames"),
            1_460,
            "retransmit was double-counted: {r:?}"
        );
    }

    /// A reaped (lost-heartbeat) worker's rates and gauges must drop out
    /// of the league view instead of freezing at their last value; its
    /// already-counted totals remain.
    #[test]
    fn reaped_worker_drops_gauges_from_view() {
        let ctrl = ctrl_with(1, 0, 300);
        let c = ReqClient::connect(&ctrl.addr);
        let learner = match register(&c, ROLE_LEARNER, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        c.request(&Msg::WorkerReady {
            worker_id: learner.worker_id,
            addrs: vec!["127.0.0.1:40011".into()],
        })
        .unwrap();
        let actor = match register(&c, ROLE_ACTOR, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        beat(
            &c,
            actor.worker_id,
            stats(&[("env_frames", 100)], &[("lag", 7.0)]),
        );
        let r = ctrl.telemetry_report();
        assert_eq!(role(&r, "actor").slots, 1);
        assert_eq!(role(&r, "actor").gauges, vec![("lag".into(), 7.0)]);

        // the actor goes silent; keep the learner alive until the
        // reaper frees the actor slot
        let deadline = Instant::now() + Duration::from_secs(10);
        while ctrl.deploy_stats().lost == 0 {
            assert!(Instant::now() < deadline, "loss never detected");
            beat(&c, learner.worker_id, None);
            std::thread::sleep(Duration::from_millis(50));
        }
        let r = ctrl.telemetry_report();
        assert_eq!(role(&r, "actor").slots, 0, "reaped slot still live: {r:?}");
        assert!(role(&r, "actor").gauges.is_empty(), "gauges froze: {r:?}");
        assert!(role(&r, "actor").rates.is_empty(), "rates froze: {r:?}");
        assert_eq!(
            total(role(&r, "actor"), "env_frames"),
            100,
            "already-counted frames must survive the reap"
        );
    }

    #[test]
    fn inf_server_gates_actor_assignment() {
        let ctrl = ctrl(1, 1);
        let c = ReqClient::connect(&ctrl.addr);
        let learner = match register(&c, ROLE_LEARNER, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        c.request(&Msg::WorkerReady {
            worker_id: learner.worker_id,
            addrs: vec!["127.0.0.1:40004".into()],
        })
        .unwrap();
        // learner ready but no inf endpoint yet: actors must wait
        match register(&c, ROLE_ACTOR, -1) {
            Msg::Retry { reason, .. } => {
                assert!(reason.contains("inf-server"), "{reason}")
            }
            other => panic!("{other:?}"),
        }
        let inf = match register(&c, ROLE_INF, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        assert_eq!(inf.role, ROLE_INF);
        c.request(&Msg::WorkerReady {
            worker_id: inf.worker_id,
            addrs: vec!["127.0.0.1:40005".into()],
        })
        .unwrap();
        let actor = match register(&c, ROLE_ACTOR, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        assert_eq!(actor.inf_addr, "127.0.0.1:40005");
    }

    /// The pure policy: thresholds fire in the right direction and the
    /// bounds clamp both ways.
    #[test]
    fn policy_decide_thresholds_and_bounds() {
        let ab = ScaleBounds { min: 1, max: 8 };
        let ib = ScaleBounds { min: 1, max: 4 };
        // saturated inf tier grows; idle one shrinks; mid-band holds
        assert_eq!(policy_decide(None, Some(0.9), 4, 2, ab, ib), (0, 1));
        assert_eq!(policy_decide(None, Some(0.1), 4, 2, ab, ib), (0, -1));
        assert_eq!(policy_decide(None, Some(0.5), 4, 2, ab, ib), (0, 0));
        // starved learner grows actors; runaway staleness drains them
        assert_eq!(policy_decide(Some(0.5), None, 4, 2, ab, ib), (1, 0));
        assert_eq!(policy_decide(Some(5.0), None, 4, 2, ab, ib), (-1, 0));
        assert_eq!(policy_decide(Some(2.0), None, 4, 2, ab, ib), (0, 0));
        // bounds clamp: at max nothing grows, at min nothing drains
        assert_eq!(policy_decide(Some(0.5), Some(0.9), 8, 4, ab, ib), (0, 0));
        assert_eq!(policy_decide(Some(5.0), Some(0.1), 1, 1, ab, ib), (0, 0));
        // no signal, no move
        assert_eq!(policy_decide(None, None, 4, 2, ab, ib), (0, 0));
    }

    /// The elastic slot table end to end: a late worker is admitted only
    /// after a scale-up opens a slot; a scale-down drains exactly one
    /// occupant (per-slot stop ack) and its clean exit retires the slot
    /// rather than re-handing it out.
    #[test]
    fn request_scale_grows_and_drains_actor_slots() {
        let ctrl = ctrl(1, 0);
        let c = ReqClient::connect(&ctrl.addr);
        let learner = match register(&c, ROLE_LEARNER, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        c.request(&Msg::WorkerReady {
            worker_id: learner.worker_id,
            addrs: vec!["127.0.0.1:40020".into()],
        })
        .unwrap();
        let a0 = match register(&c, ROLE_ACTOR, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        // the single declared slot is taken: a late joiner parks
        assert!(matches!(register(&c, ROLE_ACTOR, -1), Msg::Retry { .. }));
        assert_eq!(ctrl.deploy_stats().actor_slots, 1);

        // grow: the late joiner's retry now lands in the new slot
        assert_eq!(ctrl.request_scale(ROLE_ACTOR, 2), 2);
        assert_eq!(ctrl.deploy_stats().actor_slots, 3);
        let a1 = match register(&c, ROLE_ACTOR, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        assert_ne!(a1.slot, a0.slot);
        assert_eq!(a1.data_addr, "127.0.0.1:40020");

        // drain one: the empty grown slot retires instantly (capacity
        // 2), and no occupant is told to stop
        assert_eq!(ctrl.request_scale(ROLE_ACTOR, -1), 1);
        assert_eq!(ctrl.deploy_stats().actor_slots, 2);
        match c
            .request(&Msg::Heartbeat {
                worker_id: a1.worker_id,
                steps: 0,
                done: false,
                stats: None,
            })
            .unwrap()
        {
            Msg::HeartbeatAck { stop: false } => {}
            other => panic!("{other:?}"),
        }

        // drain again: both remaining slots are occupied, so the
        // highest-index occupant is told to stop — the other is not
        assert_eq!(ctrl.request_scale(ROLE_ACTOR, -1), 1);
        let ack = |id| match c
            .request(&Msg::Heartbeat {
                worker_id: id,
                steps: 0,
                done: false,
                stats: None,
            })
            .unwrap()
        {
            Msg::HeartbeatAck { stop } => stop,
            other => panic!("{other:?}"),
        };
        assert!(ack(a1.worker_id), "draining occupant must be stopped");
        assert!(!ack(a0.worker_id), "survivor must keep running");
        // clean exit retires the slot: capacity drops and the slot is
        // not handed back out even with a hint
        c.request(&Msg::Deregister { worker_id: a1.worker_id }).unwrap();
        assert_eq!(ctrl.deploy_stats().actor_slots, 1);
        match register(&c, ROLE_ACTOR, a1.slot as i64) {
            Msg::Retry { .. } => {}
            other => panic!("retired slot was re-handed out: {other:?}"),
        }
        // floor: min_actor_slots derives to 1, so the last slot stays
        assert_eq!(ctrl.request_scale(ROLE_ACTOR, -1), 0);
        // scaling telemetry rides the normal league view
        let r = ctrl.telemetry_report();
        let auto = role(&r, "autoscaler");
        assert_eq!(total(auto, "scale_up_actor"), 2);
        assert_eq!(total(auto, "scale_down_actor"), 2);
    }

    /// A run that declares inf-servers can grow the tier at runtime: the
    /// late-joining worker parked in Retry is admitted into the new
    /// slot, and new actors spread over every READY server.
    #[test]
    fn scaled_up_inf_slot_admits_late_worker() {
        let ctrl = ctrl(2, 1);
        let c = ReqClient::connect(&ctrl.addr);
        let learner = match register(&c, ROLE_LEARNER, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        c.request(&Msg::WorkerReady {
            worker_id: learner.worker_id,
            addrs: vec!["127.0.0.1:40021".into()],
        })
        .unwrap();
        let inf0 = match register(&c, ROLE_INF, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        c.request(&Msg::WorkerReady {
            worker_id: inf0.worker_id,
            addrs: vec!["127.0.0.1:40022".into()],
        })
        .unwrap();
        // declared capacity is full: a surplus inf worker parks
        assert!(matches!(register(&c, ROLE_INF, -1), Msg::Retry { .. }));

        assert_eq!(ctrl.request_scale(ROLE_INF, 1), 1);
        assert_eq!(ctrl.deploy_stats().inf_slots, 2);
        // the new, still-empty slot must NOT gate actor admission
        let a0 = match register(&c, ROLE_ACTOR, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        assert_eq!(a0.inf_addr, "127.0.0.1:40022");
        // the parked worker's retry lands in the grown slot
        let inf1 = match register(&c, ROLE_INF, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        assert_ne!(inf1.slot, inf0.slot);
        c.request(&Msg::WorkerReady {
            worker_id: inf1.worker_id,
            addrs: vec!["127.0.0.1:40023".into()],
        })
        .unwrap();
        // slot-stable spread over both ready servers
        let a1 = match register(&c, ROLE_ACTOR, -1) {
            Msg::Assign(a) => a,
            other => panic!("{other:?}"),
        };
        let expect = ["127.0.0.1:40022", "127.0.0.1:40023"]
            [a1.slot as usize % 2];
        assert_eq!(a1.inf_addr, expect);
    }

    /// The wire probe behind the `stats` CLI pool section: one
    /// PoolShardInfo per live replica, consistent shard-map versions,
    /// and after a kill:pool drill the dead replica is elided while the
    /// survivors report the bumped map.
    #[test]
    fn pool_shard_query_reports_live_replicas() {
        let mut cfg = RunConfig::default();
        cfg.env = "rps".into();
        cfg.mode = "procs".into();
        cfg.model_pools = 3;
        cfg.pool_replication = 2;
        cfg.heartbeat_ms = 50;
        cfg.heartbeat_timeout_ms = 3_000;
        let mut ctrl =
            Controller::start(cfg, vec!["lr".into()], vec![3e-4]).unwrap();
        let c = ReqClient::connect(&ctrl.addr);
        let infos = match c.request(&Msg::PoolShardQuery).unwrap() {
            Msg::PoolShardReply(v) => v,
            other => panic!("{other:?}"),
        };
        assert_eq!(infos.len(), 3);
        for (i, inf) in infos.iter().enumerate() {
            assert_eq!(inf.replica, i as u32);
            assert_eq!(inf.map_version, 1);
            assert!(!inf.addr.is_empty());
        }
        // kill one replica: the probe elides it and survivors hold v2
        let (addr, _moved, bit_exact) = ctrl.chaos_kill_pool().unwrap();
        assert_eq!(addr, infos[2].addr);
        assert!(bit_exact, "empty pools must trivially round-trip");
        let infos = match c.request(&Msg::PoolShardQuery).unwrap() {
            Msg::PoolShardReply(v) => v,
            other => panic!("{other:?}"),
        };
        assert_eq!(infos.len(), 2);
        for inf in &infos {
            assert_eq!(inf.map_version, 2);
        }
        // a second kill still has a survivor to fail over to...
        let (addr2, _, _) = ctrl.chaos_kill_pool().unwrap();
        assert_ne!(addr2, addr);
        // ...but the last live replica is never sacrificed
        assert!(ctrl.chaos_kill_pool().is_none());
    }
}
