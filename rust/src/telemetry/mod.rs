//! Telemetry plane: league-wide metric aggregation (DESIGN.md
//! §Telemetry plane).
//!
//! Every role instance owns a [`MetricsHub`]; [`snapshot_role`] drains
//! one reporting interval from it into a [`RoleStats`] (counter deltas
//! + rolling gauges).  Workers piggyback that snapshot on their
//! heartbeat; the controller feeds it into a [`LeagueView`], which
//! merges per-(role, slot) entries into a [`LeagueReport`]: current
//! rates summed over live slots, cumulative totals over the whole run,
//! and gauge means.  Thread mode snapshots its in-process hubs into the
//! SAME `LeagueView`, so both deployment modes report through one code
//! path.
//!
//! The merged report renders three ways: a one-line periodic summary
//! ([`summary_line`]), a JSONL trajectory row ([`jsonl_line`] /
//! [`JsonlSink`]) for offline plots, and the `Msg::StatsReply` wire
//! message behind the `stats` CLI subcommand.

pub mod trace;

use crate::proto::{LeagueReport, RoleReport, RoleStats, SpanRec};
use crate::util::json::Json;
use crate::util::metrics::{Hist, MetricsHub, HIST_BUCKETS};
use std::collections::{BTreeMap, VecDeque};
use std::io::Write;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Drain one reporting interval from `hub` into the wire snapshot for
/// role instance (`role`, `slot`).  One periodic caller per hub — the
/// deltas are consumed.  Spans are NOT filled here: the flight recorder
/// is process-global, not per-hub, so the caller drains it separately
/// (one drain per heartbeat, not one per hub).
pub fn snapshot_role(hub: &MetricsHub, role: &str, slot: u32) -> RoleStats {
    let s = hub.snapshot();
    RoleStats {
        role: role.to_string(),
        slot,
        // in-process ingests never retransmit; workers stamp their own
        // sequence numbers before sending (see worker::spawn_heartbeat)
        seq: 0,
        interval_ms: (s.interval_secs * 1e3) as u64,
        counters: s.counters,
        gauges: s.gauges,
        hists: s.hists,
        spans: Vec::new(),
    }
}

struct SlotEntry {
    /// counter → events/s over the slot's latest reported interval
    rates: BTreeMap<String, f64>,
    gauges: BTreeMap<String, f64>,
    last_seen: Instant,
}

/// Merged flight-recorder capacity at the view (league) level.
const VIEW_SPAN_CAP: usize = 16_384;
const VIEW_SLOW_CAP: usize = 1_024;

#[derive(Default)]
struct ViewInner {
    slots: BTreeMap<(String, u32), SlotEntry>,
    /// (role, counter) → cumulative events across the whole run; reaped
    /// slots keep their contribution (their frames were real)
    totals: BTreeMap<(String, String), u64>,
    /// (role, hist name) → cumulative bucket counts across the run.
    /// Like `totals`, reaped slots keep their contribution, so the
    /// percentiles never regress when a worker restarts.
    hist_totals: BTreeMap<(String, String), [u64; HIST_BUCKETS]>,
    /// league-merged flight recorder: recent spans (ring) + spans over
    /// the slow threshold (kept past ring eviction)
    spans: VecDeque<SpanRec>,
    slow: VecDeque<SpanRec>,
}

impl ViewInner {
    fn push_span(&mut self, span: &SpanRec) {
        if self.spans.len() >= VIEW_SPAN_CAP {
            self.spans.pop_front();
        }
        self.spans.push_back(span.clone());
        if span.dur_us >= trace::slow_us() {
            if self.slow.len() >= VIEW_SLOW_CAP {
                self.slow.pop_front();
            }
            self.slow.push_back(span.clone());
        }
    }
}

/// The merge side of the telemetry plane: per-(role, slot) snapshot
/// ingestion + league-wide report derivation.  Pure bookkeeping — no
/// threads, no I/O — so the controller's wire path and thread mode's
/// in-process path share it verbatim.
pub struct LeagueView {
    /// a slot silent longer than this stops contributing rates/gauges
    /// (its totals stay); the controller additionally drops reaped
    /// slots explicitly via [`drop_slot`](LeagueView::drop_slot)
    stale_after: Duration,
    inner: Mutex<ViewInner>,
}

impl Default for LeagueView {
    fn default() -> Self {
        LeagueView::new(Duration::from_secs(30))
    }
}

impl LeagueView {
    pub fn new(stale_after: Duration) -> LeagueView {
        LeagueView { stale_after, inner: Mutex::new(ViewInner::default()) }
    }

    /// Merge one snapshot.  Counter deltas accumulate into the role's
    /// run totals; the slot's current rates/gauges are replaced (an
    /// interval of zero wall clock keeps the previous rates rather than
    /// dividing by zero).
    pub fn ingest(&self, s: &RoleStats) {
        let mut g = self.inner.lock().unwrap();
        for (k, d) in &s.counters {
            *g.totals.entry((s.role.clone(), k.clone())).or_insert(0) += d;
        }
        for (name, delta) in &s.hists {
            let buckets = g
                .hist_totals
                .entry((s.role.clone(), name.clone()))
                .or_insert([0u64; HIST_BUCKETS]);
            for (idx, n) in delta {
                if (*idx as usize) < HIST_BUCKETS {
                    buckets[*idx as usize] += n;
                }
            }
        }
        for span in &s.spans {
            g.push_span(span);
        }
        let entry = g
            .slots
            .entry((s.role.clone(), s.slot))
            .or_insert_with(|| SlotEntry {
                rates: BTreeMap::new(),
                gauges: BTreeMap::new(),
                last_seen: Instant::now(),
            });
        entry.last_seen = Instant::now();
        let secs = s.interval_ms as f64 / 1e3;
        if secs > 0.0 {
            for (k, d) in &s.counters {
                entry.rates.insert(k.clone(), *d as f64 / secs);
            }
        }
        for (k, v) in &s.gauges {
            entry.gauges.insert(k.clone(), *v);
        }
    }

    /// Merge bare spans without any slot bookkeeping — the path for
    /// roles sharing the reporter's own process (thread mode, in-process
    /// pools), whose flight recorder is drained directly.
    pub fn ingest_spans(&self, spans: &[SpanRec]) {
        let mut g = self.inner.lock().unwrap();
        for span in spans {
            g.push_span(span);
        }
    }

    /// Remove a reaped/deregistered slot: its rates and gauges must not
    /// freeze at their last value in subsequent reports.  Totals stay.
    pub fn drop_slot(&self, role: &str, slot: u32) {
        self.inner
            .lock()
            .unwrap()
            .slots
            .remove(&(role.to_string(), slot));
    }

    /// Merged flight recorder: recent ring ∪ slow log, deduped (a slow
    /// span sits in both stores) and sorted by start timestamp — the
    /// payload of `Msg::TraceReply` and the Chrome-trace export.
    pub fn spans(&self) -> Vec<SpanRec> {
        let g = self.inner.lock().unwrap();
        let mut out: Vec<SpanRec> =
            g.spans.iter().chain(g.slow.iter()).cloned().collect();
        out.sort_by_key(|s| (s.ts_us, s.trace_id, s.span_id, s.name.clone()));
        out.dedup();
        out
    }

    /// Live slots currently contributing to `role`.
    pub fn live_slots(&self, role: &str) -> usize {
        let g = self.inner.lock().unwrap();
        g.slots
            .iter()
            .filter(|((r, _), e)| {
                r == role && e.last_seen.elapsed() <= self.stale_after
            })
            .count()
    }

    /// Derive the league-wide report: for every role, rates summed over
    /// live slots, run totals, and gauge means.  Read-only — safe to
    /// call from both the periodic reporter and wire probes.
    pub fn report(&self) -> LeagueReport {
        let g = self.inner.lock().unwrap();
        // role → (live slots, summed rates, gauge sums + counts)
        #[derive(Default)]
        struct Agg {
            slots: u32,
            rates: BTreeMap<String, f64>,
            gauges: BTreeMap<String, (f64, u32)>,
        }
        let mut by_role: BTreeMap<String, Agg> = BTreeMap::new();
        // totals alone keep a role visible after all its slots reaped
        for (role, _) in g.totals.keys() {
            by_role.entry(role.clone()).or_default();
        }
        for ((role, _), e) in &g.slots {
            let agg = by_role.entry(role.clone()).or_default();
            if e.last_seen.elapsed() > self.stale_after {
                continue;
            }
            agg.slots += 1;
            for (k, r) in &e.rates {
                *agg.rates.entry(k.clone()).or_insert(0.0) += r;
            }
            for (k, v) in &e.gauges {
                let s = agg.gauges.entry(k.clone()).or_insert((0.0, 0));
                s.0 += v;
                s.1 += 1;
            }
        }
        // hist-derived percentiles ride as synthetic gauges named
        // `<hist>_p50/_p95/_p99`, so every report consumer (summary
        // line, jsonl, stats CLI) shows tail latency with no schema
        // change.  Cumulative over the run, like totals.
        for ((role, name), buckets) in &g.hist_totals {
            let agg = by_role.entry(role.clone()).or_default();
            for (suffix, q) in [("p50", 0.50), ("p95", 0.95), ("p99", 0.99)] {
                let v = Hist::quantile_of(buckets, q);
                agg.gauges.insert(format!("{name}_{suffix}"), (v, 1));
            }
        }
        let roles = by_role
            .into_iter()
            .map(|(role, agg)| RoleReport {
                slots: agg.slots,
                rates: agg.rates.into_iter().collect(),
                totals: g
                    .totals
                    .iter()
                    .filter(|((r, _), _)| *r == role)
                    .map(|((_, k), v)| (k.clone(), *v))
                    .collect(),
                gauges: agg
                    .gauges
                    .into_iter()
                    .map(|(k, (sum, n))| (k, sum / n.max(1) as f64))
                    .collect(),
                role,
            })
            .collect::<Vec<_>>();
        LeagueReport { roles: sort_roles(roles) }
    }
}

/// Canonical display order: data-producing roles first, then services.
fn role_rank(role: &str) -> u32 {
    match role {
        "actor" => 0,
        "learner" => 1,
        "inf-server" => 2,
        "model-pool" => 3,
        _ => 4,
    }
}

fn sort_roles(mut roles: Vec<RoleReport>) -> Vec<RoleReport> {
    roles.sort_by(|a, b| {
        role_rank(&a.role)
            .cmp(&role_rank(&b.role))
            .then_with(|| a.role.cmp(&b.role))
    });
    roles
}

fn fmt_num(v: f64) -> String {
    if !v.is_finite() {
        "0".into()
    } else if v == v.trunc() && v.abs() < 1e15 {
        (v as i64).to_string()
    } else if v.abs() >= 100.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.3}")
    }
}

/// One-line league throughput summary, e.g.
/// `actor[4] env_frames/s=5210 episodes/s=12.3 | learner[1]
/// consumed_frames/s=4800 staleness=0.8 | ...`.  A role with no live
/// slots left (post-drain final line) falls back to its run totals.
pub fn summary_line(r: &LeagueReport) -> String {
    let mut parts = Vec::new();
    for role in &r.roles {
        let mut s = format!("{}[{}]", role.role, role.slots);
        let mut any = false;
        for (k, v) in &role.rates {
            s.push_str(&format!(" {k}/s={}", fmt_num(*v)));
            any = true;
        }
        for (k, v) in &role.gauges {
            s.push_str(&format!(" {k}={}", fmt_num(*v)));
            any = true;
        }
        if !any {
            for (k, v) in &role.totals {
                s.push_str(&format!(" {k}={v}"));
                any = true;
            }
        }
        if any {
            parts.push(s);
        }
    }
    if parts.is_empty() {
        "no telemetry yet".into()
    } else {
        parts.join(" | ")
    }
}

/// Non-finite gauges/rates must not leak "inf"/"NaN" into the file.
fn num(v: f64) -> Json {
    Json::Num(if v.is_finite() { v } else { 0.0 })
}

fn obj(fields: impl IntoIterator<Item = (String, Json)>) -> Json {
    Json::Obj(fields.into_iter().collect())
}

/// One JSONL trajectory row at timestamp `t` (unix seconds): league
/// counters and the full per-role view (rates + run totals + gauges).
/// Offline plots reconstruct per-interval deltas from consecutive
/// rows' totals.  Built on `util::json::Json`, so escaping/rendering
/// stays in one place (u64 totals ride f64 — exact up to 2^53, far
/// beyond any run).
pub fn jsonl_line(r: &LeagueReport, episodes: u64, frames: u64, t: f64) -> String {
    let pairs = |v: &[(String, f64)]| {
        obj(v.iter().map(|(k, x)| (k.clone(), num(*x))))
    };
    let roles = obj(r.roles.iter().map(|role| {
        (
            role.role.clone(),
            Json::obj()
                .set("slots", role.slots as usize)
                .set("rates", pairs(&role.rates))
                .set(
                    "totals",
                    obj(role
                        .totals
                        .iter()
                        .map(|(k, v)| (k.clone(), num(*v as f64)))),
                )
                .set("gauges", pairs(&role.gauges)),
        )
    }));
    Json::obj()
        .set("t", num(t))
        .set(
            "league",
            Json::obj()
                .set("episodes", num(episodes as f64))
                .set("frames", num(frames as f64)),
        )
        .set("roles", roles)
        .to_string()
}

/// Machine-readable `LeagueReport` for `stats --json`: one JSON object,
/// roles in canonical order, same field names as the JSONL trajectory
/// rows so downstream tooling parses both with one schema.
pub fn report_json(r: &LeagueReport) -> Json {
    let pairs = |v: &[(String, f64)]| {
        obj(v.iter().map(|(k, x)| (k.clone(), num(*x))))
    };
    Json::obj().set(
        "roles",
        obj(r.roles.iter().map(|role| {
            (
                role.role.clone(),
                Json::obj()
                    .set("slots", role.slots as usize)
                    .set("rates", pairs(&role.rates))
                    .set(
                        "totals",
                        obj(role
                            .totals
                            .iter()
                            .map(|(k, v)| (k.clone(), num(*v as f64)))),
                    )
                    .set("gauges", pairs(&role.gauges)),
            )
        })),
    )
}

/// Append-only JSONL sink for `--stats-jsonl <path>`.  Row timestamps
/// are the wall-clock epoch captured at open plus a MONOTONIC elapsed
/// offset, so an NTP step mid-run can never produce out-of-order `t`
/// values (ci.sh asserts they are sorted).
pub struct JsonlSink {
    file: std::fs::File,
    pub path: String,
    unix0: f64,
    started: Instant,
}

impl JsonlSink {
    pub fn open(path: &str) -> anyhow::Result<JsonlSink> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        let unix0 = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0);
        Ok(JsonlSink {
            file,
            path: path.to_string(),
            unix0,
            started: Instant::now(),
        })
    }

    pub fn append(&mut self, r: &LeagueReport, episodes: u64, frames: u64) {
        let t = self.unix0 + self.started.elapsed().as_secs_f64();
        let line = jsonl_line(r, episodes, frames, t);
        if let Err(e) = writeln!(self.file, "{line}") {
            eprintln!("telemetry: jsonl append to {} failed: {e}", self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(
        role: &str,
        slot: u32,
        interval_ms: u64,
        counters: &[(&str, u64)],
        gauges: &[(&str, f64)],
    ) -> RoleStats {
        RoleStats {
            role: role.into(),
            slot,
            seq: 0,
            interval_ms,
            counters: counters
                .iter()
                .map(|(k, v)| (k.to_string(), *v))
                .collect(),
            gauges: gauges.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
            ..Default::default()
        }
    }

    fn rate(r: &LeagueReport, role: &str, k: &str) -> f64 {
        r.roles
            .iter()
            .find(|x| x.role == role)
            .and_then(|x| x.rates.iter().find(|(n, _)| n == k))
            .map(|(_, v)| *v)
            .unwrap_or(f64::NAN)
    }

    fn total(r: &LeagueReport, role: &str, k: &str) -> u64 {
        r.roles
            .iter()
            .find(|x| x.role == role)
            .and_then(|x| x.totals.iter().find(|(n, _)| n == k))
            .map(|(_, v)| *v)
            .unwrap_or(u64::MAX)
    }

    #[test]
    fn merge_sums_rates_and_accumulates_totals() {
        let v = LeagueView::default();
        v.ingest(&stats("actor", 0, 1_000, &[("env_frames", 100)], &[]));
        v.ingest(&stats("actor", 1, 2_000, &[("env_frames", 400)], &[]));
        v.ingest(&stats("learner", 0, 1_000, &[("consumed_frames", 80)], &[
            ("staleness", 2.0),
        ]));
        let r = v.report();
        // 100/1s + 400/2s
        assert!((rate(&r, "actor", "env_frames") - 300.0).abs() < 1e-9);
        assert_eq!(total(&r, "actor", "env_frames"), 500);
        assert!((rate(&r, "learner", "consumed_frames") - 80.0).abs() < 1e-9);
        // next window: totals accumulate, rates replace
        v.ingest(&stats("actor", 0, 1_000, &[("env_frames", 50)], &[]));
        v.ingest(&stats("actor", 1, 1_000, &[("env_frames", 70)], &[]));
        let r = v.report();
        assert!((rate(&r, "actor", "env_frames") - 120.0).abs() < 1e-9);
        assert_eq!(total(&r, "actor", "env_frames"), 620);
        // canonical role order: actor before learner
        assert_eq!(r.roles[0].role, "actor");
        assert_eq!(r.roles[1].role, "learner");
    }

    /// A worker joining mid-window contributes from its first snapshot.
    #[test]
    fn slot_joining_mid_window_is_counted() {
        let v = LeagueView::default();
        v.ingest(&stats("actor", 0, 1_000, &[("env_frames", 100)], &[]));
        let r = v.report();
        assert_eq!(r.roles[0].slots, 1);
        v.ingest(&stats("actor", 7, 500, &[("env_frames", 100)], &[]));
        let r = v.report();
        assert_eq!(r.roles[0].slots, 2);
        assert!((rate(&r, "actor", "env_frames") - 300.0).abs() < 1e-9);
        assert_eq!(total(&r, "actor", "env_frames"), 200);
    }

    /// A reaped slot's rates and gauges must disappear, not freeze at
    /// their last reported value; its totals remain.
    #[test]
    fn dropped_slot_stops_contributing_but_keeps_totals() {
        let v = LeagueView::default();
        v.ingest(&stats("actor", 0, 1_000, &[("env_frames", 100)], &[
            ("lag", 5.0),
        ]));
        v.ingest(&stats("actor", 1, 1_000, &[("env_frames", 60)], &[
            ("lag", 1.0),
        ]));
        let r = v.report();
        assert!((rate(&r, "actor", "env_frames") - 160.0).abs() < 1e-9);
        assert_eq!(r.roles[0].gauges, vec![("lag".into(), 3.0)]);
        v.drop_slot("actor", 0);
        let r = v.report();
        assert_eq!(r.roles[0].slots, 1);
        assert!((rate(&r, "actor", "env_frames") - 60.0).abs() < 1e-9);
        assert_eq!(r.roles[0].gauges, vec![("lag".into(), 1.0)]);
        assert_eq!(total(&r, "actor", "env_frames"), 160);
        // every slot gone: the role stays visible through its totals
        v.drop_slot("actor", 1);
        let r = v.report();
        assert_eq!(r.roles[0].slots, 0);
        assert!(r.roles[0].rates.is_empty());
        assert_eq!(total(&r, "actor", "env_frames"), 160);
    }

    /// Snapshots older than `stale_after` stop contributing rates even
    /// without an explicit drop (thread mode has no reaper).
    #[test]
    fn stale_entries_excluded_from_rates() {
        let v = LeagueView::new(Duration::from_millis(20));
        v.ingest(&stats("actor", 0, 1_000, &[("env_frames", 100)], &[]));
        std::thread::sleep(Duration::from_millis(40));
        v.ingest(&stats("actor", 1, 1_000, &[("env_frames", 60)], &[]));
        let r = v.report();
        assert_eq!(r.roles[0].slots, 1);
        assert!((rate(&r, "actor", "env_frames") - 60.0).abs() < 1e-9);
        assert_eq!(total(&r, "actor", "env_frames"), 160);
        assert_eq!(v.live_slots("actor"), 1);
    }

    /// A zero-length interval must not produce infinite rates.
    #[test]
    fn zero_interval_keeps_previous_rates() {
        let v = LeagueView::default();
        v.ingest(&stats("actor", 0, 1_000, &[("env_frames", 100)], &[]));
        v.ingest(&stats("actor", 0, 0, &[("env_frames", 7)], &[]));
        let r = v.report();
        assert!((rate(&r, "actor", "env_frames") - 100.0).abs() < 1e-9);
        assert_eq!(total(&r, "actor", "env_frames"), 107);
    }

    fn gauge(r: &LeagueReport, role: &str, k: &str) -> f64 {
        r.roles
            .iter()
            .find(|x| x.role == role)
            .and_then(|x| x.gauges.iter().find(|(n, _)| n == k))
            .map(|(_, v)| *v)
            .unwrap_or(f64::NAN)
    }

    /// Hist deltas merge into cumulative buckets and surface as
    /// `<name>_p50/_p95/_p99` synthetic gauges; like totals, they
    /// survive slot drops.
    #[test]
    fn hist_deltas_surface_as_percentile_gauges() {
        let v = LeagueView::default();
        let lo = Hist::bucket_of(100) as u8; // ~100us
        let hi = Hist::bucket_of(1_000_000) as u8; // ~1s outliers
        let mut s = stats("inf-server", 0, 1_000, &[], &[]);
        s.hists = vec![("queue_wait_us".into(), vec![(lo, 54), (hi, 6)])];
        v.ingest(&s);
        // a second slot contributes to the same merged distribution
        let mut s2 = stats("inf-server", 1, 1_000, &[], &[]);
        s2.hists = vec![("queue_wait_us".into(), vec![(lo, 40)])];
        v.ingest(&s2);
        let r = v.report();
        // 94 events near 100us, 6 near 1s: p50 low, p95/p99 in the tail
        let p50 = gauge(&r, "inf-server", "queue_wait_us_p50");
        let p95 = gauge(&r, "inf-server", "queue_wait_us_p95");
        let p99 = gauge(&r, "inf-server", "queue_wait_us_p99");
        assert!(p50 > 50.0 && p50 < 200.0, "p50 {p50}");
        assert!(p95 > 500_000.0, "p95 {p95}");
        assert!(p99 >= p95, "p99 {p99} < p95 {p95}");
        // percentiles show up in the summary line like any gauge
        let line = summary_line(&r);
        assert!(line.contains("queue_wait_us_p99="), "{line}");
        // reaping both slots keeps the distribution (cumulative)
        v.drop_slot("inf-server", 0);
        v.drop_slot("inf-server", 1);
        let r = v.report();
        let p50b = gauge(&r, "inf-server", "queue_wait_us_p50");
        assert_eq!(p50, p50b);
    }

    /// Ingested spans land in the merged flight recorder; slow spans
    /// survive ring eviction through the slow log; `spans()` dedupes.
    #[test]
    fn span_ingest_merges_ring_and_slow_log() {
        let v = LeagueView::default();
        let span = |id: u64, dur_us: u64| SpanRec {
            trace_id: id,
            span_id: id,
            parent: 0,
            name: "inf_compute".into(),
            role: "inf-server".into(),
            ts_us: 1_000 + id,
            dur_us,
            rows: 1,
        };
        let mut s = stats("inf-server", 0, 1_000, &[], &[]);
        // one slow span (default threshold 50ms = 50_000us) + two fast
        s.spans = vec![span(2, 10), span(1, 60_000), span(3, 20)];
        v.ingest(&s);
        let got = v.spans();
        assert_eq!(got.len(), 3, "slow span must not double-count");
        // sorted by start timestamp
        assert!(got.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
        assert_eq!(got[0].trace_id, 1);
    }

    #[test]
    fn jsonl_line_is_valid_json_with_timestamp() {
        let v = LeagueView::default();
        v.ingest(&stats("actor", 0, 1_000, &[("env_frames", 100)], &[
            ("lag", 0.5),
        ]));
        let r = v.report();
        let line = jsonl_line(&r, 12, 3456, 1_753_900_000.25);
        let j = crate::util::json::Json::parse(&line).expect("valid json");
        assert_eq!(
            j.path("t").and_then(|t| t.as_f64()).unwrap(),
            1_753_900_000.25
        );
        assert_eq!(
            j.path("league.frames").and_then(|f| f.as_f64()).unwrap(),
            3456.0
        );
        assert_eq!(
            j.path("roles.actor.totals.env_frames")
                .and_then(|f| f.as_f64())
                .unwrap(),
            100.0
        );
        assert_eq!(
            j.path("roles.actor.slots").and_then(|s| s.as_f64()).unwrap(),
            1.0
        );
    }

    /// `stats --json` payload: valid JSON, same shape as jsonl roles.
    #[test]
    fn report_json_round_trips_through_parser() {
        let v = LeagueView::default();
        v.ingest(&stats("actor", 0, 1_000, &[("env_frames", 100)], &[
            ("lag", 0.5),
        ]));
        let j = report_json(&v.report());
        let back =
            crate::util::json::Json::parse(&j.to_string()).expect("valid json");
        assert_eq!(
            back.path("roles.actor.totals.env_frames")
                .and_then(|x| x.as_f64())
                .unwrap(),
            100.0
        );
        assert_eq!(
            back.path("roles.actor.gauges.lag")
                .and_then(|x| x.as_f64())
                .unwrap(),
            0.5
        );
    }

    #[test]
    fn summary_line_names_roles_and_rates() {
        let v = LeagueView::default();
        v.ingest(&stats("actor", 0, 1_000, &[("env_frames", 5000)], &[]));
        v.ingest(&stats(
            "learner",
            0,
            1_000,
            &[("consumed_frames", 100)],
            &[("staleness", 0.5)],
        ));
        let s = summary_line(&v.report());
        assert!(s.contains("actor[1]"), "{s}");
        assert!(s.contains("env_frames/s=5000"), "{s}");
        assert!(s.contains("learner[1]"), "{s}");
        assert!(s.contains("staleness=0.500"), "{s}");
        assert_eq!(summary_line(&LeagueReport::default()), "no telemetry yet");
        // post-drain final line: no live slots left, run totals show
        // instead of a misleading "no telemetry yet"
        v.drop_slot("actor", 0);
        v.drop_slot("learner", 0);
        let s = summary_line(&v.report());
        assert!(s.contains("actor[0] env_frames=5000"), "{s}");
        assert!(s.contains("learner[0] consumed_frames=100"), "{s}");
    }

    #[test]
    fn jsonl_sink_appends_monotone_rows() {
        let dir = std::env::temp_dir()
            .join(format!("tleague-telemetry-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("stats.jsonl");
        let mut sink = JsonlSink::open(path.to_str().unwrap()).unwrap();
        let v = LeagueView::default();
        v.ingest(&stats("actor", 0, 1_000, &[("env_frames", 1)], &[]));
        let r = v.report();
        sink.append(&r, 1, 2);
        sink.append(&r, 2, 4);
        let text = std::fs::read_to_string(&path).unwrap();
        let ts: Vec<f64> = text
            .lines()
            .map(|l| {
                crate::util::json::Json::parse(l)
                    .expect("valid jsonl row")
                    .path("t")
                    .and_then(|t| t.as_f64())
                    .expect("t field")
            })
            .collect();
        assert_eq!(ts.len(), 2);
        assert!(ts[0] > 0.0);
        assert!(ts[1] >= ts[0], "sink timestamps must be monotone: {ts:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
