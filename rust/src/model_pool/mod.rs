//! ModelPool: versioned parameter store with LRU disk spill (paper §3.2).
//!
//! "During the whole training lifecycle, ModelPool must respond to any
//! parameter requesting (read) or updating (write) instantaneously" —
//! hot parameters are kept in memory; up to M_M replicas run
//! simultaneously and clients pick a random replica per read (load
//! balancing), writing through to all replicas.
//!
//! Long CSP runs accumulate an unbounded frozen pool, so each replica
//! can be given a resident-byte budget plus a spill directory: cold
//! frozen blobs (never an agent's latest, never an unfrozen learner
//! model) are evicted to disk in LRU order and transparently faulted
//! back in on `GetModel`.  Spill files use the `ModelBlob` wire encoding
//! and are written temp-then-rename, so a crash never leaves a torn
//! blob (see DESIGN.md §Spill policy).

use crate::proto::{ModelBlob, ModelKey, Msg};
use crate::transport::{RepServer, ReqClient};
use crate::util::codec::Wire;
use crate::util::rng::Pcg32;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Memory policy for one replica.  The default (no dir, budget 0) keeps
/// everything resident forever — the seed behaviour.
#[derive(Clone, Debug, Default)]
pub struct PoolOptions {
    /// Directory for spilled blobs; None disables spilling entirely.
    pub spill_dir: Option<PathBuf>,
    /// Resident-byte budget (0 = unbounded).  Only frozen, non-latest
    /// blobs are evicted, so the budget is a target, not a hard cap, when
    /// live learner models alone exceed it.
    pub mem_budget: usize,
}

/// Approximate resident cost of a blob (param + hp payloads dominate).
fn blob_cost(b: &ModelBlob) -> usize {
    b.params.len() * 4 + b.hp.len() * 4 + std::mem::size_of::<ModelBlob>()
}

/// Assemble a full-pool snapshot from [`Store::snapshot_parts`] output.
/// Runs WITHOUT the store lock: the disk reads of spilled blobs must not
/// stall GetModel/PutModel traffic ("respond ... instantaneously").  A
/// spill file that vanishes mid-read (concurrent re-put) is skipped —
/// that blob is resident again and will be in the next snapshot.
fn assemble_blobs(
    resident: Vec<Arc<ModelBlob>>,
    spilled: &[PathBuf],
) -> Vec<ModelBlob> {
    let mut out: Vec<ModelBlob> =
        resident.iter().map(|b| (**b).clone()).collect();
    for path in spilled {
        match std::fs::read(path)
            .map_err(anyhow::Error::from)
            .and_then(|raw| ModelBlob::from_bytes(&raw))
        {
            Ok(b) => out.push(b),
            Err(e) => eprintln!(
                "model_pool: snapshot skipping {}: {e:#}",
                path.display()
            ),
        }
    }
    out.sort_by_key(|b| b.key);
    out
}

#[derive(Default)]
struct Store {
    /// resident blobs; `Arc` so snapshots and replies can deep-copy the
    /// params OUTSIDE the store lock
    blobs: BTreeMap<ModelKey, Arc<ModelBlob>>,
    /// blobs with a valid on-disk copy (may also be resident)
    on_disk: BTreeMap<ModelKey, PathBuf>,
    latest: BTreeMap<u32, ModelKey>, // per-agent newest version
    last_used: BTreeMap<ModelKey, u64>,
    tick: u64,
    resident: usize,
    opts: PoolOptions,
}

impl Store {
    fn touch(&mut self, key: ModelKey) {
        self.tick += 1;
        self.last_used.insert(key, self.tick);
    }

    fn insert(&mut self, blob: ModelBlob) {
        let key = blob.key;
        // strictly-newer versions move `latest`; an equal-version re-put
        // (learner restart, replica replay) refreshes bytes only
        let newer = self
            .latest
            .get(&key.agent)
            .map_or(true, |cur| key.version > cur.version);
        if newer {
            self.latest.insert(key.agent, key);
        }
        // a re-put invalidates any stale disk copy
        if let Some(path) = self.on_disk.remove(&key) {
            std::fs::remove_file(path).ok();
        }
        let blob = Arc::new(blob);
        let cost = blob_cost(&blob);
        if let Some(old) = self.blobs.insert(key, blob) {
            self.resident -= blob_cost(&old);
        }
        self.resident += cost;
        self.touch(key);
        self.maybe_spill();
    }

    /// Resident lookup, faulting a spilled blob back in if needed.  The
    /// returned handle is cheap; callers deep-copy after unlocking.
    fn fetch(&mut self, key: ModelKey) -> Option<Arc<ModelBlob>> {
        if let Some(b) = self.blobs.get(&key).cloned() {
            self.touch(key);
            return Some(b);
        }
        let path = self.on_disk.get(&key)?.clone();
        let blob = match std::fs::read(&path)
            .map_err(anyhow::Error::from)
            .and_then(|raw| ModelBlob::from_bytes(&raw))
        {
            Ok(b) => Arc::new(b),
            Err(e) => {
                // a swallowed I/O error here would read as a permanent,
                // undiagnosable NotFound for a frozen model
                eprintln!(
                    "model_pool: fault-in of {key} from {} failed: {e:#}",
                    path.display()
                );
                return None;
            }
        };
        self.resident += blob_cost(&blob);
        self.blobs.insert(key, blob.clone());
        self.touch(key);
        self.maybe_spill();
        Some(blob)
    }

    /// Evict cold frozen blobs until the budget is met (or no candidates
    /// remain).  The disk copy is written before the memory copy is
    /// dropped; a blob that already has one is evicted for free.
    fn maybe_spill(&mut self) {
        if self.opts.mem_budget == 0 || self.opts.spill_dir.is_none() {
            return;
        }
        while self.resident > self.opts.mem_budget {
            let victim = self
                .blobs
                .iter()
                .filter(|&(k, b)| b.frozen && self.latest.get(&k.agent) != Some(k))
                .min_by_key(|&(k, _)| self.last_used.get(k).copied().unwrap_or(0))
                .map(|(k, _)| *k);
            let Some(key) = victim else { break };
            if let Err(e) = self.spill_out(key) {
                // a silent break here would quietly stop enforcing the
                // budget (e.g. spill disk full) with no diagnostics
                eprintln!(
                    "model_pool: spill of {key} failed, budget not enforced: {e:#}"
                );
                break;
            }
        }
    }

    fn spill_out(&mut self, key: ModelKey) -> Result<()> {
        let dir = self.opts.spill_dir.clone().expect("spill dir checked");
        if !self.on_disk.contains_key(&key) {
            let blob = self.blobs.get(&key).expect("victim is resident");
            std::fs::create_dir_all(&dir)?;
            let name = format!("agt{:03}-v{:06}.blob", key.agent, key.version);
            let tmp = dir.join(format!(".{name}.tmp"));
            std::fs::write(&tmp, blob.to_bytes())?;
            let path = dir.join(name);
            std::fs::rename(&tmp, &path)?;
            self.on_disk.insert(key, path);
        }
        if let Some(b) = self.blobs.remove(&key) {
            self.resident -= blob_cost(&b);
        }
        Ok(())
    }

    /// Snapshot inputs: handles to the resident blobs plus the paths of
    /// spill files whose only copy is on disk.  O(n) Arc bumps — the
    /// caller releases the store lock before any deep copy or disk read.
    fn snapshot_parts(&self) -> (Vec<Arc<ModelBlob>>, Vec<PathBuf>) {
        let resident: Vec<Arc<ModelBlob>> = self.blobs.values().cloned().collect();
        let spilled: Vec<PathBuf> = self
            .on_disk
            .iter()
            .filter(|&(k, _)| !self.blobs.contains_key(k))
            .map(|(_, p)| p.clone())
            .collect();
        (resident, spilled)
    }

    fn model_count(&self) -> usize {
        self.blobs.len() + self.spilled_count()
    }

    fn spilled_count(&self) -> usize {
        self.on_disk.keys().filter(|&k| !self.blobs.contains_key(k)).count()
    }
}

/// One ModelPool replica: a REQ/REP service over the spill-aware store.
pub struct ModelPoolServer {
    pub addr: String,
    store: Arc<Mutex<Store>>,
    _server: RepServer,
}

impl ModelPoolServer {
    pub fn start(bind: &str) -> Result<ModelPoolServer> {
        Self::start_with(bind, PoolOptions::default())
    }

    pub fn start_with(bind: &str, opts: PoolOptions) -> Result<ModelPoolServer> {
        let store = Arc::new(Mutex::new(Store { opts, ..Store::default() }));
        let s2 = store.clone();
        let server = RepServer::serve(bind, move |msg| match msg {
            Msg::PutModel(blob) => {
                s2.lock().unwrap().insert(blob);
                Msg::Ok
            }
            Msg::GetModel { key } => {
                // bind so the guard drops before the params deep-copy
                let found = s2.lock().unwrap().fetch(key);
                match found {
                    Some(b) => Msg::Model((*b).clone()),
                    None => Msg::NotFound,
                }
            }
            Msg::GetLatest { agent } => {
                let found = {
                    let mut st = s2.lock().unwrap();
                    let key = st.latest.get(&agent).copied();
                    key.and_then(|k| st.fetch(k))
                };
                match found {
                    Some(b) => Msg::Model((*b).clone()),
                    None => Msg::NotFound,
                }
            }
            Msg::PoolStats => {
                let st = s2.lock().unwrap();
                Msg::PoolStatsReply {
                    resident_bytes: st.resident as u64,
                    models: st.model_count() as u32,
                    spilled: st.spilled_count() as u32,
                }
            }
            Msg::Ping => Msg::Pong,
            other => Msg::Err(format!("model_pool: unexpected {other:?}")),
        })?;
        Ok(ModelPoolServer { addr: server.addr.clone(), store, _server: server })
    }

    pub fn model_count(&self) -> usize {
        self.store.lock().unwrap().model_count()
    }

    /// Bytes currently held in memory (excludes spilled blobs).
    pub fn resident_bytes(&self) -> usize {
        self.store.lock().unwrap().resident
    }

    /// Blobs whose only copy is on disk.
    pub fn spilled_count(&self) -> usize {
        self.store.lock().unwrap().spilled_count()
    }

    /// Everything this replica stores, for snapshotting.  Spilled blobs
    /// are read from disk after the store lock is released.
    pub fn all_blobs(&self) -> Vec<ModelBlob> {
        let (resident, spilled) = self.store.lock().unwrap().snapshot_parts();
        assemble_blobs(resident, &spilled)
    }

    /// Restore path: bulk-load snapshot blobs.  `latest` lands on the
    /// highest version per agent regardless of load order.
    pub fn preload(&self, blobs: &[ModelBlob]) {
        let mut st = self.store.lock().unwrap();
        for b in blobs {
            st.insert(b.clone());
        }
    }

    /// Closure handle for the background snapshotter thread.
    pub fn blobs_fn(&self) -> impl Fn() -> Vec<ModelBlob> + Send + 'static {
        let store = self.store.clone();
        move || {
            let (resident, spilled) = store.lock().unwrap().snapshot_parts();
            assemble_blobs(resident, &spilled)
        }
    }
}

/// Client over one or more ModelPool replicas: writes go to every
/// replica, reads go to a random one.
pub struct ModelPoolClient {
    replicas: Vec<ReqClient>,
    rng: Mutex<Pcg32>,
}

impl ModelPoolClient {
    pub fn connect(addrs: &[String]) -> ModelPoolClient {
        assert!(!addrs.is_empty());
        ModelPoolClient {
            replicas: addrs.iter().map(|a| ReqClient::connect(a)).collect(),
            rng: Mutex::new(Pcg32::from_label(0x6d70, "mp-client")),
        }
    }

    fn pick(&self) -> &ReqClient {
        let i = self.rng.lock().unwrap().below(self.replicas.len() as u32);
        &self.replicas[i as usize]
    }

    pub fn put(&self, blob: ModelBlob) -> Result<()> {
        for r in &self.replicas {
            match r.request(&Msg::PutModel(blob.clone()))? {
                Msg::Ok => {}
                other => bail!("put: unexpected reply {other:?}"),
            }
        }
        Ok(())
    }

    pub fn get(&self, key: ModelKey) -> Result<Option<ModelBlob>> {
        match self.pick().request(&Msg::GetModel { key })? {
            Msg::Model(b) => Ok(Some(b)),
            Msg::NotFound => Ok(None),
            other => bail!("get: unexpected reply {other:?}"),
        }
    }

    pub fn get_latest(&self, agent: u32) -> Result<Option<ModelBlob>> {
        match self.pick().request(&Msg::GetLatest { agent })? {
            Msg::Model(b) => Ok(Some(b)),
            Msg::NotFound => Ok(None),
            other => bail!("get_latest: unexpected reply {other:?}"),
        }
    }

    /// (resident_bytes, models, spilled) of one random replica.
    pub fn stats(&self) -> Result<(u64, u32, u32)> {
        match self.pick().request(&Msg::PoolStats)? {
            Msg::PoolStatsReply { resident_bytes, models, spilled } => {
                Ok((resident_bytes, models, spilled))
            }
            other => bail!("stats: unexpected reply {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(agent: u32, version: u32, val: f32) -> ModelBlob {
        ModelBlob {
            key: ModelKey::new(agent, version),
            params: vec![val; 8],
            hp: vec![3e-4],
            frozen: false,
        }
    }

    fn frozen_blob(agent: u32, version: u32, n: usize) -> ModelBlob {
        ModelBlob {
            key: ModelKey::new(agent, version),
            params: vec![version as f32; n],
            hp: vec![3e-4],
            frozen: true,
        }
    }

    fn spill_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("tleague-spill-{}-{tag}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn put_get_roundtrip() {
        let server = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        client.put(blob(0, 1, 1.5)).unwrap();
        let got = client.get(ModelKey::new(0, 1)).unwrap().unwrap();
        assert_eq!(got.params, vec![1.5; 8]);
        assert!(client.get(ModelKey::new(0, 9)).unwrap().is_none());
        assert_eq!(server.model_count(), 1);
    }

    #[test]
    fn latest_tracks_highest_version() {
        let server = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        client.put(blob(0, 1, 1.0)).unwrap();
        client.put(blob(0, 3, 3.0)).unwrap();
        client.put(blob(0, 2, 2.0)).unwrap(); // stale write must not win
        let latest = client.get_latest(0).unwrap().unwrap();
        assert_eq!(latest.key.version, 3);
        assert!(client.get_latest(7).unwrap().is_none());
    }

    /// Regression: an equal-version re-put (learner restart republishing
    /// its current model) must refresh the stored bytes without being
    /// treated as a *newer* version.
    #[test]
    fn equal_version_reput_refreshes_but_is_not_newer() {
        let server = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        client.put(blob(0, 2, 1.0)).unwrap();
        client.put(blob(0, 2, 9.0)).unwrap(); // same version, new bytes
        let latest = client.get_latest(0).unwrap().unwrap();
        assert_eq!(latest.key.version, 2);
        assert_eq!(latest.params, vec![9.0; 8], "re-put must refresh bytes");
        assert_eq!(server.model_count(), 1, "no duplicate entry");
    }

    #[test]
    fn replicated_writes_readable_from_any() {
        let s1 = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let s2 = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let client = ModelPoolClient::connect(&[s1.addr.clone(), s2.addr.clone()]);
        client.put(blob(1, 4, 4.0)).unwrap();
        // both replicas hold the model, so any single-replica client sees it
        for addr in [&s1.addr, &s2.addr] {
            let c = ModelPoolClient::connect(&[addr.clone()]);
            assert!(c.get(ModelKey::new(1, 4)).unwrap().is_some());
        }
    }

    #[test]
    fn concurrent_readers_and_writers() {
        let server = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let addr = server.addr.clone();
        let mut handles = Vec::new();
        for t in 0..4u32 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let c = ModelPoolClient::connect(&[addr]);
                for v in 0..20 {
                    c.put(blob(t, v, v as f32)).unwrap();
                    let got = c.get(ModelKey::new(t, v)).unwrap().unwrap();
                    assert_eq!(got.params[0], v as f32);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.model_count(), 80);
    }

    #[test]
    fn spill_keeps_resident_under_budget_and_serves_everything() {
        let dir = spill_dir("budget");
        // ~8 KiB per blob, budget fits roughly 4
        let budget = 36 * 1024;
        let server = ModelPoolServer::start_with(
            "127.0.0.1:0",
            PoolOptions { spill_dir: Some(dir.clone()), mem_budget: budget },
        )
        .unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        for v in 0..20 {
            client.put(frozen_blob(0, v, 2000)).unwrap();
        }
        assert!(
            server.resident_bytes() <= budget,
            "resident {} > budget {budget}",
            server.resident_bytes()
        );
        assert!(server.spilled_count() > 0, "nothing spilled");
        assert_eq!(server.model_count(), 20, "spilled blobs still counted");
        // every blob — including spilled ones — remains retrievable, and
        // faulting them back in never breaks the budget
        for v in 0..20 {
            let b = client.get(ModelKey::new(0, v)).unwrap().unwrap();
            assert_eq!(b.params, vec![v as f32; 2000], "blob {v} corrupted");
            assert!(server.resident_bytes() <= budget);
        }
        let (resident, models, _spilled) = client.stats().unwrap();
        assert!(resident as usize <= budget);
        assert_eq!(models, 20);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn spill_never_evicts_latest_or_unfrozen() {
        let dir = spill_dir("protect");
        let server = ModelPoolServer::start_with(
            "127.0.0.1:0",
            PoolOptions { spill_dir: Some(dir.clone()), mem_budget: 1 },
        )
        .unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        // unfrozen learner model + the frozen latest: neither may spill
        // even with an absurdly small budget
        client
            .put(ModelBlob {
                key: ModelKey::new(0, 1),
                params: vec![1.0; 512],
                hp: vec![3e-4],
                frozen: false,
            })
            .unwrap();
        client.put(frozen_blob(1, 1, 512)).unwrap();
        assert_eq!(server.spilled_count(), 0, "protected blobs were spilled");
        // a second frozen version for agent 1 makes v1 evictable
        client.put(frozen_blob(1, 2, 512)).unwrap();
        assert_eq!(server.spilled_count(), 1);
        assert!(
            client.get(ModelKey::new(1, 1)).unwrap().is_some(),
            "spilled blob must fault back in"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn all_blobs_includes_spilled_and_preload_restores() {
        let dir = spill_dir("snapshot");
        let server = ModelPoolServer::start_with(
            "127.0.0.1:0",
            PoolOptions { spill_dir: Some(dir.clone()), mem_budget: 20 * 1024 },
        )
        .unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        for v in 0..8 {
            client.put(frozen_blob(0, v, 2000)).unwrap();
        }
        let blobs = server.all_blobs();
        assert_eq!(blobs.len(), 8, "snapshot must cover spilled blobs");
        // restore into a fresh, spill-less replica (out of order)
        let restored = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let mut shuffled = blobs.clone();
        shuffled.reverse();
        restored.preload(&shuffled);
        let c2 = ModelPoolClient::connect(&[restored.addr.clone()]);
        assert_eq!(c2.get_latest(0).unwrap().unwrap().key.version, 7);
        for v in 0..8 {
            assert_eq!(
                c2.get(ModelKey::new(0, v)).unwrap().unwrap().params,
                vec![v as f32; 2000]
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
