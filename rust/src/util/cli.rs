//! Minimal command-line parser (no clap in the offline crate set).
//!
//! Supports `program <subcommand> --flag value --bool-flag pos1 pos2`.
//!
//! Numeric accessors are fallible: a malformed value (`--total-steps
//! 10k`) aborts with an error naming the flag and the value instead of
//! silently training with the default.

use anyhow::Result;
use std::collections::BTreeMap;

/// Top-level `--help` text, printed by the binary when invoked with no
/// subcommand or with `--help`.
pub const USAGE: &str = "\
tleague — competitive self-play distributed MARL (TLeague reproduction)

usage: tleague <subcommand> [--flag value ...]

subcommands:
  run          launch a full league (kube-lite orchestrator)
    --config <spec.json>     JSON run spec (flags below override it)
    --env <name>             rps|pong2p|pommerman|pommerman_ffa|doom_lite|synthetic
                             parameterized specs: doom_lite:<players 2..8>,
                             synthetic:<episode_len>
    --artifacts <dir>        AOT artifact directory (default: artifacts)
    --total-steps N          learner steps to run (default 100)
    --period-steps N         steps per learning period (default 25)
    --actors N               actors per learner (default 2)
    --envs-per-actor N       concurrent episodes per actor (vectorized
                             rollouts: each tick gathers every slot's
                             observations into one multi-row forward
                             pass per model; default 1 = classic actor)
    --game-mgr <name>        selfplay|uniform|pfsp|pfsp_var|sp_pfsp|elo_match|agent_exploiter
    --mode thread|procs      thread (default): every role as a thread in
                             this process.  procs: spawn one supervised
                             OS process per role worker; a killed worker
                             is detected by heartbeat timeout, respawned,
                             and its slot reassigned
    --controller-bind h:p    controller bind address for --mode procs
                             (default 127.0.0.1:0; use a routable host
                             for multi-machine runs)
    --advertise-host <host>  host peers use to reach services bound
                             here — required in practice when binding
                             0.0.0.0 ('0.0.0.0:port' is unroutable)
    --heartbeat-ms N         worker heartbeat cadence (default 1000)
    --heartbeat-timeout-ms N declare a worker dead after this silence
                             (default 5000, must be >= 2x heartbeat)
    --checkpoint-dir <dir>   write durable league snapshots here
    --checkpoint-every S     seconds between snapshots (default 30)
    --resume <dir>           restart from the newest snapshot in <dir>
   telemetry knobs:
    --stats-every S          seconds between league telemetry reports:
                             the periodic one-line per-role throughput
                             summary (env frames/s, episodes/s, consumed
                             frames/s, staleness, inf rows/s, pool hit
                             counters) merged from every role's
                             delta-based interval snapshots (default 2)
    --stats-jsonl <path>     append one merged-telemetry JSON object per
                             report interval to <path> (rates + run
                             totals per role + league episode/frame
                             counters) for offline trajectory plots
    --trace-sample <f>       fraction of actor ticks [0..1] that carry a
                             trace context through the request path
                             (gather -> infer queue/compute/reply ->
                             segment push -> learner consume; default 0
                             = spans off; p50/p95/p99 latency
                             histograms record regardless)
    --trace-slow-ms <ms>     requests slower than this land in every
                             process's slow-request log even when the
                             sampler skipped them (default 50)
    --trace-out <path>       write the run's recorded spans as Chrome
                             trace-event JSON on exit (open in
                             chrome://tracing or Perfetto)
   data-plane knobs:
    --refresh-every N        actor param-refresh cadence in episodes
                             (delta-aware: an unchanged in-training model
                             costs an O(1) NotModified reply; default 1)
    --infer-max-wait-us U    InfServer partial-batch deadline in
                             microseconds (default 2000)
    --infer-refresh-ms M     InfServer in-training param cache TTL in
                             milliseconds (default 50)
    --local-lanes <mode>     shared-memory lanes for actor->InfServer
                             requests when both ends share a host:
                             auto (lane when the address is loopback),
                             on (always negotiate), off (TCP only).
                             Lanes carry the same frames as TCP and
                             fall back to TCP on any failure
                             (default auto)
    --shm-dir <path>         directory for lane ring files (default
                             /dev/shm, else the system temp dir)
    --net-threads N          event-loop threads per transport server
                             (default 0 = auto from the core count)
   elasticity / pool sharding knobs:
    --model-pools N          in-process ModelPool replicas behind the
                             controller (default 1); models are placed
                             on a consistent-hash ring keyed by agent
    --pool-replication R     owners per agent key on the ring (default
                             2, clamped to --model-pools): writes go to
                             all R owners, reads fail over among them,
                             so kill:pool keeps every model readable
    --autoscale              procs mode only: run the closed-loop
                             scaling policy — grow inf-server slots
                             when batch fill stays above 0.8, drain
                             them below 0.2; drain actor slots when
                             learner staleness exceeds 3.0 periods,
                             grow them below 1.0.  Late-joining workers
                             are admitted into grown slots; drained
                             actors finish their episode and flush
                             segments before the slot retires.  Every
                             decision lands in the telemetry stream
                             (role 'autoscaler' in --stats-jsonl and
                             `stats`)
    --scale-every S          seconds between policy evaluations
                             (default 5; two intervals of cooldown per
                             role between moves)
    --min-actor-slots N      lower bound for actor scale-down
                             (default 1)
    --max-actor-slots N      upper bound for actor scale-up (default
                             4x the declared actor count)
    --min-inf-slots N        lower bound for inf-server scale-down
                             (default 1 when the spec declares any)
    --max-inf-slots N        upper bound for inf-server scale-up
                             (default 4x the declared count)
   fault-injection / chaos knobs:
    --faults <spec>          deterministic fault plan injected inside the
                             transport, comma-separated rules of the form
                             kind:target@prob[+delay_ms] where kind is
                             drop|delay|truncate|reject|partition and
                             target matches role/site/addr ('*' = any),
                             e.g. 'drop:learner@0.1,delay:*@0.05+3'.
                             Injections count in the faults_injected
                             meter; successful retries after injected
                             failures count in recoveries.  Off by
                             default: the hot-path check is one relaxed
                             atomic load
    --fault-seed N           seed of the fault plan (default 0): every
                             process derives the same per-site streams,
                             so a drill replays exactly
    --chaos <schedule>       procs-mode kill schedule, comma-separated
                             kill:<role>@<ms> with role one of
                             learner|actor|inf-server|pool|controller,
                             e.g. 'kill:inf-server@500,kill:pool@900'.
                             Workers are SIGKILLed and respawned (slots
                             reassigned); kill:pool downs an in-process
                             replica (clients fail over; needs
                             --model-pools >= 2 in the spec);
                             kill:controller snapshots, crashes and
                             restarts the control plane (needs
                             --checkpoint-dir and a fixed
                             --controller-bind port)
  controller   league control plane for a hand-launched multi-process
               deployment: owns LeagueMgr/ModelPool/CheckpointMgr,
               registers workers, reassigns slots on heartbeat loss
    --bind host:port (default 127.0.0.1:9100) + the `run` flags above
  worker       run exactly one league role, directed by a controller
    --role learner|actor|inf-server
    --controller host:port   controller to register with
    --artifacts <dir>        AOT artifact directory (default: artifacts)
    --bind-host <host>       host to bind role endpoints on
                             (default 127.0.0.1)
    --advertise-host <host>  host peers use for this worker's endpoints
                             (learner data ports, inf-server address)
  stats        probe a running controller for the merged league
               telemetry (per-role rates + run totals, including
               p50/p95/p99 inference queue-wait and row latency) plus
               the pool shard view: per-replica agent ownership,
               resident/spilled bytes, frame-cache hit rate, aggregate
    --controller host:port   controller to query
    --deploy                 also print worker/slot deployment counters
    --json                   emit the merged report as one JSON object
                             (telemetry roles + a `pool` array)
                             instead of the human-readable lines
  trace        drain the flight recorder of a running league (recent +
               slow request spans merged at the controller) and export
               Chrome trace-event JSON
    --controller host:port   controller to query
    --trace-out <path>       output file (default trace.json)
  info         print the artifact manifest summary (--artifacts <dir>)
  eval-doom    FRAG matches, Tables 1-2
    --checkpoint <f32 file> --setting 1|2a|2b|2c --games N
  eval-rps     RPS pool exploitability demo (--artifacts <dir>)
  model-pool   standalone ModelPool replica
    --bind host:port --spill-dir <dir> --mem-budget-mb N
    (SIGINT/SIGTERM or a wire Shutdown message stops it cleanly)
  league-mgr   standalone LeagueMgr (same shutdown paths)
    --bind host:port --n-agents N --n-opponents N --game-mgr <name> --seed S

dev tooling (separate binary, run by ci.sh as a hard gate):
  league-lint  project-invariant static analyzer: proto tag registry
               conformance, unsafe-block SAFETY hygiene, nonblocking
               region enforcement, and the network-path unwrap budget
               (cargo run --bin league-lint; see DESIGN.md
               'Correctness tooling')
    --root <dir>             tree to lint (default rust/src)
    --allow <file>           unwrap-budget allowlist (default
                             lint-allow.toml; missing = empty,
                             malformed = hard error)
    --check-file <f>         lint the given file(s) instead of the tree
    --self-test <dir>        run the analyzer's seeded-bad fixture
                             suite (rust/lint-fixtures)
";

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse argv[1..]; the first non-flag token becomes the subcommand.
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Parse `--name` as a `T`, falling back to `default` only when the
    /// flag is ABSENT.  A present-but-malformed value is an error — a
    /// typo like `--total-steps 10k` must abort, not silently train with
    /// the default.
    fn parsed<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!(
                    "invalid value for --{name}: '{v}' (expected a number)"
                )
            }),
        }
    }
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        self.parsed(name, default)
    }
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        self.parsed(name, default)
    }
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        self.parsed(name, default)
    }
    pub fn bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("actor --env pommerman --replicas 4 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("actor"));
        assert_eq!(a.get("env"), Some("pommerman"));
        assert_eq!(a.usize_or("replicas", 1).unwrap(), 4);
        assert!(a.bool("verbose"));
    }

    #[test]
    fn equals_form_and_positional() {
        let a = parse("eval --games=10 file1 file2");
        assert_eq!(a.usize_or("games", 0).unwrap(), 10);
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.f64_or("lr", 3e-4).unwrap(), 3e-4);
        assert_eq!(a.str_or("mode", "thread"), "thread");
        assert!(!a.bool("missing"));
    }

    /// A present-but-malformed numeric flag must error (naming the flag
    /// and the offending value), never fall back to the default —
    /// `--total-steps 10k` used to silently train 100 steps.
    #[test]
    fn malformed_numeric_flags_error() {
        let a = parse("run --total-steps 10k --lr 3e-4x --actors -2");
        let err = a.u64_or("total-steps", 100).unwrap_err().to_string();
        assert!(err.contains("--total-steps"), "flag name missing: {err}");
        assert!(err.contains("10k"), "offending value missing: {err}");
        assert!(a.f64_or("lr", 3e-4).is_err());
        // negative counts don't parse as usize either
        assert!(a.usize_or("actors", 2).is_err());
        // absent flags still fall back cleanly
        assert_eq!(a.u64_or("seed", 7).unwrap(), 7);
    }

    #[test]
    fn negative_and_float_forms_parse() {
        let a = parse("run --offset -3.5 --steps 0");
        assert_eq!(a.f64_or("offset", 0.0).unwrap(), -3.5);
        assert_eq!(a.u64_or("steps", 9).unwrap(), 0);
    }

    /// USAGE's `--game-mgr` list and the league factory must accept the
    /// exact same set of names (both directions).
    #[test]
    fn usage_game_mgr_list_matches_factory() {
        use crate::league::game_mgr::{make_game_mgr, GAME_MGR_NAMES};
        let listed: Vec<&str> = USAGE
            .lines()
            .find(|l| l.trim_start().starts_with("--game-mgr"))
            .and_then(|l| l.split_whitespace().last())
            .expect("USAGE must document --game-mgr")
            .split('|')
            .collect();
        for name in &listed {
            assert!(
                make_game_mgr(name).is_ok(),
                "USAGE lists '{name}' but the factory rejects it"
            );
        }
        for name in GAME_MGR_NAMES {
            assert!(
                listed.contains(name),
                "factory accepts '{name}' but USAGE does not list it"
            );
        }
        assert_eq!(listed.len(), GAME_MGR_NAMES.len(), "duplicate names");
    }
}
