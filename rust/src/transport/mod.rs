//! Message transport: the ZeroMQ-substitute (§3.3 of the paper).
//!
//! Three socket patterns TLeague uses, over length-prefixed TCP frames:
//!   - REQ/REP  — task requests, ModelPool read/write (`ReqClient`/`RepServer`)
//!   - PUSH/PULL — actor→learner trajectory streaming (`PushClient`/`PullServer`)
//!   - (PUB/SUB is folded into REQ/REP polling: ModelPool reads are cheap)
//!
//! Frame format: u32 little-endian length + payload (a `Wire`-encoded
//! `Msg`).  Every server spawns one thread per connection; this repo's
//! scale (tens of actors per learner per machine) does not need epoll.

pub mod fault;

use crate::proto::Msg;
use crate::util::codec::Wire;
use crate::util::metrics::Meter;
use anyhow::{bail, Context, Result};
use std::io::{IoSlice, Read, Write as IoWrite};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub const MAX_FRAME: u32 = 512 << 20; // 512 MiB guard (synthetic params are 25 MiB)

/// How long a frame that has STARTED arriving may stall before the
/// connection is declared dead (see `read_frame`).
const FRAME_STALL_DEADLINE: Duration = Duration::from_secs(30);

/// Write one length-prefixed frame assembled from `parts` — a single
/// vectored syscall in the common case, so a pre-encoded reply frame
/// (the ModelPool's cached `Arc<[u8]>`) is never copied into a staging
/// buffer on its way out.
pub fn write_frame_parts(stream: &mut TcpStream, parts: &[&[u8]]) -> Result<()> {
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let len = (total as u32).to_le_bytes();
    let grand = total + 4;
    let mut written = 0usize;
    let mut bufs: Vec<IoSlice> = Vec::with_capacity(parts.len() + 1);
    while written < grand {
        // rebuild the iovec from the current offset (first iteration
        // covers everything; later ones only run after a partial write)
        bufs.clear();
        let mut skip = written;
        if skip < 4 {
            bufs.push(IoSlice::new(&len[skip..]));
            skip = 0;
        } else {
            skip -= 4;
        }
        for p in parts {
            if skip >= p.len() {
                skip -= p.len();
                continue;
            }
            bufs.push(IoSlice::new(&p[skip..]));
            skip = 0;
        }
        let n = match stream.write_vectored(&bufs) {
            Ok(0) => bail!("connection closed mid-write"),
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        };
        written += n;
    }
    Ok(())
}

/// Write one length-prefixed frame.
pub fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    write_frame_parts(stream, &[payload])
}

/// The frame-size guard, applied before any payload allocation.  The
/// bound is inclusive: exactly MAX_FRAME is a legal frame.
fn check_frame_len(len: u32) -> Result<()> {
    if len > MAX_FRAME {
        bail!("frame too large: {len}");
    }
    Ok(())
}

/// Read one length-prefixed frame into `buf` (reused across calls).
pub fn read_frame(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<()> {
    let mut len_bytes = [0u8; 4];
    read_full(stream, &mut len_bytes, true)?;
    let len = u32::from_le_bytes(len_bytes);
    check_frame_len(len)?;
    buf.resize(len as usize, 0);
    read_full(stream, buf, false)?;
    Ok(())
}

/// `read_exact` with frame-aware timeout semantics.  A read timeout with
/// ZERO bytes consumed surfaces as WouldBlock/TimedOut so server loops
/// can poll their stop flag between frames — but once a frame has begun,
/// returning early would desync the length-prefix framing (the next read
/// would parse payload bytes as a length).  Mid-frame timeouts therefore
/// keep reading until `FRAME_STALL_DEADLINE`, then error fatally.
fn read_full(stream: &mut TcpStream, out: &mut [u8], frame_start: bool) -> Result<()> {
    let mut got = 0usize;
    let mut stalled_since: Option<Instant> = None;
    while got < out.len() {
        match stream.read(&mut out[got..]) {
            Ok(0) => bail!("connection closed"),
            Ok(n) => {
                got += n;
                stalled_since = None;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if frame_start && got == 0 {
                    return Err(e.into()); // clean between-frames poll
                }
                let t0 = *stalled_since.get_or_insert_with(Instant::now);
                if t0.elapsed() > FRAME_STALL_DEADLINE {
                    bail!("frame stalled mid-read ({got}/{} bytes)", out.len());
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// What a `RepServer` handler returns: an owned message (encoded into
/// the connection's reused reply buffer) or a pre-encoded frame — a
/// small owned `head` (wire tag + fixed fields) followed by a shared
/// `tail` (e.g. the ModelPool's cached `ModelBlob` encoding).  Framed
/// replies go out in one vectored syscall with zero copies of the tail.
pub enum Reply {
    Msg(Msg),
    Framed { head: Vec<u8>, tail: Arc<[u8]> },
}

impl Reply {
    pub fn framed(head: Vec<u8>, tail: Arc<[u8]>) -> Reply {
        Reply::Framed { head, tail }
    }
}

impl From<Msg> for Reply {
    fn from(m: Msg) -> Reply {
        Reply::Msg(m)
    }
}

/// Blocking request/response client with lazy (re)connect.
pub struct ReqClient {
    addr: String,
    inner: Mutex<ReqInner>,
    /// Frame bytes received/sent (payload + 4-byte length prefix),
    /// counted once per completed exchange — a retransmitted request
    /// after a connection break counts once, matching what the peer
    /// actually consumed.  Re-pointed at a hub's meters by role wiring
    /// (e.g. `Actor::use_hub`) so bandwidth shows up in role snapshots.
    pub bytes_in: Arc<Meter>,
    pub bytes_out: Arc<Meter>,
}

/// Connection + reply buffer, reused across requests so the read path
/// stays allocation-free once the buffer has grown to frame size.
#[derive(Default)]
struct ReqInner {
    stream: Option<TcpStream>,
    buf: Vec<u8>,
}

impl ReqClient {
    pub fn connect(addr: &str) -> ReqClient {
        ReqClient {
            addr: addr.to_string(),
            inner: Mutex::new(ReqInner::default()),
            bytes_in: Arc::new(Meter::new()),
            bytes_out: Arc::new(Meter::new()),
        }
    }

    /// Send `msg`, wait for the reply.  Reconnects (with retry/backoff)
    /// on broken connections — the k8s-restart story of the paper means
    /// peers can briefly vanish.
    pub fn request(&self, msg: &Msg) -> Result<Msg> {
        self.request_n(msg, 40)
    }

    /// [`request`](Self::request) with a caller-chosen attempt budget.
    /// For callers that hold a fallback peer (e.g. another ModelPool
    /// replica): failing over beats riding the full ~9s backoff ladder
    /// against a dead endpoint.
    pub fn request_n(&self, msg: &Msg, attempts: u32) -> Result<Msg> {
        let payload = msg.to_bytes();
        let tag = payload.first().copied().unwrap_or(0);
        let mut guard = self.inner.lock().unwrap();
        let mut last_err = None;
        let mut failures = 0u32;
        for attempt in 0..attempts {
            if guard.stream.is_none() {
                match TcpStream::connect(&self.addr) {
                    Ok(s) => {
                        s.set_nodelay(true).ok();
                        guard.stream = Some(s);
                    }
                    Err(e) => {
                        last_err = Some(e.into());
                        failures += 1;
                        drop(guard);
                        std::thread::sleep(Duration::from_millis(
                            25 * (attempt + 1).min(10),
                        ));
                        guard = self.inner.lock().unwrap();
                        continue;
                    }
                }
            }
            match fault::check(fault::SITE_REQ, &self.addr, tag) {
                fault::Verdict::Pass => {}
                fault::Verdict::Delay(d) => std::thread::sleep(d),
                fault::Verdict::Drop | fault::Verdict::Reject => {
                    guard.stream = None;
                    last_err =
                        Some(anyhow::anyhow!("fault: injected connection drop"));
                    failures += 1;
                    continue;
                }
                fault::Verdict::Truncate => {
                    // write a short frame, then kill the connection —
                    // the server sees a mid-frame close
                    if let Some(s) = guard.stream.as_mut() {
                        let _ = s.write_all(
                            &(payload.len() as u32).to_le_bytes(),
                        );
                        let _ = s.write_all(&payload[..payload.len() / 2]);
                    }
                    guard.stream = None;
                    last_err =
                        Some(anyhow::anyhow!("fault: injected truncated frame"));
                    failures += 1;
                    continue;
                }
            }
            let ReqInner { stream, buf } = &mut *guard;
            let stream = stream.as_mut().unwrap();
            let ok = (|| {
                write_frame(stream, &payload)?;
                read_frame(stream, buf)?;
                Msg::from_bytes(buf)
            })();
            match ok {
                Ok(reply) => {
                    if failures > 0 {
                        // exchange completed after at least one failed
                        // attempt: that is a healed fault
                        fault::on_recovery();
                    }
                    self.bytes_out.add(payload.len() as u64 + 4);
                    self.bytes_in.add(guard.buf.len() as u64 + 4);
                    return Ok(reply);
                }
                Err(e) => {
                    guard.stream = None; // force reconnect
                    last_err = Some(e);
                    failures += 1;
                }
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow::anyhow!("request failed")))
            .with_context(|| format!("req to {}", self.addr))
    }
}

/// Request/response server: spawns a handler thread per connection.
pub struct RepServer {
    pub addr: String,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    /// Frame bytes received/sent summed over every connection this
    /// server accepted (payload + 4-byte length prefix).  Registered
    /// into the owning role's `MetricsHub` so bandwidth rides the
    /// telemetry plane next to request rates.
    pub bytes_in: Arc<Meter>,
    pub bytes_out: Arc<Meter>,
}

impl RepServer {
    /// Bind to `addr` ("127.0.0.1:0" for an ephemeral port) and serve
    /// `handler(msg) -> reply` until `shutdown()`.
    pub fn serve<F>(addr: &str, handler: F) -> Result<RepServer>
    where
        F: Fn(Msg) -> Msg + Send + Sync + 'static,
    {
        Self::serve_frames(addr, move |msg| Reply::Msg(handler(msg)))
    }

    /// Like [`RepServer::serve`], but the handler may reply with a
    /// pre-encoded [`Reply::Framed`] frame (zero encode, zero copy of
    /// the shared tail) — the ModelPool serve path.
    pub fn serve_frames<F>(addr: &str, handler: F) -> Result<RepServer>
    where
        F: Fn(Msg) -> Reply + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handler = Arc::new(handler);
        let bytes_in = Arc::new(Meter::new());
        let bytes_out = Arc::new(Meter::new());
        let (bin, bout) = (bytes_in.clone(), bytes_out.clone());
        let local2 = local.clone();
        let handle = std::thread::Builder::new()
            .name(format!("rep@{local}"))
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            match fault::check(fault::SITE_ACCEPT, &local2, 0) {
                                fault::Verdict::Pass => {}
                                fault::Verdict::Delay(d) => {
                                    std::thread::sleep(d)
                                }
                                // reject/drop at accept: close right away
                                _ => continue,
                            }
                            let h = handler.clone();
                            let stop3 = stop2.clone();
                            let (bin, bout) = (bin.clone(), bout.clone());
                            std::thread::spawn(move || {
                                Self::conn_loop(stream, h, stop3, bin, bout);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(RepServer { addr: local, stop, handle: Some(handle), bytes_in, bytes_out })
    }

    fn conn_loop(
        mut stream: TcpStream,
        handler: Arc<dyn Fn(Msg) -> Reply + Send + Sync>,
        stop: Arc<AtomicBool>,
        bytes_in: Arc<Meter>,
        bytes_out: Arc<Meter>,
    ) {
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(Duration::from_millis(200)))
            .ok();
        let laddr = stream
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_default();
        let mut buf = Vec::new();
        // reply staging buffer, reused across requests: [len;4][payload]
        let mut reply_buf: Vec<u8> = Vec::new();
        loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            match read_frame(&mut stream, &mut buf) {
                Ok(()) => {}
                Err(e) => {
                    // timeouts poll the stop flag; anything else ends the conn
                    if let Some(io) = e.downcast_ref::<std::io::Error>() {
                        if matches!(
                            io.kind(),
                            std::io::ErrorKind::WouldBlock
                                | std::io::ErrorKind::TimedOut
                        ) {
                            continue;
                        }
                    }
                    return;
                }
            }
            bytes_in.add(buf.len() as u64 + 4);
            let tag = buf.first().copied().unwrap_or(0);
            match fault::check(fault::SITE_REP, &laddr, tag) {
                fault::Verdict::Pass => {}
                fault::Verdict::Delay(d) => std::thread::sleep(d),
                fault::Verdict::Drop | fault::Verdict::Reject => return,
                fault::Verdict::Truncate => {
                    // claim a longer reply than we send, then die — the
                    // client sees a mid-frame close and retries
                    let _ = stream.write_all(&64u32.to_le_bytes());
                    let _ = stream.write_all(&[0u8; 8]);
                    return;
                }
            }
            let reply = match Msg::from_bytes(&buf) {
                Ok(msg) => handler(msg),
                Err(e) => Reply::Msg(Msg::Err(format!("decode: {e}"))),
            };
            let sent = match reply {
                Reply::Msg(msg) => {
                    reply_buf.clear();
                    reply_buf.extend_from_slice(&[0u8; 4]);
                    msg.encode(&mut reply_buf);
                    let len = (reply_buf.len() - 4) as u32;
                    reply_buf[..4].copy_from_slice(&len.to_le_bytes());
                    bytes_out.add(reply_buf.len() as u64);
                    // header + payload leave in one buffered write
                    stream.write_all(&reply_buf).map_err(anyhow::Error::from)
                }
                Reply::Framed { head, tail } => {
                    bytes_out.add(head.len() as u64 + tail.len() as u64 + 4);
                    write_frame_parts(&mut stream, &[&head, &tail])
                }
            };
            if sent.is_err() {
                return;
            }
        }
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

impl Drop for RepServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One-way streaming sender (actor side of trajectory PUSH).
pub struct PushClient {
    addr: String,
    stream: Mutex<Option<TcpStream>>,
    /// Frame bytes sent (payload + length prefix), once per delivered
    /// push.  Re-pointed at a hub meter by `Actor::use_hub`.
    pub bytes_out: Arc<Meter>,
}

impl PushClient {
    pub fn connect(addr: &str) -> PushClient {
        PushClient {
            addr: addr.to_string(),
            stream: Mutex::new(None),
            bytes_out: Arc::new(Meter::new()),
        }
    }

    /// One connect + one write; on failure the connection is dropped
    /// and the error returned (no retries — `push`/`try_push` decide
    /// the retry policy).
    fn push_once(
        conn: &mut Option<TcpStream>,
        addr: &str,
        payload: &[u8],
        tag: u8,
    ) -> Result<()> {
        if conn.is_none() {
            let s = TcpStream::connect(addr)
                .with_context(|| format!("connect {addr}"))?;
            s.set_nodelay(true).ok();
            *conn = Some(s);
        }
        match fault::check(fault::SITE_PUSH, addr, tag) {
            fault::Verdict::Pass => {}
            fault::Verdict::Delay(d) => std::thread::sleep(d),
            fault::Verdict::Drop | fault::Verdict::Reject => {
                *conn = None;
                bail!("fault: injected connection drop");
            }
            fault::Verdict::Truncate => {
                if let Some(s) = conn.as_mut() {
                    let _ = s.write_all(&(payload.len() as u32).to_le_bytes());
                    let _ = s.write_all(&payload[..payload.len() / 2]);
                }
                *conn = None;
                bail!("fault: injected truncated frame");
            }
        }
        if let Err(e) = write_frame(conn.as_mut().unwrap(), payload) {
            *conn = None;
            return Err(e);
        }
        Ok(())
    }

    pub fn push(&self, msg: &Msg) -> Result<()> {
        let payload = msg.to_bytes();
        let tag = payload.first().copied().unwrap_or(0);
        let mut guard = self.stream.lock().unwrap();
        let mut failures = 0u32;
        for attempt in 0..40 {
            match Self::push_once(&mut guard, &self.addr, &payload, tag) {
                Ok(()) => {
                    if failures > 0 {
                        fault::on_recovery();
                    }
                    self.bytes_out.add(payload.len() as u64 + 4);
                    return Ok(());
                }
                Err(_) => {
                    failures += 1;
                    drop(guard);
                    std::thread::sleep(Duration::from_millis(
                        25 * (attempt + 1).min(10),
                    ));
                    guard = self.stream.lock().unwrap();
                }
            }
        }
        bail!("push to {} failed", self.addr)
    }

    /// Single-attempt push for callers that keep their own bounded
    /// retry queue (the Actor's segment buffer): one connect + one
    /// write, error back immediately — never sleeps through the ~10s
    /// backoff ladder `push` uses, so a dead learner cannot stall the
    /// rollout tick.
    pub fn try_push(&self, msg: &Msg) -> Result<()> {
        let payload = msg.to_bytes();
        let tag = payload.first().copied().unwrap_or(0);
        let mut guard = self.stream.lock().unwrap();
        Self::push_once(&mut guard, &self.addr, &payload, tag)?;
        self.bytes_out.add(payload.len() as u64 + 4);
        Ok(())
    }
}

/// One-way streaming receiver (learner side of trajectory PULL); frames
/// from all connections are funneled into one bounded queue, giving the
/// blocking-queue backpressure the paper's on-policy mode relies on.
pub struct PullServer {
    pub addr: String,
    rx: std::sync::mpsc::Receiver<Msg>,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    /// Undecodable frames dropped, across all connections.  A nonzero
    /// rate means a peer speaks a different protocol version — silent
    /// drops here used to be invisible (PoolStats-style observability).
    pub decode_errors: Arc<Meter>,
    /// Frame bytes received across all connections (payload + prefix),
    /// including frames that later fail to decode — the wire carried
    /// them either way.
    pub bytes_in: Arc<Meter>,
}

impl PullServer {
    pub fn bind(addr: &str, queue_cap: usize) -> Result<PullServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let (tx, rx) = std::sync::mpsc::sync_channel(queue_cap);
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let decode_errors = Arc::new(Meter::new());
        let errs = decode_errors.clone();
        let bytes_in = Arc::new(Meter::new());
        let bin = bytes_in.clone();
        let handle = std::thread::Builder::new()
            .name(format!("pull@{local}"))
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let tx = tx.clone();
                            let stop3 = stop2.clone();
                            let errs = errs.clone();
                            let bin = bin.clone();
                            std::thread::spawn(move || {
                                Self::conn_loop(stream, tx, stop3, errs, bin);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(PullServer {
            addr: local,
            rx,
            stop,
            handle: Some(handle),
            decode_errors,
            bytes_in,
        })
    }

    fn conn_loop(
        mut stream: TcpStream,
        tx: std::sync::mpsc::SyncSender<Msg>,
        stop: Arc<AtomicBool>,
        decode_errors: Arc<Meter>,
        bytes_in: Arc<Meter>,
    ) {
        stream
            .set_read_timeout(Some(Duration::from_millis(200)))
            .ok();
        let laddr = stream
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_default();
        let mut buf = Vec::new();
        let mut err_logged = false;
        loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            match read_frame(&mut stream, &mut buf) {
                Ok(()) => {
                    bytes_in.add(buf.len() as u64 + 4);
                    match fault::check(
                        fault::SITE_PULL,
                        &laddr,
                        buf.first().copied().unwrap_or(0),
                    ) {
                        fault::Verdict::Pass => {}
                        fault::Verdict::Delay(d) => std::thread::sleep(d),
                        // swallow just this frame
                        fault::Verdict::Truncate => continue,
                        fault::Verdict::Drop | fault::Verdict::Reject => return,
                    }
                    match Msg::from_bytes(&buf) {
                        Ok(msg) => {
                            // blocking send = backpressure to the TCP
                            // socket, which stalls the pushing actor
                            // (on-policy mode)
                            if tx.send(msg).is_err() {
                                return;
                            }
                        }
                        Err(e) => {
                            decode_errors.add(1);
                            if !err_logged {
                                err_logged = true;
                                let peer = stream
                                    .peer_addr()
                                    .map(|a| a.to_string())
                                    .unwrap_or_else(|_| "?".into());
                                eprintln!(
                                    "pull: dropping undecodable {}-byte frame \
                                     from {peer}: {e} (counting further drops \
                                     silently)",
                                    buf.len()
                                );
                            }
                        }
                    }
                }
                Err(e) => {
                    if let Some(io) = e.downcast_ref::<std::io::Error>() {
                        if matches!(
                            io.kind(),
                            std::io::ErrorKind::WouldBlock
                                | std::io::ErrorKind::TimedOut
                        ) {
                            continue;
                        }
                    }
                    return;
                }
            }
        }
    }

    pub fn recv_timeout(&self, d: Duration) -> Option<Msg> {
        self.rx.recv_timeout(d).ok()
    }
    pub fn try_recv(&self) -> Option<Msg> {
        self.rx.try_recv().ok()
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

impl Drop for PullServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::{ModelKey, TrajSegment};

    #[test]
    fn req_rep_roundtrip() {
        let server = RepServer::serve("127.0.0.1:0", |msg| match msg {
            Msg::Ping => Msg::Pong,
            other => Msg::Err(format!("unexpected {other:?}")),
        })
        .unwrap();
        let client = ReqClient::connect(&server.addr);
        for _ in 0..10 {
            assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
        }
    }

    #[test]
    fn req_rep_many_clients() {
        let server = RepServer::serve("127.0.0.1:0", |_| Msg::Ok).unwrap();
        let addr = server.addr.clone();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let c = ReqClient::connect(&addr);
                    for _ in 0..50 {
                        assert_eq!(c.request(&Msg::Ping).unwrap(), Msg::Ok);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn push_pull_stream() {
        let server = PullServer::bind("127.0.0.1:0", 64).unwrap();
        let client = PushClient::connect(&server.addr);
        let seg = TrajSegment {
            model_key: ModelKey::new(0, 1),
            t: 2,
            n_agents: 1,
            obs: vec![0.0; 12],
            actions: vec![1, 2],
            behavior_logp: vec![-1.0, -1.0],
            rewards: vec![0.5, -0.5],
            discounts: vec![0.99, 0.0],
            trace: None,
        };
        for _ in 0..20 {
            client.push(&Msg::Traj(seg.clone())).unwrap();
        }
        let mut got = 0;
        while got < 20 {
            let msg = server
                .recv_timeout(Duration::from_secs(5))
                .expect("timed out");
            assert!(matches!(msg, Msg::Traj(ref s) if *s == seg));
            got += 1;
        }
    }

    /// A handler replying with a pre-encoded frame (head tag + shared
    /// tail) must be indistinguishable on the wire from an owned reply.
    #[test]
    fn framed_reply_matches_owned_encoding() {
        use crate::proto::{ModelBlob, TAG_MODEL};
        let blob = ModelBlob {
            key: ModelKey::new(2, 5),
            params: vec![1.0, -2.5, 3.25],
            hp: vec![3e-4],
            frozen: true,
        };
        let tail: Arc<[u8]> = blob.to_bytes().into();
        let server = RepServer::serve_frames("127.0.0.1:0", move |msg| match msg {
            Msg::Ping => Reply::framed(vec![TAG_MODEL], tail.clone()),
            other => Reply::Msg(Msg::Err(format!("unexpected {other:?}"))),
        })
        .unwrap();
        let client = ReqClient::connect(&server.addr);
        for _ in 0..3 {
            match client.request(&Msg::Ping).unwrap() {
                Msg::Model(b) => {
                    assert_eq!(b.key, ModelKey::new(2, 5));
                    assert_eq!(b.params, vec![1.0, -2.5, 3.25]);
                    assert!(b.frozen);
                }
                other => panic!("expected Model, got {other:?}"),
            }
        }
    }

    /// Undecodable-but-well-framed payloads must get an error reply and
    /// leave the connection usable (no desync of the length framing).
    #[test]
    fn garbage_frames_do_not_corrupt_connection() {
        let server = RepServer::serve("127.0.0.1:0", |msg| match msg {
            Msg::Ping => Msg::Pong,
            other => Msg::Err(format!("unexpected {other:?}")),
        })
        .unwrap();
        let mut stream = TcpStream::connect(&server.addr).unwrap();
        let mut buf = Vec::new();
        crate::util::proptest::forall(40, "garbage-frame", |rng| {
            // tag >= 50 is unknown, so decode always fails
            let n = 1 + rng.below(64) as usize;
            let mut garbage = vec![50 + (rng.below(200) as u8); 1];
            for _ in 1..n {
                garbage.push(rng.next_u32() as u8);
            }
            write_frame(&mut stream, &garbage).map_err(|e| e.to_string())?;
            read_frame(&mut stream, &mut buf).map_err(|e| e.to_string())?;
            let reply = Msg::from_bytes(&buf).map_err(|e| e.to_string())?;
            crate::prop_assert!(
                matches!(reply, Msg::Err(_)),
                "garbage must get Err, got {reply:?}"
            );
            // the same connection still serves real requests
            write_frame(&mut stream, &Msg::Ping.to_bytes())
                .map_err(|e| e.to_string())?;
            read_frame(&mut stream, &mut buf).map_err(|e| e.to_string())?;
            crate::prop_assert_eq!(
                Msg::from_bytes(&buf).map_err(|e| e.to_string())?,
                Msg::Pong
            );
            Ok(())
        });
    }

    /// An over-MAX_FRAME length prefix is rejected before any allocation
    /// and kills only that connection; fresh connections keep working.
    #[test]
    fn oversized_frame_rejected_and_server_survives() {
        let server = RepServer::serve("127.0.0.1:0", |_| Msg::Pong).unwrap();
        let mut bad = TcpStream::connect(&server.addr).unwrap();
        bad.write_all(&(MAX_FRAME + 1).to_le_bytes()).unwrap();
        // server drops the connection: the read eventually sees EOF
        bad.set_read_timeout(Some(Duration::from_secs(5))).ok();
        let mut probe = [0u8; 1];
        assert_eq!(bad.read(&mut probe).unwrap_or(0), 0, "conn must close");
        // a new connection is unaffected
        let client = ReqClient::connect(&server.addr);
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
    }

    /// A frame truncated by peer death must error out, not hang or get
    /// misread as a shorter frame.
    #[test]
    fn truncated_frame_errors_cleanly() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&100u32.to_le_bytes()).unwrap();
            s.write_all(&[7u8; 50]).unwrap(); // half the promised payload
            // dropped here: peer closes mid-frame
        });
        let (mut conn, _) = listener.accept().unwrap();
        let mut buf = Vec::new();
        let err = read_frame(&mut conn, &mut buf).unwrap_err();
        assert!(
            err.to_string().contains("connection closed"),
            "want mid-frame close error, got: {err}"
        );
        writer.join().unwrap();
    }

    /// The size guard is inclusive at exactly MAX_FRAME and rejects one
    /// byte more — checked on the predicate so the test doesn't have to
    /// allocate a 512 MiB payload buffer.
    #[test]
    fn max_frame_boundary() {
        assert!(check_frame_len(MAX_FRAME).is_ok());
        assert!(check_frame_len(MAX_FRAME + 1).is_err());
        assert!(check_frame_len(0).is_ok());
    }

    #[test]
    fn pull_server_counts_undecodable_frames() {
        let server = PullServer::bind("127.0.0.1:0", 16).unwrap();
        let mut s = TcpStream::connect(&server.addr).unwrap();
        // two garbage frames, then a real one
        write_frame(&mut s, &[99u8, 1, 2, 3]).unwrap();
        write_frame(&mut s, &[200u8]).unwrap();
        write_frame(&mut s, &Msg::Ping.to_bytes()).unwrap();
        let msg = server.recv_timeout(Duration::from_secs(5)).expect("timed out");
        assert_eq!(msg, Msg::Ping);
        assert_eq!(server.decode_errors.count(), 2);
    }

    /// Satellite: byte accounting — client-out equals server-in and
    /// vice versa (both count payload + 4-byte prefix per frame), and
    /// push/pull agree the same way.
    #[test]
    fn byte_meters_agree_across_the_wire() {
        let server = RepServer::serve("127.0.0.1:0", |_| Msg::Pong).unwrap();
        let client = ReqClient::connect(&server.addr);
        for _ in 0..5 {
            client.request(&Msg::Ping).unwrap();
        }
        let req_frame = Msg::Ping.to_bytes().len() as u64 + 4;
        let rep_frame = Msg::Pong.to_bytes().len() as u64 + 4;
        assert_eq!(client.bytes_out.count(), 5 * req_frame);
        assert_eq!(client.bytes_in.count(), 5 * rep_frame);
        // conn threads count on their side of the same frames
        assert_eq!(server.bytes_in.count(), client.bytes_out.count());
        assert_eq!(server.bytes_out.count(), client.bytes_in.count());

        let pull = PullServer::bind("127.0.0.1:0", 16).unwrap();
        let push = PushClient::connect(&pull.addr);
        push.push(&Msg::Ping).unwrap();
        push.push(&Msg::Ping).unwrap();
        assert_eq!(pull.recv_timeout(Duration::from_secs(5)), Some(Msg::Ping));
        assert_eq!(pull.recv_timeout(Duration::from_secs(5)), Some(Msg::Ping));
        assert_eq!(push.bytes_out.count(), 2 * req_frame);
        assert_eq!(pull.bytes_in.count(), push.bytes_out.count());
    }

    #[test]
    fn client_survives_server_restart() {
        let mut server = RepServer::serve("127.0.0.1:0", |_| Msg::Ok).unwrap();
        let addr = server.addr.clone();
        let client = ReqClient::connect(&addr);
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Ok);
        server.shutdown();
        // old per-connection threads poll the stop flag every 200ms;
        // wait for them to drain before the client reconnects.
        std::thread::sleep(Duration::from_millis(400));
        // restart on the same port
        let _server2 = RepServer::serve(&addr, |_| Msg::Pong).unwrap();
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
    }

    /// Injected request-path drops are retried through and healed: every
    /// exchange still completes, and the fault/recovery meters move.
    #[test]
    fn req_client_heals_injected_drops() {
        let _g = fault::TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        let server = RepServer::serve("127.0.0.1:0", |_| Msg::Pong).unwrap();
        let client = ReqClient::connect(&server.addr);
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
        fault::set_role("req-heal-test");
        // target THIS server's (unique ephemeral) address so concurrent
        // tests in the binary never match the plan
        fault::install(
            7,
            fault::parse_spec(&format!("drop:{}@0.5", server.addr)).unwrap(),
        );
        let injected0 = fault::injected_meter().count();
        let recovered0 = fault::recovered_meter().count();
        for _ in 0..20 {
            assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
        }
        fault::clear();
        assert!(
            fault::injected_meter().count() > injected0,
            "p=0.5 over 20+ draws must inject at least once"
        );
        assert!(
            fault::recovered_meter().count() > recovered0,
            "a retried-through drop must count as a recovery"
        );
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
    }

    /// Truncate faults kill the connection mid-frame without desyncing
    /// the length-prefix protocol: the client reconnects and completes.
    #[test]
    fn truncate_fault_breaks_conn_not_protocol() {
        let _g = fault::TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        let server = RepServer::serve("127.0.0.1:0", |_| Msg::Pong).unwrap();
        let client = ReqClient::connect(&server.addr);
        fault::set_role("truncate-test");
        fault::install(
            11,
            fault::parse_spec(&format!("truncate:{}@0.3", server.addr))
                .unwrap(),
        );
        for _ in 0..20 {
            assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
        }
        fault::clear();
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
    }

    /// `try_push` is single-attempt: under a full partition it errors
    /// immediately instead of sleeping through the backoff ladder, and
    /// works again the moment the partition lifts.
    #[test]
    fn try_push_fails_fast_under_partition() {
        let _g = fault::TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        let pull = PullServer::bind("127.0.0.1:0", 64).unwrap();
        let push = PushClient::connect(&pull.addr);
        push.try_push(&Msg::Ping).unwrap();
        assert_eq!(pull.recv_timeout(Duration::from_secs(5)), Some(Msg::Ping));
        fault::set_role("push-test");
        fault::install(
            7,
            fault::parse_spec(&format!("partition:{}@1", pull.addr)).unwrap(),
        );
        let t0 = Instant::now();
        assert!(push.try_push(&Msg::Ping).is_err());
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "try_push must not sleep through a retry ladder"
        );
        fault::clear();
        push.try_push(&Msg::Ping).unwrap();
        assert_eq!(pull.recv_timeout(Duration::from_secs(5)), Some(Msg::Ping));
    }
}
