//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Python is NEVER on this path — artifacts were lowered once by
//! `make artifacts`.
//!
//! Thread-safety: the crate's `PjRtClient` is `Rc`-based (!Send).  An
//! [`Engine`] owns the client plus every compiled executable and
//! serializes all PJRT calls behind one `Mutex`; the `unsafe impl Send`
//! is sound because the `Rc` refcount is only ever touched while holding
//! that mutex (the underlying XLA CPU client itself is thread-safe).
//! Modules that want parallel execution create their own `Engine`.

pub mod manifest;

use crate::util::metrics::Meter;
use anyhow::{bail, Context, Result};
use manifest::{ArtifactSpec, Dtype, Manifest};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Host-side tensor handed to / returned from an executable.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn dtype(&self) -> Dtype {
        match self {
            Tensor::F32(_) => Dtype::F32,
            Tensor::I32(_) => Dtype::I32,
        }
    }
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }
}

struct EngineInner {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// device-resident input buffers keyed by caller-provided id —
    /// model parameters are uploaded once per version instead of per
    /// call (they dominate transfer volume: ~3 MB vs ~8 KB of obs)
    buffer_cache: HashMap<u64, xla::PjRtBuffer>,
    cache_order: Vec<u64>,
}

/// Engine input: plain host tensor, or host tensor + stable cache id
/// (the device buffer is reused across calls with the same id).
pub enum In<'a> {
    Host(&'a Tensor),
    Cached(u64, &'a Tensor),
}

const BUFFER_CACHE_CAP: usize = 48;

/// Process-unique id for [`In::Cached`] / [`Engine::infer_cached`]
/// buffers (avoids collisions when many modules share one Engine).
pub fn new_cache_id() -> u64 {
    static NEXT: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);
    NEXT.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
}

/// Compiled-artifact cache + executor.  One per module that needs
/// compute (Learner, InfServer, local-inference Actor pool, eval).
pub struct Engine {
    dir: PathBuf,
    pub manifest: Manifest,
    inner: Mutex<EngineInner>,
    /// executions performed (for profiling / Table-3 accounting)
    pub exec_meter: Meter,
}

// SAFETY: see module docs — all Rc clones/drops happen under `inner`'s
// Mutex, and the C++ PJRT CPU client is itself thread-safe.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine {
            dir,
            manifest,
            inner: Mutex::new(EngineInner {
                client,
                executables: HashMap::new(),
                buffer_cache: HashMap::new(),
                cache_order: Vec::new(),
            }),
            exec_meter: Meter::new(),
        })
    }

    /// Initial flat parameter vector for `env` (little-endian f32 file
    /// written by aot.py).
    pub fn init_params(&self, env: &str) -> Result<Vec<f32>> {
        let m = self.manifest.env(env)?;
        let path = self.dir.join(&m.init_params_file);
        let raw = std::fs::read(&path)
            .with_context(|| format!("read {path:?}"))?;
        if raw.len() != m.param_count * 4 {
            bail!(
                "init params size mismatch: {} bytes for P={}",
                raw.len(),
                m.param_count
            );
        }
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Compile (once) and cache the named artifact of `env`.
    fn ensure_compiled(&self, env: &str, artifact: &str) -> Result<ArtifactSpec> {
        let spec = self.manifest.env(env)?.artifact(artifact)?.clone();
        let mut inner = self.inner.lock().unwrap();
        if !inner.executables.contains_key(artifact) {
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("utf8 path")?,
            )
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {artifact}: {e:?}"))?;
            inner.executables.insert(artifact.to_string(), exe);
        }
        Ok(spec)
    }

    /// Execute `artifact` with host tensors; validates dtypes/lengths
    /// against the manifest and returns the host output tensors.
    pub fn run(&self, env: &str, artifact: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let ins: Vec<In> = inputs.iter().map(In::Host).collect();
        self.run_in(env, artifact, &ins)
    }

    /// Like [`Engine::run`], but inputs tagged `In::Cached(id, _)` keep
    /// their device buffer across calls (uploaded once per id) — the
    /// policy-parameter fast path for actors / InfServer / eval.
    ///
    /// Implementation note: execution goes through `execute_b`
    /// (device-buffer args) rather than `execute` (literal args) — the
    /// xla crate's literal path leaks the implicit host→device buffers
    /// (~one params-worth of memory per call; measured in
    /// EXPERIMENTS.md §Perf), while `PjRtBuffer` has a sound `Drop`.
    pub fn run_in(&self, env: &str, artifact: &str, inputs: &[In]) -> Result<Vec<Tensor>> {
        let spec = self.ensure_compiled(env, artifact)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{artifact}: {} inputs given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        let mut inner = self.inner.lock().unwrap();
        let mut owned: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        let mut arg_refs: Vec<(bool, usize, u64)> = Vec::with_capacity(inputs.len());
        for (input, ts) in inputs.iter().zip(&spec.inputs) {
            let (tensor, cache_id) = match input {
                In::Host(t) => (*t, None),
                In::Cached(id, t) => (*t, Some(*id)),
            };
            if tensor.len() != ts.elems() {
                bail!(
                    "{artifact}: input '{}' has {} elems, manifest wants {:?}",
                    ts.name,
                    tensor.len(),
                    ts.shape
                );
            }
            if tensor.dtype() != ts.dtype {
                bail!("{artifact}: input '{}' dtype mismatch", ts.name);
            }
            if let Some(id) = cache_id {
                if !inner.buffer_cache.contains_key(&id) {
                    let buf = Self::upload(&inner.client, tensor, &ts.shape)?;
                    inner.buffer_cache.insert(id, buf);
                    inner.cache_order.push(id);
                    while inner.cache_order.len() > BUFFER_CACHE_CAP {
                        let evict = inner.cache_order.remove(0);
                        inner.buffer_cache.remove(&evict);
                    }
                }
                arg_refs.push((true, 0, id));
            } else {
                let buf = Self::upload(&inner.client, tensor, &ts.shape)?;
                arg_refs.push((false, owned.len(), 0));
                owned.push(buf);
            }
        }
        let args: Vec<&xla::PjRtBuffer> = arg_refs
            .iter()
            .map(|&(cached, idx, id)| {
                if cached {
                    inner.buffer_cache.get(&id).unwrap()
                } else {
                    &owned[idx]
                }
            })
            .collect();
        let exe = inner.executables.get(artifact).unwrap();
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow::anyhow!("execute {artifact}: {e:?}"))?;
        self.exec_meter.add(1);
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        drop(args);
        drop(owned);
        drop(inner);
        // aot.py lowers with return_tuple=True: root is always a tuple.
        let parts = root
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{artifact}: executable returned {} outputs, manifest wants {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ts) in parts.into_iter().zip(&spec.outputs) {
            let tensor = match ts.dtype {
                Dtype::F32 => Tensor::F32(
                    lit.to_vec::<f32>()
                        .map_err(|e| anyhow::anyhow!("out {}: {e:?}", ts.name))?,
                ),
                Dtype::I32 => Tensor::I32(
                    lit.to_vec::<i32>()
                        .map_err(|e| anyhow::anyhow!("out {}: {e:?}", ts.name))?,
                ),
            };
            if tensor.len() != ts.elems() {
                bail!(
                    "{artifact}: output '{}' has {} elems, manifest wants {:?}",
                    ts.name,
                    tensor.len(),
                    ts.shape
                );
            }
            out.push(tensor);
        }
        Ok(out)
    }

    fn upload(
        client: &xla::PjRtClient,
        tensor: &Tensor,
        shape: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        let buf = match tensor {
            Tensor::F32(v) => client.buffer_from_host_buffer(v, shape, None),
            Tensor::I32(v) => client.buffer_from_host_buffer(v, shape, None),
        };
        buf.map_err(|e| anyhow::anyhow!("host->device: {e:?}"))
    }

    /// Convenience: run inference for a batch of observations.
    /// Returns (logits, value) as flat vectors.
    pub fn infer(
        &self,
        env: &str,
        batch: usize,
        params: &[f32],
        obs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.infer_impl(env, batch, params, obs, None)
    }

    /// Inference with a device-cached parameter buffer: `params_id`
    /// must change whenever `params` content changes.
    pub fn infer_cached(
        &self,
        env: &str,
        batch: usize,
        params_id: u64,
        params: &[f32],
        obs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.infer_impl(env, batch, params, obs, Some(params_id))
    }

    fn infer_impl(
        &self,
        env: &str,
        batch: usize,
        params: &[f32],
        obs: &[f32],
        params_id: Option<u64>,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let artifact = format!("infer_{env}_b{batch}");
        let pt = Tensor::F32(params.to_vec());
        let ot = Tensor::F32(obs.to_vec());
        let ins = [
            match params_id {
                Some(id) => In::Cached(id, &pt),
                None => In::Host(&pt),
            },
            In::Host(&ot),
        ];
        let out = self.run_in(env, &artifact, &ins)?;
        let mut it = out.into_iter();
        let logits = it.next().context("logits")?.into_f32()?;
        let value = it.next().context("value")?.into_f32()?;
        Ok((logits, value))
    }

    /// Drop a cached device buffer (e.g. when a model version retires).
    pub fn evict_cached(&self, id: u64) {
        let mut inner = self.inner.lock().unwrap();
        inner.buffer_cache.remove(&id);
        inner.cache_order.retain(|&x| x != id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn engine() -> Option<Engine> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Engine::load(dir).unwrap())
    }

    #[test]
    fn loads_manifest_and_init_params() {
        let Some(eng) = engine() else { return };
        let m = eng.manifest.env("rps").unwrap();
        let params = eng.init_params("rps").unwrap();
        assert_eq!(params.len(), m.param_count);
        assert!(params.iter().any(|&p| p != 0.0));
    }

    #[test]
    fn infer_rps_shapes_and_determinism() {
        let Some(eng) = engine() else { return };
        let m = eng.manifest.env("rps").unwrap();
        let params = eng.init_params("rps").unwrap();
        let obs = vec![1.0f32; m.obs_dim];
        let (l1, v1) = eng.infer("rps", 1, &params, &obs).unwrap();
        let (l2, v2) = eng.infer("rps", 1, &params, &obs).unwrap();
        assert_eq!(l1.len(), m.act_dim);
        assert_eq!(v1.len(), 1);
        assert_eq!(l1, l2);
        assert_eq!(v1, v2);
        assert_eq!(eng.exec_meter.count(), 2);
    }

    #[test]
    fn input_validation_errors() {
        let Some(eng) = engine() else { return };
        // wrong input count
        assert!(eng.run("rps", "infer_rps_b1", &[]).is_err());
        // wrong length
        let bad = vec![Tensor::F32(vec![0.0; 3]), Tensor::F32(vec![0.0; 4])];
        assert!(eng.run("rps", "infer_rps_b1", &bad).is_err());
        // unknown artifact
        assert!(eng.run("rps", "nope", &[]).is_err());
    }

    #[test]
    fn train_step_runs_and_updates_params() {
        let Some(eng) = engine() else { return };
        let m = eng.manifest.env("rps").unwrap().clone();
        let p = m.param_count;
        let (t, b, d) = (m.train_t, m.train_b, m.obs_dim);
        let params = eng.init_params("rps").unwrap();
        let hp = eng.manifest.default_hp();
        let inputs = vec![
            Tensor::F32(params.clone()),
            Tensor::F32(vec![0.0; p]),
            Tensor::F32(vec![0.0; p]),
            Tensor::F32(vec![0.0]),
            Tensor::F32(hp),
            Tensor::F32(vec![0.1; (t + 1) * b * d]),
            Tensor::I32(vec![1; t * b]),
            Tensor::F32(vec![-1.0986; t * b]), // log(1/3)
            Tensor::F32(vec![1.0; t * b]),
            Tensor::F32(vec![0.0; t * b]),
        ];
        let out = eng.run("rps", "train_ppo_rps", &inputs).unwrap();
        assert_eq!(out.len(), 5);
        let new_params = out[0].as_f32().unwrap();
        assert_eq!(new_params.len(), p);
        assert_ne!(new_params, &params[..], "params must move");
        let step = out[3].as_f32().unwrap();
        assert_eq!(step[0], 1.0);
        let stats = out[4].as_f32().unwrap();
        assert_eq!(stats.len(), 9);
        assert!(stats[0].is_finite());
    }
}
