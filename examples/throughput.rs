//! Table 3 harness: throughput (rfps / cfps / in-game fps) per env.
//!
//! For each environment, launches the full stack for a fixed wall-clock
//! window and reports the paper's Table-3 columns: M_G, CPU workers
//! (actors ≙ CPU cores here), learners (≙ GPUs), rfps, cfps, and the
//! cfps/rfps ratio (the on-policyness / reuse diagnostic of §4.4).
//! Absolute numbers are testbed-specific; the *shape* — heavier envs
//! yield lower fps, ratio ≈ 1 in blocking mode, > 1 with replay reuse —
//! is what reproduces.
//!
//!     cargo run --release --example throughput -- [secs-per-env]

use std::sync::Arc;
use std::time::{Duration, Instant};
use tleague::config::RunConfig;
use tleague::orchestrator::Deployment;
use tleague::runtime::Engine;

struct Row {
    env: &'static str,
    mg: u32,
    actors: usize,
    learners: usize,
    rfps: f64,
    cfps: f64,
    replay: &'static str,
}

fn measure(
    engine: Arc<Engine>,
    env: &'static str,
    actors: usize,
    replay_mode: &'static str,
    secs: u64,
) -> anyhow::Result<Row> {
    let mut cfg = RunConfig::default();
    cfg.env = env.into();
    cfg.actors_per_learner = actors;
    cfg.total_steps = u64::MAX / 2; // run by wall clock, not steps
    cfg.period_steps = 1_000_000;
    cfg.publish_every = 16;
    cfg.replay_mode = replay_mode.into();
    if env == "doom_lite" {
        cfg.opponents_per_episode = 7;
    }
    let mut dep = Deployment::start(cfg, engine)?;
    // warmup then measurement window
    std::thread::sleep(Duration::from_secs(1));
    let s0 = &dep.learner_status[0];
    let r0 = s0.rfps_frames.load(std::sync::atomic::Ordering::Relaxed);
    let c0 = s0.cfps_frames.load(std::sync::atomic::Ordering::Relaxed);
    let t0 = Instant::now();
    std::thread::sleep(Duration::from_secs(secs));
    let dt = t0.elapsed().as_secs_f64();
    let r1 = s0.rfps_frames.load(std::sync::atomic::Ordering::Relaxed);
    let c1 = s0.cfps_frames.load(std::sync::atomic::Ordering::Relaxed);
    dep.shutdown();
    Ok(Row {
        env,
        mg: 1,
        actors,
        learners: 1,
        rfps: (r1 - r0) as f64 / dt,
        cfps: (c1 - c0) as f64 / dt,
        replay: replay_mode,
    })
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let secs: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6);
    let engine = Arc::new(Engine::load("artifacts")?);

    println!("== Table 3: throughput per env ({secs}s window each) ==\n");
    let mut rows = Vec::new();
    for (env, actors, replay) in [
        ("rps", 4, "blocking"),
        ("pong2p", 4, "blocking"),
        ("pommerman", 4, "blocking"),
        ("doom_lite", 4, "blocking"),
        ("synthetic", 4, "blocking"),
        // the paper's cfps > rfps rows (Pommerman: 20k cfps vs 2.9k rfps)
        ("pommerman", 4, "ratio:6"),
    ] {
        print!("measuring {env} ({replay}) ... ");
        use std::io::Write;
        std::io::stdout().flush().ok();
        match measure(engine.clone(), env, actors, replay, secs) {
            Ok(row) => {
                println!("rfps={:.0} cfps={:.0}", row.rfps, row.cfps);
                rows.push(row);
            }
            Err(e) => println!("FAILED: {e}"),
        }
    }

    println!("\n{:<12} {:>3} {:>7} {:>9} {:>8} {:>8} {:>10} {:>9}",
             "Env", "M_G", "#actors", "#learners", "rfps", "cfps",
             "cfps/rfps", "replay");
    for r in &rows {
        println!(
            "{:<12} {:>3} {:>7} {:>9} {:>8.0} {:>8.0} {:>10.2} {:>9}",
            r.env, r.mg, r.actors, r.learners, r.rfps, r.cfps,
            r.cfps / r.rfps.max(1e-9), r.replay
        );
    }
    println!("\npaper reference rows (Table 3): Dota2-5v5 493K/1.0M, \
              AlphaStar 25K/50K, TStarBot-X 1.7K/4.2K, ViZDoom 6.0K/8.2K, \
              Pommerman 2.9K/20.0K (all per learning agent, 10^2-10^4 hosts)");
    Ok(())
}
