//! Cross-checks between the live wire protocol and league-lint's view
//! of it, plus the analyzer's own fixture suite.  The point: the lint's
//! tag table is parsed *lexically* from proto/mod.rs, so these tests
//! pin the lexical view to runtime behavior — if either drifts (a new
//! variant, a renumbered tag, a decode arm dropped), something here or
//! in `league-lint` itself goes red.

use std::collections::BTreeSet;
use std::path::Path;

use tleague::lint;
use tleague::proto::testkit;
use tleague::util::codec::Wire;

fn proto_src() -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src/proto/mod.rs");
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

/// The lexical tag table parses, has no duplicate values, and every
/// name follows the TAG_ convention.
#[test]
fn tag_table_parses_and_is_unique() {
    let table = lint::proto_tag_table(&proto_src()).expect("tag table");
    assert!(table.len() >= 42, "expected the full registry, got {}", table.len());
    let values: BTreeSet<u8> = table.iter().map(|(_, v)| *v).collect();
    assert_eq!(values.len(), table.len(), "duplicate wire tag values");
    for (name, _) in &table {
        assert!(name.starts_with("TAG_"), "non-conventional const {name}");
    }
}

/// Property: every Msg variant round-trips encode → decode → encode
/// bit-exactly, and the first byte of each encoding is a value from the
/// lexical tag table.
#[test]
fn every_variant_roundtrips_under_table_tags() {
    let table = lint::proto_tag_table(&proto_src()).expect("tag table");
    let values: BTreeSet<u8> = table.iter().map(|(_, v)| *v).collect();
    let msgs = testkit::sample_msgs();
    assert!(msgs.len() >= 42, "sample set shrank to {}", msgs.len());
    for (i, msg) in msgs.iter().enumerate() {
        let bytes = msg.to_bytes();
        let tag = *bytes.first().unwrap_or_else(|| panic!("sample {i} encoded empty"));
        assert!(values.contains(&tag), "sample {i} ({msg:?}) used unregistered tag {tag}");
        let decoded = tleague::proto::Msg::from_bytes(&bytes)
            .unwrap_or_else(|e| panic!("sample {i} ({msg:?}) failed decode: {e}"));
        let re = decoded.to_bytes();
        assert_eq!(bytes, re, "sample {i} ({msg:?}) re-encoded differently");
    }
}

/// Coverage: the sample set exercises EVERY registered tag, so a new
/// tag const without a testkit sample fails here rather than shipping
/// untested.
#[test]
fn sample_set_covers_every_tag() {
    let table = lint::proto_tag_table(&proto_src()).expect("tag table");
    let declared: BTreeSet<u8> = table.iter().map(|(_, v)| *v).collect();
    let observed: BTreeSet<u8> =
        testkit::sample_msgs().iter().filter_map(|m| m.to_bytes().first().copied()).collect();
    let unexercised: Vec<u8> = declared.difference(&observed).copied().collect();
    assert!(unexercised.is_empty(), "tags with no testkit sample: {unexercised:?}");
    let unregistered: Vec<u8> = observed.difference(&declared).copied().collect();
    assert!(unregistered.is_empty(), "samples using unregistered tags: {unregistered:?}");
}

/// The seeded-bad fixture suite behaves as labeled (each `<rule>__*.rs`
/// is flagged by that rule; `clean__*.rs` is clean).
#[test]
fn fixture_suite_behaves_as_seeded() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/lint-fixtures");
    let msg = lint::self_test(&dir).expect("fixture suite");
    assert!(msg.contains("self-test OK"), "{msg}");
}

/// The shipped tree is lint-clean under the checked-in allowlist — the
/// same invariant the CI stage enforces, runnable via `cargo test`.
#[test]
fn shipped_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let allow = lint::Allowlist::load(&root.join("lint-allow.toml")).expect("allowlist");
    let (findings, files, _) = lint::lint_tree(&root.join("rust/src"), &allow).expect("walk");
    assert!(files > 20, "walked only {files} files — wrong root?");
    assert!(
        findings.is_empty(),
        "league-lint findings on the shipped tree:\n{}",
        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}
