//! LeagueMgr: sponsors the training and coordinates the other modules
//! (paper §3.2).  Owns the GameMgr (opponent sampling over the frozen
//! pool + payoff matrix) and the HyperMgr (per-model hyper-parameters),
//! issues tasks to Actors and Learners, ingests match outcomes, and
//! freezes learner models into the opponent pool at period boundaries.

pub mod game_mgr;
pub mod hyper;
pub mod payoff;

use crate::checkpoint::LeagueSnapshot;
use crate::proto::{MatchOutcome, ModelKey, Msg, TaskSpec};
use crate::transport::{RepServer, ReqClient};
use crate::util::metrics::Meter;
use crate::util::rng::Pcg32;
use anyhow::{bail, Result};
use game_mgr::GameMgr;
use hyper::HyperMgr;
use payoff::PayoffMatrix;
use std::sync::{Arc, Mutex};

pub struct LeagueConfig {
    /// number of parallel learning agents (M_G)
    pub n_agents: u32,
    /// opponents per episode (1 for 1v1 envs, 7 for doom_lite FFA, ...)
    pub n_opponents: usize,
    pub game_mgr: String,
    pub hp_layout: Vec<String>,
    pub hp_default: Vec<f32>,
    pub seed: u64,
}

struct LeagueState {
    pool: Vec<ModelKey>, // frozen models, freeze order
    current: Vec<ModelKey>,
    payoff: PayoffMatrix,
    game_mgr: Box<dyn GameMgr>,
    game_mgr_name: String, // kept so snapshots can rebuild the sampler
    hyper: HyperMgr,
    rng: Pcg32,
    next_task: u64,
    n_opponents: usize,
    episodes: u64,
    frames: u64,
}

/// Shared league statistics snapshot.
#[derive(Clone, Debug)]
pub struct LeagueStats {
    pub pool_size: usize,
    pub episodes: u64,
    pub frames: u64,
    pub total_matches: u64,
    pub current: Vec<ModelKey>,
}

pub struct LeagueMgrServer {
    pub addr: String,
    state: Arc<Mutex<LeagueState>>,
    pub task_meter: Meter,
    stop_flag: Arc<std::sync::atomic::AtomicBool>,
    _server: RepServer,
}

impl LeagueMgrServer {
    pub fn start(bind: &str, cfg: LeagueConfig) -> Result<LeagueMgrServer> {
        Self::start_with(bind, cfg, None)
    }

    /// Start the LeagueMgr, optionally restoring every piece of league
    /// state (pool, payoff/Elo, hyper tables, RNG streams, counters) from
    /// a snapshot.  With `resume`, `cfg` only supplies defaults that the
    /// snapshot itself carries — the snapshot wins.
    pub fn start_with(
        bind: &str,
        cfg: LeagueConfig,
        resume: Option<&LeagueSnapshot>,
    ) -> Result<LeagueMgrServer> {
        let mut state = LeagueState {
            pool: Vec::new(),
            current: (0..cfg.n_agents).map(|a| ModelKey::new(a, 1)).collect(),
            payoff: PayoffMatrix::new(),
            game_mgr: game_mgr::make_game_mgr(&cfg.game_mgr)?,
            game_mgr_name: cfg.game_mgr.clone(),
            hyper: HyperMgr::new(cfg.hp_layout, cfg.hp_default, cfg.seed),
            rng: Pcg32::from_label(cfg.seed, "league"),
            next_task: 1,
            n_opponents: cfg.n_opponents,
            episodes: 0,
            frames: 0,
        };
        if let Some(snap) = resume {
            state.pool = snap.pool.clone();
            state.current = snap.current.clone();
            state.payoff = snap.payoff.clone();
            state.game_mgr = game_mgr::make_game_mgr(&snap.game_mgr)?;
            state.game_mgr_name = snap.game_mgr.clone();
            state.hyper = snap.hyper.clone();
            state.rng = Pcg32::from_state_parts(snap.rng.0, snap.rng.1);
            state.next_task = snap.next_task;
            state.n_opponents = snap.n_opponents as usize;
            state.episodes = snap.episodes;
            state.frames = snap.frames;
        } else {
            // seed models (version 0) enter the pool immediately so FSP has
            // a mixture to sample from ("initial size of the pool is one")
            for a in 0..cfg.n_agents {
                let seed_key = ModelKey::new(a, 0);
                state.pool.push(seed_key);
                state.payoff.add_model(seed_key);
            }
        }
        let state = Arc::new(Mutex::new(state));
        let stop_flag = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let sf = stop_flag.clone();
        let s2 = state.clone();
        let server = RepServer::serve(bind, move |msg| {
            if let Msg::Shutdown = msg {
                // remote stop request: the owning loop (standalone
                // subcommand) polls stop_requested() and exits cleanly
                sf.store(true, std::sync::atomic::Ordering::Relaxed);
                return Msg::Ok;
            }
            let mut st = s2.lock().unwrap();
            match msg {
                Msg::RequestActorTask { actor_id } => {
                    // actor_id convention: "<agent>/<name>"
                    let agent: u32 = actor_id
                        .split('/')
                        .next()
                        .and_then(|s| s.parse().ok())
                        .unwrap_or(0);
                    let learner_key = st.current[agent as usize % st.current.len()];
                    let pool: Vec<ModelKey> = st.pool.clone();
                    let n = st.n_opponents;
                    let task_id = st.next_task;
                    st.next_task += 1;
                    let LeagueState { game_mgr, payoff, rng, hyper, .. } = &mut *st;
                    let opponents =
                        game_mgr.sample_opponents(learner_key, n, &pool, payoff, rng);
                    let hp = hyper.get(learner_key);
                    Msg::Task(TaskSpec { task_id, learner_key, opponents, hp })
                }
                Msg::ReportOutcome(o) => {
                    st.episodes += 1;
                    st.frames += o.frames;
                    for &op in &o.opponents {
                        st.payoff.record(o.learner_key, op, o.outcome);
                    }
                    Msg::Ok
                }
                Msg::RequestLearnerTask { learner_id } => {
                    let key = st.current[learner_id as usize % st.current.len()];
                    let hp = st.hyper.get(key);
                    Msg::Task(TaskSpec {
                        task_id: 0,
                        learner_key: key,
                        opponents: vec![],
                        hp,
                    })
                }
                Msg::NotifyPeriodDone { key } => {
                    // freeze `key` into the pool; advance the agent's version
                    if !st.pool.contains(&key) {
                        st.pool.push(key);
                        st.payoff.add_model(key);
                    }
                    let next = ModelKey::new(key.agent, key.version + 1);
                    st.hyper.inherit(key, next);
                    // PBT across the learning agents (scored by pool winrate)
                    let population: Vec<ModelKey> = st.current.clone();
                    let scores: std::collections::BTreeMap<ModelKey, f64> =
                        population
                            .iter()
                            .map(|&k| (k, st.payoff.pool_winrate(k)))
                            .collect();
                    st.hyper.pbt_step(next, &population, |k| {
                        scores.get(&k).copied().unwrap_or(0.5)
                    });
                    if let Some(cur) =
                        st.current.get_mut(key.agent as usize)
                    {
                        *cur = next;
                    }
                    Msg::Ok
                }
                Msg::Ping => Msg::Pong,
                other => Msg::Err(format!("league: unexpected {other:?}")),
            }
        })?;
        Ok(LeagueMgrServer {
            addr: server.addr.clone(),
            state,
            task_meter: Meter::new(),
            stop_flag,
            _server: server,
        })
    }

    /// True once a wire `Shutdown` request has been received.
    pub fn stop_requested(&self) -> bool {
        self.stop_flag.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Stop serving (chaos drills simulate a crashed control plane by
    /// closing the service ports): joins the accept loop; per-connection
    /// threads drain within their ~200ms read timeout.
    pub fn shutdown(&mut self) {
        self._server.shutdown();
    }

    pub fn stats(&self) -> LeagueStats {
        let st = self.state.lock().unwrap();
        LeagueStats {
            pool_size: st.pool.len(),
            episodes: st.episodes,
            frames: st.frames,
            total_matches: st.payoff.total_games(),
            current: st.current.clone(),
        }
    }

    /// Durable snapshot of the league state under one lock acquisition.
    /// `models` is left empty — the caller attaches the ModelPool blobs
    /// (they live in a different service).
    pub fn snapshot(&self) -> LeagueSnapshot {
        snapshot_of(&self.state.lock().unwrap())
    }

    /// Closure handle for the background snapshotter thread.
    pub fn snapshot_fn(&self) -> impl Fn() -> LeagueSnapshot + Send + 'static {
        let state = self.state.clone();
        move || snapshot_of(&state.lock().unwrap())
    }

    /// Read-only view of the payoff matrix (copied) for analysis/benches.
    pub fn winrate(&self, row: ModelKey, col: ModelKey) -> f64 {
        self.state.lock().unwrap().payoff.winrate(row, col)
    }

    pub fn elo(&self, key: ModelKey) -> f64 {
        self.state.lock().unwrap().payoff.elo(key)
    }

    pub fn pool(&self) -> Vec<ModelKey> {
        self.state.lock().unwrap().pool.clone()
    }

    pub fn enable_pbt(&self) {
        self.state.lock().unwrap().hyper.pbt_enabled = true;
    }
}

fn snapshot_of(st: &LeagueState) -> LeagueSnapshot {
    LeagueSnapshot {
        pool: st.pool.clone(),
        current: st.current.clone(),
        next_task: st.next_task,
        episodes: st.episodes,
        frames: st.frames,
        n_opponents: st.n_opponents as u32,
        game_mgr: st.game_mgr_name.clone(),
        rng: st.rng.state_parts(),
        payoff: st.payoff.clone(),
        hyper: st.hyper.clone(),
        models: Vec::new(),
    }
}

/// Typed client for the LeagueMgr service.
pub struct LeagueClient {
    req: ReqClient,
}

impl LeagueClient {
    pub fn connect(addr: &str) -> LeagueClient {
        LeagueClient { req: ReqClient::connect(addr) }
    }

    pub fn request_actor_task(&self, actor_id: &str) -> Result<TaskSpec> {
        match self.req.request(&Msg::RequestActorTask {
            actor_id: actor_id.to_string(),
        })? {
            Msg::Task(t) => Ok(t),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    pub fn report_outcome(&self, outcome: MatchOutcome) -> Result<()> {
        match self.req.request(&Msg::ReportOutcome(outcome))? {
            Msg::Ok => Ok(()),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    pub fn request_learner_task(&self, learner_id: u32) -> Result<TaskSpec> {
        match self.req.request(&Msg::RequestLearnerTask { learner_id })? {
            Msg::Task(t) => Ok(t),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    pub fn notify_period_done(&self, key: ModelKey) -> Result<()> {
        match self.req.request(&Msg::NotifyPeriodDone { key })? {
            Msg::Ok => Ok(()),
            other => bail!("unexpected reply {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn league(game_mgr: &str) -> LeagueMgrServer {
        LeagueMgrServer::start(
            "127.0.0.1:0",
            LeagueConfig {
                n_agents: 1,
                n_opponents: 1,
                game_mgr: game_mgr.into(),
                hp_layout: vec!["lr".into()],
                hp_default: vec![3e-4],
                seed: 1,
            },
        )
        .unwrap()
    }

    #[test]
    fn task_cycle_and_freeze() {
        let server = league("uniform");
        let client = LeagueClient::connect(&server.addr);

        let t = client.request_actor_task("0/a0").unwrap();
        assert_eq!(t.learner_key, ModelKey::new(0, 1));
        // only the seed model is frozen
        assert_eq!(t.opponents, vec![ModelKey::new(0, 0)]);
        assert_eq!(t.hp, vec![3e-4]);

        client
            .report_outcome(MatchOutcome {
                task_id: t.task_id,
                learner_key: t.learner_key,
                opponents: t.opponents.clone(),
                outcome: 1.0,
                episode_len: 10,
                frames: 10,
            })
            .unwrap();
        let stats = server.stats();
        assert_eq!(stats.episodes, 1);
        assert_eq!(stats.frames, 10);

        // learner finishes its period: model frozen, version bumped
        client.notify_period_done(ModelKey::new(0, 1)).unwrap();
        let t2 = client.request_learner_task(0).unwrap();
        assert_eq!(t2.learner_key, ModelKey::new(0, 2));
        assert_eq!(server.pool(), vec![ModelKey::new(0, 0), ModelKey::new(0, 1)]);
    }

    #[test]
    fn freeze_is_idempotent() {
        let server = league("uniform");
        let client = LeagueClient::connect(&server.addr);
        client.notify_period_done(ModelKey::new(0, 1)).unwrap();
        client.notify_period_done(ModelKey::new(0, 1)).unwrap();
        assert_eq!(server.pool().len(), 2, "no duplicate pool entries");
    }

    #[test]
    fn outcomes_drive_winrate() {
        let server = league("pfsp");
        let client = LeagueClient::connect(&server.addr);
        let me = ModelKey::new(0, 1);
        let seed = ModelKey::new(0, 0);
        for _ in 0..10 {
            client
                .report_outcome(MatchOutcome {
                    task_id: 0,
                    learner_key: me,
                    opponents: vec![seed],
                    outcome: 1.0,
                    episode_len: 1,
                    frames: 1,
                })
                .unwrap();
        }
        assert!(server.winrate(me, seed) > 0.9);
        assert!(server.elo(me) > server.elo(seed));
    }

    #[test]
    fn snapshot_restore_preserves_league_state() {
        let server = league("pfsp");
        let client = LeagueClient::connect(&server.addr);
        let me = ModelKey::new(0, 1);
        let seed = ModelKey::new(0, 0);
        for i in 0..6 {
            client
                .report_outcome(MatchOutcome {
                    task_id: 0,
                    learner_key: me,
                    opponents: vec![seed],
                    outcome: if i % 3 == 0 { 1.0 } else { 0.0 },
                    episode_len: 5,
                    frames: 5,
                })
                .unwrap();
        }
        client.notify_period_done(me).unwrap();
        let t = client.request_actor_task("0/a").unwrap(); // advances rng + task ids
        let snap = server.snapshot();
        let stats = server.stats();
        let elo_me = server.elo(me);
        let wr = server.winrate(me, seed);
        let pool = server.pool();
        drop(server);

        let restored = LeagueMgrServer::start_with(
            "127.0.0.1:0",
            LeagueConfig {
                n_agents: 1,
                n_opponents: 1,
                game_mgr: "uniform".into(), // snapshot's "pfsp" must win
                hp_layout: vec!["lr".into()],
                hp_default: vec![3e-4],
                seed: 999,
            },
            Some(&snap),
        )
        .unwrap();
        let rstats = restored.stats();
        assert_eq!(rstats.pool_size, stats.pool_size);
        assert_eq!(rstats.episodes, stats.episodes);
        assert_eq!(rstats.frames, stats.frames);
        assert_eq!(rstats.total_matches, stats.total_matches);
        assert_eq!(rstats.current, stats.current);
        assert_eq!(restored.pool(), pool);
        assert_eq!(restored.elo(me).to_bits(), elo_me.to_bits());
        assert_eq!(restored.winrate(me, seed).to_bits(), wr.to_bits());
        // task ids keep counting instead of restarting at 1
        let c2 = LeagueClient::connect(&restored.addr);
        let t2 = c2.request_actor_task("0/a").unwrap();
        assert_eq!(t2.task_id, t.task_id + 1);
    }

    #[test]
    fn multi_agent_versions_are_independent() {
        let server = LeagueMgrServer::start(
            "127.0.0.1:0",
            LeagueConfig {
                n_agents: 3,
                n_opponents: 2,
                game_mgr: "agent_exploiter".into(),
                hp_layout: vec!["lr".into()],
                hp_default: vec![3e-4],
                seed: 2,
            },
        )
        .unwrap();
        let client = LeagueClient::connect(&server.addr);
        client.notify_period_done(ModelKey::new(1, 1)).unwrap();
        assert_eq!(
            client.request_learner_task(0).unwrap().learner_key,
            ModelKey::new(0, 1)
        );
        assert_eq!(
            client.request_learner_task(1).unwrap().learner_key,
            ModelKey::new(1, 2)
        );
        // actor for agent 1 gets tasks for agent 1
        let t = client.request_actor_task("1/x").unwrap();
        assert_eq!(t.learner_key.agent, 1);
        assert_eq!(t.opponents.len(), 2);
    }
}
