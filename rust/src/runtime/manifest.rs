//! Artifact manifest: the shape contract between python/compile/aot.py
//! and the Rust runtime.  Parsed from artifacts/manifest.json.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct EnvManifest {
    pub name: String,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub hidden: Vec<usize>,
    pub team: bool,
    pub param_count: usize,
    pub train_t: usize,
    pub train_b: usize,
    pub infer_b: usize,
    pub init_params_file: String,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl EnvManifest {
    /// Observations per env step fed to the net (2 for team mode).
    pub fn n_agents(&self) -> usize {
        if self.team {
            2
        } else {
            1
        }
    }
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("env {} has no artifact '{name}'", self.name))
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub hp_layout: Vec<String>,
    pub hp_defaults: BTreeMap<String, f32>,
    pub envs: BTreeMap<String, EnvManifest>,
}

fn tensors(j: &Json) -> Result<Vec<TensorSpec>> {
    let arr = j.as_arr().context("tensor list")?;
    arr.iter()
        .map(|t| {
            let t = t.as_arr().context("tensor triple")?;
            if t.len() != 3 {
                bail!("tensor spec must be [name, shape, dtype]");
            }
            let shape = t[1]
                .as_arr()
                .context("shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?;
            let dtype = match t[2].as_str() {
                Some("f32") => Dtype::F32,
                Some("i32") => Dtype::I32,
                other => bail!("bad dtype {other:?}"),
            };
            Ok(TensorSpec {
                name: t[0].as_str().context("name")?.to_string(),
                shape,
                dtype,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} (run `make artifacts`)"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let hp_layout = j
            .req("hp_layout")?
            .as_arr()
            .context("hp_layout")?
            .iter()
            .map(|s| s.as_str().unwrap_or("").to_string())
            .collect::<Vec<_>>();
        let hp_defaults = j
            .req("hp_defaults")?
            .as_obj()
            .context("hp_defaults")?
            .iter()
            .map(|(k, v)| (k.clone(), v.as_f64().unwrap_or(0.0) as f32))
            .collect();
        let mut envs = BTreeMap::new();
        for (name, e) in j.req("envs")?.as_obj().context("envs")? {
            let mut artifacts = BTreeMap::new();
            for (aname, a) in e.req("artifacts")?.as_obj().context("artifacts")? {
                artifacts.insert(
                    aname.clone(),
                    ArtifactSpec {
                        name: aname.clone(),
                        file: a.req("file")?.as_str().context("file")?.to_string(),
                        inputs: tensors(a.req("inputs")?)?,
                        outputs: tensors(a.req("outputs")?)?,
                    },
                );
            }
            envs.insert(
                name.clone(),
                EnvManifest {
                    name: name.clone(),
                    obs_dim: e.req("obs_dim")?.as_usize().context("obs_dim")?,
                    act_dim: e.req("act_dim")?.as_usize().context("act_dim")?,
                    hidden: e
                        .req("hidden")?
                        .as_arr()
                        .context("hidden")?
                        .iter()
                        .map(|h| h.as_usize().unwrap_or(0))
                        .collect(),
                    team: e.req("team")?.as_bool().context("team")?,
                    param_count: e.req("param_count")?.as_usize().context("P")?,
                    train_t: e.req("train_t")?.as_usize().context("T")?,
                    train_b: e.req("train_b")?.as_usize().context("B")?,
                    infer_b: e.req("infer_b")?.as_usize().context("IB")?,
                    init_params_file: e
                        .req("init_params")?
                        .as_str()
                        .context("init_params")?
                        .to_string(),
                    artifacts,
                },
            );
        }
        Ok(Manifest { hp_layout, hp_defaults, envs })
    }

    pub fn env(&self, name: &str) -> Result<&EnvManifest> {
        self.envs
            .get(name)
            .with_context(|| format!("manifest has no env '{name}'"))
    }

    /// Default hyperparameter vector in hp_layout order.
    pub fn default_hp(&self) -> Vec<f32> {
        self.hp_layout
            .iter()
            .map(|k| self.hp_defaults.get(k).copied().unwrap_or(0.0))
            .collect()
    }

    pub fn hp_index(&self, name: &str) -> Option<usize> {
        self.hp_layout.iter().position(|k| k == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "hp_layout": ["lr", "clip_eps"],
      "hp_defaults": {"lr": 0.0003, "clip_eps": 0.2},
      "envs": {
        "toy": {
          "obs_dim": 4, "act_dim": 3, "hidden": [32], "team": false,
          "param_count": 295, "train_t": 1, "train_b": 256, "infer_b": 32,
          "init_params": "init_toy.f32", "init_sha": "x",
          "artifacts": {
            "infer_toy_b1": {
              "file": "infer_toy_b1.hlo.txt",
              "inputs": [["params", [295], "f32"], ["obs", [1, 4], "f32"]],
              "outputs": [["logits", [1, 3], "f32"], ["value", [1], "f32"]]
            }
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.hp_layout, vec!["lr", "clip_eps"]);
        assert_eq!(m.default_hp(), vec![0.0003, 0.2]);
        let env = m.env("toy").unwrap();
        assert_eq!(env.param_count, 295);
        let art = env.artifact("infer_toy_b1").unwrap();
        assert_eq!(art.inputs.len(), 2);
        assert_eq!(art.inputs[1].elems(), 4);
        assert_eq!(art.outputs[0].dtype, Dtype::F32);
    }

    #[test]
    fn missing_env_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.env("nope").is_err());
        assert!(m.env("toy").unwrap().artifact("nope").is_err());
    }

    #[test]
    fn hp_index() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.hp_index("clip_eps"), Some(1));
        assert_eq!(m.hp_index("zzz"), None);
    }
}
