//! Tiny property-based testing harness (no proptest crate offline).
//!
//! `forall(cases, |rng| ...)` runs a closure over many PCG-seeded cases;
//! on failure it reports the failing seed so the case can be replayed
//! deterministically with `replay(seed, ...)`.  Used by the coordinator
//! invariants tests (routing, batching, payoff/Elo state, replay memory).

use super::rng::Pcg32;

/// Run `f` against `cases` independently seeded RNGs; panic with the seed
/// on the first failure (an Err return or a panic inside `f`).
pub fn forall<F>(cases: u64, label: &str, mut f: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    for seed in 0..cases {
        let mut rng = Pcg32::from_label(seed, label);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{label}' failed at seed {seed}: {msg}");
        }
    }
}

/// Replay a single failing case.
pub fn replay<F>(seed: u64, label: &str, mut f: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    let mut rng = Pcg32::from_label(seed, label);
    if let Err(msg) = f(&mut rng) {
        panic!("property '{label}' failed at seed {seed}: {msg}");
    }
}

/// Assertion helpers that return Err instead of panicking, so `forall`
/// can attach the seed.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!("{:?} != {:?}", a, b));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes() {
        forall(50, "sum-commutes", |rng| {
            let a = rng.next_u32() as u64;
            let b = rng.next_u32() as u64;
            prop_assert!(a + b == b + a, "bad {a} {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn forall_reports_seed() {
        forall(5, "always-fails", |_rng| Err("nope".into()));
    }
}
