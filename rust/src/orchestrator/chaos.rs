//! Chaos schedules for the procs deployment.
//!
//! `run --mode procs --chaos <spec>` executes a timed kill schedule
//! against the live run: worker processes get a real SIGKILL (the
//! supervisor's respawn + the controller's heartbeat reaping take it
//! from there), a `pool` event stops one in-controller ModelPool
//! replica (exercising client failover), and a `controller` event
//! crashes and restarts the control plane itself from its last
//! periodic snapshot.  Combined with `--faults`/`--fault-seed` this is
//! the end-to-end driver for the transport fault plan.

use anyhow::{bail, Context, Result};

/// Roles a chaos event may target.  `pool` is special-cased (replicas
/// live inside the controller process); the rest name worker roles or
/// the controller.
pub const CHAOS_ROLES: &[&str] =
    &["learner", "actor", "inf-server", "pool", "controller"];

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChaosEvent {
    /// one of [`CHAOS_ROLES`]
    pub role: String,
    /// milliseconds after run start
    pub at_ms: u64,
}

/// Parse a chaos spec: comma-separated `kill:<role>@<ms>` events,
/// e.g. `"kill:inf-server@500, kill:pool@800, kill:controller@1500"`.
/// Returned sorted by fire time.
pub fn parse_chaos(spec: &str) -> Result<Vec<ChaosEvent>> {
    let mut out = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let body = part.strip_prefix("kill:").with_context(|| {
            format!("chaos event '{part}': want kill:<role>@<ms>")
        })?;
        let (role, at_s) = body.rsplit_once('@').with_context(|| {
            format!("chaos event '{part}': missing @<ms> fire time")
        })?;
        if !CHAOS_ROLES.contains(&role) {
            bail!(
                "chaos event '{part}': unknown role '{role}' \
                 (want learner|actor|inf-server|pool|controller)"
            );
        }
        let at_ms: u64 = at_s.parse().with_context(|| {
            format!("chaos event '{part}': bad fire time '{at_s}'")
        })?;
        out.push(ChaosEvent { role: role.to_string(), at_ms });
    }
    if out.is_empty() {
        bail!("chaos spec '{spec}' contains no events");
    }
    out.sort_by_key(|e| e.at_ms);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_spec_parses_sorted_and_rejects() {
        let ev = parse_chaos(
            "kill:controller@1500, kill:inf-server@500 ,kill:pool@800",
        )
        .unwrap();
        assert_eq!(
            ev,
            vec![
                ChaosEvent { role: "inf-server".into(), at_ms: 500 },
                ChaosEvent { role: "pool".into(), at_ms: 800 },
                ChaosEvent { role: "controller".into(), at_ms: 1500 },
            ]
        );
        for bad in [
            "",
            "kill:learner",        // no fire time
            "pause:learner@100",   // unknown verb
            "kill:driver@100",     // unknown role
            "kill:learner@soon",   // non-numeric time
        ] {
            assert!(parse_chaos(bad).is_err(), "'{bad}' should not parse");
        }
    }
}
