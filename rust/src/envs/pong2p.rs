//! Pong-2p: the minimal two-player env the paper uses as its
//! "Adding New Env" extension example (§3.6).  Also the fastest real
//! env, so integration tests train against it.
//!
//! Continuous-state paddle game on the unit square.  Obs (8): ball
//! x/y/vx/vy, own paddle y/vy, opponent paddle y, side flag.  Actions
//! (3): stay / up / down.  First to `TARGET` points wins; hard step cap
//! ends the episode in a tie on points.

use super::{Info, MultiAgentEnv, Step};
use crate::util::rng::Pcg32;

const PADDLE_H: f32 = 0.2;
const PADDLE_SPEED: f32 = 0.035;
const BALL_SPEED: f32 = 0.02;
const TARGET: u32 = 3;
const MAX_STEPS: usize = 3000;

pub struct Pong2p {
    rng: Pcg32,
    ball: [f32; 4],     // x, y, vx, vy
    paddles: [f32; 2],  // y centers; player 0 at x=0, player 1 at x=1
    pvel: [f32; 2],
    score: [u32; 2],
    steps: usize,
}

impl Pong2p {
    pub fn new(seed: u64) -> Self {
        Pong2p {
            rng: Pcg32::from_label(seed, "pong2p"),
            ball: [0.5, 0.5, BALL_SPEED, 0.0],
            paddles: [0.5, 0.5],
            pvel: [0.0, 0.0],
            score: [0, 0],
            steps: 0,
        }
    }

    fn serve(&mut self, towards: usize) {
        let angle = self.rng.range_f32(-0.6, 0.6);
        let dir = if towards == 0 { -1.0 } else { 1.0 };
        self.ball = [
            0.5,
            self.rng.range_f32(0.3, 0.7),
            dir * BALL_SPEED * angle.cos(),
            BALL_SPEED * angle.sin(),
        ];
    }

    fn obs_for(&self, who: usize) -> Vec<f32> {
        // egocentric: mirror x for player 1 so both see the same frame
        let (bx, bvx) = if who == 0 {
            (self.ball[0], self.ball[2])
        } else {
            (1.0 - self.ball[0], -self.ball[2])
        };
        vec![
            bx,
            self.ball[1],
            bvx / BALL_SPEED,
            self.ball[3] / BALL_SPEED,
            self.paddles[who],
            self.pvel[who] / PADDLE_SPEED,
            self.paddles[1 - who],
            if who == 0 { 0.0 } else { 1.0 },
        ]
    }

    fn all_obs(&self) -> Vec<Vec<f32>> {
        vec![self.obs_for(0), self.obs_for(1)]
    }
}

impl MultiAgentEnv for Pong2p {
    fn n_agents(&self) -> usize {
        2
    }
    fn obs_dim(&self) -> usize {
        8
    }
    fn act_dim(&self) -> usize {
        3
    }
    fn max_steps(&self) -> usize {
        MAX_STEPS
    }

    fn reset(&mut self) -> Vec<Vec<f32>> {
        self.score = [0, 0];
        self.steps = 0;
        self.paddles = [0.5, 0.5];
        self.pvel = [0.0, 0.0];
        let towards = (self.rng.below(2)) as usize;
        self.serve(towards);
        self.all_obs()
    }

    fn step(&mut self, actions: &[usize]) -> Step {
        self.steps += 1;
        let mut rewards = vec![0.0f32; 2];
        for (i, &a) in actions.iter().enumerate() {
            self.pvel[i] = match a {
                1 => PADDLE_SPEED,
                2 => -PADDLE_SPEED,
                _ => 0.0,
            };
            self.paddles[i] = (self.paddles[i] + self.pvel[i])
                .clamp(PADDLE_H / 2.0, 1.0 - PADDLE_H / 2.0);
        }
        // ball motion + wall bounce
        self.ball[0] += self.ball[2];
        self.ball[1] += self.ball[3];
        if self.ball[1] <= 0.0 || self.ball[1] >= 1.0 {
            self.ball[3] = -self.ball[3];
            self.ball[1] = self.ball[1].clamp(0.0, 1.0);
        }
        // paddle collision / scoring
        let mut point: Option<usize> = None;
        if self.ball[0] <= 0.0 {
            if (self.ball[1] - self.paddles[0]).abs() <= PADDLE_H / 2.0 {
                self.ball[2] = self.ball[2].abs();
                // english: deflect by hit offset
                self.ball[3] += (self.ball[1] - self.paddles[0]) * 0.08;
                rewards[0] += 0.1; // shaped return for rally
            } else {
                point = Some(1);
            }
        } else if self.ball[0] >= 1.0 {
            if (self.ball[1] - self.paddles[1]).abs() <= PADDLE_H / 2.0 {
                self.ball[2] = -self.ball[2].abs();
                self.ball[3] += (self.ball[1] - self.paddles[1]) * 0.08;
                rewards[1] += 0.1;
            } else {
                point = Some(0);
            }
        }
        if let Some(w) = point {
            self.score[w] += 1;
            rewards[w] += 1.0;
            rewards[1 - w] -= 1.0;
            self.serve(1 - w);
        }
        let done = self.score.iter().any(|&s| s >= TARGET)
            || self.steps >= MAX_STEPS;
        let info = if done {
            let outcome = match self.score[0].cmp(&self.score[1]) {
                std::cmp::Ordering::Greater => vec![1.0, 0.0],
                std::cmp::Ordering::Less => vec![0.0, 1.0],
                std::cmp::Ordering::Equal => vec![0.5, 0.5],
            };
            Info { outcome: Some(outcome), frags: None }
        } else {
            Info::default()
        };
        Step { obs: self.all_obs(), rewards, done, info }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ball_stays_in_bounds() {
        let mut env = Pong2p::new(1);
        env.reset();
        for t in 0..2000 {
            let s = env.step(&[t % 3, (t + 1) % 3]);
            assert!((-0.05..=1.05).contains(&env.ball[0]));
            assert!((-0.05..=1.05).contains(&env.ball[1]));
            if s.done {
                break;
            }
        }
    }

    #[test]
    fn zero_sum_on_points() {
        let mut env = Pong2p::new(2);
        env.reset();
        loop {
            // both paddles idle: points get scored quickly
            let s = env.step(&[0, 0]);
            let point_r: f32 = s
                .rewards
                .iter()
                .filter(|r| r.abs() >= 0.9)
                .sum();
            assert!(point_r.abs() < 1e-6, "point rewards must cancel");
            if s.done {
                return;
            }
        }
    }

    #[test]
    fn tracker_beats_idler() {
        // a paddle that follows the ball should beat an idle one
        let mut wins = 0;
        for seed in 0..10 {
            let mut env = Pong2p::new(seed);
            let mut obs = env.reset();
            loop {
                let me = &obs[0];
                let act0 = if me[1] > me[4] + 0.02 {
                    1
                } else if me[1] < me[4] - 0.02 {
                    2
                } else {
                    0
                };
                let s = env.step(&[act0, 0]);
                obs = s.obs;
                if s.done {
                    if s.info.outcome.unwrap()[0] == 1.0 {
                        wins += 1;
                    }
                    break;
                }
            }
        }
        assert!(wins >= 8, "tracker won only {wins}/10");
    }
}
