"""AOT compiler: lower every artifact to HLO text + write the manifest.

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the published ``xla`` 0.1.6 rust crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run as:  cd python && python -m compile.aot --out ../artifacts
The Makefile skips the run when artifacts are newer than the sources.
"""

import argparse
import hashlib
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import algo, model
from .envs_spec import ENV_SPECS, HP_LAYOUT, HP_DEFAULTS

# Which envs get which artifacts.  V-trace is demonstrated on the solo
# envs the paper used IMPALA-style training for; the split grad/apply
# path (Horovod design point) is emitted for every env so multi-learner
# runs are possible everywhere.
VTRACE_ENVS = ("doom_lite", "pong2p", "synthetic")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def emit(out_dir, name, fn, example, io, manifest_arts):
    path = os.path.join(out_dir, name + ".hlo.txt")
    text = lower(fn, example)
    with open(path, "w") as f:
        f.write(text)
    manifest_arts[name] = dict(file=name + ".hlo.txt", **io)
    print(f"  {name}: {len(text) // 1024} KiB")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--envs", default=",".join(ENV_SPECS))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = dict(hp_layout=HP_LAYOUT, hp_defaults=HP_DEFAULTS, envs={})
    for env in args.envs.split(","):
        spec = ENV_SPECS[env]
        print(f"[aot] {env}: obs={spec['obs_dim']} act={spec['act_dim']} "
              f"hidden={spec['hidden']} team={spec['team']}")
        arts = {}

        for b in sorted({1, spec["infer_b"]}):
            fn, ex, io = model.make_infer(spec, b)
            emit(args.out, f"infer_{env}_b{b}", fn, ex, io, arts)

        fn, ex, io = model.make_train(spec, algo.ppo_loss)
        emit(args.out, f"train_ppo_{env}", fn, ex, io, arts)

        fn, ex, io = model.make_grad(spec, algo.ppo_loss)
        emit(args.out, f"grad_ppo_{env}", fn, ex, io, arts)

        fn, ex, io = model.make_apply_adam(spec)
        emit(args.out, f"apply_adam_{env}", fn, ex, io, arts)

        if env in VTRACE_ENVS:
            fn, ex, io = model.make_train(spec, algo.vtrace_loss)
            emit(args.out, f"train_vtrace_{env}", fn, ex, io, arts)

        params = model.init_state(spec, seed=17)
        init_file = f"init_{env}.f32"
        params.astype("<f4").tofile(os.path.join(args.out, init_file))

        from . import nets
        manifest["envs"][env] = dict(
            obs_dim=spec["obs_dim"], act_dim=spec["act_dim"],
            hidden=spec["hidden"], team=spec["team"],
            param_count=nets.param_count(nets.specs_for(spec)),
            train_t=spec["train_t"], train_b=spec["train_b"],
            infer_b=spec["infer_b"],
            init_params=init_file,
            init_sha=hashlib.sha256(params.tobytes()).hexdigest()[:16],
            artifacts=arts,
        )

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {args.out}/manifest.json")


if __name__ == "__main__":
    main()
