//! Binary wire codec: little-endian, length-prefixed primitives.
//!
//! The paper serializes its inter-process messages with native Python
//! pickling over ZeroMQ; here every wire message implements `Wire`
//! (encode into a byte buffer / decode from a cursor).  Kept deliberately
//! simple and allocation-friendly: the trajectory hot path reuses
//! buffers (see transport + learner).

use anyhow::{bail, Result};

pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("codec underflow: need {n}, have {}", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }
    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }
    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        #[cfg(target_endian = "little")]
        {
            let mut v = vec![0.0f32; n];
            // SAFETY: `raw` holds exactly n*4 bytes and `v` owns n f32s;
            // on little-endian targets the LE wire layout matches the
            // in-memory layout, so one memcpy replaces the per-element
            // from_le_bytes loop (hot path: 25 MiB params vectors).
            unsafe {
                std::ptr::copy_nonoverlapping(
                    raw.as_ptr(),
                    v.as_mut_ptr().cast::<u8>(),
                    n * 4,
                );
            }
            Ok(v)
        }
        #[cfg(not(target_endian = "little"))]
        {
            let mut v = Vec::with_capacity(n);
            for c in raw.chunks_exact(4) {
                v.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            Ok(v)
        }
    }
    /// Zero-copy view used by the learner hot path: validates length,
    /// returns the raw bytes to be memcpy'd straight into a batch buffer.
    pub fn f32s_raw(&mut self) -> Result<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n * 4)
    }
    pub fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        #[cfg(target_endian = "little")]
        {
            let mut v = vec![0i32; n];
            // SAFETY: same argument as `f32s` — exact-length LE memcpy.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    raw.as_ptr(),
                    v.as_mut_ptr().cast::<u8>(),
                    n * 4,
                );
            }
            Ok(v)
        }
        #[cfg(not(target_endian = "little"))]
        {
            let mut v = Vec::with_capacity(n);
            for c in raw.chunks_exact(4) {
                v.push(i32::from_le_bytes(c.try_into().unwrap()));
            }
            Ok(v)
        }
    }
}

pub trait Enc {
    fn put_u8(&mut self, v: u8);
    fn put_u32(&mut self, v: u32);
    fn put_u64(&mut self, v: u64);
    fn put_i32(&mut self, v: i32);
    fn put_f32(&mut self, v: f32);
    fn put_f64(&mut self, v: f64);
    fn put_str(&mut self, v: &str);
    fn put_bytes(&mut self, v: &[u8]);
    fn put_f32s(&mut self, v: &[f32]);
    fn put_i32s(&mut self, v: &[i32]);
}

impl Enc for Vec<u8> {
    fn put_u8(&mut self, v: u8) {
        self.push(v);
    }
    fn put_u32(&mut self, v: u32) {
        self.extend_from_slice(&v.to_le_bytes());
    }
    fn put_u64(&mut self, v: u64) {
        self.extend_from_slice(&v.to_le_bytes());
    }
    fn put_i32(&mut self, v: i32) {
        self.extend_from_slice(&v.to_le_bytes());
    }
    fn put_f32(&mut self, v: f32) {
        self.extend_from_slice(&v.to_le_bytes());
    }
    fn put_f64(&mut self, v: f64) {
        self.extend_from_slice(&v.to_le_bytes());
    }
    fn put_str(&mut self, v: &str) {
        self.put_u32(v.len() as u32);
        self.extend_from_slice(v.as_bytes());
    }
    fn put_bytes(&mut self, v: &[u8]) {
        self.put_u32(v.len() as u32);
        self.extend_from_slice(v);
    }
    fn put_f32s(&mut self, v: &[f32]) {
        self.put_u32(v.len() as u32);
        // SAFETY: viewing &[f32] as &[u8] is sound (no padding, u8 has
        // alignment 1); on little-endian targets the in-memory layout IS
        // the LE wire layout, so the whole vector appends as one memcpy.
        #[cfg(target_endian = "little")]
        self.extend_from_slice(unsafe {
            std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 4)
        });
        #[cfg(not(target_endian = "little"))]
        {
            self.reserve(v.len() * 4);
            for &x in v {
                self.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    fn put_i32s(&mut self, v: &[i32]) {
        self.put_u32(v.len() as u32);
        // SAFETY: same argument as `put_f32s`.
        #[cfg(target_endian = "little")]
        self.extend_from_slice(unsafe {
            std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 4)
        });
        #[cfg(not(target_endian = "little"))]
        {
            self.reserve(v.len() * 4);
            for &x in v {
                self.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

/// Anything that can cross a transport boundary.
pub trait Wire: Sized {
    fn encode(&self, buf: &mut Vec<u8>);
    fn decode(cur: &mut Cursor) -> Result<Self>;

    fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        self.encode(&mut buf);
        buf
    }
    fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut cur = Cursor::new(bytes);
        let v = Self::decode(&mut cur)?;
        if !cur.is_empty() {
            bail!("codec: {} trailing bytes", cur.remaining());
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_primitives() {
        let mut buf = Vec::new();
        buf.put_u8(7);
        buf.put_u32(0xdead_beef);
        buf.put_i32(-42);
        buf.put_f32(1.5);
        buf.put_f64(-2.25);
        buf.put_str("hello");
        buf.put_f32s(&[1.0, 2.0, 3.0]);
        buf.put_i32s(&[-1, 0, 1]);
        let mut c = Cursor::new(&buf);
        assert_eq!(c.u8().unwrap(), 7);
        assert_eq!(c.u32().unwrap(), 0xdead_beef);
        assert_eq!(c.i32().unwrap(), -42);
        assert_eq!(c.f32().unwrap(), 1.5);
        assert_eq!(c.f64().unwrap(), -2.25);
        assert_eq!(c.str().unwrap(), "hello");
        assert_eq!(c.f32s().unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(c.i32s().unwrap(), vec![-1, 0, 1]);
        assert!(c.is_empty());
    }

    #[test]
    fn underflow_errors() {
        let buf = vec![1u8, 2];
        let mut c = Cursor::new(&buf);
        assert!(c.u32().is_err());
    }

    /// The bulk-memcpy encode/decode must be bit-exact, including NaN
    /// payloads, signed zero, and subnormals (params are raw bit
    /// patterns to us, not arithmetic values).
    #[test]
    fn f32s_bulk_copy_is_bit_exact() {
        let vals: Vec<f32> = vec![
            f32::from_bits(0x7fc0_dead), // NaN with payload
            -0.0,
            f32::from_bits(0x0000_0001), // smallest subnormal
            f32::MAX,
            f32::NEG_INFINITY,
            1.5,
        ];
        let mut buf = Vec::new();
        buf.put_f32s(&vals);
        // wire layout: count + each value as LE bytes
        assert_eq!(buf.len(), 4 + vals.len() * 4);
        assert_eq!(buf[4..8], vals[0].to_le_bytes());
        let mut c = Cursor::new(&buf);
        let back = c.f32s().unwrap();
        assert_eq!(back.len(), vals.len());
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let ints = vec![i32::MIN, -1, 0, 1, i32::MAX];
        let mut buf = Vec::new();
        buf.put_i32s(&ints);
        let mut c = Cursor::new(&buf);
        assert_eq!(c.i32s().unwrap(), ints);
    }

    #[test]
    fn f32s_raw_zero_copy() {
        let mut buf = Vec::new();
        buf.put_f32s(&[4.0, 5.0]);
        let mut c = Cursor::new(&buf);
        let raw = c.f32s_raw().unwrap();
        assert_eq!(raw.len(), 8);
        assert_eq!(f32::from_le_bytes(raw[0..4].try_into().unwrap()), 4.0);
    }
}
