//! Request-path tracing: flight recorder + Chrome-trace export
//! (DESIGN.md §Request-path tracing).
//!
//! Every process owns one global [`TraceRecorder`] — a fixed-size ring
//! of recently finished spans plus a slow-request log (spans over a
//! configurable threshold survive ring eviction).  Roles record spans
//! only for *sampled* requests (the actor rolls `trace_sample` per
//! rollout row and propagates a [`TraceCtx`] downstream), so the
//! untraced hot path allocates nothing and takes no lock.  Workers
//! drain the recorder into `RoleStats.spans` on each heartbeat; the
//! controller merges them in `LeagueView`, serves them as
//! `Msg::TraceReply`, and the `trace` CLI subcommand renders the result
//! as Chrome trace-event JSON (loadable in Perfetto / chrome://tracing).

use crate::proto::{SpanRec, TraceCtx};
use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Per-process recent-span ring capacity.
pub const RING_CAP: usize = 8_192;
/// Per-process slow-log capacity.
pub const SLOW_CAP: usize = 1_024;
/// Default slow threshold: 50ms.
pub const DEFAULT_SLOW_US: u64 = 50_000;

/// Fixed-size flight recorder: always on, bounded memory, lock held
/// only while a *sampled* span is pushed or a heartbeat drains.
pub struct TraceRecorder {
    ring: Mutex<VecDeque<SpanRec>>,
    slow: Mutex<VecDeque<SpanRec>>,
    ring_cap: usize,
    slow_cap: usize,
    slow_us: AtomicU64,
}

impl Default for TraceRecorder {
    fn default() -> Self {
        TraceRecorder::with_caps(RING_CAP, SLOW_CAP)
    }
}

impl TraceRecorder {
    pub fn with_caps(ring_cap: usize, slow_cap: usize) -> TraceRecorder {
        TraceRecorder {
            ring: Mutex::new(VecDeque::with_capacity(ring_cap.min(1024))),
            slow: Mutex::new(VecDeque::with_capacity(slow_cap.min(1024))),
            ring_cap,
            slow_cap,
            slow_us: AtomicU64::new(DEFAULT_SLOW_US),
        }
    }

    /// Push one finished span; spans over the slow threshold are also
    /// retained in the slow log past ring eviction.
    pub fn record(&self, s: SpanRec) {
        if s.dur_us >= self.slow_us.load(Ordering::Relaxed) {
            let mut slow = self.slow.lock().unwrap();
            if slow.len() >= self.slow_cap {
                slow.pop_front();
            }
            slow.push_back(s.clone());
        }
        let mut ring = self.ring.lock().unwrap();
        if ring.len() >= self.ring_cap {
            ring.pop_front();
        }
        ring.push_back(s);
    }

    /// Drain up to `max` spans from the ring, oldest first (heartbeat
    /// piggyback).  The slow log is NOT drained here — it is a local
    /// retention buffer, consumed by [`drain_slow`](Self::drain_slow).
    pub fn drain(&self, max: usize) -> Vec<SpanRec> {
        let mut ring = self.ring.lock().unwrap();
        let n = ring.len().min(max);
        ring.drain(..n).collect()
    }

    /// Drain up to `max` slow-log spans, oldest first.
    pub fn drain_slow(&self, max: usize) -> Vec<SpanRec> {
        let mut slow = self.slow.lock().unwrap();
        let n = slow.len().min(max);
        slow.drain(..n).collect()
    }

    /// Non-destructive copy of the ring (tests and local inspection —
    /// concurrent readers must not steal each other's spans).
    pub fn snapshot(&self) -> Vec<SpanRec> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn set_slow_ms(&self, ms: u64) {
        self.slow_us.store(ms.saturating_mul(1_000), Ordering::Relaxed);
    }

    pub fn slow_us(&self) -> u64 {
        self.slow_us.load(Ordering::Relaxed)
    }
}

static GLOBAL: OnceLock<TraceRecorder> = OnceLock::new();

/// The process-global flight recorder (all roles in a process share it;
/// spans carry their own `role` tag).
pub fn recorder() -> &'static TraceRecorder {
    GLOBAL.get_or_init(TraceRecorder::default)
}

/// Set the process-wide slow-request threshold (`--trace-slow-ms`).
pub fn set_slow_ms(ms: u64) {
    recorder().set_slow_ms(ms);
}

/// Current slow threshold in microseconds.
pub fn slow_us() -> u64 {
    recorder().slow_us()
}

// --- id generation ------------------------------------------------------

static COUNTER: AtomicU64 = AtomicU64::new(1);
static BASE: OnceLock<u64> = OnceLock::new();

fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Fresh non-zero trace/span id, unique across the processes of one
/// deployment (pid + boot time seed the stream, splitmix64 whitens).
pub fn next_id() -> u64 {
    let base = *BASE.get_or_init(|| {
        let pid = std::process::id() as u64;
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        (pid << 48) ^ t
    });
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    let v = splitmix64(base ^ n.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    if v == 0 {
        1
    } else {
        v
    }
}

/// Microseconds since the unix epoch (span timestamps).
pub fn now_us() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

/// Record a span that just finished: `started` is its monotonic start
/// instant; the wall-clock start is derived as now − duration so span
/// bars line up in the exported trace.  Returns the new span's id (the
/// parent for any child spans).
pub fn finish_span(
    ctx: TraceCtx,
    parent: u64,
    name: &str,
    role: &str,
    started: Instant,
    rows: u32,
) -> u64 {
    let id = next_id();
    finish_span_id(ctx.trace_id, id, parent, name, role, started, rows);
    id
}

/// [`finish_span`] with a caller-allocated span id — used when the id
/// had to be propagated downstream (in a [`TraceCtx`]) before the span
/// itself finished, e.g. the actor's `actor_infer` span whose id is the
/// parent of every server-side span of that request.
pub fn finish_span_id(
    trace_id: u64,
    span_id: u64,
    parent: u64,
    name: &str,
    role: &str,
    started: Instant,
    rows: u32,
) {
    let dur_us = started.elapsed().as_micros() as u64;
    recorder().record(SpanRec {
        trace_id,
        span_id,
        parent,
        name: name.to_string(),
        role: role.to_string(),
        ts_us: now_us().saturating_sub(dur_us),
        dur_us,
        rows,
    });
}

// --- Chrome trace-event export ------------------------------------------

/// Render spans as Chrome trace-event JSON (the `traceEvents` array
/// format loadable in Perfetto and chrome://tracing).  Events are
/// complete-spans (`ph: "X"`), sorted by start timestamp; `pid` groups
/// by role, `tid` groups by trace so one sampled row reads as one
/// track.  64-bit ids render as hex strings in `args` (JSON numbers
/// are f64 — exact only to 2^53).
pub fn chrome_trace_json(spans: &[SpanRec]) -> String {
    let mut sorted: Vec<&SpanRec> = spans.iter().collect();
    sorted.sort_by_key(|s| (s.ts_us, s.trace_id, s.span_id));
    let events: Vec<Json> = sorted
        .iter()
        .map(|s| {
            Json::obj()
                .set("name", s.name.clone())
                .set("cat", s.role.clone())
                .set("ph", "X")
                .set("ts", s.ts_us as f64)
                .set("dur", s.dur_us as f64)
                .set("pid", super::role_rank(&s.role) as usize)
                .set("tid", (s.trace_id % 1_000_000) as usize)
                .set(
                    "args",
                    Json::obj()
                        .set("trace_id", format!("{:016x}", s.trace_id))
                        .set("span_id", format!("{:016x}", s.span_id))
                        .set("parent", format!("{:016x}", s.parent))
                        .set("rows", s.rows as usize),
                )
        })
        .collect();
    Json::obj()
        .set("traceEvents", Json::Arr(events))
        .set("displayTimeUnit", "ms")
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: u64, ts_us: u64, dur_us: u64) -> SpanRec {
        SpanRec {
            trace_id: id,
            span_id: id,
            parent: 0,
            name: "inf_compute".into(),
            role: "inf-server".into(),
            ts_us,
            dur_us,
            rows: 8,
        }
    }

    #[test]
    fn ring_evicts_oldest_but_slow_log_retains() {
        let rec = TraceRecorder::with_caps(4, 4);
        rec.set_slow_ms(1); // 1000us threshold
        rec.record(span(1, 10, 5_000)); // slow
        for i in 2..=6 {
            rec.record(span(i, 10 + i, 10)); // fast, evict the ring
        }
        assert_eq!(rec.len(), 4);
        let ring = rec.drain(100);
        assert_eq!(ring.len(), 4);
        // span 1 and 2 were evicted from the ring...
        assert!(ring.iter().all(|s| s.trace_id >= 3));
        // ...but the slow one survives in the slow log
        let slow = rec.drain_slow(100);
        assert_eq!(slow.len(), 1);
        assert_eq!(slow[0].trace_id, 1);
        assert!(rec.is_empty());
    }

    #[test]
    fn drain_respects_max_and_order() {
        let rec = TraceRecorder::with_caps(16, 16);
        for i in 0..10 {
            rec.record(span(i, i, 1));
        }
        let first = rec.drain(3);
        assert_eq!(
            first.iter().map(|s| s.trace_id).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        assert_eq!(rec.drain(100).len(), 7);
    }

    #[test]
    fn next_id_is_unique_and_nonzero() {
        let mut seen = std::collections::HashSet::new();
        for _ in 0..10_000 {
            let id = next_id();
            assert_ne!(id, 0);
            assert!(seen.insert(id), "duplicate id {id}");
        }
    }

    #[test]
    fn finish_span_lands_in_global_recorder() {
        let ctx = TraceCtx { trace_id: next_id(), span_id: 0 };
        let t0 = Instant::now();
        let id = finish_span(ctx, 7, "actor_gather", "actor", t0, 3);
        assert_ne!(id, 0);
        // global recorder is shared across tests: find by trace_id via a
        // non-destructive read so concurrent tests keep their spans
        let got = recorder()
            .snapshot()
            .into_iter()
            .find(|s| s.trace_id == ctx.trace_id)
            .expect("span recorded");
        assert_eq!(got.span_id, id);
        assert_eq!(got.parent, 7);
        assert_eq!(got.name, "actor_gather");
        assert_eq!(got.rows, 3);
        assert!(got.ts_us > 0);
    }

    #[test]
    fn chrome_export_is_valid_json_with_monotone_ts() {
        // deliberately unsorted input
        let spans = vec![span(3, 300, 10), span(1, 100, 50), span(2, 200, 5)];
        let text = chrome_trace_json(&spans);
        let j = Json::parse(&text).expect("valid chrome trace json");
        let events = match j.path("traceEvents") {
            Some(Json::Arr(a)) => a.clone(),
            other => panic!("traceEvents missing: {other:?}"),
        };
        assert_eq!(events.len(), 3);
        let ts: Vec<f64> = events
            .iter()
            .map(|e| e.path("ts").and_then(|t| t.as_f64()).expect("ts"))
            .collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "ts not monotone: {ts:?}");
        for e in &events {
            assert_eq!(
                e.path("ph").and_then(|p| p.as_str().map(String::from)),
                Some("X".to_string())
            );
            assert!(e.path("args.trace_id").is_some());
        }
    }
}
