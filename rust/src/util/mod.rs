//! Substrate utilities the offline crate set lacks: RNG, JSON, CLI
//! parsing, binary codec, metrics, lock-order-checked sync primitives,
//! and a property-testing harness.

pub mod cli;
pub mod codec;
pub mod json;
pub mod metrics;
pub mod proptest;
pub mod rng;
pub mod signal;
pub mod sync;
