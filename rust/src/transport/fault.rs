//! Deterministic, seeded fault injection for the transport layer.
//!
//! A process-global fault plan — installed once per run from
//! `--faults <spec>` / `--fault-seed <s>` (procs workers receive both
//! through the [`crate::proto::RunSlice`]) — lets chaos drills drop
//! connections, delay or truncate frames, reject accepts, and partition
//! role pairs at exact, reproducible points.  Determinism comes from
//! one [`Pcg32`] stream per *site descriptor*: the descriptor string
//! `"{role}/{site}/{addr}/t{tag}"` is hashed into the stream selector,
//! so the k-th check at a given site draws the same verdict for the
//! same `--fault-seed` regardless of thread interleaving elsewhere.
//!
//! Rules address sites by substring match on the descriptor, which
//! makes every axis targetable without a query language: a role
//! (`"actor/"`), a peer endpoint (`":9100"`), a message tag
//! (`"/t30"` — Traj frames), or everything (`"*"`).  A partition
//! between role pairs is a `partition` rule naming the initiating
//! role + the peer's address at probability 1.
//!
//! When no plan is installed the hot-path cost is a single relaxed
//! atomic load ([`check`] inlines to load-and-branch; everything else
//! lives behind `#[cold]`) — measured by the `faults` bench group.
//!
//! Injections bump the `faults_injected` meter; components that heal
//! from a failure (a request that succeeded after a reconnect, a
//! sticky pool replica rotation, an actor flushing its parked segment
//! queue) report through [`on_recovery`].  Both meters are surfaced in
//! the telemetry plane as `faults_injected` / `recoveries`.

use crate::util::metrics::Meter;
use crate::util::rng::Pcg32;
use crate::util::sync::lock_recover;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Site names used in descriptors (one per injection point).
pub const SITE_REQ: &str = "req"; // ReqClient request exchange
pub const SITE_PUSH: &str = "push"; // PushClient frame write
pub const SITE_ACCEPT: &str = "accept"; // RepServer accept loop
pub const SITE_REP: &str = "rep"; // RepServer per-request handling
pub const SITE_PULL: &str = "pull"; // PullServer frame receive

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Kill the connection (client: error + reconnect; server: close).
    Drop,
    /// Sleep `delay_ms` before proceeding.
    Delay,
    /// Write a deliberately short frame, then kill the connection —
    /// exercises the receiver's partial-frame handling.
    Truncate,
    /// Server side: accept then immediately close (connection refused
    /// as seen by the peer's next read).
    Reject,
    /// Alias of Drop for specs that express role-pair partitions
    /// (typically at probability 1 against a role+addr target).
    Partition,
}

#[derive(Clone, Debug)]
pub struct FaultRule {
    pub kind: FaultKind,
    /// Substring matched against `"{role}/{site}/{addr}/t{tag}"`;
    /// `"*"` matches every site.
    pub target: String,
    /// Per-check injection probability in `[0, 1]`.
    pub prob: f64,
    /// Delay kinds only: how long to stall.
    pub delay_ms: u64,
}

/// Outcome of a fault check at one site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Pass,
    Drop,
    Delay(Duration),
    Truncate,
    Reject,
}

struct PlanState {
    seed: u64,
    rules: Vec<FaultRule>,
    /// one deterministic RNG stream per site descriptor
    streams: HashMap<String, Pcg32>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<PlanState>> = Mutex::new(None);
static ROLE: Mutex<String> = Mutex::new(String::new());

/// Parse a fault spec: comma-separated rules of the form
/// `kind:target@prob[+delay_ms]` with kind one of
/// `drop|delay|truncate|reject|partition`, e.g.
/// `"drop:pool@0.05, delay:*@0.1+20, partition:actor/push@1"`.
pub fn parse_spec(spec: &str) -> Result<Vec<FaultRule>> {
    let mut out = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (kind_s, rest) = part.split_once(':').with_context(|| {
            format!("fault rule '{part}': want kind:target@prob[+delay_ms]")
        })?;
        let kind = match kind_s {
            "drop" => FaultKind::Drop,
            "delay" => FaultKind::Delay,
            "truncate" => FaultKind::Truncate,
            "reject" => FaultKind::Reject,
            "partition" => FaultKind::Partition,
            other => bail!(
                "fault rule '{part}': unknown kind '{other}' \
                 (want drop|delay|truncate|reject|partition)"
            ),
        };
        let (target, prob_s) = rest
            .rsplit_once('@')
            .with_context(|| format!("fault rule '{part}': missing @prob"))?;
        if target.is_empty() {
            bail!("fault rule '{part}': empty target (use '*' for all)");
        }
        let (prob_s, delay_s) = match prob_s.split_once('+') {
            Some((p, d)) => (p, Some(d)),
            None => (prob_s, None),
        };
        let prob: f64 = prob_s.parse().with_context(|| {
            format!("fault rule '{part}': bad probability '{prob_s}'")
        })?;
        if !(0.0..=1.0).contains(&prob) {
            bail!("fault rule '{part}': probability {prob} outside [0, 1]");
        }
        let delay_ms: u64 = match delay_s {
            Some(d) => d.parse().with_context(|| {
                format!("fault rule '{part}': bad delay '{d}'")
            })?,
            None => 0,
        };
        if kind == FaultKind::Delay && delay_ms == 0 {
            bail!("fault rule '{part}': delay needs a +<ms> suffix");
        }
        out.push(FaultRule { kind, target: target.to_string(), prob, delay_ms });
    }
    if out.is_empty() {
        bail!("fault spec '{spec}' contains no rules");
    }
    Ok(out)
}

/// Install (or replace) the process-global plan.  An empty rule set
/// disables injection entirely.
pub fn install(seed: u64, rules: Vec<FaultRule>) {
    let on = !rules.is_empty();
    *lock_recover(&PLAN) = Some(PlanState { seed, rules, streams: HashMap::new() });
    ENABLED.store(on, Ordering::Relaxed);
}

/// [`parse_spec`] + [`install`] in one step.
pub fn install_spec(seed: u64, spec: &str) -> Result<()> {
    install(seed, parse_spec(spec)?);
    Ok(())
}

/// Remove the plan; [`check`] returns to its one-atomic-load fast path.
pub fn clear() {
    ENABLED.store(false, Ordering::Relaxed);
    *lock_recover(&PLAN) = None;
}

/// Name this process's role for site descriptors (`"actor"`,
/// `"learner"`, `"controller"`, ...).  Workers call it on assignment.
pub fn set_role(role: &str) {
    *lock_recover(&ROLE) = role.to_string();
}

/// True when a non-empty plan is installed (one relaxed load).
#[inline(always)]
pub fn active() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Draw the verdict for one operation at `site` against `addr` with
/// message tag `tag`.  Free when no plan is installed.
#[inline]
pub fn check(site: &str, addr: &str, tag: u8) -> Verdict {
    if !active() {
        return Verdict::Pass;
    }
    check_slow(site, addr, tag)
}

#[cold]
fn check_slow(site: &str, addr: &str, tag: u8) -> Verdict {
    let role = match ROLE.lock() {
        Ok(r) => r.clone(),
        Err(_) => return Verdict::Pass,
    };
    let Ok(mut guard) = PLAN.lock() else { return Verdict::Pass };
    let Some(plan) = guard.as_mut() else { return Verdict::Pass };
    let desc = format!("{role}/{site}/{addr}/t{tag}");
    let hits: Vec<FaultRule> = plan
        .rules
        .iter()
        .filter(|r| r.target == "*" || desc.contains(r.target.as_str()))
        .cloned()
        .collect();
    if hits.is_empty() {
        return Verdict::Pass;
    }
    let seed = plan.seed;
    let rng = plan
        .streams
        .entry(desc.clone())
        .or_insert_with(|| Pcg32::from_label(seed, &desc));
    for rule in &hits {
        if rng.chance(rule.prob) {
            injected_meter().add(1);
            return match rule.kind {
                FaultKind::Drop | FaultKind::Partition => Verdict::Drop,
                FaultKind::Delay => {
                    Verdict::Delay(Duration::from_millis(rule.delay_ms))
                }
                FaultKind::Truncate => Verdict::Truncate,
                FaultKind::Reject => Verdict::Reject,
            };
        }
    }
    Verdict::Pass
}

/// Process-wide count of injected faults (`faults_injected`).
pub fn injected_meter() -> Arc<Meter> {
    static M: OnceLock<Arc<Meter>> = OnceLock::new();
    M.get_or_init(|| Arc::new(Meter::new())).clone()
}

/// Process-wide count of healed failures (`recoveries`) — bumped by
/// any component that re-established service after a failure, injected
/// or real.
pub fn recovered_meter() -> Arc<Meter> {
    static M: OnceLock<Arc<Meter>> = OnceLock::new();
    M.get_or_init(|| Arc::new(Meter::new())).clone()
}

/// Record one healed failure (reconnect succeeded, replica failover,
/// parked queue flushed, ...).
pub fn on_recovery() {
    recovered_meter().add(1);
}

/// Serializes tests that touch the process-global plan (the plan is
/// shared by every test thread in the binary).
#[cfg(test)]
pub(crate) static TEST_MUTEX: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_parses_and_rejects() {
        let rules = parse_spec(
            "drop:pool@0.5, delay:*@1+20 ,truncate:actor/push@0.25, \
             partition:req/127.0.0.1:9@1",
        )
        .unwrap();
        assert_eq!(rules.len(), 4);
        assert_eq!(rules[0].kind, FaultKind::Drop);
        assert_eq!(rules[0].target, "pool");
        assert!((rules[0].prob - 0.5).abs() < 1e-12);
        assert_eq!(rules[1].kind, FaultKind::Delay);
        assert_eq!(rules[1].delay_ms, 20);
        assert_eq!(rules[3].kind, FaultKind::Partition);
        assert!((rules[3].prob - 1.0).abs() < 1e-12);
        for bad in [
            "",
            "drop",           // no target/prob
            "zap:x@0.5",      // unknown kind
            "drop:x@1.5",     // prob out of range
            "drop:@0.5",      // empty target
            "delay:x@0.5",    // delay without +ms
            "drop:x@maybe",   // non-numeric prob
            "drop:x@0.1+abc", // non-numeric delay
        ] {
            assert!(parse_spec(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn plan_is_deterministic_seeded_and_scoped() {
        let _g = TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        clear();
        set_role("tester");
        let schedule = |seed: u64| {
            install(seed, parse_spec("drop:fault-sentinel@0.5").unwrap());
            let v: Vec<bool> = (0..64)
                .map(|_| {
                    check(SITE_REQ, "fault-sentinel:1", 3) == Verdict::Drop
                })
                .collect();
            clear();
            v
        };
        let a = schedule(7);
        let b = schedule(7);
        let c = schedule(8);
        assert_eq!(a, b, "same seed must give the same fault schedule");
        assert_ne!(a, c, "different seeds should diverge");
        assert!(
            a.iter().any(|&x| x) && !a.iter().all(|&x| x),
            "p=0.5 should mix verdicts: {a:?}"
        );

        // rules only hit matching descriptors; everything else passes
        // untouched even while the plan is active
        install(7, parse_spec("drop:fault-sentinel@1").unwrap());
        assert!(active());
        assert_eq!(check(SITE_REQ, "10.9.9.9:5", 3), Verdict::Pass);
        assert_eq!(check(SITE_REQ, "fault-sentinel:1", 3), Verdict::Drop);
        // tag addressing: "/t30" matches Traj frames only
        install(7, parse_spec("drop:/t30@1").unwrap());
        assert_eq!(check(SITE_PUSH, "fault-sentinel:1", 30), Verdict::Drop);
        assert_eq!(check(SITE_PUSH, "fault-sentinel:1", 31), Verdict::Pass);
        // delay carries its parameter through
        install(7, parse_spec("delay:fault-sentinel@1+25").unwrap());
        assert_eq!(
            check(SITE_REP, "fault-sentinel:1", 0),
            Verdict::Delay(Duration::from_millis(25))
        );
        clear();
        assert!(!active());
        assert_eq!(check(SITE_REQ, "fault-sentinel:1", 3), Verdict::Pass);
    }
}
