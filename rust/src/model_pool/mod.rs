//! ModelPool: versioned parameter store with LRU disk spill (paper §3.2).
//!
//! "During the whole training lifecycle, ModelPool must respond to any
//! parameter requesting (read) or updating (write) instantaneously" —
//! hot parameters are kept in memory; up to M_M replicas run
//! simultaneously and clients pick a random replica per read (load
//! balancing), writing through to all replicas.
//!
//! Long CSP runs accumulate an unbounded frozen pool, so each replica
//! can be given a resident-byte budget plus a spill directory: cold
//! frozen blobs (never an agent's latest, never an unfrozen learner
//! model) are evicted to disk in LRU order and transparently faulted
//! back in on `GetModel`.  Spill files use the `ModelBlob` wire encoding
//! and are written temp-then-rename, so a crash never leaves a torn
//! blob (see DESIGN.md §Spill policy).
//!
//! Deployments with several replicas run **sharded** (see [`shard`]):
//! each agent's models live on R owners of a consistent-hash ring
//! instead of every replica holding everything.  Writes go only to the
//! owners; a non-owner replies `WrongShard` carrying the current map so
//! clients self-correct without a coordinator round-trip; reads are
//! served whenever the data is present (availability during membership
//! transitions).  [`rebalance`] is the anti-entropy pass run on
//! membership change — it reuses the `GetModelIfNewer` rev protocol so
//! only blobs that actually changed hands move.

use crate::proto::{
    ModelBlob, ModelKey, Msg, PoolShardInfo, ShardMap, TraceCtx, TAG_MODEL,
    TAG_MODEL_REV,
};
use crate::telemetry::trace;
use crate::transport::{fault, RepServer, Reply, ReqClient};
use crate::util::codec::{Enc, Wire};
use crate::util::metrics::{Meter, MetricsHub};
use crate::util::rng::Pcg32;
use crate::util::sync::OrderedMutex;
use anyhow::{bail, Result};
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

pub mod shard;
pub use shard::{default_replication, set_default_replication, MapHolder};

/// Memory policy for one replica.  The default (no dir, budget 0) keeps
/// everything resident forever — the seed behaviour.
#[derive(Clone, Debug, Default)]
pub struct PoolOptions {
    /// Directory for spilled blobs; None disables spilling entirely.
    pub spill_dir: Option<PathBuf>,
    /// Resident-byte budget (0 = unbounded).  Only frozen, non-latest
    /// blobs are evicted, so the budget is a target, not a hard cap, when
    /// live learner models alone exceed it.
    pub mem_budget: usize,
}

/// Approximate resident cost of a blob (param + hp payloads dominate).
fn blob_cost(b: &ModelBlob) -> usize {
    b.params.len() * 4 + b.hp.len() * 4 + std::mem::size_of::<ModelBlob>()
}

/// Assemble a full-pool snapshot from [`Store::snapshot_parts`] output.
/// Runs WITHOUT the store lock: the disk reads of spilled blobs must not
/// stall GetModel/PutModel traffic ("respond ... instantaneously").  A
/// spill file that vanishes mid-read (concurrent re-put) is skipped —
/// that blob is resident again and will be in the next snapshot.
fn assemble_blobs(
    resident: Vec<Arc<ModelBlob>>,
    spilled: &[PathBuf],
) -> Vec<ModelBlob> {
    let mut out: Vec<ModelBlob> =
        resident.iter().map(|b| (**b).clone()).collect();
    for path in spilled {
        match std::fs::read(path)
            .map_err(anyhow::Error::from)
            .and_then(|raw| ModelBlob::from_bytes(&raw))
        {
            Ok(b) => out.push(b),
            Err(e) => eprintln!(
                "model_pool: snapshot skipping {}: {e:#}",
                path.display()
            ),
        }
    }
    out.sort_by_key(|b| b.key);
    out
}

#[derive(Default)]
struct Store {
    /// resident blobs; `Arc` so snapshots and replies can deep-copy the
    /// params OUTSIDE the store lock
    blobs: BTreeMap<ModelKey, Arc<ModelBlob>>,
    /// pre-encoded `ModelBlob` wire bytes per resident blob — the reply
    /// frame tail served on GetModel/GetLatest/if-newer hits with zero
    /// params copy and zero encode.  Invalidated on re-put (incl.
    /// freezes, which arrive as re-puts) and on spill; rebuilt lazily on
    /// the next read.
    frames: BTreeMap<ModelKey, Arc<[u8]>>,
    /// replica-local put counter per blob — the `rev` of the if-newer
    /// protocol.  Bumped on EVERY put, so same-version re-puts of the
    /// in-training model (the learner's publish_every cadence) are
    /// visible to refreshing clients.
    revs: BTreeMap<ModelKey, u64>,
    puts: u64,
    /// reply-frame (re)builds — steady-state read traffic must not move
    /// this (the zero-encode invariant the pool bench asserts)
    encodes: u64,
    /// blobs with a valid on-disk copy (may also be resident)
    on_disk: BTreeMap<ModelKey, PathBuf>,
    latest: BTreeMap<u32, ModelKey>, // per-agent newest version
    last_used: BTreeMap<ModelKey, u64>,
    tick: u64,
    resident: usize,
    opts: PoolOptions,
    /// anti-entropy bookkeeping: agent → (source replica slot, source
    /// rev) of the last rebalance transfer.  Lets the next rebalance
    /// from the same source ask `GetModelIfNewer` with a comparable rev
    /// and get an O(1) `NotModified` when nothing changed hands.
    origin: BTreeMap<u32, (u32, u64)>,
}

impl Store {
    fn touch(&mut self, key: ModelKey) {
        self.tick += 1;
        self.last_used.insert(key, self.tick);
    }

    fn rev(&self, key: ModelKey) -> u64 {
        self.revs.get(&key).copied().unwrap_or(0)
    }

    fn insert(&mut self, blob: ModelBlob) {
        let key = blob.key;
        // strictly-newer versions move `latest`; an equal-version re-put
        // (learner restart, replica replay) refreshes bytes only
        let newer = self
            .latest
            .get(&key.agent)
            .map_or(true, |cur| key.version > cur.version);
        if newer {
            self.latest.insert(key.agent, key);
        }
        self.puts += 1;
        self.revs.insert(key, self.puts);
        // new bytes invalidate the cached reply frame and any disk copy
        if let Some(f) = self.frames.remove(&key) {
            self.resident -= f.len();
        }
        if let Some(path) = self.on_disk.remove(&key) {
            std::fs::remove_file(path).ok();
        }
        let blob = Arc::new(blob);
        let cost = blob_cost(&blob);
        if let Some(old) = self.blobs.insert(key, blob) {
            self.resident -= blob_cost(&old);
        }
        self.resident += cost;
        self.touch(key);
        self.maybe_spill();
    }

    /// Publish a freshly built reply frame (frame bytes count toward the
    /// resident budget — they are a second in-memory copy of the params).
    fn install_frame(&mut self, key: ModelKey, frame: Arc<[u8]>) {
        self.resident += frame.len();
        if let Some(old) = self.frames.insert(key, frame) {
            self.resident -= old.len();
        }
        self.maybe_spill();
    }

    /// Resident lookup, faulting a spilled blob back in if needed.  The
    /// returned handle is cheap; callers deep-copy after unlocking.
    fn fetch(&mut self, key: ModelKey) -> Option<Arc<ModelBlob>> {
        if let Some(b) = self.blobs.get(&key).cloned() {
            self.touch(key);
            return Some(b);
        }
        let path = self.on_disk.get(&key)?.clone();
        let blob = match std::fs::read(&path)
            .map_err(anyhow::Error::from)
            .and_then(|raw| ModelBlob::from_bytes(&raw))
        {
            Ok(b) => Arc::new(b),
            Err(e) => {
                // a swallowed I/O error here would read as a permanent,
                // undiagnosable NotFound for a frozen model
                eprintln!(
                    "model_pool: fault-in of {key} from {} failed: {e:#}",
                    path.display()
                );
                return None;
            }
        };
        self.resident += blob_cost(&blob);
        self.blobs.insert(key, blob.clone());
        self.touch(key);
        self.maybe_spill();
        Some(blob)
    }

    /// Evict cold frozen blobs until the budget is met (or no candidates
    /// remain).  The disk copy is written before the memory copy is
    /// dropped; a blob that already has one is evicted for free.
    fn maybe_spill(&mut self) {
        if self.opts.mem_budget == 0 || self.opts.spill_dir.is_none() {
            return;
        }
        while self.resident > self.opts.mem_budget {
            let victim = self
                .blobs
                .iter()
                .filter(|&(k, b)| b.frozen && self.latest.get(&k.agent) != Some(k))
                .min_by_key(|&(k, _)| self.last_used.get(k).copied().unwrap_or(0))
                .map(|(k, _)| *k);
            let Some(key) = victim else { break };
            if let Err(e) = self.spill_out(key) {
                // a silent break here would quietly stop enforcing the
                // budget (e.g. spill disk full) with no diagnostics
                eprintln!(
                    "model_pool: spill of {key} failed, budget not enforced: {e:#}"
                );
                break;
            }
        }
    }

    fn spill_out(&mut self, key: ModelKey) -> Result<()> {
        let dir = self.opts.spill_dir.clone().expect("spill dir checked");
        if !self.on_disk.contains_key(&key) {
            let blob = self.blobs.get(&key).expect("victim is resident");
            std::fs::create_dir_all(&dir)?;
            let name = format!("agt{:03}-v{:06}.blob", key.agent, key.version);
            let tmp = dir.join(format!(".{name}.tmp"));
            std::fs::write(&tmp, blob.to_bytes())?;
            let path = dir.join(name);
            std::fs::rename(&tmp, &path)?;
            self.on_disk.insert(key, path);
        }
        if let Some(b) = self.blobs.remove(&key) {
            self.resident -= blob_cost(&b);
        }
        // the reply frame of a spilled blob goes with it; the next read
        // faults the blob in and rebuilds the frame
        if let Some(f) = self.frames.remove(&key) {
            self.resident -= f.len();
        }
        Ok(())
    }

    /// Snapshot inputs: handles to the resident blobs plus the paths of
    /// spill files whose only copy is on disk.  O(n) Arc bumps — the
    /// caller releases the store lock before any deep copy or disk read.
    fn snapshot_parts(&self) -> (Vec<Arc<ModelBlob>>, Vec<PathBuf>) {
        let resident: Vec<Arc<ModelBlob>> = self.blobs.values().cloned().collect();
        let spilled: Vec<PathBuf> = self
            .on_disk
            .iter()
            .filter(|&(k, _)| !self.blobs.contains_key(k))
            .map(|(_, p)| p.clone())
            .collect();
        (resident, spilled)
    }

    fn model_count(&self) -> usize {
        self.blobs.len() + self.spilled_count()
    }

    fn spilled_count(&self) -> usize {
        self.on_disk.keys().filter(|&k| !self.blobs.contains_key(k)).count()
    }

    /// Distinct agents with at least one model here (resident or
    /// spilled).  `latest` covers them all: every insert path updates it.
    fn agents(&self) -> Vec<u32> {
        self.latest.keys().copied().collect()
    }

    /// Every key stored for `agent` (resident or spilled), no payloads.
    fn keys_for(&self, agent: u32) -> Vec<ModelKey> {
        let mut keys: Vec<ModelKey> = self
            .blobs
            .keys()
            .chain(self.on_disk.keys())
            .filter(|k| k.agent == agent)
            .copied()
            .collect();
        keys.sort_unstable();
        keys.dedup();
        keys
    }

    /// Drop every trace of `agent` — the GC step after a rebalance moved
    /// its ownership elsewhere.  Reclaims memory AND flips subsequent
    /// reads for the agent to the `WrongShard` redirect (data absent).
    fn evict_agent(&mut self, agent: u32) {
        let keys: Vec<ModelKey> = self.keys_for(agent);
        for key in keys {
            if let Some(b) = self.blobs.remove(&key) {
                self.resident -= blob_cost(&b);
            }
            if let Some(f) = self.frames.remove(&key) {
                self.resident -= f.len();
            }
            if let Some(path) = self.on_disk.remove(&key) {
                std::fs::remove_file(path).ok();
            }
            self.revs.remove(&key);
            self.last_used.remove(&key);
        }
        self.latest.remove(&agent);
        self.origin.remove(&agent);
    }
}

/// Which blob a read request resolves to.
enum Sel {
    Exact(ModelKey),
    Latest(u32),
}

/// Read-path telemetry (hub meters): every counter is lock-free, so
/// instrumenting the serve path costs a relaxed atomic add.
struct ReadMeters {
    /// all read requests (GetModel / GetLatest / GetModelIfNewer)
    reads: Arc<Meter>,
    /// reads served from the pre-encoded frame cache (zero encode)
    frame_hits: Arc<Meter>,
    /// if-newer reads answered O(1) (requester already current)
    not_modified: Arc<Meter>,
}

/// What the first (locked) pass of a read produced.
enum Found {
    /// frame-cache hit: the pre-encoded reply bytes
    Frame(Arc<[u8]>),
    /// cache miss: a cheap handle to encode outside the lock
    Blob(Arc<ModelBlob>),
}

/// Serve a model read.  `have` carries the requester's (version, rev)
/// for the if-newer protocol; `None` is an unconditional read.  On a
/// frame-cache hit the reply is the cached pre-encoded bytes — zero
/// params copy, zero encode, O(1) lock hold.  On a miss the params are
/// encoded once OUTSIDE the lock ("respond ... instantaneously") and
/// the frame is published for subsequent readers.
fn model_reply(
    store: &OrderedMutex<Store>,
    sel: Sel,
    have: Option<(u32, u64)>,
    m: &ReadMeters,
) -> Reply {
    m.reads.add(1);
    let (key, rev, found) = {
        let mut st = store.lock();
        let key = match sel {
            Sel::Exact(k) => k,
            Sel::Latest(agent) => match st.latest.get(&agent) {
                Some(&k) => k,
                None => return Reply::Msg(Msg::NotFound),
            },
        };
        let rev = st.rev(key);
        if let Some((have_version, have_rev)) = have {
            // "nothing newer than what you hold" — a strictly-older
            // latest (lagging replica) must not regress the client
            if key.version < have_version
                || (key.version == have_version && rev == have_rev)
            {
                m.not_modified.add(1);
                return Reply::Msg(Msg::NotModified);
            }
        }
        if let Some(f) = st.frames.get(&key).cloned() {
            st.touch(key);
            m.frame_hits.add(1);
            (key, rev, Found::Frame(f))
        } else {
            match st.fetch(key) {
                Some(b) => (key, rev, Found::Blob(b)),
                None => return Reply::Msg(Msg::NotFound),
            }
        }
    };
    let frame = match found {
        Found::Frame(frame) => frame,
        Found::Blob(blob) => {
            let mut buf =
                Vec::with_capacity(24 + blob.params.len() * 4 + blob.hp.len() * 4);
            blob.encode(&mut buf);
            let frame: Arc<[u8]> = buf.into();
            let mut st = store.lock();
            st.encodes += 1;
            // publish unless a concurrent re-put or spill superseded it;
            // the reply itself stays valid either way (REQ/REP snapshot)
            if st.rev(key) == rev && st.blobs.contains_key(&key) {
                st.install_frame(key, frame.clone());
            }
            frame
        }
    };
    match have {
        Some(_) => {
            let mut head = Vec::with_capacity(9);
            head.put_u8(TAG_MODEL_REV);
            head.put_u64(rev);
            Reply::framed(head, frame)
        }
        None => Reply::framed(vec![TAG_MODEL], frame),
    }
}

/// The sharding hook of one replica: the deployment-shared (map, ring)
/// holder plus this replica's slot index.
type ShardRole = Option<(Arc<MapHolder>, u32)>;

/// The availability rule of the sharded pool: a replica SERVES any read
/// it can answer (even mid-rebalance, even after losing ownership), and
/// only redirects when the data is absent AND the ring says someone
/// else owns it — then the reply piggybacks the current map so the
/// client self-corrects.  Absent data on the rightful owner stays a
/// plain `NotFound` (the model genuinely does not exist yet).
fn redirect_if_absent(reply: Reply, agent: u32, sh: &ShardRole) -> Reply {
    if let (Reply::Msg(Msg::NotFound), Some((holder, slot))) = (&reply, sh) {
        let (map, ring) = holder.get();
        if !ring.is_owner(agent, *slot) {
            return Reply::Msg(Msg::WrongShard((*map).clone()));
        }
    }
    reply
}

/// One ModelPool replica: a REQ/REP service over the spill-aware store.
pub struct ModelPoolServer {
    pub addr: String,
    store: Arc<OrderedMutex<Store>>,
    stop_flag: Arc<std::sync::atomic::AtomicBool>,
    /// telemetry registry: meters `reads` / `frame_hits` /
    /// `not_modified` / `puts` (hit rate = frame_hits/reads, if-newer
    /// hit rate = not_modified/reads over an interval)
    hub: Arc<MetricsHub>,
    /// sharded deployments: shared (map, ring) + this replica's slot.
    /// None = standalone own-everything replica (the seed behaviour).
    shard: ShardRole,
    _server: RepServer,
}

impl ModelPoolServer {
    pub fn start(bind: &str) -> Result<ModelPoolServer> {
        Self::start_with(bind, PoolOptions::default())
    }

    pub fn start_with(bind: &str, opts: PoolOptions) -> Result<ModelPoolServer> {
        Self::start_inner(bind, opts, None)
    }

    /// One replica of a sharded deployment: `slot` is its index in
    /// `holder`'s map; writes for agents the ring assigns elsewhere are
    /// bounced with `WrongShard` + the current map.
    pub fn start_sharded(
        bind: &str,
        opts: PoolOptions,
        holder: Arc<MapHolder>,
        slot: u32,
    ) -> Result<ModelPoolServer> {
        Self::start_inner(bind, opts, Some((holder, slot)))
    }

    fn start_inner(
        bind: &str,
        opts: PoolOptions,
        shard: ShardRole,
    ) -> Result<ModelPoolServer> {
        let store =
            Arc::new(OrderedMutex::new("model_pool.store", Store { opts, ..Store::default() }));
        let stop_flag = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let hub = Arc::new(MetricsHub::default());
        let meters = ReadMeters {
            reads: hub.meter("reads"),
            frame_hits: hub.meter("frame_hits"),
            not_modified: hub.meter("not_modified"),
        };
        let puts = hub.meter("puts");
        let s2 = store.clone();
        let sf = stop_flag.clone();
        let sh = shard.clone();
        let server = RepServer::serve_frames(bind, move |msg| match msg {
            Msg::PutModel(blob) => {
                // writes are owner-only: the replication factor is a
                // real bound, not "R copies plus whoever got written"
                if let Some((holder, slot)) = &sh {
                    let (map, ring) = holder.get();
                    if !ring.is_owner(blob.key.agent, *slot) {
                        return Reply::Msg(Msg::WrongShard((*map).clone()));
                    }
                }
                s2.lock().insert(blob);
                puts.add(1);
                Reply::Msg(Msg::Ok)
            }
            Msg::GetModel { key, trace } => {
                let t0 = std::time::Instant::now();
                let reply = model_reply(&s2, Sel::Exact(key), None, &meters);
                let reply = redirect_if_absent(reply, key.agent, &sh);
                if let Some(c) = trace {
                    trace::finish_span(
                        c, c.span_id, "pool_get", "model-pool", t0, 0,
                    );
                }
                reply
            }
            Msg::GetLatest { agent } => redirect_if_absent(
                model_reply(&s2, Sel::Latest(agent), None, &meters),
                agent,
                &sh,
            ),
            Msg::GetModelIfNewer { agent, have_version, have_rev, trace } => {
                let t0 = std::time::Instant::now();
                let reply = model_reply(
                    &s2,
                    Sel::Latest(agent),
                    Some((have_version, have_rev)),
                    &meters,
                );
                let reply = redirect_if_absent(reply, agent, &sh);
                if let Some(c) = trace {
                    trace::finish_span(
                        c, c.span_id, "pool_get", "model-pool", t0, 0,
                    );
                }
                reply
            }
            Msg::GetShardMap => match &sh {
                Some((holder, _)) => {
                    Reply::Msg(Msg::ShardMapMsg((*holder.get().0).clone()))
                }
                None => Reply::Msg(Msg::Err(
                    "model_pool: replica is not sharded".into(),
                )),
            },
            Msg::PoolStats => {
                let st = s2.lock();
                Reply::Msg(Msg::PoolStatsReply {
                    resident_bytes: st.resident as u64,
                    models: st.model_count() as u32,
                    spilled: st.spilled_count() as u32,
                    reads: meters.reads.count(),
                    frame_hits: meters.frame_hits.count(),
                })
            }
            Msg::Shutdown => {
                // remote stop request: the owning loop (standalone
                // subcommand) polls stop_requested() and exits cleanly
                sf.store(true, Ordering::Relaxed);
                Reply::Msg(Msg::Ok)
            }
            Msg::Ping => Reply::Msg(Msg::Pong),
            other => Reply::Msg(Msg::Err(format!("model_pool: unexpected {other:?}"))),
        })?;
        // wire byte accounting rides the same telemetry snapshot
        hub.register("bytes_in", server.bytes_in.clone());
        hub.register("bytes_out", server.bytes_out.clone());
        Ok(ModelPoolServer {
            addr: server.addr.clone(),
            store,
            stop_flag,
            hub,
            shard,
            _server: server,
        })
    }

    /// Telemetry registry for this replica (role `model-pool` in the
    /// league view).
    pub fn hub(&self) -> &Arc<MetricsHub> {
        &self.hub
    }

    /// True once a wire `Shutdown` request has been received.
    pub fn stop_requested(&self) -> bool {
        self.stop_flag.load(Ordering::Relaxed)
    }

    /// Stop accepting connections and join the accept loop.
    pub fn shutdown(&mut self) {
        self._server.shutdown();
    }

    /// Reply-frame (re)builds since start.  A frame-cache hit does not
    /// move this — the zero-encode invariant tests and benches assert.
    pub fn frame_encodes(&self) -> u64 {
        self.store.lock().encodes
    }

    pub fn model_count(&self) -> usize {
        self.store.lock().model_count()
    }

    /// Bytes currently held in memory (excludes spilled blobs).
    pub fn resident_bytes(&self) -> usize {
        self.store.lock().resident
    }

    /// Blobs whose only copy is on disk.
    pub fn spilled_count(&self) -> usize {
        self.store.lock().spilled_count()
    }

    /// Everything this replica stores, for snapshotting.  Spilled blobs
    /// are read from disk after the store lock is released.
    pub fn all_blobs(&self) -> Vec<ModelBlob> {
        let (resident, spilled) = self.store.lock().snapshot_parts();
        assemble_blobs(resident, &spilled)
    }

    /// Restore path: bulk-load snapshot blobs.  `latest` lands on the
    /// highest version per agent regardless of load order.
    pub fn preload(&self, blobs: &[ModelBlob]) {
        let mut st = self.store.lock();
        for b in blobs {
            st.insert(b.clone());
        }
    }

    /// Closure handle for the background snapshotter thread.
    pub fn blobs_fn(&self) -> impl Fn() -> Vec<ModelBlob> + Send + 'static {
        let store = self.store.clone();
        move || {
            let (resident, spilled) = store.lock().snapshot_parts();
            assemble_blobs(resident, &spilled)
        }
    }

    /// Direct (in-process) insert bypassing the ownership check — the
    /// [`rebalance`] ingest path on a destination replica, which is
    /// usually NOT yet an owner under the map the handler would consult
    /// mid-transition.
    pub fn ingest(&self, blob: ModelBlob) {
        self.store.lock().insert(blob);
    }

    /// Whether `key` is stored here (resident or spilled).
    pub fn has_key(&self, key: ModelKey) -> bool {
        let st = self.store.lock();
        st.blobs.contains_key(&key) || st.on_disk.contains_key(&key)
    }

    /// Distinct agents with at least one model on this replica.
    pub fn agents(&self) -> Vec<u32> {
        self.store.lock().agents()
    }

    /// Every key stored for `agent` on this replica (no payloads).
    pub fn keys_for_agent(&self, agent: u32) -> Vec<ModelKey> {
        self.store.lock().keys_for(agent)
    }

    /// `agent`'s latest key and its replica-local rev, if present.
    pub fn latest_with_rev(&self, agent: u32) -> Option<(ModelKey, u64)> {
        let st = self.store.lock();
        let key = *st.latest.get(&agent)?;
        Some((key, st.rev(key)))
    }

    /// Anti-entropy bookkeeping: the (source slot, source rev) of the
    /// last rebalance transfer of `agent` into this replica.
    pub fn origin_of(&self, agent: u32) -> Option<(u32, u64)> {
        self.store.lock().origin.get(&agent).copied()
    }

    pub fn set_origin(&self, agent: u32, src_slot: u32, src_rev: u64) {
        self.store.lock().origin.insert(agent, (src_slot, src_rev));
    }

    /// Drop every trace of `agent` — rebalance GC on an old owner that
    /// lost the agent.  Subsequent reads here redirect via `WrongShard`.
    pub fn evict_agent(&self, agent: u32) {
        self.store.lock().evict_agent(agent);
    }

    /// Per-replica shard report for the `stats` CLI pool section.
    pub fn shard_info(&self) -> PoolShardInfo {
        shard_info_of(&self.store, &self.hub, &self.shard, &self.addr)
    }

    /// Closure handle for the controller's `PoolShardQuery` arm.
    pub fn shard_info_fn(&self) -> impl Fn() -> PoolShardInfo + Send + 'static {
        let store = self.store.clone();
        let hub = self.hub.clone();
        let shard = self.shard.clone();
        let addr = self.addr.clone();
        move || shard_info_of(&store, &hub, &shard, &addr)
    }
}

fn shard_info_of(
    store: &OrderedMutex<Store>,
    hub: &MetricsHub,
    shard: &ShardRole,
    addr: &str,
) -> PoolShardInfo {
    let st = store.lock();
    let (replica, map_version) = match shard {
        Some((holder, slot)) => (*slot, holder.version()),
        None => (0, 0),
    };
    PoolShardInfo {
        replica,
        addr: addr.to_string(),
        owned_agents: st.agents(),
        resident_bytes: st.resident as u64,
        models: st.model_count() as u32,
        spilled: st.spilled_count() as u32,
        reads: hub.meter("reads").count(),
        frame_hits: hub.meter("frame_hits").count(),
        map_version,
    }
}

/// Result of a delta-aware [`ModelPoolClient::get_latest_if_newer`].
#[derive(Debug)]
pub enum LatestFetch {
    /// the requester's (version, rev) is current — the reply was O(1)
    NotModified,
    /// newer (or byte-refreshed) params; `rev` is the stamp to echo on
    /// the next refresh
    New { rev: u64, blob: ModelBlob },
    NotFound,
}

/// Client over one or more ModelPool replicas.  Routing is shard-aware:
/// a cached (map, ring) pair — bootstrapped from the address list, kept
/// fresh by `WrongShard` piggybacks — sends writes to the R owner
/// replicas of the blob's agent and reads to a random live owner.  A
/// replica that fails a request is remembered dead for a backoff window
/// (500 ms doubling to 8 s) so a downed owner is not re-attempted on
/// every read.
pub struct ModelPoolClient {
    replicas: Vec<ReqClient>,
    /// cached placement: replaced whenever a reply (or an off-path
    /// `GetShardMap`) carries a strictly newer map.
    map: OrderedMutex<(Arc<ShardMap>, Arc<shard::Ring>)>,
    /// per-replica dead mark: (retry-after, current backoff ms).  Set on
    /// transport failure, doubled while failures continue, cleared on
    /// the first success.  A marked replica is skipped by routing until
    /// the window expires, so `faults_injected` stays flat under a
    /// sustained partition instead of climbing on every read.
    dead: OrderedMutex<Vec<Option<(Instant, u64)>>>,
    /// replica preferred for if-newer refreshes: revs are replica-local
    /// put counters, so bouncing between replicas would make them
    /// incomparable and turn every refresh into a full transfer.
    /// Rotated on transport failure so a dead replica doesn't pin every
    /// future refresh to its ~9s reconnect loop.
    sticky: AtomicUsize,
    /// bumped on every sticky rotation AND every map install.  Two
    /// replicas can hold the SAME (version, rev) numbers for DIFFERENT
    /// bytes (revs count local puts), so rev state learned before a
    /// rotation or re-route must never be echoed at the replacement
    /// replica — it could collide into a bogus `NotModified` that
    /// silently pins stale params.
    generation: AtomicU64,
    /// agent → (replica index, generation) under which its last `New`
    /// rev was learned; any mismatch downgrades the next if-newer read
    /// to unconditional.
    have_from: OrderedMutex<HashMap<u32, (usize, u64)>>,
    rng: OrderedMutex<Pcg32>,
}

/// Distinct RNG stream per client so co-located clients don't all pick
/// the same "random" replica sequence (and sticky replicas spread).
static NEXT_CLIENT: AtomicU64 = AtomicU64::new(0);

const DEAD_BACKOFF_MS: u64 = 500;
const DEAD_BACKOFF_CAP_MS: u64 = 8_000;

impl ModelPoolClient {
    /// Connect with the process-default replication factor (installed
    /// from the run config via [`set_default_replication`]).
    pub fn connect(addrs: &[String]) -> ModelPoolClient {
        Self::connect_with(addrs, default_replication() as u32)
    }

    /// Connect with an explicit replication factor.  The bootstrap map
    /// (version 1) is derived locally from `addrs` + `replication`;
    /// because placement hashes replica *indices*, every process that
    /// derives from the same run config lands on the identical ring.
    pub fn connect_with(addrs: &[String], replication: u32) -> ModelPoolClient {
        assert!(!addrs.is_empty());
        let mut rng = Pcg32::from_label(
            NEXT_CLIENT.fetch_add(1, Ordering::Relaxed),
            "mp-client",
        );
        let sticky = rng.below(addrs.len() as u32) as usize;
        let map = shard::bootstrap_map(addrs, replication);
        let ring = Arc::new(shard::Ring::build(&map));
        ModelPoolClient {
            replicas: addrs.iter().map(|a| ReqClient::connect(a)).collect(),
            map: OrderedMutex::new("pool_client.map", (Arc::new(map), ring)),
            dead: OrderedMutex::new("pool_client.dead", vec![None; addrs.len()]),
            sticky: AtomicUsize::new(sticky),
            generation: AtomicU64::new(0),
            have_from: OrderedMutex::new("pool_client.have_from", HashMap::new()),
            rng: OrderedMutex::new("pool_client.rng", rng),
        }
    }

    /// Index of the replica currently preferred for if-newer refreshes
    /// (rotates on transport failure).  Exposed for failover tests and
    /// chaos drills.
    pub fn sticky_index(&self) -> usize {
        self.sticky.load(Ordering::Relaxed) % self.replicas.len()
    }

    /// Version of the cached shard map (bootstrap = 1).
    pub fn map_version(&self) -> u64 {
        self.map.lock().0.version
    }

    /// Replica indices currently inside their dead-backoff window — the
    /// satellite behaviour the partition tests assert on.
    pub fn dead_replica_indices(&self) -> Vec<usize> {
        (0..self.replicas.len()).filter(|&i| self.is_dead(i)).collect()
    }

    fn map_pair(&self) -> (Arc<ShardMap>, Arc<shard::Ring>) {
        self.map.lock().clone()
    }

    /// Adopt `map` if strictly newer than the cached one.  A placement
    /// change invalidates cross-replica rev state (generation bump).
    fn install_map(&self, map: ShardMap) -> bool {
        {
            let mut g = self.map.lock();
            if map.version <= g.0.version {
                return false;
            }
            let ring = Arc::new(shard::Ring::build(&map));
            *g = (Arc::new(map), ring);
        }
        self.generation.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Off-hot-path map refresh: ask any live replica for its current
    /// map (used when a replica dies or every owner bounced a write).
    /// Unsharded replicas answer `Err` and are simply skipped.
    fn refresh_map(&self) {
        for (i, r) in self.replicas.iter().enumerate() {
            if self.is_dead(i) {
                continue;
            }
            if let Ok(Msg::ShardMapMsg(map)) = r.request_n(&Msg::GetShardMap, 1)
            {
                self.install_map(map);
                return;
            }
        }
    }

    fn mark_dead(&self, idx: usize) {
        let mut d = self.dead.lock();
        let ms = match d[idx] {
            Some((_, prev)) => (prev * 2).min(DEAD_BACKOFF_CAP_MS),
            None => DEAD_BACKOFF_MS,
        };
        d[idx] = Some((Instant::now() + Duration::from_millis(ms), ms));
    }

    fn mark_alive(&self, idx: usize) {
        self.dead.lock()[idx] = None;
    }

    fn is_dead(&self, idx: usize) -> bool {
        matches!(
            self.dead.lock()[idx],
            Some((until, _)) if Instant::now() < until
        )
    }

    /// Owner replica indices for `agent` under the cached ring; an
    /// empty ring (degenerate map) falls back to every replica.
    fn owner_indices(&self, agent: u32) -> Vec<usize> {
        let (_, ring) = self.map_pair();
        let owners: Vec<usize> = ring
            .owners(agent)
            .into_iter()
            .map(|s| s as usize)
            .filter(|&s| s < self.replicas.len())
            .collect();
        if owners.is_empty() {
            (0..self.replicas.len()).collect()
        } else {
            owners
        }
    }

    /// Random owner for a read, preferring replicas that are neither
    /// locally banned (bounced this request already) nor in their dead
    /// window.
    fn pick_owner(&self, agent: u32, banned: &[usize]) -> usize {
        let owners = self.owner_indices(agent);
        let fresh: Vec<usize> = owners
            .iter()
            .copied()
            .filter(|i| !banned.contains(i) && !self.is_dead(*i))
            .collect();
        let cands = if !fresh.is_empty() {
            fresh
        } else {
            let unbanned: Vec<usize> =
                owners.iter().copied().filter(|i| !banned.contains(i)).collect();
            if unbanned.is_empty() { owners } else { unbanned }
        };
        let j = self.rng.lock().below(cands.len() as u32) as usize;
        cands[j]
    }

    /// Write to the R owner replicas of the blob's agent.  The write is
    /// durable once at least one owner acks: a dead owner must not
    /// stall or fail the learner's publish cadence (anti-entropy
    /// re-syncs it), so per-replica attempts are bounded instead of
    /// riding the full reconnect ladder.  If EVERY owner bounces with
    /// `WrongShard` (our map is stale), adopt the piggybacked map and
    /// retry against the new owners.
    pub fn put(&self, blob: ModelBlob) -> Result<()> {
        let mut last_err: Option<anyhow::Error> = None;
        for _round in 0..2 {
            let owners = self.owner_indices(blob.key.agent);
            let mut acks = 0usize;
            let mut newer: Option<ShardMap> = None;
            for &i in &owners {
                match self.replicas[i].request_n(&Msg::PutModel(blob.clone()), 4)
                {
                    Ok(Msg::Ok) => {
                        self.mark_alive(i);
                        acks += 1;
                    }
                    Ok(Msg::WrongShard(map)) => newer = Some(map),
                    Ok(other) => {
                        last_err = Some(anyhow::anyhow!(
                            "put: unexpected reply {other:?}"
                        ));
                    }
                    Err(e) => {
                        self.mark_dead(i);
                        last_err = Some(e);
                    }
                }
            }
            if acks > 0 {
                if acks < owners.len() {
                    eprintln!(
                        "model_pool: put {} acked by {acks}/{} owners",
                        blob.key,
                        owners.len()
                    );
                }
                return Ok(());
            }
            match newer {
                Some(map) if self.install_map(map) => {}
                _ => self.refresh_map(),
            }
        }
        Err(last_err
            .unwrap_or_else(|| anyhow::anyhow!("put: no owners"))
            .context("put: no owner acked"))
    }

    /// Owner-routed read with `WrongShard` self-correction: a bounced
    /// request installs the piggybacked map and retries against the new
    /// owners; a transport failure marks the replica dead and tries the
    /// next owner.
    fn read_routed(&self, agent: u32, req: &Msg) -> Result<Msg> {
        let attempts = if self.replicas.len() > 1 { 5 } else { 40 };
        let mut banned: Vec<usize> = Vec::new();
        let mut last_err: Option<anyhow::Error> = None;
        for round in 0..self.replicas.len() + 2 {
            let idx = self.pick_owner(agent, &banned);
            match self.replicas[idx].request_n(req, attempts) {
                Ok(Msg::WrongShard(map)) => {
                    // no coordinator round-trip: the bounce carries the
                    // truth.  A non-newer map means we already hold it —
                    // just avoid this replica for the rest of the call.
                    if !self.install_map(map) {
                        banned.push(idx);
                    }
                }
                Ok(reply) => {
                    self.mark_alive(idx);
                    if round > 0 {
                        fault::on_recovery();
                    }
                    return Ok(reply);
                }
                Err(e) => {
                    self.mark_dead(idx);
                    banned.push(idx);
                    self.refresh_map();
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.unwrap_or_else(|| {
            anyhow::anyhow!("pool read: no owner answered (routing unresolved)")
        }))
    }

    pub fn get(&self, key: ModelKey) -> Result<Option<ModelBlob>> {
        match self.read_routed(key.agent, &Msg::GetModel { key, trace: None })? {
            Msg::Model(b) => Ok(Some(b)),
            Msg::NotFound => Ok(None),
            other => bail!("get: unexpected reply {other:?}"),
        }
    }

    pub fn get_latest(&self, agent: u32) -> Result<Option<ModelBlob>> {
        match self.read_routed(agent, &Msg::GetLatest { agent })? {
            Msg::Model(b) => Ok(Some(b)),
            Msg::NotFound => Ok(None),
            other => bail!("get_latest: unexpected reply {other:?}"),
        }
    }

    /// Delta-aware latest read: transfers the params only when the pool
    /// holds something newer than `(have_version, have_rev)`.  Pass
    /// `(0, 0)` to fetch unconditionally (revs start at 1).  Asks the
    /// sticky replica, failing over (and invalidating rev state) when
    /// it is unreachable — see the field docs.
    pub fn get_latest_if_newer(
        &self,
        agent: u32,
        have_version: u32,
        have_rev: u64,
    ) -> Result<LatestFetch> {
        self.get_latest_if_newer_traced(agent, have_version, have_rev, None)
    }

    /// [`get_latest_if_newer`](Self::get_latest_if_newer) with an
    /// optional trace context riding the request — the serving replica
    /// records a `pool_get` span parented to `trace.span_id`.
    pub fn get_latest_if_newer_traced(
        &self,
        agent: u32,
        have_version: u32,
        have_rev: u64,
        trace: Option<TraceCtx>,
    ) -> Result<LatestFetch> {
        // with a fallback replica available, give up on the sticky one
        // quickly instead of riding the full reconnect ladder
        let attempts = if self.replicas.len() > 1 { 5 } else { 40 };
        let mut last_err = None;
        for round in 0..self.replicas.len() + 1 {
            let idx = self.refresh_target(agent);
            let gen = self.generation.load(Ordering::Relaxed);
            // rev state learned at a different replica or under an
            // older generation is incomparable: downgrade to an
            // unconditional read rather than risk a colliding, bogus
            // NotModified (see the `generation` field docs)
            let (hv, hr) = if self.have_from.lock().get(&agent) == Some(&(idx, gen)) {
                (have_version, have_rev)
            } else {
                (0, 0)
            };
            let req = Msg::GetModelIfNewer {
                agent,
                have_version: hv,
                have_rev: hr,
                trace,
            };
            match self.replicas[idx].request_n(&req, attempts) {
                Ok(Msg::WrongShard(map)) => {
                    // stale placement: adopt the piggybacked map (the
                    // install bumps the generation, so stale rev state
                    // cannot leak to the new owner) and retry
                    self.install_map(map);
                }
                Ok(reply) => {
                    self.mark_alive(idx);
                    if round > 0 {
                        fault::on_recovery();
                    }
                    return match reply {
                        Msg::NotModified => Ok(LatestFetch::NotModified),
                        Msg::ModelRev { rev, blob } => {
                            self.have_from.lock().insert(agent, (idx, gen));
                            Ok(LatestFetch::New { rev, blob })
                        }
                        Msg::NotFound => Ok(LatestFetch::NotFound),
                        other => bail!(
                            "get_latest_if_newer: unexpected reply {other:?}"
                        ),
                    };
                }
                Err(e) => {
                    // replica unreachable: mark it dead so routing skips
                    // it, rotate sticky off it, and bump the generation
                    // so its rev state is never echoed at a replacement
                    self.mark_dead(idx);
                    self.sticky
                        .store((idx + 1) % self.replicas.len(), Ordering::Relaxed);
                    self.generation.fetch_add(1, Ordering::Relaxed);
                    self.refresh_map();
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.unwrap_or_else(|| {
            anyhow::anyhow!("get_latest_if_newer: no owner answered")
        }))
    }

    /// The replica an if-newer refresh should ask: the sticky replica
    /// when it owns the agent and is believed live (replica-local revs
    /// stay comparable), otherwise the first live owner.
    fn refresh_target(&self, agent: u32) -> usize {
        let owners = self.owner_indices(agent);
        let sticky = self.sticky.load(Ordering::Relaxed) % self.replicas.len();
        if owners.contains(&sticky) && !self.is_dead(sticky) {
            return sticky;
        }
        owners
            .iter()
            .copied()
            .find(|&i| !self.is_dead(i))
            .or_else(|| owners.first().copied())
            .unwrap_or(sticky)
    }

    /// Aggregated (resident_bytes, models, spilled) across every
    /// reachable replica.  With replication factor R a blob owned by R
    /// replicas counts R times — the numbers describe the deployment's
    /// footprint, not the distinct-model count.
    pub fn stats(&self) -> Result<(u64, u32, u32)> {
        let (mut rb, mut mo, mut sp) = (0u64, 0u32, 0u32);
        let mut any = false;
        let mut last_err: Option<anyhow::Error> = None;
        for (i, r) in self.replicas.iter().enumerate() {
            if self.is_dead(i) {
                continue;
            }
            match r.request_n(&Msg::PoolStats, 2) {
                Ok(Msg::PoolStatsReply {
                    resident_bytes, models, spilled, ..
                }) => {
                    self.mark_alive(i);
                    any = true;
                    rb += resident_bytes;
                    mo += models;
                    sp += spilled;
                }
                Ok(other) => {
                    last_err =
                        Some(anyhow::anyhow!("stats: unexpected reply {other:?}"));
                }
                Err(e) => {
                    self.mark_dead(i);
                    last_err = Some(e);
                }
            }
        }
        if any {
            Ok((rb, mo, sp))
        } else {
            Err(last_err
                .unwrap_or_else(|| anyhow::anyhow!("stats: no replicas"))
                .context("stats: no replica answered"))
        }
    }
}

/// Outcome of one [`rebalance`] pass — surfaced by the `kill:pool`
/// chaos drill and the elastic bench group.
#[derive(Clone, Copy, Debug, Default)]
pub struct MoveStats {
    /// agents whose data actually changed hands
    pub agents: u32,
    pub blobs_moved: u32,
    pub bytes_moved: u64,
    /// transfers answered `NotModified` by the rev protocol (a prior
    /// pass already delivered the bytes) — the anti-entropy savings
    pub blobs_skipped: u32,
}

fn blob_bytes(b: &ModelBlob) -> u64 {
    (b.params.len() * 4 + b.hp.len() * 4 + 16) as u64
}

/// Anti-entropy pass after a shard-map change (`old_map` → `new_map`):
/// for every agent whose owner set changed, pull its data from a
/// surviving old owner into each new owner that lacks it — the latest
/// model via the `GetModelIfNewer` rev protocol (an O(1) `NotModified`
/// when a previous pass already moved it, tracked per destination in
/// `Store::origin`), frozen history via plain `GetModel` for keys the
/// destination is missing.  Agents whose owners are unchanged are not
/// touched at all, so a rebalance moves only the blobs that actually
/// changed hands.  Old owners that lost an agent GC it afterwards.
///
/// `pools` are the deployment's in-process replica handles indexed by
/// slot; `live[i]` is false for replicas that are down (tombstoned or
/// crashed).  Enumeration is in-process; blob payloads move over the
/// wire from the source replica's service address.
pub fn rebalance(
    pools: &[ModelPoolServer],
    live: &[bool],
    old_map: &ShardMap,
    new_map: &ShardMap,
) -> MoveStats {
    let is_live = |slot: u32| live.get(slot as usize).copied().unwrap_or(false);
    let old_ring = shard::Ring::build(old_map);
    let new_ring = shard::Ring::build(new_map);
    let mut stats = MoveStats::default();
    let mut agents: Vec<u32> = Vec::new();
    for (i, p) in pools.iter().enumerate() {
        if live.get(i).copied().unwrap_or(false) {
            agents.extend(p.agents());
        }
    }
    agents.sort_unstable();
    agents.dedup();
    let mut srcs: HashMap<u32, ReqClient> = HashMap::new();
    for agent in agents {
        let old_owners = old_ring.owners(agent);
        let new_owners = new_ring.owners(agent);
        if old_owners == new_owners {
            continue; // nothing changed hands for this agent
        }
        let Some(src) = old_owners.iter().copied().find(|&s| {
            is_live(s) && pools[s as usize].latest_with_rev(agent).is_some()
        }) else {
            continue; // no surviving copy — nothing to transfer
        };
        let conn = srcs
            .entry(src)
            .or_insert_with(|| ReqClient::connect(&pools[src as usize].addr));
        let mut touched = false;
        for &dst in &new_owners {
            if dst == src || !is_live(dst) {
                continue;
            }
            let dstp = &pools[dst as usize];
            // latest model: rev-conditional pull.  The source rev is
            // only comparable if our last transfer came from the same
            // source slot; otherwise ask unconditionally on the version.
            let (hv, hr) =
                match (dstp.latest_with_rev(agent), dstp.origin_of(agent)) {
                    (Some((k, _)), Some((oslot, orev))) if oslot == src => {
                        (k.version, orev)
                    }
                    (Some((k, _)), _) => (k.version, 0),
                    _ => (0, 0),
                };
            let req = Msg::GetModelIfNewer {
                agent,
                have_version: hv,
                have_rev: hr,
                trace: None,
            };
            match conn.request_n(&req, 4) {
                Ok(Msg::ModelRev { rev, blob }) => {
                    stats.blobs_moved += 1;
                    stats.bytes_moved += blob_bytes(&blob);
                    dstp.ingest(blob);
                    dstp.set_origin(agent, src, rev);
                    touched = true;
                }
                Ok(Msg::NotModified) => stats.blobs_skipped += 1,
                Ok(_) | Err(_) => {}
            }
            // frozen history the destination is still missing
            for key in pools[src as usize].keys_for_agent(agent) {
                if dstp.has_key(key) {
                    continue;
                }
                if let Ok(Msg::Model(blob)) =
                    conn.request_n(&Msg::GetModel { key, trace: None }, 4)
                {
                    stats.blobs_moved += 1;
                    stats.bytes_moved += blob_bytes(&blob);
                    dstp.ingest(blob);
                    touched = true;
                }
            }
        }
        if touched {
            stats.agents += 1;
        }
        // GC: survivors that lost ownership of this agent drop it, so
        // their reads flip to the WrongShard redirect and memory frees
        for &old in &old_owners {
            if !new_owners.contains(&old) && is_live(old) {
                pools[old as usize].evict_agent(agent);
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(agent: u32, version: u32, val: f32) -> ModelBlob {
        ModelBlob {
            key: ModelKey::new(agent, version),
            params: vec![val; 8],
            hp: vec![3e-4],
            frozen: false,
        }
    }

    fn frozen_blob(agent: u32, version: u32, n: usize) -> ModelBlob {
        ModelBlob {
            key: ModelKey::new(agent, version),
            params: vec![version as f32; n],
            hp: vec![3e-4],
            frozen: true,
        }
    }

    fn spill_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("tleague-spill-{}-{tag}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn put_get_roundtrip() {
        let server = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        client.put(blob(0, 1, 1.5)).unwrap();
        let got = client.get(ModelKey::new(0, 1)).unwrap().unwrap();
        assert_eq!(got.params, vec![1.5; 8]);
        assert!(client.get(ModelKey::new(0, 9)).unwrap().is_none());
        assert_eq!(server.model_count(), 1);
    }

    #[test]
    fn latest_tracks_highest_version() {
        let server = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        client.put(blob(0, 1, 1.0)).unwrap();
        client.put(blob(0, 3, 3.0)).unwrap();
        client.put(blob(0, 2, 2.0)).unwrap(); // stale write must not win
        let latest = client.get_latest(0).unwrap().unwrap();
        assert_eq!(latest.key.version, 3);
        assert!(client.get_latest(7).unwrap().is_none());
    }

    /// Regression: an equal-version re-put (learner restart republishing
    /// its current model) must refresh the stored bytes without being
    /// treated as a *newer* version.
    #[test]
    fn equal_version_reput_refreshes_but_is_not_newer() {
        let server = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        client.put(blob(0, 2, 1.0)).unwrap();
        client.put(blob(0, 2, 9.0)).unwrap(); // same version, new bytes
        let latest = client.get_latest(0).unwrap().unwrap();
        assert_eq!(latest.key.version, 2);
        assert_eq!(latest.params, vec![9.0; 8], "re-put must refresh bytes");
        assert_eq!(server.model_count(), 1, "no duplicate entry");
    }

    #[test]
    fn replicated_writes_readable_from_any() {
        let s1 = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let s2 = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let client = ModelPoolClient::connect(&[s1.addr.clone(), s2.addr.clone()]);
        client.put(blob(1, 4, 4.0)).unwrap();
        // both replicas hold the model, so any single-replica client sees it
        for addr in [&s1.addr, &s2.addr] {
            let c = ModelPoolClient::connect(&[addr.clone()]);
            assert!(c.get(ModelKey::new(1, 4)).unwrap().is_some());
        }
    }

    #[test]
    fn concurrent_readers_and_writers() {
        let server = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let addr = server.addr.clone();
        let mut handles = Vec::new();
        for t in 0..4u32 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let c = ModelPoolClient::connect(&[addr]);
                for v in 0..20 {
                    c.put(blob(t, v, v as f32)).unwrap();
                    let got = c.get(ModelKey::new(t, v)).unwrap().unwrap();
                    assert_eq!(got.params[0], v as f32);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.model_count(), 80);
    }

    /// The if-newer protocol: miss (full transfer + rev), hit
    /// (NotModified), same-version re-put visibility, frozen version
    /// bumps, and the lagging-replica guard.
    #[test]
    fn if_newer_hit_miss_and_frozen_roundtrips() {
        let server = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        // miss: empty pool
        assert!(matches!(
            client.get_latest_if_newer(0, 0, 0).unwrap(),
            LatestFetch::NotFound
        ));
        client.put(blob(0, 1, 1.0)).unwrap();
        // unconditional fetch returns the blob plus its rev stamp
        let rev1 = match client.get_latest_if_newer(0, 0, 0).unwrap() {
            LatestFetch::New { rev, blob } => {
                assert_eq!(blob.key.version, 1);
                assert_eq!(blob.params, vec![1.0; 8]);
                rev
            }
            other => panic!("expected New, got {other:?}"),
        };
        assert!(rev1 > 0);
        // hit: holding the current (version, rev) → O(1) reply
        assert!(matches!(
            client.get_latest_if_newer(0, 1, rev1).unwrap(),
            LatestFetch::NotModified
        ));
        // same-version re-put (the in-training publish cadence) must be
        // visible: same version, new rev, new bytes
        client.put(blob(0, 1, 2.0)).unwrap();
        let rev2 = match client.get_latest_if_newer(0, 1, rev1).unwrap() {
            LatestFetch::New { rev, blob } => {
                assert_eq!(blob.key.version, 1);
                assert_eq!(blob.params, vec![2.0; 8], "re-put bytes must flow");
                rev
            }
            other => panic!("expected New after re-put, got {other:?}"),
        };
        assert_ne!(rev2, rev1);
        // frozen version bump
        client.put(frozen_blob(0, 2, 8)).unwrap();
        let rev3 = match client.get_latest_if_newer(0, 1, rev2).unwrap() {
            LatestFetch::New { rev, blob } => {
                assert_eq!(blob.key.version, 2);
                assert!(blob.frozen);
                rev
            }
            other => panic!("expected New after freeze, got {other:?}"),
        };
        assert!(matches!(
            client.get_latest_if_newer(0, 2, rev3).unwrap(),
            LatestFetch::NotModified
        ));
        // client ahead of a lagging replica: never regress its params
        assert!(matches!(
            client.get_latest_if_newer(0, 99, 12345).unwrap(),
            LatestFetch::NotModified
        ));
    }

    /// Regression for the cross-replica `NotModified` staleness hazard:
    /// revs are replica-local put counters, so two replicas can hold
    /// the SAME (version, rev) numbers for DIFFERENT bytes.  After the
    /// sticky replica dies, the client must fail over within the call
    /// AND downgrade to an unconditional read — echoing the dead
    /// replica's rev at the survivor would collide into a bogus
    /// `NotModified` that silently pins stale params.
    #[test]
    fn sticky_failover_never_yields_stale_not_modified() {
        let mut s1 = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let mut s2 = ModelPoolServer::start("127.0.0.1:0").unwrap();
        // engineer the rev collision: one put each → (v1, rev 1) on
        // both replicas, different params
        ModelPoolClient::connect(&[s1.addr.clone()]).put(blob(0, 1, 1.0)).unwrap();
        ModelPoolClient::connect(&[s2.addr.clone()]).put(blob(0, 1, 2.0)).unwrap();
        let client =
            ModelPoolClient::connect(&[s1.addr.clone(), s2.addr.clone()]);
        let (rev, first) = match client.get_latest_if_newer(0, 0, 0).unwrap() {
            LatestFetch::New { rev, blob } => (rev, blob.params[0]),
            other => panic!("expected New, got {other:?}"),
        };
        // steady state: holding the current (version, rev) is a hit
        assert!(matches!(
            client.get_latest_if_newer(0, 1, rev).unwrap(),
            LatestFetch::NotModified
        ));
        // kill the sticky replica; the same refresh must now fail over
        // and come back `New` with the survivor's bytes
        let sticky = client.sticky_index();
        if sticky == 0 {
            s1.shutdown();
        } else {
            s2.shutdown();
        }
        // conn threads poll the stop flag on a 200ms read timeout — wait
        // them out so the dead replica cannot serve one last request
        std::thread::sleep(std::time::Duration::from_millis(400));
        match client.get_latest_if_newer(0, 1, rev).unwrap() {
            LatestFetch::New { blob, .. } => {
                let survivor = if first == 1.0 { 2.0 } else { 1.0 };
                assert_eq!(blob.params[0], survivor, "must serve survivor bytes");
            }
            other => panic!("expected New after failover, got {other:?}"),
        }
        assert_ne!(client.sticky_index(), sticky, "sticky must rotate");
    }

    /// Repeated reads of one blob encode its reply frame exactly once;
    /// a re-put (including the freeze re-put) invalidates the frame so
    /// readers see the new bytes.
    #[test]
    fn frame_cache_hits_skip_encode_and_invalidate_on_put() {
        let server = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        let key = ModelKey::new(0, 1);
        client.put(blob(0, 1, 1.0)).unwrap();
        assert_eq!(server.frame_encodes(), 0);
        for _ in 0..5 {
            let got = client.get(key).unwrap().unwrap();
            assert_eq!(got.params, vec![1.0; 8]);
        }
        assert_eq!(server.frame_encodes(), 1, "one build, then cache hits");
        // GetLatest and if-newer share the same cached frame
        assert_eq!(client.get_latest(0).unwrap().unwrap().params, vec![1.0; 8]);
        match client.get_latest_if_newer(0, 0, 0).unwrap() {
            LatestFetch::New { blob, .. } => {
                assert_eq!(blob.params, vec![1.0; 8])
            }
            other => panic!("expected New, got {other:?}"),
        }
        assert_eq!(server.frame_encodes(), 1);
        // freeze arrives as a re-put: frame invalidated, new bytes flow
        client
            .put(ModelBlob {
                key,
                params: vec![9.0; 8],
                hp: vec![3e-4],
                frozen: true,
            })
            .unwrap();
        let got = client.get(key).unwrap().unwrap();
        assert_eq!(got.params, vec![9.0; 8]);
        assert!(got.frozen);
        assert_eq!(server.frame_encodes(), 2, "re-put must rebuild the frame");
    }

    /// Spilling a blob drops its cached frame; fault-in serves correct
    /// bytes and rebuilds the frame for later hits.
    #[test]
    fn frame_cache_invalidates_on_spill_and_rebuilds_on_fault_in() {
        let dir = spill_dir("frame-spill");
        let server = ModelPoolServer::start_with(
            "127.0.0.1:0",
            PoolOptions { spill_dir: Some(dir.clone()), mem_budget: 40 * 1024 },
        )
        .unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        client.put(frozen_blob(0, 0, 2000)).unwrap();
        // read it so its frame is cached
        assert_eq!(
            client.get(ModelKey::new(0, 0)).unwrap().unwrap().params,
            vec![0.0; 2000]
        );
        let builds_before = server.frame_encodes();
        // push enough newer frozen blobs to spill v0 (blob AND frame)
        for v in 1..8 {
            client.put(frozen_blob(0, v, 2000)).unwrap();
        }
        assert!(server.spilled_count() > 0, "v0 should have spilled");
        // fault-in: correct bytes, frame rebuilt exactly once for the
        // two follow-up reads
        for _ in 0..2 {
            let b = client.get(ModelKey::new(0, 0)).unwrap().unwrap();
            assert_eq!(b.params, vec![0.0; 2000]);
        }
        assert_eq!(server.frame_encodes(), builds_before + 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn spill_keeps_resident_under_budget_and_serves_everything() {
        let dir = spill_dir("budget");
        // ~8 KiB per blob, budget fits roughly 4
        let budget = 36 * 1024;
        let server = ModelPoolServer::start_with(
            "127.0.0.1:0",
            PoolOptions { spill_dir: Some(dir.clone()), mem_budget: budget },
        )
        .unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        for v in 0..20 {
            client.put(frozen_blob(0, v, 2000)).unwrap();
        }
        assert!(
            server.resident_bytes() <= budget,
            "resident {} > budget {budget}",
            server.resident_bytes()
        );
        assert!(server.spilled_count() > 0, "nothing spilled");
        assert_eq!(server.model_count(), 20, "spilled blobs still counted");
        // every blob — including spilled ones — remains retrievable, and
        // faulting them back in never breaks the budget
        for v in 0..20 {
            let b = client.get(ModelKey::new(0, v)).unwrap().unwrap();
            assert_eq!(b.params, vec![v as f32; 2000], "blob {v} corrupted");
            assert!(server.resident_bytes() <= budget);
        }
        let (resident, models, _spilled) = client.stats().unwrap();
        assert!(resident as usize <= budget);
        assert_eq!(models, 20);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn spill_never_evicts_latest_or_unfrozen() {
        let dir = spill_dir("protect");
        let server = ModelPoolServer::start_with(
            "127.0.0.1:0",
            PoolOptions { spill_dir: Some(dir.clone()), mem_budget: 1 },
        )
        .unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        // unfrozen learner model + the frozen latest: neither may spill
        // even with an absurdly small budget
        client
            .put(ModelBlob {
                key: ModelKey::new(0, 1),
                params: vec![1.0; 512],
                hp: vec![3e-4],
                frozen: false,
            })
            .unwrap();
        client.put(frozen_blob(1, 1, 512)).unwrap();
        assert_eq!(server.spilled_count(), 0, "protected blobs were spilled");
        // a second frozen version for agent 1 makes v1 evictable
        client.put(frozen_blob(1, 2, 512)).unwrap();
        assert_eq!(server.spilled_count(), 1);
        assert!(
            client.get(ModelKey::new(1, 1)).unwrap().is_some(),
            "spilled blob must fault back in"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn all_blobs_includes_spilled_and_preload_restores() {
        let dir = spill_dir("snapshot");
        let server = ModelPoolServer::start_with(
            "127.0.0.1:0",
            PoolOptions { spill_dir: Some(dir.clone()), mem_budget: 20 * 1024 },
        )
        .unwrap();
        let client = ModelPoolClient::connect(&[server.addr.clone()]);
        for v in 0..8 {
            client.put(frozen_blob(0, v, 2000)).unwrap();
        }
        let blobs = server.all_blobs();
        assert_eq!(blobs.len(), 8, "snapshot must cover spilled blobs");
        // restore into a fresh, spill-less replica (out of order)
        let restored = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let mut shuffled = blobs.clone();
        shuffled.reverse();
        restored.preload(&shuffled);
        let c2 = ModelPoolClient::connect(&[restored.addr.clone()]);
        assert_eq!(c2.get_latest(0).unwrap().unwrap().key.version, 7);
        for v in 0..8 {
            assert_eq!(
                c2.get(ModelKey::new(0, v)).unwrap().unwrap().params,
                vec![v as f32; 2000]
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Sharding contract: non-owners bounce writes with the current map
    /// piggybacked, serve-or-redirect reads, and the routed client lands
    /// on owners without ever being bounced.
    #[test]
    fn sharded_put_bounces_non_owner_and_reads_redirect() {
        let holder = Arc::new(MapHolder::new(shard::bootstrap_map(
            &["a".into(), "b".into(), "c".into()],
            1,
        )));
        let servers: Vec<ModelPoolServer> = (0..3)
            .map(|i| {
                ModelPoolServer::start_sharded(
                    "127.0.0.1:0",
                    PoolOptions::default(),
                    holder.clone(),
                    i,
                )
                .unwrap()
            })
            .collect();
        let agent = 5u32;
        let owner = holder.get().1.primary(agent).unwrap() as usize;
        let other = (owner + 1) % 3;
        let raw_owner = ReqClient::connect(&servers[owner].addr);
        let raw_other = ReqClient::connect(&servers[other].addr);
        // non-owner bounces the write, piggybacking the current map
        match raw_other.request(&Msg::PutModel(blob(agent, 1, 1.0))).unwrap() {
            Msg::WrongShard(map) => {
                assert_eq!(map.version, 1);
                assert_eq!(map.replicas.len(), 3);
            }
            o => panic!("expected WrongShard, got {o:?}"),
        }
        assert!(matches!(
            raw_owner.request(&Msg::PutModel(blob(agent, 1, 1.0))).unwrap(),
            Msg::Ok
        ));
        // reads: absent on a non-owner → redirect; present → served
        assert!(matches!(
            raw_other.request(&Msg::GetLatest { agent }).unwrap(),
            Msg::WrongShard(_)
        ));
        match raw_owner.request(&Msg::GetLatest { agent }).unwrap() {
            Msg::Model(b) => assert_eq!(b.key.version, 1),
            o => panic!("expected Model, got {o:?}"),
        }
        // replicas serve their map on request
        assert!(matches!(
            raw_owner.request(&Msg::GetShardMap).unwrap(),
            Msg::ShardMapMsg(_)
        ));
        // the routed client derives the same placement from the real
        // address list (index-keyed hashing) — writes go only to the
        // owner, reads find it, and the map never needed refreshing
        let addrs: Vec<String> =
            servers.iter().map(|s| s.addr.clone()).collect();
        let client = ModelPoolClient::connect_with(&addrs, 1);
        client.put(blob(agent, 2, 2.0)).unwrap();
        assert_eq!(client.get_latest(agent).unwrap().unwrap().key.version, 2);
        assert_eq!(servers[owner].model_count(), 2);
        for (i, s) in servers.iter().enumerate() {
            if i != owner {
                assert_eq!(s.model_count(), 0, "non-owner {i} stored data");
            }
        }
        assert_eq!(client.map_version(), 1, "no bounce should have occurred");
    }

    /// Satellite: a downed replica is remembered with a backoff expiry —
    /// routing skips it instead of re-attempting it on every read.
    #[test]
    fn dead_replica_backoff_remembers_downed_owner() {
        let mut s1 = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let s2 = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let client =
            ModelPoolClient::connect(&[s1.addr.clone(), s2.addr.clone()]);
        client.put(blob(0, 1, 1.0)).unwrap();
        s1.shutdown();
        std::thread::sleep(Duration::from_millis(400));
        // every read keeps succeeding; the first one that trips over the
        // dead replica marks it for the backoff window
        for _ in 0..16 {
            assert!(client.get(ModelKey::new(0, 1)).unwrap().is_some());
            if !client.dead_replica_indices().is_empty() {
                break;
            }
        }
        assert_eq!(client.dead_replica_indices(), vec![0]);
        // within the window the dead owner is skipped entirely: reads
        // route straight to the survivor
        let t0 = Instant::now();
        for _ in 0..10 {
            assert!(client.get(ModelKey::new(0, 1)).unwrap().is_some());
        }
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "reads under partition must not ride the reconnect ladder"
        );
    }

    fn pool_union(servers: &[ModelPoolServer], live: &[bool]) -> Vec<ModelBlob> {
        let mut all: Vec<ModelBlob> = Vec::new();
        for (i, s) in servers.iter().enumerate() {
            if live[i] {
                all.extend(s.all_blobs());
            }
        }
        all.sort_by_key(|b| b.key);
        all.dedup_by(|a, b| a.key == b.key);
        all
    }

    /// The `kill:pool` drill at the storage layer: with R=2, killing a
    /// replica and rebalancing leaves the survivors' union bit-exact
    /// with the pre-kill pool, stale-map clients keep reading
    /// successfully throughout, and a repeated pass moves zero bytes
    /// (the rev protocol answers NotModified).
    #[test]
    fn kill_pool_rebalance_is_bit_exact_and_converges() {
        let map0 = shard::bootstrap_map(
            &["a".into(), "b".into(), "c".into()],
            2,
        );
        let holder = Arc::new(MapHolder::new(map0.clone()));
        let mut servers: Vec<ModelPoolServer> = (0..3)
            .map(|i| {
                ModelPoolServer::start_sharded(
                    "127.0.0.1:0",
                    PoolOptions::default(),
                    holder.clone(),
                    i,
                )
                .unwrap()
            })
            .collect();
        let addrs: Vec<String> =
            servers.iter().map(|s| s.addr.clone()).collect();
        let client = ModelPoolClient::connect_with(&addrs, 2);
        for agent in 0..6u32 {
            client.put(frozen_blob(agent, 1, 64)).unwrap();
            client.put(blob(agent, 2, agent as f32)).unwrap();
        }
        let before = pool_union(&servers, &[true, true, true]);
        assert_eq!(before.len(), 12);
        // kill replica 2, publish the tombstoned map, rebalance
        servers[2].shutdown();
        std::thread::sleep(Duration::from_millis(400));
        let live = [true, true, false];
        let map1 = shard::without_replica(&map0, 2);
        assert!(holder.install(map1.clone()));
        let mv = rebalance(&servers, &live, &map0, &map1);
        assert!(mv.blobs_moved > 0, "victim's keys must change hands");
        // bit-exact: survivors' union equals the pre-kill pool
        assert_eq!(pool_union(&servers, &live), before);
        // the client still holds the v1 map; every read must keep
        // succeeding (surviving owners stayed owners), self-correcting
        // to the v2 map along the way
        for agent in 0..6u32 {
            let b = client.get_latest(agent).unwrap().unwrap();
            assert_eq!(b.key.version, 2);
            assert_eq!(b.params, vec![agent as f32; 8]);
            assert!(
                client.get(ModelKey::new(agent, 1)).unwrap().is_some(),
                "frozen history lost for agent {agent}"
            );
        }
        // a second pass over the same transition is a no-op
        let mv2 = rebalance(&servers, &live, &map0, &map1);
        assert_eq!(mv2.bytes_moved, 0, "second pass must move nothing");
        assert!(mv2.blobs_skipped > 0, "rev protocol must short-circuit");
    }
}
