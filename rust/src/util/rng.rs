//! PCG32 pseudo-random generator + sampling helpers.
//!
//! The offline crate set has no `rand`, so this is the project-wide RNG.
//! PCG-XSH-RR 64/32 (O'Neill 2014): tiny state, good statistical quality,
//! and — critically for reproducible league runs — explicit seeding
//! everywhere; no global RNG.

#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Raw generator state, for checkpointing a stream mid-run.
    pub fn state_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Self::state_parts`] output; the restored
    /// stream continues bit-exactly where the snapshotted one left off.
    pub fn from_state_parts(state: u64, inc: u64) -> Self {
        Pcg32 { state, inc }
    }

    /// Seed from an arbitrary string (used to derive per-module streams).
    pub fn from_label(seed: u64, label: &str) -> Self {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        Self::new(seed, h)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u32) as usize]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Falls back to uniform if all weights are ~0.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 1e-12 {
            return self.below(weights.len() as u32) as usize;
        }
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample an action index from a categorical distribution given logits
    /// (Gumbel-max; numerically robust, no normalization needed).
    pub fn sample_logits(&mut self, logits: &[f32]) -> usize {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &l) in logits.iter().enumerate() {
            let g = -(-(self.next_f32().max(1e-9).ln())).ln();
            let v = l + g;
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }
}

/// log-prob of `action` under softmax(logits); used by actors to record
/// behaviour-policy log-probs in trajectories.
pub fn log_softmax_at(logits: &[f32], action: usize) -> f32 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let z: f32 = logits.iter().map(|&l| (l - m).exp()).sum();
    logits[action] - m - z.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::new(42, 2);
        assert_ne!(a.next_u32(), c.next_u32());
    }

    #[test]
    fn state_parts_roundtrip_continues_stream() {
        let mut a = Pcg32::from_label(99, "ckpt");
        for _ in 0..17 {
            a.next_u32();
        }
        let (state, inc) = a.state_parts();
        let mut b = Pcg32::from_state_parts(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg32::new(7, 3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = rng.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_unit_interval() {
        let mut rng = Pcg32::new(1, 1);
        for _ in 0..1000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn weighted_respects_weights() {
        let mut rng = Pcg32::new(5, 5);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[rng.weighted(&[1.0, 0.0, 3.0])] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }

    #[test]
    fn sample_logits_prefers_high_logit() {
        let mut rng = Pcg32::new(9, 1);
        let mut hits = 0;
        for _ in 0..1000 {
            if rng.sample_logits(&[0.0, 5.0, 0.0]) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 900, "{hits}");
    }

    #[test]
    fn log_softmax_normalizes() {
        let logits = [0.3f32, -1.0, 2.0];
        let total: f32 = (0..3).map(|a| log_softmax_at(&logits, a).exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(11, 4);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.1, "{var}");
    }
}
