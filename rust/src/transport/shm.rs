//! Shared-memory local lanes: an mmap-backed SPSC byte ring per
//! direction, carrying the exact frames the TCP transport carries
//! (`[u32 len][Wire payload]`), so a colocated actor↔inf-server pair can
//! exchange multi-row `InferReq`/`InferResp` without touching the
//! kernel, while staying bit-compatible with the TCP lane.
//!
//! Ring file layout (little-endian, 64-byte header, data after):
//!   @0  magic        u64  — format guard
//!   @8  capacity     u64  — data bytes, power of two
//!   @16 head         u64  — free-running write cursor (producer owns)
//!   @24 tail         u64  — free-running read cursor (consumer owns)
//!   @32 writer_beat  u64  — producer liveness counter
//!   @40 reader_beat  u64  — consumer liveness counter
//!   @48 closed       u32  — either side sets on orderly teardown
//!   @64 data[capacity]
//!
//! Records are byte-granular: `[u32 len][len bytes]` written modulo the
//! capacity mask, wrapping mid-record when needed.  `head`/`tail` are
//! free-running (never wrapped), so `head - tail` is the used byte
//! count; Release stores on the cursor publish the copied bytes to the
//! Acquire load on the other side.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::time::{Duration, Instant};

const RING_MAGIC: u64 = 0x544c_475f_5348_4d31; // "TLG_SHM1"
const HDR: usize = 64;
const OFF_CAP: usize = 8;
const OFF_HEAD: usize = 16;
const OFF_TAIL: usize = 24;
const OFF_WBEAT: usize = 32;
const OFF_RBEAT: usize = 40;
const OFF_CLOSED: usize = 48;

/// Per-direction ring capacity for negotiated lanes.  Frames that do
/// not fit (minus the 4-byte record header) fall back to TCP per-op.
pub const LANE_CAPACITY: usize = 4 << 20;

/// How long a peer's heartbeat word may sit still — while we are
/// actively blocked on its progress — before the lane is declared dead.
pub const STALE_DEADLINE: Duration = Duration::from_secs(5);

#[cfg(unix)]
extern "C" {
    fn mmap(
        addr: *mut u8,
        len: usize,
        prot: i32,
        flags: i32,
        fd: i32,
        offset: i64,
    ) -> *mut u8;
    fn munmap(addr: *mut u8, len: usize) -> i32;
}

const PROT_READ: i32 = 1;
const PROT_WRITE: i32 = 2;
const MAP_SHARED: i32 = 1;

/// One direction of a lane.  Exactly one producer and one consumer
/// process/thread; both sides map the same file.
pub struct ShmRing {
    base: *mut u8,
    map_len: usize,
    cap: u64,
    mask: u64,
    /// Set on the creating side: the file is unlinked when that side
    /// drops the ring (the attached side keeps its mapping alive).
    unlink: Option<PathBuf>,
}

// SAFETY: the raw pointer is to a MAP_SHARED region that stays mapped
// for the ring's lifetime; all cross-thread access goes through the
// atomic header words and the Release/Acquire cursor protocol above.
unsafe impl Send for ShmRing {}
// SAFETY: see Send — &self methods only touch the mapping via atomics
// or inside the cursor-protocol exclusive windows.
unsafe impl Sync for ShmRing {}

impl ShmRing {
    fn map(path: &Path, len: usize) -> Result<*mut u8> {
        use std::os::unix::io::AsRawFd;
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("open ring {}", path.display()))?;
        // SAFETY: mmap with a null hint maps `len` bytes of the open
        // file; arguments are plain values and the fd outlives the
        // call.  The result is validated before use below.
        let base = unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                PROT_READ | PROT_WRITE,
                MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if base as isize == -1 || base.is_null() {
            bail!(
                "mmap {} ({len} bytes): {}",
                path.display(),
                std::io::Error::last_os_error()
            );
        }
        Ok(base)
    }

    /// Create + size + map a fresh ring file.  `capacity` is rounded up
    /// to a power of two.
    pub fn create(path: &Path, capacity: usize) -> Result<ShmRing> {
        let cap = capacity.max(4096).next_power_of_two();
        let map_len = HDR + cap;
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .with_context(|| format!("create ring {}", path.display()))?;
        file.set_len(map_len as u64)
            .with_context(|| format!("size ring {}", path.display()))?;
        drop(file);
        let base = Self::map(path, map_len)?;
        let ring = ShmRing {
            base,
            map_len,
            cap: cap as u64,
            mask: cap as u64 - 1,
            unlink: Some(path.to_path_buf()),
        };
        ring.at_u64(OFF_CAP).store(cap as u64, Ordering::Relaxed);
        // magic last, Release: an attacher that sees it sees the header
        ring.at_u64(0).store(RING_MAGIC, Ordering::Release);
        Ok(ring)
    }

    /// Map a ring created by the peer.
    pub fn attach(path: &Path) -> Result<ShmRing> {
        let meta = std::fs::metadata(path)
            .with_context(|| format!("stat ring {}", path.display()))?;
        let map_len = meta.len() as usize;
        if map_len <= HDR {
            bail!("ring {} too small ({map_len} bytes)", path.display());
        }
        let base = Self::map(path, map_len)?;
        let ring = ShmRing {
            base,
            map_len,
            cap: 0,
            mask: 0,
            unlink: None,
        };
        if ring.at_u64(0).load(Ordering::Acquire) != RING_MAGIC {
            bail!("ring {}: bad magic", path.display());
        }
        let cap = ring.at_u64(OFF_CAP).load(Ordering::Relaxed);
        if !cap.is_power_of_two() || map_len != HDR + cap as usize {
            bail!("ring {}: corrupt capacity {cap}", path.display());
        }
        let mut ring = ring;
        ring.cap = cap;
        ring.mask = cap - 1;
        Ok(ring)
    }

    fn at_u64(&self, off: usize) -> &AtomicU64 {
        // SAFETY: `off` is one of the 8-aligned header offsets inside
        // the 64-byte header; the mapping outlives &self, and shared
        // mutation is done by the kernel/peer only through atomics.
        unsafe { &*(self.base.add(off) as *const AtomicU64) }
    }

    fn at_u32(&self, off: usize) -> &AtomicU32 {
        // SAFETY: same as `at_u64` — aligned header word, live mapping.
        unsafe { &*(self.base.add(off) as *const AtomicU32) }
    }

    fn data(&self) -> *mut u8 {
        // SAFETY: HDR is within the mapping (map_len = HDR + cap,
        // validated at open/create).
        unsafe { self.base.add(HDR) }
    }

    /// Copy `src` into the ring at free-running offset `at`, wrapping.
    fn copy_in(&self, at: u64, src: &[u8]) {
        let off = (at & self.mask) as usize;
        let first = src.len().min(self.cap as usize - off);
        // SAFETY: both chunks stay inside [data, data+cap) by
        // construction (`off < cap`, `first <= cap - off`); writers
        // hold the exclusive producer window granted by the cursor
        // protocol, so ranges never overlap live reader bytes.
        unsafe {
            std::ptr::copy_nonoverlapping(src.as_ptr(), self.data().add(off), first);
            if first < src.len() {
                std::ptr::copy_nonoverlapping(
                    src.as_ptr().add(first),
                    self.data(),
                    src.len() - first,
                );
            }
        }
    }

    /// Copy out of the ring at free-running offset `at`, wrapping.
    fn copy_out(&self, at: u64, dst: &mut [u8]) {
        let off = (at & self.mask) as usize;
        let first = dst.len().min(self.cap as usize - off);
        // SAFETY: mirror of `copy_in` — in-bounds chunks inside the
        // consumer's exclusive window, into a caller-owned buffer.
        unsafe {
            std::ptr::copy_nonoverlapping(self.data().add(off), dst.as_mut_ptr(), first);
            if first < dst.len() {
                std::ptr::copy_nonoverlapping(
                    self.data(),
                    dst.as_mut_ptr().add(first),
                    dst.len() - first,
                );
            }
        }
    }

    /// Max payload a single record can carry in this ring.
    pub fn max_payload(&self) -> usize {
        self.cap as usize - 4
    }

    /// Try to append one `[len][payload]` record.  `Ok(false)` = ring
    /// full (writer-faster-than-reader backpressure); `Err` only when
    /// the payload can never fit.
    pub fn try_write_frame(&self, payload: &[u8]) -> Result<bool> {
        self.try_write_frame_parts(&[payload])
    }

    /// [`try_write_frame`](Self::try_write_frame) from scattered parts
    /// (a `Reply::Framed` head + shared tail) without a staging concat.
    // lint: nonblocking
    pub fn try_write_frame_parts(&self, parts: &[&[u8]]) -> Result<bool> {
        let total: usize = parts.iter().map(|p| p.len()).sum();
        let rec = total as u64 + 4;
        if rec > self.cap {
            bail!("frame of {total} bytes exceeds ring capacity {}", self.cap);
        }
        let head = self.at_u64(OFF_HEAD).load(Ordering::Relaxed);
        let tail = self.at_u64(OFF_TAIL).load(Ordering::Acquire);
        if self.cap - (head - tail) < rec {
            return Ok(false);
        }
        self.copy_in(head, &(total as u32).to_le_bytes());
        let mut at = head + 4;
        for p in parts {
            self.copy_in(at, p);
            at += p.len() as u64;
        }
        self.at_u64(OFF_HEAD).store(head + rec, Ordering::Release);
        Ok(true)
    }

    /// Try to pop one record into `buf`.  `Ok(false)` = ring empty.
    // lint: nonblocking
    pub fn try_read_frame(&self, buf: &mut Vec<u8>) -> Result<bool> {
        let tail = self.at_u64(OFF_TAIL).load(Ordering::Relaxed);
        let head = self.at_u64(OFF_HEAD).load(Ordering::Acquire);
        if head == tail {
            return Ok(false);
        }
        let avail = head - tail;
        if avail < 4 {
            bail!("ring corrupt: {avail} bytes available, need a 4-byte header");
        }
        let mut len_bytes = [0u8; 4];
        self.copy_out(tail, &mut len_bytes);
        let len = u32::from_le_bytes(len_bytes) as u64;
        if len + 4 > avail || len + 4 > self.cap {
            bail!("ring corrupt: record claims {len} bytes, {avail} available");
        }
        buf.resize(len as usize, 0);
        self.copy_out(tail + 4, buf);
        self.at_u64(OFF_TAIL).store(tail + 4 + len, Ordering::Release);
        Ok(true)
    }

    pub fn beat_writer(&self) {
        self.at_u64(OFF_WBEAT).fetch_add(1, Ordering::Relaxed);
    }
    pub fn beat_reader(&self) {
        self.at_u64(OFF_RBEAT).fetch_add(1, Ordering::Relaxed);
    }
    pub fn writer_beat(&self) -> u64 {
        self.at_u64(OFF_WBEAT).load(Ordering::Relaxed)
    }
    pub fn reader_beat(&self) -> u64 {
        self.at_u64(OFF_RBEAT).load(Ordering::Relaxed)
    }

    pub fn set_closed(&self) {
        self.at_u32(OFF_CLOSED).store(1, Ordering::Release);
    }
    pub fn is_closed(&self) -> bool {
        self.at_u32(OFF_CLOSED).load(Ordering::Acquire) != 0
    }
}

impl Drop for ShmRing {
    fn drop(&mut self) {
        // SAFETY: unmaps the exact region this ring mapped; &mut self
        // guarantees no outstanding borrows of the mapping.
        unsafe {
            munmap(self.base, self.map_len);
        }
        if let Some(p) = self.unlink.take() {
            let _ = std::fs::remove_file(p);
        }
    }
}

/// Crash detection: a heartbeat word is stale when it has not advanced
/// for longer than `timeout` while we were actively watching it.  Only
/// consulted while blocked on peer progress — an idle-but-alive peer is
/// never declared dead, because nobody is watching it.
pub struct BeatWatch {
    last: u64,
    since: Instant,
}

impl BeatWatch {
    pub fn new(initial: u64) -> BeatWatch {
        BeatWatch { last: initial, since: Instant::now() }
    }

    /// Feed the current beat value; true once it has sat unchanged past
    /// `timeout`.
    pub fn stale(&mut self, beat: u64, timeout: Duration) -> bool {
        if beat != self.last {
            self.last = beat;
            self.since = Instant::now();
            return false;
        }
        self.since.elapsed() > timeout
    }
}

/// A bidirectional lane: `tx` is the ring this side writes, `rx` the
/// ring it reads.  The client creates both files (`<base>.c2s`,
/// `<base>.s2c`) and sends the base path in `Msg::ShmHello`; the server
/// attaches with the directions swapped.
pub struct ShmLane {
    pub tx: ShmRing,
    pub rx: ShmRing,
}

static LANE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Directory lane files go in: `/dev/shm` when present (Linux tmpfs —
/// the whole point is staying off the disk), else the OS temp dir.
pub fn default_dir() -> PathBuf {
    let shm = PathBuf::from("/dev/shm");
    if shm.is_dir() {
        shm
    } else {
        std::env::temp_dir()
    }
}

impl ShmLane {
    /// Client side: create both rings, return the lane and the base
    /// path to send in the hello.
    pub fn create(dir: &Path, capacity: usize) -> Result<(ShmLane, String)> {
        let n = LANE_SEQ.fetch_add(1, Ordering::Relaxed);
        let base = dir.join(format!("tleague-lane-{}-{n}", std::process::id()));
        let base_str = base
            .to_str()
            .with_context(|| format!("non-utf8 lane path {}", base.display()))?
            .to_string();
        let tx = ShmRing::create(&base.with_extension("c2s"), capacity)?;
        let rx = ShmRing::create(&base.with_extension("s2c"), capacity)?;
        Ok((ShmLane { tx, rx }, base_str))
    }

    /// Server side: attach to a client-created lane (directions swap).
    pub fn attach(base: &str) -> Result<ShmLane> {
        let base = PathBuf::from(base);
        let tx = ShmRing::attach(&base.with_extension("s2c"))?;
        let rx = ShmRing::attach(&base.with_extension("c2s"))?;
        Ok(ShmLane { tx, rx })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring(cap: usize) -> ShmRing {
        let n = LANE_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir()
            .join(format!("tleague-ringtest-{}-{n}", std::process::id()));
        ShmRing::create(&path, cap).unwrap()
    }

    /// Frames survive many laps of the cursor, including records that
    /// straddle the wrap point, byte-for-byte.
    #[test]
    #[cfg_attr(miri, ignore = "file-backed mmap FFI is outside Miri's model")]
    fn wraparound_preserves_frames() {
        let r = ring(4096); // real capacity: 4096
        let mut buf = Vec::new();
        let mut seq = 0u32;
        // total traffic ≫ capacity with coprime-ish sizes forces many
        // wrap-straddling records
        for round in 0..200 {
            let size = 1 + (round * 37) % 977;
            let payload: Vec<u8> =
                (0..size).map(|i| ((seq as usize + i) % 251) as u8).collect();
            assert!(r.try_write_frame(&payload).unwrap(), "round {round}");
            assert!(r.try_read_frame(&mut buf).unwrap());
            assert_eq!(buf, payload, "round {round}");
            seq = seq.wrapping_add(1);
        }
    }

    /// Writer-faster-than-reader: the ring refuses writes when full and
    /// accepts again after a drain, never overwriting unread data.
    #[test]
    #[cfg_attr(miri, ignore = "file-backed mmap FFI is outside Miri's model")]
    fn full_ring_applies_backpressure() {
        let r = ring(4096);
        let payload = [7u8; 1000]; // 1004-byte records
        let mut accepted = 0;
        while r.try_write_frame(&payload).unwrap() {
            accepted += 1;
            assert!(accepted < 100, "ring never reported full");
        }
        assert_eq!(accepted, 4); // 4 × 1004 ≤ 4096 < 5 × 1004
        let mut buf = Vec::new();
        assert!(r.try_read_frame(&mut buf).unwrap());
        assert_eq!(buf, payload);
        assert!(r.try_write_frame(&payload).unwrap(), "drain frees space");
        // unread frames are intact after the backpressure episode
        for _ in 0..4 {
            assert!(r.try_read_frame(&mut buf).unwrap());
            assert_eq!(buf, payload);
        }
        assert!(!r.try_read_frame(&mut buf).unwrap());
    }

    /// One-side-crash detection: a beat that keeps advancing is never
    /// stale; a frozen beat is, once the deadline passes.
    #[test]
    #[cfg_attr(miri, ignore = "file-backed mmap FFI is outside Miri's model")]
    fn stale_heartbeat_detected() {
        let r = ring(4096);
        let timeout = Duration::from_millis(40);
        let mut watch = BeatWatch::new(r.writer_beat());
        for _ in 0..5 {
            r.beat_writer();
            assert!(!watch.stale(r.writer_beat(), timeout));
            std::thread::sleep(Duration::from_millis(15));
        }
        // peer "crashes": beat stops advancing
        let t0 = Instant::now();
        let mut stale = false;
        while t0.elapsed() < Duration::from_secs(2) {
            if watch.stale(r.writer_beat(), timeout) {
                stale = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(stale, "frozen heartbeat never went stale");
    }

    /// A payload that can never fit errors instead of blocking forever;
    /// the closed flag crosses the mapping.
    #[test]
    #[cfg_attr(miri, ignore = "file-backed mmap FFI is outside Miri's model")]
    fn oversize_rejected_and_close_flag_crosses() {
        let r = ring(4096);
        assert!(r.try_write_frame(&[0u8; 8192]).is_err());
        assert!(!r.is_closed());
        r.set_closed();
        assert!(r.is_closed());
    }

    /// Lane plumbing: attach sees create's rings with directions
    /// swapped, and frames cross between the two mappings.
    #[test]
    #[cfg_attr(miri, ignore = "file-backed mmap FFI is outside Miri's model")]
    fn lane_create_attach_roundtrip() {
        let (client, base) =
            ShmLane::create(&std::env::temp_dir(), 4096).unwrap();
        let server = ShmLane::attach(&base).unwrap();
        let mut buf = Vec::new();
        assert!(client.tx.try_write_frame(b"request").unwrap());
        assert!(server.rx.try_read_frame(&mut buf).unwrap());
        assert_eq!(buf, b"request");
        assert!(server.tx.try_write_frame(b"reply").unwrap());
        assert!(client.rx.try_read_frame(&mut buf).unwrap());
        assert_eq!(buf, b"reply");
    }
}
