"""Pallas kernel: fused PPO loss terms, forward AND backward.

The PPO surrogate is the learner's per-sample hot spot outside the matmuls:
the naive jnp version makes ~6 HBM round trips over [N, A] / [N] streams
(log-softmax, gather, ratio, clip, entropy, value loss).  This kernel fuses
them into a single pass.  Because ``pallas_call`` is not differentiable,
the backward pass is a second hand-derived kernel wired up via
``jax.custom_vjp`` and validated against the jnp autodiff oracle in
python/tests/test_ppo_kernel.py.

Derivatives (per sample i, logits l, probs p, logp_all lp, entropy H):
  d pol/d logp   = -ratio * adv   if unclipped branch active, else 0
  d logp/d l_j   = onehot_j - p_j
  d H/d l_j      = -p_j (lp_j + H)
  d vloss/d v    = v - ret
approx_kl is emitted as a statistic only (no gradient contribution).

Tiling: grid over N = T*B sample tiles; each block holds [N_TILE, A] logits
in VMEM (A <= 16 for every env spec, so a 128-row tile is 8 KiB).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_N_TILE = 128


def _log_softmax(logits):
    m = jnp.max(logits, axis=-1, keepdims=True)
    z = jnp.sum(jnp.exp(logits - m), axis=-1, keepdims=True)
    return logits - (m + jnp.log(z))


def _fwd_kernel(clip_ref, logits_ref, act_ref, lpo_ref, adv_ref,
                val_ref, ret_ref, pol_ref, vl_ref, ent_ref, kl_ref):
    clip_eps = clip_ref[0, 0]
    logits = logits_ref[...]                    # [Nt, A]
    a = act_ref[...]                            # [Nt, 1] int32
    lp_all = _log_softmax(logits)
    A = logits.shape[1]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
              == a).astype(jnp.float32)
    lp = jnp.sum(onehot * lp_all, axis=1, keepdims=True)
    lpo = lpo_ref[...]
    adv = adv_ref[...]
    ratio = jnp.exp(lp - lpo)
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
    s1 = ratio * adv
    s2 = clipped * adv
    pol_ref[...] = -jnp.minimum(s1, s2)
    v = val_ref[...]
    r = ret_ref[...]
    vl_ref[...] = 0.5 * (v - r) * (v - r)
    p = jnp.exp(lp_all)
    ent_ref[...] = -jnp.sum(p * lp_all, axis=1, keepdims=True)
    kl_ref[...] = lpo - lp


def _bwd_kernel(clip_ref, logits_ref, act_ref, lpo_ref, adv_ref,
                val_ref, ret_ref, gp_ref, gv_ref, ge_ref,
                dlogits_ref, dval_ref):
    clip_eps = clip_ref[0, 0]
    logits = logits_ref[...]
    a = act_ref[...]
    lp_all = _log_softmax(logits)
    p = jnp.exp(lp_all)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
              == a).astype(jnp.float32)
    lp = jnp.sum(onehot * lp_all, axis=1, keepdims=True)
    lpo = lpo_ref[...]
    adv = adv_ref[...]
    ratio = jnp.exp(lp - lpo)
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
    s1 = ratio * adv
    s2 = clipped * adv
    # pol = -min(s1, s2); unclipped branch iff s1 <= s2 (ties equal-valued).
    g_lp_pol = jnp.where(s1 <= s2, -ratio * adv, 0.0)    # [Nt, 1]
    gp = gp_ref[...]
    ge = ge_ref[...]
    ent = -jnp.sum(p * lp_all, axis=1, keepdims=True)
    dlogits = (gp * g_lp_pol) * (onehot - p) \
        + ge * (-p * (lp_all + ent))
    dlogits_ref[...] = dlogits
    dval_ref[...] = gv_ref[...] * (val_ref[...] - ret_ref[...])


def _pad_rows(x, n_pad):
    return jnp.pad(x, ((0, n_pad), (0, 0)))


def _col(x):
    return x.reshape(-1, 1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(7,))
def ppo_terms_pallas(logits, actions, logp_old, adv, value, ret, clip_eps,
                     n_tile=DEFAULT_N_TILE):
    """Fused per-sample PPO terms (Pallas). Same contract as ref.ppo_terms_ref.

    Differentiable w.r.t. ``logits`` and ``value`` only (the rest are
    treated as constants, matching PPO where adv/ret/logp_old carry
    stop-gradient semantics).
    """
    out = _ppo_fwd_impl(logits, actions, logp_old, adv, value, ret,
                        clip_eps, n_tile)
    return out


def _ppo_fwd_impl(logits, actions, logp_old, adv, value, ret, clip_eps,
                  n_tile):
    N, A = logits.shape
    nt = min(n_tile, N)
    pad = (nt - N % nt) % nt
    logits_p = _pad_rows(logits, pad)
    act_p = _pad_rows(_col(actions).astype(jnp.int32), pad)
    lpo_p = _pad_rows(_col(logp_old), pad)
    adv_p = _pad_rows(_col(adv), pad)
    val_p = _pad_rows(_col(value), pad)
    ret_p = _pad_rows(_col(ret), pad)
    np_ = N + pad
    clip_arr = jnp.asarray(clip_eps, jnp.float32).reshape(1, 1)
    vec = pl.BlockSpec((nt, 1), lambda i: (i, 0))
    mat = pl.BlockSpec((nt, A), lambda i: (i, 0))
    smem = pl.BlockSpec((1, 1), lambda i: (0, 0))
    pol, vl, ent, kl = pl.pallas_call(
        _fwd_kernel,
        grid=(np_ // nt,),
        in_specs=[smem, mat, vec, vec, vec, vec, vec],
        out_specs=[vec, vec, vec, vec],
        out_shape=[jax.ShapeDtypeStruct((np_, 1), jnp.float32)] * 4,
        interpret=True,
    )(clip_arr, logits_p, act_p, lpo_p, adv_p, val_p, ret_p)
    return (pol[:N, 0], vl[:N, 0], ent[:N, 0], kl[:N, 0])


def _ppo_vjp_fwd(logits, actions, logp_old, adv, value, ret, clip_eps,
                 n_tile):
    out = _ppo_fwd_impl(logits, actions, logp_old, adv, value, ret,
                        clip_eps, n_tile)
    res = (logits, actions, logp_old, adv, value, ret, clip_eps)
    return out, res


def _ppo_vjp_bwd(n_tile, res, cots):
    logits, actions, logp_old, adv, value, ret, clip_eps = res
    g_pol, g_vl, g_ent, _g_kl = cots   # approx_kl: statistic only, no grad
    N, A = logits.shape
    nt = min(n_tile, N)
    pad = (nt - N % nt) % nt
    logits_p = _pad_rows(logits, pad)
    act_p = _pad_rows(_col(actions).astype(jnp.int32), pad)
    lpo_p = _pad_rows(_col(logp_old), pad)
    adv_p = _pad_rows(_col(adv), pad)
    val_p = _pad_rows(_col(value), pad)
    ret_p = _pad_rows(_col(ret), pad)
    gp_p = _pad_rows(_col(g_pol), pad)
    gv_p = _pad_rows(_col(g_vl), pad)
    ge_p = _pad_rows(_col(g_ent), pad)
    np_ = N + pad
    clip_arr = jnp.asarray(clip_eps, jnp.float32).reshape(1, 1)
    vec = pl.BlockSpec((nt, 1), lambda i: (i, 0))
    mat = pl.BlockSpec((nt, A), lambda i: (i, 0))
    smem = pl.BlockSpec((1, 1), lambda i: (0, 0))
    dlogits, dval = pl.pallas_call(
        _bwd_kernel,
        grid=(np_ // nt,),
        in_specs=[smem, mat, vec, vec, vec, vec, vec, vec, vec, vec],
        out_specs=[mat, vec],
        out_shape=[jax.ShapeDtypeStruct((np_, A), jnp.float32),
                   jax.ShapeDtypeStruct((np_, 1), jnp.float32)],
        interpret=True,
    )(clip_arr, logits_p, act_p, lpo_p, adv_p, val_p, ret_p,
      gp_p, gv_p, ge_p)
    zeros = jnp.zeros_like
    return (dlogits[:N], zeros(actions), zeros(logp_old), zeros(adv),
            dval[:N, 0], zeros(ret), jnp.zeros(()))


ppo_terms_pallas.defvjp(_ppo_vjp_fwd, _ppo_vjp_bwd)
