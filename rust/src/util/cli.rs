//! Minimal command-line parser (no clap in the offline crate set).
//!
//! Supports `program <subcommand> --flag value --bool-flag pos1 pos2`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse argv[1..]; the first non-flag token becomes the subcommand.
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("actor --env pommerman --replicas 4 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("actor"));
        assert_eq!(a.get("env"), Some("pommerman"));
        assert_eq!(a.usize_or("replicas", 1), 4);
        assert!(a.bool("verbose"));
    }

    #[test]
    fn equals_form_and_positional() {
        let a = parse("eval --games=10 file1 file2");
        assert_eq!(a.usize_or("games", 0), 10);
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.f64_or("lr", 3e-4), 3e-4);
        assert_eq!(a.str_or("mode", "thread"), "thread");
        assert!(!a.bool("missing"));
    }
}
