"""Pallas kernel: Generalized Advantage Estimation reverse scan.

The scan is sequential in T and embarrassingly parallel in B, so the grid
tiles the batch dimension: each program instance owns a [T, B_TILE] block
held entirely in VMEM and runs the reverse recurrence in registers.

TPU sizing (DESIGN.md "Hardware adaptation"): with T=16, B_TILE=128 the
working set is 4 arrays x 16x128 x 4B = 32 KiB, far below the ~16 MiB VMEM
budget; the kernel is bandwidth-bound (element-wise, MXU idle) and its win
over the jnp reference is fusing the reward/discount/value streams into a
single HBM pass instead of one per scan step.

Runs with interpret=True on CPU (Mosaic custom-calls are TPU-only).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_B_TILE = 128


def _gae_kernel(lam_ref, rew_ref, disc_ref, val_ref, adv_ref):
    # Blocks: rew/disc/adv [T, Bt]; val [T+1, Bt]; lam [1, 1].
    T = rew_ref.shape[0]
    lam = lam_ref[0, 0]

    def body(i, acc):
        t = T - 1 - i
        rew = pl.load(rew_ref, (pl.ds(t, 1), slice(None)))
        disc = pl.load(disc_ref, (pl.ds(t, 1), slice(None)))
        v_t = pl.load(val_ref, (pl.ds(t, 1), slice(None)))
        v_tp1 = pl.load(val_ref, (pl.ds(t + 1, 1), slice(None)))
        delta = rew + disc * v_tp1 - v_t
        acc = delta + disc * lam * acc
        pl.store(adv_ref, (pl.ds(t, 1), slice(None)), acc)
        return acc

    acc0 = jnp.zeros((1, rew_ref.shape[1]), jnp.float32)
    jax.lax.fori_loop(0, T, body, acc0)


@functools.partial(jax.jit, static_argnames=("b_tile",))
def gae_pallas(rewards, discounts, values, lam, b_tile=DEFAULT_B_TILE):
    """GAE advantages via the Pallas kernel.

    Args:
      rewards, discounts: [T, B] f32 (discounts = gamma * (1 - done)).
      values: [T+1, B] f32 (last row = bootstrap value).
      lam: scalar f32 (traced; runtime-tunable by the HyperMgr).
      b_tile: batch tile width (static).
    Returns advantages [T, B] f32.
    """
    T, B = rewards.shape
    bt = min(b_tile, B)
    if B % bt != 0:  # pad batch to a tile multiple, strip after
        pad = bt - B % bt
        rewards = jnp.pad(rewards, ((0, 0), (0, pad)))
        discounts = jnp.pad(discounts, ((0, 0), (0, pad)))
        values = jnp.pad(values, ((0, 0), (0, pad)))
    bp = rewards.shape[1]
    lam_arr = jnp.asarray(lam, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _gae_kernel,
        grid=(bp // bt,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((T, bt), lambda i: (0, i)),
            pl.BlockSpec((T, bt), lambda i: (0, i)),
            pl.BlockSpec((T + 1, bt), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((T, bt), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((T, bp), jnp.float32),
        interpret=True,
    )(lam_arr, rewards, discounts, values)
    return out[:, :B]
