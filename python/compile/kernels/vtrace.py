"""Pallas kernel: V-trace targets + policy-gradient advantages (IMPALA).

Same tiling strategy as gae.py: grid over batch tiles, reverse recurrence
over T inside the kernel.  Two outputs are produced in one pass: the value
targets vs_t and the policy-gradient advantages

    vs_t     = V_t + delta_t + disc_t * c_t * (vs_{t+1} - V_{t+1})
    delta_t  = rho_t * (r_t + disc_t * V_{t+1} - V_t)
    pg_adv_t = rho_t * (r_t + disc_t * vs_{t+1} - V_t)

with rho_t = min(rho_bar, e^{log_rho_t}) and c_t = lam * min(c_bar, e^{log_rho_t}).
The recurrence carries acc = vs_{t+1} - V_{t+1}, from which vs_{t+1} is
reconstructed for the pg term, so values are read once per step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_B_TILE = 128


def _vtrace_kernel(hp_ref, lrho_ref, rew_ref, disc_ref, val_ref,
                   vs_ref, pg_ref):
    # Blocks: lrho/rew/disc/vs/pg [T, Bt]; val [T+1, Bt]; hp [1, 3]=(lam, rho_bar, c_bar)
    T = rew_ref.shape[0]
    lam = hp_ref[0, 0]
    rho_bar = hp_ref[0, 1]
    c_bar = hp_ref[0, 2]

    def body(i, acc):
        t = T - 1 - i
        lrho = pl.load(lrho_ref, (pl.ds(t, 1), slice(None)))
        rew = pl.load(rew_ref, (pl.ds(t, 1), slice(None)))
        disc = pl.load(disc_ref, (pl.ds(t, 1), slice(None)))
        v_t = pl.load(val_ref, (pl.ds(t, 1), slice(None)))
        v_tp1 = pl.load(val_ref, (pl.ds(t + 1, 1), slice(None)))
        rho = jnp.minimum(rho_bar, jnp.exp(lrho))
        c = lam * jnp.minimum(c_bar, jnp.exp(lrho))
        delta = rho * (rew + disc * v_tp1 - v_t)
        # acc (incoming) = vs_{t+1} - V_{t+1}
        vs_tp1 = acc + v_tp1
        pg = rho * (rew + disc * vs_tp1 - v_t)
        acc = delta + disc * c * acc           # now vs_t - V_t
        pl.store(vs_ref, (pl.ds(t, 1), slice(None)), acc + v_t)
        pl.store(pg_ref, (pl.ds(t, 1), slice(None)), pg)
        return acc

    acc0 = jnp.zeros((1, rew_ref.shape[1]), jnp.float32)
    jax.lax.fori_loop(0, T, body, acc0)


@functools.partial(jax.jit, static_argnames=("b_tile",))
def vtrace_pallas(log_rhos, rewards, discounts, values, lam, rho_bar, c_bar,
                  b_tile=DEFAULT_B_TILE):
    """V-trace (vs, pg_adv) via the Pallas kernel; all seq args time-major.

    log_rhos/rewards/discounts: [T, B]; values: [T+1, B];
    lam/rho_bar/c_bar: scalars (traced).  Returns (vs [T,B], pg_adv [T,B]).
    """
    T, B = rewards.shape
    bt = min(b_tile, B)
    if B % bt != 0:
        pad = bt - B % bt
        log_rhos = jnp.pad(log_rhos, ((0, 0), (0, pad)))
        rewards = jnp.pad(rewards, ((0, 0), (0, pad)))
        discounts = jnp.pad(discounts, ((0, 0), (0, pad)))
        values = jnp.pad(values, ((0, 0), (0, pad)))
    bp = rewards.shape[1]
    hp = jnp.stack([jnp.asarray(lam, jnp.float32),
                    jnp.asarray(rho_bar, jnp.float32),
                    jnp.asarray(c_bar, jnp.float32)]).reshape(1, 3)
    vs, pg = pl.pallas_call(
        _vtrace_kernel,
        grid=(bp // bt,),
        in_specs=[
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
            pl.BlockSpec((T, bt), lambda i: (0, i)),
            pl.BlockSpec((T, bt), lambda i: (0, i)),
            pl.BlockSpec((T, bt), lambda i: (0, i)),
            pl.BlockSpec((T + 1, bt), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((T, bt), lambda i: (0, i)),
            pl.BlockSpec((T, bt), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, bp), jnp.float32),
            jax.ShapeDtypeStruct((T, bp), jnp.float32),
        ],
        interpret=True,
    )(hp, log_rhos, rewards, discounts, values)
    return vs[:, :B], pg[:, :B]
