//! Message transport: the ZeroMQ-substitute (§3.3 of the paper).
//!
//! Three socket patterns TLeague uses, over length-prefixed TCP frames:
//!   - REQ/REP  — task requests, ModelPool read/write (`ReqClient`/`RepServer`)
//!   - PUSH/PULL — actor→learner trajectory streaming (`PushClient`/`PullServer`)
//!   - (PUB/SUB is folded into REQ/REP polling: ModelPool reads are cheap)
//!
//! Frame format: u32 little-endian length + payload (a `Wire`-encoded
//! `Msg`).  Servers run a readiness-driven epoll core (`poll`): a small
//! fixed pool of event-loop threads owns all connections on nonblocking
//! sockets, so per-connection cost is O(buffers), not an 8 MB thread
//! stack.  An eventfd per loop makes shutdown and cross-thread reply
//! injection immediate.  Colocated peers can negotiate a shared-memory
//! lane (`shm`): one mmap-backed SPSC ring per direction carrying the
//! same encoded frames, bit-compatible with the TCP path.

pub mod fault;
pub mod poll;
pub mod shm;

use crate::proto::Msg;
use crate::util::codec::Wire;
use crate::util::metrics::Meter;
use crate::util::sync::lock_recover;
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{IoSlice, Read, Write as IoWrite};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub const MAX_FRAME: u32 = 512 << 20; // 512 MiB guard (synthetic params are 25 MiB)

/// How long a frame that has STARTED arriving may stall before the
/// connection is declared dead (see `read_frame` and the event loop's
/// stall sweep).
const FRAME_STALL_DEADLINE: Duration = Duration::from_secs(30);

/// Reserved event-loop tokens (connection tokens count up from 0).
const TOK_WAKE: u64 = u64::MAX;
const TOK_LISTENER: u64 = u64::MAX - 1;

/// Write one length-prefixed frame assembled from `parts` — a single
/// vectored syscall in the common case, so a pre-encoded reply frame
/// (the ModelPool's cached `Arc<[u8]>`) is never copied into a staging
/// buffer on its way out.  Blocking-socket helper used by clients and
/// tests; the server side resumes short writes via the event loop.
pub fn write_frame_parts(stream: &mut TcpStream, parts: &[&[u8]]) -> Result<()> {
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let len = (total as u32).to_le_bytes();
    let grand = total + 4;
    let mut written = 0usize;
    let mut bufs: Vec<IoSlice> = Vec::with_capacity(parts.len() + 1);
    while written < grand {
        // rebuild the iovec from the current offset (first iteration
        // covers everything; later ones only run after a partial write)
        bufs.clear();
        let mut skip = written;
        if skip < 4 {
            bufs.push(IoSlice::new(&len[skip..]));
            skip = 0;
        } else {
            skip -= 4;
        }
        for p in parts {
            if skip >= p.len() {
                skip -= p.len();
                continue;
            }
            bufs.push(IoSlice::new(&p[skip..]));
            skip = 0;
        }
        let n = match stream.write_vectored(&bufs) {
            Ok(0) => bail!("connection closed mid-write"),
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        };
        written += n;
    }
    Ok(())
}

/// Write one length-prefixed frame.
pub fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    write_frame_parts(stream, &[payload])
}

/// The frame-size guard, applied before any payload allocation.  The
/// bound is inclusive: exactly MAX_FRAME is a legal frame.
fn check_frame_len(len: u32) -> Result<()> {
    if len > MAX_FRAME {
        bail!("frame too large: {len}");
    }
    Ok(())
}

/// Read one length-prefixed frame into `buf` (reused across calls).
pub fn read_frame(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<()> {
    let mut len_bytes = [0u8; 4];
    read_full(stream, &mut len_bytes, true)?;
    let len = u32::from_le_bytes(len_bytes);
    check_frame_len(len)?;
    buf.resize(len as usize, 0);
    read_full(stream, buf, false)?;
    Ok(())
}

/// `read_exact` with frame-aware timeout semantics.  A read timeout with
/// ZERO bytes consumed surfaces as WouldBlock/TimedOut so callers can
/// poll between frames — but once a frame has begun, returning early
/// would desync the length-prefix framing (the next read would parse
/// payload bytes as a length).  Mid-frame timeouts therefore keep
/// reading until `FRAME_STALL_DEADLINE`, then error fatally.
fn read_full(stream: &mut TcpStream, out: &mut [u8], frame_start: bool) -> Result<()> {
    let mut got = 0usize;
    let mut stalled_since: Option<Instant> = None;
    while got < out.len() {
        match stream.read(&mut out[got..]) {
            Ok(0) => bail!("connection closed"),
            Ok(n) => {
                got += n;
                stalled_since = None;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if frame_start && got == 0 {
                    return Err(e.into()); // clean between-frames poll
                }
                let t0 = *stalled_since.get_or_insert_with(Instant::now);
                if t0.elapsed() > FRAME_STALL_DEADLINE {
                    bail!("frame stalled mid-read ({got}/{} bytes)", out.len());
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// What a `RepServer` handler returns: an owned message (encoded into
/// the connection's reused reply buffer) or a pre-encoded frame — a
/// small owned `head` (wire tag + fixed fields) followed by a shared
/// `tail` (e.g. the ModelPool's cached `ModelBlob` encoding).  Framed
/// replies go out vectored with zero copies of the tail, resumed across
/// short writes by the event loop.
pub enum Reply {
    Msg(Msg),
    Framed { head: Vec<u8>, tail: Arc<[u8]> },
}

impl Reply {
    pub fn framed(head: Vec<u8>, tail: Arc<[u8]>) -> Reply {
        Reply::Framed { head, tail }
    }
}

impl From<Msg> for Reply {
    fn from(m: Msg) -> Reply {
        Reply::Msg(m)
    }
}

/// Server tuning knobs.  `net_threads` sizes the event-loop pool
/// (0 = auto: min(2, available cores)); `sndbuf` shrinks the kernel
/// send buffer (0 = kernel default) — the short-write test hook.
#[derive(Clone, Default)]
pub struct ServerOpts {
    pub net_threads: usize,
    pub sndbuf: usize,
}

/// When a `ReqClient` should try to negotiate a shared-memory lane.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum LaneMode {
    Auto,
    On,
    #[default]
    Off,
}

impl LaneMode {
    /// Parse the `--local-lanes` value; unknown strings mean Off.
    pub fn parse(s: &str) -> LaneMode {
        match s {
            "auto" => LaneMode::Auto,
            "on" => LaneMode::On,
            _ => LaneMode::Off,
        }
    }
}

/// Client-side lane selection: mode, ring directory (default `/dev/shm`
/// when present), and per-direction ring capacity (0 = LANE_CAPACITY).
#[derive(Clone, Default)]
pub struct LaneOpts {
    pub mode: LaneMode,
    pub dir: Option<PathBuf>,
    pub capacity: usize,
}

impl LaneOpts {
    /// Build lane options from run-config strings (`--local-lanes`,
    /// `--shm-dir`); an empty dir means the platform default.
    pub fn from_config(mode: &str, dir: &str) -> LaneOpts {
        LaneOpts {
            mode: LaneMode::parse(mode),
            dir: (!dir.is_empty()).then(|| PathBuf::from(dir)),
            capacity: 0,
        }
    }
}

/// One queued outbound frame: an owned head (starting with the 4-byte
/// length prefix) plus an optional shared tail, with a resume offset so
/// short writes pick up exactly where the kernel stopped.
struct OutFrame {
    head: Vec<u8>,
    tail: Option<Arc<[u8]>>,
    off: usize,
}

impl OutFrame {
    fn total(&self) -> usize {
        self.head.len() + self.tail.as_ref().map_or(0, |t| t.len())
    }
}

/// Encode a handler reply into an `OutFrame`, counting its wire bytes.
fn encode_reply(reply: Reply, bytes_out: &Meter) -> OutFrame {
    match reply {
        Reply::Msg(msg) => {
            let mut buf = vec![0u8; 4];
            msg.encode(&mut buf);
            let len = (buf.len() - 4) as u32;
            buf[..4].copy_from_slice(&len.to_le_bytes());
            bytes_out.add(buf.len() as u64);
            OutFrame { head: buf, tail: None, off: 0 }
        }
        Reply::Framed { head, tail } => {
            let total = head.len() + tail.len();
            let mut buf = Vec::with_capacity(4 + head.len());
            buf.extend_from_slice(&(total as u32).to_le_bytes());
            buf.extend_from_slice(&head);
            bytes_out.add(total as u64 + 4);
            OutFrame { head: buf, tail: Some(tail), off: 0 }
        }
    }
}

/// Work injected into an event loop from another thread (the acceptor
/// distributing a connection, or an async handler delivering a reply).
enum Inject {
    Conn(TcpStream),
    Reply { token: u64, frame: OutFrame },
}

/// The cross-thread face of one event loop: push work, ring the bell.
struct LoopShared {
    wake: poll::WakeFd,
    inbox: Mutex<Vec<Inject>>,
}

/// The two handler shapes a `RepServer` can run: synchronous (reply
/// returned inline, runs on the loop thread) or asynchronous (handler
/// receives a [`Responder`] and replies from any thread later — the
/// inference-server batching path).
enum ServiceKind {
    Sync(Box<dyn Fn(Msg) -> Reply + Send + Sync>),
    Async(Box<dyn Fn(Msg, Responder) + Send + Sync>),
}

type Service = Arc<ServiceKind>;

/// What one event loop does with a decoded frame.
enum Kind {
    Rep { service: Service, lanes: Arc<LaneHub> },
    Pull {
        tx: std::sync::mpsc::SyncSender<Msg>,
        decode_errors: Arc<Meter>,
    },
}

impl Clone for Kind {
    fn clone(&self) -> Kind {
        match self {
            Kind::Rep { service, lanes } => {
                Kind::Rep { service: service.clone(), lanes: lanes.clone() }
            }
            Kind::Pull { tx, decode_errors } => Kind::Pull {
                tx: tx.clone(),
                decode_errors: decode_errors.clone(),
            },
        }
    }
}

/// Where an async reply goes: back through an event loop's inbox (TCP)
/// or straight onto a shared-memory lane.
enum RespondTo {
    Loop {
        token: u64,
        shared: Arc<LoopShared>,
        bytes_out: Arc<Meter>,
    },
    Lane {
        srv: Arc<LaneSrv>,
        bytes_out: Arc<Meter>,
        stop: Arc<AtomicBool>,
    },
}

/// One-shot reply handle handed to async handlers.  Dropping it without
/// calling [`send`](Responder::send) delivers `Msg::Err` so the client
/// never hangs on a handler that lost the request.
pub struct Responder {
    inner: Option<RespondTo>,
}

impl Responder {
    pub fn send(mut self, reply: Reply) {
        if let Some(inner) = self.inner.take() {
            deliver(inner, reply);
        }
    }
}

impl Drop for Responder {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            deliver(
                inner,
                Reply::Msg(Msg::Err(
                    "handler dropped the request without replying".into(),
                )),
            );
        }
    }
}

fn deliver(inner: RespondTo, reply: Reply) {
    match inner {
        RespondTo::Loop { token, shared, bytes_out } => {
            let frame = encode_reply(reply, &bytes_out);
            lock_recover(&shared.inbox).push(Inject::Reply { token, frame });
            shared.wake.wake();
        }
        RespondTo::Lane { srv, bytes_out, stop } => {
            if !send_on_lane(&srv, reply, &bytes_out, &stop) {
                srv.dead.store(true, Ordering::Relaxed);
                srv.lane.tx.set_closed();
            }
        }
    }
}

/// Per-connection state owned by exactly one event loop.  Memory here
/// is the per-connection cost: two elastic buffers and a queue — no
/// thread stack.
struct Conn {
    stream: TcpStream,
    fd: i32,
    token: u64,
    laddr: String,
    len_bytes: [u8; 4],
    payload: Vec<u8>,
    got: usize,
    need: usize,
    in_payload: bool,
    mid_frame: bool,
    last_progress: Instant,
    out: VecDeque<OutFrame>,
    interest: u32,
    paused: bool,
    close_after_write: bool,
    parked: Option<Msg>,
    err_logged: bool,
}

impl Conn {
    fn new(stream: TcpStream, fd: i32, token: u64, laddr: String) -> Conn {
        Conn {
            stream,
            fd,
            token,
            laddr,
            len_bytes: [0u8; 4],
            payload: Vec::new(),
            got: 0,
            need: 0,
            in_payload: false,
            mid_frame: false,
            last_progress: Instant::now(),
            out: VecDeque::new(),
            interest: poll::EPOLLIN,
            paused: false,
            close_after_write: false,
            parked: None,
            err_logged: false,
        }
    }
}

/// One readiness-driven loop thread: owns its `Poller`, its share of
/// the connections, and (loop 0 only) the listener.
struct EventLoop {
    poller: poll::Poller,
    shared: Arc<LoopShared>,
    peers: Vec<Arc<LoopShared>>,
    listener: Option<TcpListener>,
    kind: Kind,
    stop: Arc<AtomicBool>,
    bytes_in: Arc<Meter>,
    bytes_out: Arc<Meter>,
    opts: ServerOpts,
    laddr: String,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    rr: usize,
    last_sweep: Instant,
}

fn effective_threads(n: usize) -> usize {
    if n > 0 {
        n
    } else {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(2)
    }
}

impl EventLoop {
    fn run(mut self) {
        if self
            .poller
            .add(self.shared.wake.raw(), TOK_WAKE, poll::EPOLLIN)
            .is_err()
        {
            return;
        }
        if let Some(l) = &self.listener {
            if self.poller.add(l.as_raw_fd(), TOK_LISTENER, poll::EPOLLIN).is_err() {
                return;
            }
        }
        let mut events: Vec<(u64, u32)> = Vec::new();
        loop {
            if self.stop.load(Ordering::Relaxed) {
                return;
            }
            let timeout =
                if self.conns.values().any(|c| c.parked.is_some()) { 5 } else { 200 };
            if self.poller.wait(&mut events, timeout).is_err() {
                return;
            }
            if self.stop.load(Ordering::Relaxed) {
                return;
            }
            // injected work first, every iteration — wakes coalesce, so
            // the inbox is authoritative, not the eventfd
            let inbox: Vec<Inject> =
                std::mem::take(&mut *lock_recover(&self.shared.inbox));
            for inj in inbox {
                match inj {
                    Inject::Conn(s) => self.register_conn(s),
                    Inject::Reply { token, frame } => {
                        if let Some(conn) = self.conns.get_mut(&token) {
                            conn.out.push_back(frame);
                            conn.paused = false;
                            self.service_conn(token, 0);
                        }
                        // token already gone: conn died while the
                        // handler was in flight; drop the reply
                    }
                }
            }
            let evs = std::mem::take(&mut events);
            for (token, ready) in &evs {
                match *token {
                    TOK_WAKE => self.shared.wake.drain(),
                    TOK_LISTENER => self.accept_ready(),
                    t => self.service_conn(t, *ready),
                }
            }
            events = evs;
            self.retry_parked();
            if self.last_sweep.elapsed() >= Duration::from_millis(100) {
                self.last_sweep = Instant::now();
                self.sweep_stalls();
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            let res = match &self.listener {
                Some(l) => l.accept(),
                None => return,
            };
            match res {
                Ok((stream, _)) => {
                    match fault::check(fault::SITE_ACCEPT, &self.laddr, 0) {
                        fault::Verdict::Pass => {}
                        fault::Verdict::Delay(d) => std::thread::sleep(d),
                        // reject/drop at accept: close right away
                        _ => continue,
                    }
                    self.rr = (self.rr + 1) % self.peers.len();
                    if self.rr == 0 {
                        self.register_conn(stream);
                    } else {
                        let peer = &self.peers[self.rr];
                        lock_recover(&peer.inbox).push(Inject::Conn(stream));
                        peer.wake.wake();
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(_) => {
                    // transient accept error (e.g. fd exhaustion): back
                    // off briefly; the level-triggered listener retries
                    std::thread::sleep(Duration::from_millis(2));
                    return;
                }
            }
        }
    }

    fn register_conn(&mut self, stream: TcpStream) {
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        stream.set_nodelay(true).ok();
        let fd = stream.as_raw_fd();
        if self.opts.sndbuf > 0 {
            poll::set_sndbuf(fd, self.opts.sndbuf).ok();
        }
        let laddr = stream.local_addr().map(|a| a.to_string()).unwrap_or_default();
        let token = self.next_token;
        self.next_token += 1;
        if self.poller.add(fd, token, poll::EPOLLIN).is_err() {
            return;
        }
        self.conns.insert(token, Conn::new(stream, fd, token, laddr));
    }

    /// Drive one connection for the readiness bits in `ready`; closes
    /// and deregisters it on any fatal condition.
    fn service_conn(&mut self, token: u64, ready: u32) {
        let Some(mut conn) = self.conns.remove(&token) else { return };
        let mut close = false;
        if conn.paused && ready & (poll::EPOLLHUP | poll::EPOLLERR) != 0 {
            // a paused conn ignores EPOLLIN, but peer death still ends it
            close = true;
        }
        if !close
            && !conn.paused
            && ready & (poll::EPOLLIN | poll::EPOLLHUP | poll::EPOLLERR) != 0
        {
            close = self.drive_read(&mut conn, token);
        }
        if !close {
            close = Self::flush_conn(&mut conn);
        }
        if close {
            let _ = self.poller.del(conn.fd);
        } else {
            self.update_interest(&mut conn);
            self.conns.insert(token, conn);
        }
    }

    /// Exact-read state machine: header bytes, then payload bytes, then
    /// dispatch; greedy until WouldBlock.  Returns true to close.
    // lint: nonblocking
    fn drive_read(&mut self, conn: &mut Conn, token: u64) -> bool {
        loop {
            let res = if !conn.in_payload {
                conn.stream.read(&mut conn.len_bytes[conn.got..])
            } else {
                conn.stream.read(&mut conn.payload[conn.got..conn.need])
            };
            match res {
                Ok(0) => return true,
                Ok(n) => {
                    conn.got += n;
                    conn.mid_frame = true;
                    conn.last_progress = Instant::now();
                    if !conn.in_payload && conn.got == 4 {
                        let len = u32::from_le_bytes(conn.len_bytes);
                        if check_frame_len(len).is_err() {
                            return true;
                        }
                        conn.in_payload = true;
                        conn.need = len as usize;
                        conn.got = 0;
                        conn.payload.clear();
                        conn.payload.resize(conn.need, 0);
                    }
                    if conn.in_payload && conn.got == conn.need {
                        conn.in_payload = false;
                        conn.got = 0;
                        conn.mid_frame = false;
                        let close = self.on_frame(conn, token);
                        if conn.payload.capacity() > (1 << 20) {
                            // a one-off giant frame must not pin memory
                            conn.payload = Vec::new();
                        }
                        if close {
                            return true;
                        }
                        if conn.paused || conn.close_after_write {
                            return false;
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return false,
                Err(_) => return true,
            }
        }
    }

    /// One complete frame is in `conn.payload`: run fault checks,
    /// decode, dispatch to the service.  Returns true to close.
    // lint: nonblocking
    fn on_frame(&self, conn: &mut Conn, token: u64) -> bool {
        match &self.kind {
            Kind::Rep { service, lanes } => {
                self.bytes_in.add(conn.payload.len() as u64 + 4);
                let tag = conn.payload.first().copied().unwrap_or(0);
                match fault::check(fault::SITE_REP, &conn.laddr, tag) {
                    fault::Verdict::Pass => {}
                    fault::Verdict::Delay(d) => {
                        std::thread::sleep(d) // lint: blocking-ok: seeded fault delay
                    }
                    fault::Verdict::Drop | fault::Verdict::Reject => return true,
                    fault::Verdict::Truncate => {
                        // claim a longer reply than we send, then die —
                        // the client sees a mid-frame close and retries
                        let mut head = Vec::with_capacity(12);
                        head.extend_from_slice(&64u32.to_le_bytes());
                        head.extend_from_slice(&[0u8; 8]);
                        conn.out.push_back(OutFrame { head, tail: None, off: 0 });
                        conn.close_after_write = true;
                        return false;
                    }
                }
                let reply = match Msg::from_bytes(&conn.payload) {
                    // lane negotiation is core protocol, not handler business
                    Ok(Msg::ShmHello { path }) => Reply::Msg(lanes.attach(&path)),
                    Ok(msg) => match &**service {
                        ServiceKind::Sync(f) => f(msg),
                        ServiceKind::Async(f) => {
                            conn.paused = true; // one in flight per conn
                            f(
                                msg,
                                Responder {
                                    inner: Some(RespondTo::Loop {
                                        token,
                                        shared: self.shared.clone(),
                                        bytes_out: self.bytes_out.clone(),
                                    }),
                                },
                            );
                            return false;
                        }
                    },
                    Err(e) => Reply::Msg(Msg::Err(format!("decode: {e}"))),
                };
                conn.out.push_back(encode_reply(reply, &self.bytes_out));
                false
            }
            Kind::Pull { tx, decode_errors } => {
                self.bytes_in.add(conn.payload.len() as u64 + 4);
                match fault::check(
                    fault::SITE_PULL,
                    &conn.laddr,
                    conn.payload.first().copied().unwrap_or(0),
                ) {
                    fault::Verdict::Pass => {}
                    fault::Verdict::Delay(d) => {
                        std::thread::sleep(d) // lint: blocking-ok: seeded fault delay
                    }
                    // swallow just this frame
                    fault::Verdict::Truncate => return false,
                    fault::Verdict::Drop | fault::Verdict::Reject => return true,
                }
                match Msg::from_bytes(&conn.payload) {
                    Ok(msg) => match tx.try_send(msg) {
                        Ok(()) => {}
                        Err(std::sync::mpsc::TrySendError::Full(m)) => {
                            // queue full = backpressure: park the frame
                            // and stop reading this conn, which stalls
                            // the pushing actor (on-policy mode)
                            conn.parked = Some(m);
                            conn.paused = true;
                        }
                        Err(std::sync::mpsc::TrySendError::Disconnected(_)) => {
                            return true;
                        }
                    },
                    Err(e) => {
                        decode_errors.add(1);
                        if !conn.err_logged {
                            conn.err_logged = true;
                            let peer = conn
                                .stream
                                .peer_addr()
                                .map(|a| a.to_string())
                                .unwrap_or_else(|_| "?".into());
                            eprintln!(
                                "pull: dropping undecodable {}-byte frame \
                                 from {peer}: {e} (counting further drops \
                                 silently)",
                                conn.payload.len()
                            );
                        }
                    }
                }
                false
            }
        }
    }

    /// Greedy write of the outbound queue, resuming partial frames at
    /// their recorded offset.  Returns true to close.
    // lint: nonblocking
    fn flush_conn(conn: &mut Conn) -> bool {
        loop {
            let Some(front) = conn.out.front_mut() else {
                return conn.close_after_write;
            };
            let head_len = front.head.len();
            let total = front.total();
            let res = if front.off < head_len {
                match &front.tail {
                    Some(tail) => {
                        let bufs = [
                            IoSlice::new(&front.head[front.off..]),
                            IoSlice::new(tail),
                        ];
                        conn.stream.write_vectored(&bufs)
                    }
                    None => conn.stream.write(&front.head[front.off..]),
                }
            } else {
                // off >= head_len with the frame unfinished implies a tail
                let tail = front.tail.as_ref().unwrap();
                conn.stream.write(&tail[front.off - head_len..])
            };
            match res {
                Ok(0) => return true,
                Ok(n) => {
                    front.off += n;
                    if front.off >= total {
                        conn.out.pop_front();
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return false,
                Err(_) => return true,
            }
        }
    }

    /// Keep epoll interest in sync with what the conn can make progress
    /// on: EPOLLIN unless paused, EPOLLOUT only while output is queued.
    // lint: nonblocking
    fn update_interest(&self, conn: &mut Conn) {
        let mut want = 0u32;
        if !conn.paused {
            want |= poll::EPOLLIN;
        }
        if !conn.out.is_empty() {
            want |= poll::EPOLLOUT;
        }
        // want == 0 is legal: HUP/ERR are always reported
        if want != conn.interest && self.poller.modify(conn.fd, conn.token, want).is_ok()
        {
            conn.interest = want;
        }
    }

    /// Re-offer parked pull frames to the queue; unpause on success.
    // lint: nonblocking
    fn retry_parked(&mut self) {
        let tx = match &self.kind {
            Kind::Pull { tx, .. } => tx.clone(),
            _ => return,
        };
        let mut resumed = Vec::new();
        let mut dead = Vec::new();
        for (tok, conn) in self.conns.iter_mut() {
            if let Some(m) = conn.parked.take() {
                match tx.try_send(m) {
                    Ok(()) => {
                        conn.paused = false;
                        resumed.push(*tok);
                    }
                    Err(std::sync::mpsc::TrySendError::Full(m)) => {
                        conn.parked = Some(m);
                    }
                    Err(std::sync::mpsc::TrySendError::Disconnected(_)) => {
                        dead.push(*tok);
                    }
                }
            }
        }
        for tok in resumed {
            // restore EPOLLIN; buffered socket data re-fires level-triggered
            self.service_conn(tok, 0);
        }
        for tok in dead {
            if let Some(c) = self.conns.remove(&tok) {
                let _ = self.poller.del(c.fd);
            }
        }
    }

    /// Enforce FRAME_STALL_DEADLINE for conns stuck mid-frame — the
    /// event-loop equivalent of `read_full`'s stall tracking.
    // lint: nonblocking
    fn sweep_stalls(&mut self) {
        let stale: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| {
                c.mid_frame && c.last_progress.elapsed() > FRAME_STALL_DEADLINE
            })
            .map(|(t, _)| *t)
            .collect();
        for tok in stale {
            if let Some(c) = self.conns.remove(&tok) {
                let _ = self.poller.del(c.fd);
            }
        }
    }
}

/// Spawn the event-loop pool for one server: N loops, listener owned by
/// loop 0, connections distributed round-robin via loop inboxes.
fn spawn_loops(
    prefix: &str,
    listener: TcpListener,
    local: &str,
    opts: &ServerOpts,
    kind: Kind,
    stop: Arc<AtomicBool>,
    bytes_in: Arc<Meter>,
    bytes_out: Arc<Meter>,
) -> Result<(Vec<Arc<LoopShared>>, Vec<std::thread::JoinHandle<()>>)> {
    let n = effective_threads(opts.net_threads);
    let mut shareds = Vec::with_capacity(n);
    for _ in 0..n {
        shareds.push(Arc::new(LoopShared {
            wake: poll::WakeFd::new()?,
            inbox: Mutex::new(Vec::new()),
        }));
    }
    let mut listener = Some(listener);
    let mut handles = Vec::with_capacity(n);
    for (i, shared) in shareds.iter().enumerate() {
        let lp = EventLoop {
            poller: poll::Poller::new()?,
            shared: shared.clone(),
            peers: shareds.clone(),
            listener: if i == 0 { listener.take() } else { None },
            kind: kind.clone(),
            stop: stop.clone(),
            bytes_in: bytes_in.clone(),
            bytes_out: bytes_out.clone(),
            opts: opts.clone(),
            laddr: local.to_string(),
            conns: HashMap::new(),
            next_token: 0,
            rr: 0,
            last_sweep: Instant::now(),
        };
        handles.push(
            std::thread::Builder::new()
                .name(format!("{prefix}{i}@{local}"))
                .spawn(move || lp.run())?,
        );
    }
    Ok((shareds, handles))
}

/// One attached shared-memory lane, server side.  `laddr` is the
/// server's TCP address — fault rules target lanes and sockets alike.
struct LaneSrv {
    lane: shm::ShmLane,
    laddr: String,
    dead: AtomicBool,
}

/// Serves every attached shm lane from one thread: polls the inbound
/// rings, runs the same service the TCP path runs, beats the heartbeat
/// words so peers can detect a crashed server.  The thread only exists
/// once a client has attached a lane.
struct LaneHub {
    service: Service,
    laddr: String,
    lanes: Mutex<Vec<Arc<LaneSrv>>>,
    stop: Arc<AtomicBool>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    bytes_in: Arc<Meter>,
    bytes_out: Arc<Meter>,
}

impl LaneHub {
    fn new(
        service: Service,
        laddr: String,
        stop: Arc<AtomicBool>,
        bytes_in: Arc<Meter>,
        bytes_out: Arc<Meter>,
    ) -> LaneHub {
        LaneHub {
            service,
            laddr,
            lanes: Mutex::new(Vec::new()),
            stop,
            handle: Mutex::new(None),
            bytes_in,
            bytes_out,
        }
    }

    /// Handle a `ShmHello`: map the client's rings, start the lane
    /// thread, confirm.  Any failure is an `Err` reply — the client
    /// falls back to TCP permanently.
    fn attach(self: &Arc<Self>, base: &str) -> Msg {
        let lane = match shm::ShmLane::attach(base) {
            Ok(l) => l,
            Err(e) => return Msg::Err(format!("lane attach: {e}")),
        };
        if !self.ensure_thread() {
            return Msg::Err("lane attach: service thread unavailable".into());
        }
        let srv = Arc::new(LaneSrv {
            lane,
            laddr: self.laddr.clone(),
            dead: AtomicBool::new(false),
        });
        lock_recover(&self.lanes).push(srv);
        Msg::Ok
    }

    fn ensure_thread(self: &Arc<Self>) -> bool {
        let mut h = lock_recover(&self.handle);
        if h.is_some() {
            return true;
        }
        let hub = self.clone();
        match std::thread::Builder::new()
            .name(format!("shm@{}", self.laddr))
            .spawn(move || hub.run())
        {
            Ok(handle) => {
                *h = Some(handle);
                true
            }
            Err(_) => false,
        }
    }

    fn run(&self) {
        let mut buf = Vec::new();
        let mut idle = 0u32;
        while !self.stop.load(Ordering::Relaxed) {
            let lanes: Vec<Arc<LaneSrv>> = lock_recover(&self.lanes).clone();
            if lanes.is_empty() {
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
            let mut progressed = false;
            for srv in &lanes {
                if srv.dead.load(Ordering::Relaxed) {
                    continue;
                }
                // heartbeats: prove this side alive even when idle
                srv.lane.rx.beat_reader();
                srv.lane.tx.beat_writer();
                if srv.lane.rx.is_closed() {
                    srv.dead.store(true, Ordering::Relaxed);
                    srv.lane.tx.set_closed();
                    continue;
                }
                loop {
                    match srv.lane.rx.try_read_frame(&mut buf) {
                        Ok(true) => {
                            progressed = true;
                            if !self.serve_frame(srv, &buf) {
                                srv.dead.store(true, Ordering::Relaxed);
                                srv.lane.tx.set_closed();
                                break;
                            }
                        }
                        Ok(false) => break,
                        Err(_) => {
                            // corrupt ring: kill the lane, keep the hub
                            srv.dead.store(true, Ordering::Relaxed);
                            srv.lane.tx.set_closed();
                            break;
                        }
                    }
                    if self.stop.load(Ordering::Relaxed) {
                        break;
                    }
                }
            }
            {
                let mut guard = lock_recover(&self.lanes);
                if guard.iter().any(|s| s.dead.load(Ordering::Relaxed)) {
                    guard.retain(|s| !s.dead.load(Ordering::Relaxed));
                }
            }
            if progressed {
                idle = 0;
            } else {
                idle += 1;
                if idle < 64 {
                    std::thread::yield_now();
                } else {
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
        }
        for srv in lock_recover(&self.lanes).iter() {
            srv.lane.tx.set_closed();
            srv.lane.rx.set_closed();
        }
    }

    /// One inbound lane frame: same fault site, same decode, same
    /// service dispatch as a TCP frame.  Returns false to kill the lane.
    fn serve_frame(&self, srv: &Arc<LaneSrv>, payload: &[u8]) -> bool {
        self.bytes_in.add(payload.len() as u64 + 4);
        let tag = payload.first().copied().unwrap_or(0);
        match fault::check(fault::SITE_REP, &srv.laddr, tag) {
            fault::Verdict::Pass => {}
            fault::Verdict::Delay(d) => std::thread::sleep(d),
            // a mid-frame truncation cannot exist on a ring: any
            // non-pass verdict kills the lane (client falls back to TCP)
            _ => return false,
        }
        let reply = match Msg::from_bytes(payload) {
            Ok(msg) => match &*self.service {
                ServiceKind::Sync(f) => f(msg),
                ServiceKind::Async(f) => {
                    f(
                        msg,
                        Responder {
                            inner: Some(RespondTo::Lane {
                                srv: srv.clone(),
                                bytes_out: self.bytes_out.clone(),
                                stop: self.stop.clone(),
                            }),
                        },
                    );
                    return true;
                }
            },
            Err(e) => Reply::Msg(Msg::Err(format!("decode: {e}"))),
        };
        send_on_lane(srv, reply, &self.bytes_out, &self.stop)
    }

    fn join(&self) {
        if let Some(h) = lock_recover(&self.handle).take() {
            h.join().ok();
        }
    }
}

/// Write one reply frame onto a lane's outbound ring, waiting out
/// backpressure with heartbeat-based liveness checks.  Returns false if
/// the lane is dead (peer gone, ring too small, or server stopping).
fn send_on_lane(
    srv: &LaneSrv,
    reply: Reply,
    bytes_out: &Meter,
    stop: &AtomicBool,
) -> bool {
    let (head, tail): (Vec<u8>, Option<Arc<[u8]>>) = match reply {
        Reply::Msg(msg) => {
            let mut b = Vec::new();
            msg.encode(&mut b);
            (b, None)
        }
        Reply::Framed { head, tail } => (head, Some(tail)),
    };
    let total = head.len() + tail.as_ref().map_or(0, |t| t.len());
    let empty: &[u8] = &[];
    let parts: [&[u8]; 2] = [&head, tail.as_deref().unwrap_or(empty)];
    let mut watch = shm::BeatWatch::new(srv.lane.tx.reader_beat());
    loop {
        if stop.load(Ordering::Relaxed)
            || srv.lane.tx.is_closed()
            || srv.lane.rx.is_closed()
        {
            return false;
        }
        match srv.lane.tx.try_write_frame_parts(&parts) {
            Ok(true) => break,
            Ok(false) => {} // ring full: reader lagging
            Err(_) => return false, // frame exceeds ring capacity
        }
        srv.lane.tx.beat_writer();
        if watch.stale(srv.lane.tx.reader_beat(), shm::STALE_DEADLINE) {
            return false;
        }
        std::thread::yield_now();
    }
    srv.lane.tx.beat_writer();
    bytes_out.add(total as u64 + 4);
    true
}

/// Request/response server on the event-loop pool.
pub struct RepServer {
    pub addr: String,
    stop: Arc<AtomicBool>,
    loops: Vec<Arc<LoopShared>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    lane_hub: Arc<LaneHub>,
    /// Frame bytes received/sent summed over every connection and lane
    /// this server accepted (payload + 4-byte length prefix).
    /// Registered into the owning role's `MetricsHub` so bandwidth
    /// rides the telemetry plane next to request rates.
    pub bytes_in: Arc<Meter>,
    pub bytes_out: Arc<Meter>,
}

impl RepServer {
    /// Bind to `addr` ("127.0.0.1:0" for an ephemeral port) and serve
    /// `handler(msg) -> reply` until `shutdown()`.
    pub fn serve<F>(addr: &str, handler: F) -> Result<RepServer>
    where
        F: Fn(Msg) -> Msg + Send + Sync + 'static,
    {
        Self::serve_frames(addr, move |msg| Reply::Msg(handler(msg)))
    }

    /// Like [`RepServer::serve`], but the handler may reply with a
    /// pre-encoded [`Reply::Framed`] frame (zero encode, zero copy of
    /// the shared tail) — the ModelPool serve path.
    pub fn serve_frames<F>(addr: &str, handler: F) -> Result<RepServer>
    where
        F: Fn(Msg) -> Reply + Send + Sync + 'static,
    {
        Self::serve_frames_opts(addr, ServerOpts::default(), handler)
    }

    /// [`serve_frames`](Self::serve_frames) with explicit pool/socket
    /// knobs.
    pub fn serve_frames_opts<F>(
        addr: &str,
        opts: ServerOpts,
        handler: F,
    ) -> Result<RepServer>
    where
        F: Fn(Msg) -> Reply + Send + Sync + 'static,
    {
        Self::serve_core(addr, opts, ServiceKind::Sync(Box::new(handler)))
    }

    /// Asynchronous variant: the handler receives a [`Responder`] and
    /// may reply from any thread later (the inference batching path).
    /// The connection reads one request at a time — the next frame is
    /// not consumed until the responder fires.
    pub fn serve_async<F>(addr: &str, opts: ServerOpts, handler: F) -> Result<RepServer>
    where
        F: Fn(Msg, Responder) + Send + Sync + 'static,
    {
        Self::serve_core(addr, opts, ServiceKind::Async(Box::new(handler)))
    }

    fn serve_core(addr: &str, opts: ServerOpts, service: ServiceKind) -> Result<RepServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let bytes_in = Arc::new(Meter::new());
        let bytes_out = Arc::new(Meter::new());
        let service: Service = Arc::new(service);
        let lane_hub = Arc::new(LaneHub::new(
            service.clone(),
            local.clone(),
            stop.clone(),
            bytes_in.clone(),
            bytes_out.clone(),
        ));
        let kind = Kind::Rep { service, lanes: lane_hub.clone() };
        let (loops, handles) = spawn_loops(
            "rep",
            listener,
            &local,
            &opts,
            kind,
            stop.clone(),
            bytes_in.clone(),
            bytes_out.clone(),
        )?;
        Ok(RepServer {
            addr: local,
            stop,
            loops,
            handles,
            lane_hub,
            bytes_in,
            bytes_out,
        })
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for l in &self.loops {
            l.wake.wake();
        }
        for h in self.handles.drain(..) {
            h.join().ok();
        }
        self.lane_hub.join();
    }
}

impl Drop for RepServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One-way streaming receiver (learner side of trajectory PULL); frames
/// from all connections are funneled into one bounded queue.  When the
/// queue is full the owning loop parks the frame and stops reading that
/// connection — TCP backpressure stalls the pushing actor (the paper's
/// on-policy mode).
pub struct PullServer {
    pub addr: String,
    rx: std::sync::mpsc::Receiver<Msg>,
    stop: Arc<AtomicBool>,
    loops: Vec<Arc<LoopShared>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Undecodable frames dropped, across all connections.  A nonzero
    /// rate means a peer speaks a different protocol version — silent
    /// drops here used to be invisible (PoolStats-style observability).
    pub decode_errors: Arc<Meter>,
    /// Frame bytes received across all connections (payload + prefix),
    /// including frames that later fail to decode — the wire carried
    /// them either way.
    pub bytes_in: Arc<Meter>,
}

impl PullServer {
    pub fn bind(addr: &str, queue_cap: usize) -> Result<PullServer> {
        Self::bind_opts(addr, queue_cap, ServerOpts::default())
    }

    pub fn bind_opts(
        addr: &str,
        queue_cap: usize,
        opts: ServerOpts,
    ) -> Result<PullServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let (tx, rx) = std::sync::mpsc::sync_channel(queue_cap);
        let stop = Arc::new(AtomicBool::new(false));
        let decode_errors = Arc::new(Meter::new());
        let bytes_in = Arc::new(Meter::new());
        let kind = Kind::Pull { tx, decode_errors: decode_errors.clone() };
        let (loops, handles) = spawn_loops(
            "pull",
            listener,
            &local,
            &opts,
            kind,
            stop.clone(),
            bytes_in.clone(),
            Arc::new(Meter::new()), // pull sends nothing
        )?;
        Ok(PullServer {
            addr: local,
            rx,
            stop,
            loops,
            handles,
            decode_errors,
            bytes_in,
        })
    }

    pub fn recv_timeout(&self, d: Duration) -> Option<Msg> {
        self.rx.recv_timeout(d).ok()
    }
    pub fn try_recv(&self) -> Option<Msg> {
        self.rx.try_recv().ok()
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for l in &self.loops {
            l.wake.wake();
        }
        for h in self.handles.drain(..) {
            h.join().ok();
        }
    }
}

impl Drop for PullServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Whether `host:port` names an endpoint on this machine — the `Auto`
/// lane-mode predicate.
fn is_loopback_addr(addr: &str) -> bool {
    let host = addr.rsplit_once(':').map(|(h, _)| h).unwrap_or(addr);
    host == "localhost" || host == "::1" || host == "[::1]" || host.starts_with("127.")
}

/// Client-side lane state: negotiation is tried once per client; any
/// lane failure afterwards falls back to TCP permanently (`Denied`).
#[derive(Default)]
enum LaneState {
    #[default]
    Untried,
    Active(Box<shm::ShmLane>),
    Denied,
}

/// Connection + reply buffer, reused across requests so the read path
/// stays allocation-free once the buffer has grown to frame size.
#[derive(Default)]
struct ReqInner {
    stream: Option<TcpStream>,
    buf: Vec<u8>,
    lane: LaneState,
}

/// Blocking request/response client with lazy (re)connect and optional
/// shared-memory lane negotiation for colocated servers.
pub struct ReqClient {
    addr: String,
    inner: Mutex<ReqInner>,
    lane_opts: LaneOpts,
    /// Requests that completed over the shm lane (vs TCP).
    pub lane_requests: Arc<Meter>,
    /// Frame bytes received/sent (payload + 4-byte length prefix),
    /// counted once per completed exchange — a retransmitted request
    /// after a connection break counts once, matching what the peer
    /// actually consumed.  Re-pointed at a hub's meters by role wiring
    /// (e.g. `Actor::use_hub`) so bandwidth shows up in role snapshots.
    pub bytes_in: Arc<Meter>,
    pub bytes_out: Arc<Meter>,
}

impl ReqClient {
    pub fn connect(addr: &str) -> ReqClient {
        Self::connect_opts(addr, LaneOpts::default())
    }

    /// [`connect`](Self::connect) with lane selection — `Auto` tries a
    /// shared-memory lane when `addr` is loopback, `On` always tries,
    /// `Off` never does.  Lane failure at any point falls back to TCP.
    pub fn connect_opts(addr: &str, lane_opts: LaneOpts) -> ReqClient {
        ReqClient {
            addr: addr.to_string(),
            inner: Mutex::new(ReqInner::default()),
            lane_opts,
            lane_requests: Arc::new(Meter::new()),
            bytes_in: Arc::new(Meter::new()),
            bytes_out: Arc::new(Meter::new()),
        }
    }

    fn lanes_wanted(&self) -> bool {
        match self.lane_opts.mode {
            LaneMode::Off => false,
            LaneMode::On => true,
            LaneMode::Auto => is_loopback_addr(&self.addr),
        }
    }

    /// Send `msg`, wait for the reply.  Reconnects (with retry/backoff)
    /// on broken connections — the k8s-restart story of the paper means
    /// peers can briefly vanish.
    pub fn request(&self, msg: &Msg) -> Result<Msg> {
        self.request_n(msg, 40)
    }

    /// [`request`](Self::request) with a caller-chosen attempt budget.
    /// For callers that hold a fallback peer (e.g. another ModelPool
    /// replica): failing over beats riding the full ~9s backoff ladder
    /// against a dead endpoint.
    pub fn request_n(&self, msg: &Msg, attempts: u32) -> Result<Msg> {
        let payload = msg.to_bytes();
        let tag = payload.first().copied().unwrap_or(0);
        let lanes_wanted = self.lanes_wanted();
        let mut guard = lock_recover(&self.inner);
        let mut last_err = None;
        let mut failures = 0u32;
        for attempt in 0..attempts {
            if guard.stream.is_none() {
                match TcpStream::connect(&self.addr) {
                    Ok(s) => {
                        s.set_nodelay(true).ok();
                        guard.stream = Some(s);
                    }
                    Err(e) => {
                        last_err = Some(e.into());
                        failures += 1;
                        drop(guard);
                        std::thread::sleep(Duration::from_millis(
                            25 * (attempt + 1).min(10),
                        ));
                        guard = lock_recover(&self.inner);
                        continue;
                    }
                }
            }
            if lanes_wanted && matches!(guard.lane, LaneState::Untried) {
                let ReqInner { stream, buf, lane } = &mut *guard;
                match self.negotiate_lane(stream.as_mut().unwrap(), buf) {
                    Ok(next) => *lane = next,
                    Err(e) => {
                        // hello exchange broke the TCP conn: reconnect
                        // and retry negotiation on the next attempt
                        *stream = None;
                        last_err = Some(e);
                        failures += 1;
                        continue;
                    }
                }
            }
            match fault::check(fault::SITE_REQ, &self.addr, tag) {
                fault::Verdict::Pass => {}
                fault::Verdict::Delay(d) => std::thread::sleep(d),
                fault::Verdict::Drop | fault::Verdict::Reject => {
                    guard.stream = None;
                    last_err =
                        Some(anyhow::anyhow!("fault: injected connection drop"));
                    failures += 1;
                    continue;
                }
                fault::Verdict::Truncate => {
                    // write a short frame, then kill the connection —
                    // the server sees a mid-frame close
                    if let Some(s) = guard.stream.as_mut() {
                        let _ = s.write_all(
                            &(payload.len() as u32).to_le_bytes(),
                        );
                        let _ = s.write_all(&payload[..payload.len() / 2]);
                    }
                    guard.stream = None;
                    last_err =
                        Some(anyhow::anyhow!("fault: injected truncated frame"));
                    failures += 1;
                    continue;
                }
            }
            let ReqInner { stream, buf, lane } = &mut *guard;
            if let LaneState::Active(l) = lane {
                if payload.len() <= l.tx.max_payload() {
                    match Self::lane_exchange(l, &payload, buf) {
                        Ok(()) => match Msg::from_bytes(buf) {
                            Ok(reply) => {
                                if failures > 0 {
                                    fault::on_recovery();
                                }
                                self.bytes_out.add(payload.len() as u64 + 4);
                                self.bytes_in.add(buf.len() as u64 + 4);
                                self.lane_requests.add(1);
                                return Ok(reply);
                            }
                            Err(e) => {
                                *lane = LaneState::Denied;
                                last_err = Some(e);
                                failures += 1;
                                continue;
                            }
                        },
                        Err(e) => {
                            l.tx.set_closed();
                            l.rx.set_closed();
                            *lane = LaneState::Denied;
                            last_err = Some(e);
                            failures += 1;
                            continue;
                        }
                    }
                }
                // frame exceeds the ring: use TCP for this request only
            }
            let stream = stream.as_mut().unwrap();
            let ok = (|| {
                write_frame(stream, &payload)?;
                read_frame(stream, buf)?;
                Msg::from_bytes(buf)
            })();
            match ok {
                Ok(reply) => {
                    if failures > 0 {
                        // exchange completed after at least one failed
                        // attempt: that is a healed fault
                        fault::on_recovery();
                    }
                    self.bytes_out.add(payload.len() as u64 + 4);
                    self.bytes_in.add(guard.buf.len() as u64 + 4);
                    return Ok(reply);
                }
                Err(e) => {
                    guard.stream = None; // force reconnect
                    last_err = Some(e);
                    failures += 1;
                }
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow::anyhow!("request failed")))
            .with_context(|| format!("req to {}", self.addr))
    }

    /// Create the ring pair and offer it over TCP.  `Ok(state)` means
    /// the TCP conn is still healthy (lane active or denied); `Err`
    /// means the hello exchange itself broke the connection.
    fn negotiate_lane(
        &self,
        stream: &mut TcpStream,
        buf: &mut Vec<u8>,
    ) -> Result<LaneState> {
        let dir = self
            .lane_opts
            .dir
            .clone()
            .unwrap_or_else(shm::default_dir);
        let cap = if self.lane_opts.capacity > 0 {
            self.lane_opts.capacity
        } else {
            shm::LANE_CAPACITY
        };
        let (lane, base) = match shm::ShmLane::create(&dir, cap) {
            Ok(x) => x,
            Err(_) => return Ok(LaneState::Denied), // no shm here: stay on TCP
        };
        let hello = Msg::ShmHello { path: base }.to_bytes();
        write_frame(stream, &hello)?;
        read_frame(stream, buf)?;
        let reply = Msg::from_bytes(buf)?;
        self.bytes_out.add(hello.len() as u64 + 4);
        self.bytes_in.add(buf.len() as u64 + 4);
        match reply {
            Msg::Ok => Ok(LaneState::Active(Box::new(lane))),
            _ => Ok(LaneState::Denied),
        }
    }

    /// One request/reply over the rings, with heartbeat-based liveness:
    /// there is no kernel to notice a dead peer, so staleness of the
    /// opposite side's beat word is the failure signal.
    fn lane_exchange(
        lane: &shm::ShmLane,
        payload: &[u8],
        buf: &mut Vec<u8>,
    ) -> Result<()> {
        let mut watch = shm::BeatWatch::new(lane.tx.reader_beat());
        loop {
            if lane.tx.is_closed() || lane.rx.is_closed() {
                bail!("lane closed by peer");
            }
            if lane.tx.try_write_frame(payload)? {
                break;
            }
            lane.tx.beat_writer();
            if watch.stale(lane.tx.reader_beat(), shm::STALE_DEADLINE) {
                bail!("lane peer stale (no reader progress)");
            }
            std::thread::yield_now();
        }
        lane.tx.beat_writer();
        let mut watch = shm::BeatWatch::new(lane.rx.writer_beat());
        let mut idle = 0u32;
        loop {
            if lane.rx.try_read_frame(buf)? {
                lane.rx.beat_reader();
                return Ok(());
            }
            lane.rx.beat_reader();
            if lane.rx.is_closed() {
                // drain race: the peer may close right after replying
                if lane.rx.try_read_frame(buf)? {
                    lane.rx.beat_reader();
                    return Ok(());
                }
                bail!("lane closed by peer");
            }
            if watch.stale(lane.rx.writer_beat(), shm::STALE_DEADLINE) {
                bail!("lane peer stale (no writer progress)");
            }
            idle += 1;
            if idle < 64 {
                std::thread::yield_now();
            } else {
                std::thread::sleep(Duration::from_micros(50));
            }
        }
    }
}

/// One-way streaming sender (actor side of trajectory PUSH).
pub struct PushClient {
    addr: String,
    stream: Mutex<Option<TcpStream>>,
    /// Frame bytes sent (payload + length prefix), once per delivered
    /// push.  Re-pointed at a hub meter by `Actor::use_hub`.
    pub bytes_out: Arc<Meter>,
}

impl PushClient {
    pub fn connect(addr: &str) -> PushClient {
        PushClient {
            addr: addr.to_string(),
            stream: Mutex::new(None),
            bytes_out: Arc::new(Meter::new()),
        }
    }

    /// One connect + one write; on failure the connection is dropped
    /// and the error returned (no retries — `push`/`try_push` decide
    /// the retry policy).
    fn push_once(
        conn: &mut Option<TcpStream>,
        addr: &str,
        payload: &[u8],
        tag: u8,
    ) -> Result<()> {
        if conn.is_none() {
            let s = TcpStream::connect(addr)
                .with_context(|| format!("connect {addr}"))?;
            s.set_nodelay(true).ok();
            *conn = Some(s);
        }
        match fault::check(fault::SITE_PUSH, addr, tag) {
            fault::Verdict::Pass => {}
            fault::Verdict::Delay(d) => std::thread::sleep(d),
            fault::Verdict::Drop | fault::Verdict::Reject => {
                *conn = None;
                bail!("fault: injected connection drop");
            }
            fault::Verdict::Truncate => {
                if let Some(s) = conn.as_mut() {
                    let _ = s.write_all(&(payload.len() as u32).to_le_bytes());
                    let _ = s.write_all(&payload[..payload.len() / 2]);
                }
                *conn = None;
                bail!("fault: injected truncated frame");
            }
        }
        if let Err(e) = write_frame(conn.as_mut().unwrap(), payload) {
            *conn = None;
            return Err(e);
        }
        Ok(())
    }

    pub fn push(&self, msg: &Msg) -> Result<()> {
        let payload = msg.to_bytes();
        let tag = payload.first().copied().unwrap_or(0);
        let mut guard = lock_recover(&self.stream);
        let mut failures = 0u32;
        for attempt in 0..40 {
            match Self::push_once(&mut guard, &self.addr, &payload, tag) {
                Ok(()) => {
                    if failures > 0 {
                        fault::on_recovery();
                    }
                    self.bytes_out.add(payload.len() as u64 + 4);
                    return Ok(());
                }
                Err(_) => {
                    failures += 1;
                    drop(guard);
                    std::thread::sleep(Duration::from_millis(
                        25 * (attempt + 1).min(10),
                    ));
                    guard = lock_recover(&self.stream);
                }
            }
        }
        bail!("push to {} failed", self.addr)
    }

    /// Single-attempt push for callers that keep their own bounded
    /// retry queue (the Actor's segment buffer): one connect + one
    /// write, error back immediately — never sleeps through the ~10s
    /// backoff ladder `push` uses, so a dead learner cannot stall the
    /// rollout tick.
    pub fn try_push(&self, msg: &Msg) -> Result<()> {
        let payload = msg.to_bytes();
        let tag = payload.first().copied().unwrap_or(0);
        let mut guard = lock_recover(&self.stream);
        Self::push_once(&mut guard, &self.addr, &payload, tag)?;
        self.bytes_out.add(payload.len() as u64 + 4);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::{ModelKey, TrajSegment};

    #[test]
    fn req_rep_roundtrip() {
        let server = RepServer::serve("127.0.0.1:0", |msg| match msg {
            Msg::Ping => Msg::Pong,
            other => Msg::Err(format!("unexpected {other:?}")),
        })
        .unwrap();
        let client = ReqClient::connect(&server.addr);
        for _ in 0..10 {
            assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
        }
    }

    #[test]
    fn req_rep_many_clients() {
        let server = RepServer::serve("127.0.0.1:0", |_| Msg::Ok).unwrap();
        let addr = server.addr.clone();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let c = ReqClient::connect(&addr);
                    for _ in 0..50 {
                        assert_eq!(c.request(&Msg::Ping).unwrap(), Msg::Ok);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn push_pull_stream() {
        let server = PullServer::bind("127.0.0.1:0", 64).unwrap();
        let client = PushClient::connect(&server.addr);
        let seg = TrajSegment {
            model_key: ModelKey::new(0, 1),
            t: 2,
            n_agents: 1,
            obs: vec![0.0; 12],
            actions: vec![1, 2],
            behavior_logp: vec![-1.0, -1.0],
            rewards: vec![0.5, -0.5],
            discounts: vec![0.99, 0.0],
            trace: None,
        };
        for _ in 0..20 {
            client.push(&Msg::Traj(seg.clone())).unwrap();
        }
        let mut got = 0;
        while got < 20 {
            let msg = server
                .recv_timeout(Duration::from_secs(5))
                .expect("timed out");
            assert!(matches!(msg, Msg::Traj(ref s) if *s == seg));
            got += 1;
        }
    }

    /// A handler replying with a pre-encoded frame (head tag + shared
    /// tail) must be indistinguishable on the wire from an owned reply.
    #[test]
    fn framed_reply_matches_owned_encoding() {
        use crate::proto::{ModelBlob, TAG_MODEL};
        let blob = ModelBlob {
            key: ModelKey::new(2, 5),
            params: vec![1.0, -2.5, 3.25],
            hp: vec![3e-4],
            frozen: true,
        };
        let tail: Arc<[u8]> = blob.to_bytes().into();
        let server = RepServer::serve_frames("127.0.0.1:0", move |msg| match msg {
            Msg::Ping => Reply::framed(vec![TAG_MODEL], tail.clone()),
            other => Reply::Msg(Msg::Err(format!("unexpected {other:?}"))),
        })
        .unwrap();
        let client = ReqClient::connect(&server.addr);
        for _ in 0..3 {
            match client.request(&Msg::Ping).unwrap() {
                Msg::Model(b) => {
                    assert_eq!(b.key, ModelKey::new(2, 5));
                    assert_eq!(b.params, vec![1.0, -2.5, 3.25]);
                    assert!(b.frozen);
                }
                other => panic!("expected Model, got {other:?}"),
            }
        }
    }

    /// Undecodable-but-well-framed payloads must get an error reply and
    /// leave the connection usable (no desync of the length framing).
    #[test]
    fn garbage_frames_do_not_corrupt_connection() {
        let server = RepServer::serve("127.0.0.1:0", |msg| match msg {
            Msg::Ping => Msg::Pong,
            other => Msg::Err(format!("unexpected {other:?}")),
        })
        .unwrap();
        let mut stream = TcpStream::connect(&server.addr).unwrap();
        let mut buf = Vec::new();
        crate::util::proptest::forall(40, "garbage-frame", |rng| {
            // tag >= 50 is unknown, so decode always fails
            let n = 1 + rng.below(64) as usize;
            let mut garbage = vec![50 + (rng.below(200) as u8); 1];
            for _ in 1..n {
                garbage.push(rng.next_u32() as u8);
            }
            write_frame(&mut stream, &garbage).map_err(|e| e.to_string())?;
            read_frame(&mut stream, &mut buf).map_err(|e| e.to_string())?;
            let reply = Msg::from_bytes(&buf).map_err(|e| e.to_string())?;
            crate::prop_assert!(
                matches!(reply, Msg::Err(_)),
                "garbage must get Err, got {reply:?}"
            );
            // the same connection still serves real requests
            write_frame(&mut stream, &Msg::Ping.to_bytes())
                .map_err(|e| e.to_string())?;
            read_frame(&mut stream, &mut buf).map_err(|e| e.to_string())?;
            crate::prop_assert_eq!(
                Msg::from_bytes(&buf).map_err(|e| e.to_string())?,
                Msg::Pong
            );
            Ok(())
        });
    }

    /// An over-MAX_FRAME length prefix is rejected before any allocation
    /// and kills only that connection; fresh connections keep working.
    #[test]
    fn oversized_frame_rejected_and_server_survives() {
        let server = RepServer::serve("127.0.0.1:0", |_| Msg::Pong).unwrap();
        let mut bad = TcpStream::connect(&server.addr).unwrap();
        bad.write_all(&(MAX_FRAME + 1).to_le_bytes()).unwrap();
        // server drops the connection: the read eventually sees EOF
        bad.set_read_timeout(Some(Duration::from_secs(5))).ok();
        let mut probe = [0u8; 1];
        assert_eq!(bad.read(&mut probe).unwrap_or(0), 0, "conn must close");
        // a new connection is unaffected
        let client = ReqClient::connect(&server.addr);
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
    }

    /// A frame truncated by peer death must error out, not hang or get
    /// misread as a shorter frame.
    #[test]
    fn truncated_frame_errors_cleanly() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&100u32.to_le_bytes()).unwrap();
            s.write_all(&[7u8; 50]).unwrap(); // half the promised payload
            // dropped here: peer closes mid-frame
        });
        let (mut conn, _) = listener.accept().unwrap();
        let mut buf = Vec::new();
        let err = read_frame(&mut conn, &mut buf).unwrap_err();
        assert!(
            err.to_string().contains("connection closed"),
            "want mid-frame close error, got: {err}"
        );
        writer.join().unwrap();
    }

    /// The size guard is inclusive at exactly MAX_FRAME and rejects one
    /// byte more — checked on the predicate so the test doesn't have to
    /// allocate a 512 MiB payload buffer.
    #[test]
    fn max_frame_boundary() {
        assert!(check_frame_len(MAX_FRAME).is_ok());
        assert!(check_frame_len(MAX_FRAME + 1).is_err());
        assert!(check_frame_len(0).is_ok());
    }

    #[test]
    fn pull_server_counts_undecodable_frames() {
        let server = PullServer::bind("127.0.0.1:0", 16).unwrap();
        let mut s = TcpStream::connect(&server.addr).unwrap();
        // two garbage frames, then a real one
        write_frame(&mut s, &[99u8, 1, 2, 3]).unwrap();
        write_frame(&mut s, &[200u8]).unwrap();
        write_frame(&mut s, &Msg::Ping.to_bytes()).unwrap();
        let msg = server.recv_timeout(Duration::from_secs(5)).expect("timed out");
        assert_eq!(msg, Msg::Ping);
        assert_eq!(server.decode_errors.count(), 2);
    }

    /// Satellite: byte accounting — client-out equals server-in and
    /// vice versa (both count payload + 4-byte prefix per frame), and
    /// push/pull agree the same way.
    #[test]
    fn byte_meters_agree_across_the_wire() {
        let server = RepServer::serve("127.0.0.1:0", |_| Msg::Pong).unwrap();
        let client = ReqClient::connect(&server.addr);
        for _ in 0..5 {
            client.request(&Msg::Ping).unwrap();
        }
        let req_frame = Msg::Ping.to_bytes().len() as u64 + 4;
        let rep_frame = Msg::Pong.to_bytes().len() as u64 + 4;
        assert_eq!(client.bytes_out.count(), 5 * req_frame);
        assert_eq!(client.bytes_in.count(), 5 * rep_frame);
        // the event loops count on their side of the same frames
        assert_eq!(server.bytes_in.count(), client.bytes_out.count());
        assert_eq!(server.bytes_out.count(), client.bytes_in.count());

        let pull = PullServer::bind("127.0.0.1:0", 16).unwrap();
        let push = PushClient::connect(&pull.addr);
        push.push(&Msg::Ping).unwrap();
        push.push(&Msg::Ping).unwrap();
        assert_eq!(pull.recv_timeout(Duration::from_secs(5)), Some(Msg::Ping));
        assert_eq!(pull.recv_timeout(Duration::from_secs(5)), Some(Msg::Ping));
        assert_eq!(push.bytes_out.count(), 2 * req_frame);
        assert_eq!(pull.bytes_in.count(), push.bytes_out.count());
    }

    #[test]
    fn client_survives_server_restart() {
        let mut server = RepServer::serve("127.0.0.1:0", |_| Msg::Ok).unwrap();
        let addr = server.addr.clone();
        let client = ReqClient::connect(&addr);
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Ok);
        server.shutdown();
        // restart on the same port — shutdown joins the event loops, so
        // the listener and every conn are already closed here
        let _server2 = RepServer::serve(&addr, |_| Msg::Pong).unwrap();
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
    }

    /// Injected request-path drops are retried through and healed: every
    /// exchange still completes, and the fault/recovery meters move.
    #[test]
    fn req_client_heals_injected_drops() {
        let _g = fault::TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        let server = RepServer::serve("127.0.0.1:0", |_| Msg::Pong).unwrap();
        let client = ReqClient::connect(&server.addr);
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
        fault::set_role("req-heal-test");
        // target THIS server's (unique ephemeral) address so concurrent
        // tests in the binary never match the plan
        fault::install(
            7,
            fault::parse_spec(&format!("drop:{}@0.5", server.addr)).unwrap(),
        );
        let injected0 = fault::injected_meter().count();
        let recovered0 = fault::recovered_meter().count();
        for _ in 0..20 {
            assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
        }
        fault::clear();
        assert!(
            fault::injected_meter().count() > injected0,
            "p=0.5 over 20+ draws must inject at least once"
        );
        assert!(
            fault::recovered_meter().count() > recovered0,
            "a retried-through drop must count as a recovery"
        );
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
    }

    /// Truncate faults kill the connection mid-frame without desyncing
    /// the length-prefix protocol: the client reconnects and completes.
    #[test]
    fn truncate_fault_breaks_conn_not_protocol() {
        let _g = fault::TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        let server = RepServer::serve("127.0.0.1:0", |_| Msg::Pong).unwrap();
        let client = ReqClient::connect(&server.addr);
        fault::set_role("truncate-test");
        fault::install(
            11,
            fault::parse_spec(&format!("truncate:{}@0.3", server.addr))
                .unwrap(),
        );
        for _ in 0..20 {
            assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
        }
        fault::clear();
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
    }

    /// `try_push` is single-attempt: under a full partition it errors
    /// immediately instead of sleeping through the backoff ladder, and
    /// works again the moment the partition lifts.
    #[test]
    fn try_push_fails_fast_under_partition() {
        let _g = fault::TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        let pull = PullServer::bind("127.0.0.1:0", 64).unwrap();
        let push = PushClient::connect(&pull.addr);
        push.try_push(&Msg::Ping).unwrap();
        assert_eq!(pull.recv_timeout(Duration::from_secs(5)), Some(Msg::Ping));
        fault::set_role("push-test");
        fault::install(
            7,
            fault::parse_spec(&format!("partition:{}@1", pull.addr)).unwrap(),
        );
        let t0 = Instant::now();
        assert!(push.try_push(&Msg::Ping).is_err());
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "try_push must not sleep through a retry ladder"
        );
        fault::clear();
        push.try_push(&Msg::Ping).unwrap();
        assert_eq!(pull.recv_timeout(Duration::from_secs(5)), Some(Msg::Ping));
    }

    /// Satellite: the wakeup eventfd makes shutdown effectively
    /// immediate even with live, idle connections parked on the loops —
    /// no more 200ms stop-flag polling.
    #[test]
    fn shutdown_is_immediate_with_live_conns() {
        let mut server = RepServer::serve("127.0.0.1:0", |_| Msg::Ok).unwrap();
        let client = ReqClient::connect(&server.addr);
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Ok);
        let t0 = Instant::now();
        server.shutdown();
        assert!(
            t0.elapsed() < Duration::from_millis(50),
            "rep shutdown took {:?}",
            t0.elapsed()
        );

        let mut pull = PullServer::bind("127.0.0.1:0", 16).unwrap();
        let push = PushClient::connect(&pull.addr);
        push.push(&Msg::Ping).unwrap();
        assert_eq!(pull.recv_timeout(Duration::from_secs(5)), Some(Msg::Ping));
        let t0 = Instant::now();
        pull.shutdown();
        assert!(
            t0.elapsed() < Duration::from_millis(50),
            "pull shutdown took {:?}",
            t0.elapsed()
        );
    }

    /// Satellite: a tiny kernel send buffer forces the event loop
    /// through its short-write resumption path on a large framed reply;
    /// every frame must still arrive intact.
    #[test]
    fn framed_reply_survives_short_writes() {
        use crate::proto::{ModelBlob, TAG_MODEL};
        let blob = ModelBlob {
            key: ModelKey::new(3, 9),
            params: (0..200_000).map(|i| (i % 251) as f32 * 0.5).collect(),
            hp: vec![1e-3],
            frozen: false,
        };
        let expect = blob.params.clone();
        let tail: Arc<[u8]> = blob.to_bytes().into();
        let server = RepServer::serve_frames_opts(
            "127.0.0.1:0",
            ServerOpts { net_threads: 1, sndbuf: 4096 },
            move |_| Reply::framed(vec![TAG_MODEL], tail.clone()),
        )
        .unwrap();
        let client = ReqClient::connect(&server.addr);
        for _ in 0..3 {
            match client.request(&Msg::Ping).unwrap() {
                Msg::Model(b) => {
                    assert_eq!(b.key, ModelKey::new(3, 9));
                    assert_eq!(b.params, expect);
                }
                other => panic!("expected Model, got {other:?}"),
            }
        }
    }

    /// Async handlers reply through a `Responder` from any thread; a
    /// responder dropped without sending delivers an error instead of
    /// hanging the client.
    #[test]
    fn async_handler_replies_out_of_band() {
        let server = RepServer::serve_async(
            "127.0.0.1:0",
            ServerOpts::default(),
            |msg, responder| match msg {
                Msg::Ping => {
                    std::thread::spawn(move || {
                        std::thread::sleep(Duration::from_millis(5));
                        responder.send(Reply::Msg(Msg::Pong));
                    });
                }
                _ => drop(responder),
            },
        )
        .unwrap();
        let client = ReqClient::connect(&server.addr);
        for _ in 0..5 {
            assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
        }
        match client.request(&Msg::Ok).unwrap() {
            Msg::Err(e) => assert!(e.contains("dropped"), "got: {e}"),
            other => panic!("expected Err for dropped responder, got {other:?}"),
        }
    }

    /// Satellite: SITE_REP faults fire inside the event loop exactly as
    /// they did in the thread-per-conn core.
    #[test]
    fn rep_site_faults_fire_through_event_loop() {
        let _g = fault::TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        let server = RepServer::serve("127.0.0.1:0", |_| Msg::Pong).unwrap();
        let client = ReqClient::connect(&server.addr);
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
        fault::set_role("rep-epoll-test");
        fault::install(
            13,
            fault::parse_spec(&format!("drop:rep/{}@0.5", server.addr)).unwrap(),
        );
        let injected0 = fault::injected_meter().count();
        for _ in 0..20 {
            assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
        }
        fault::clear();
        assert!(
            fault::injected_meter().count() > injected0,
            "rep-site drops must fire through the epoll core"
        );
    }

    /// Satellite: accept-site reject and delay verdicts fire in the
    /// event loop's acceptor.
    #[test]
    fn accept_and_delay_faults_fire_through_event_loop() {
        let _g = fault::TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        let server = RepServer::serve("127.0.0.1:0", |_| Msg::Pong).unwrap();
        fault::set_role("accept-epoll-test");
        fault::install(
            5,
            fault::parse_spec(&format!("reject:accept/{}@1", server.addr))
                .unwrap(),
        );
        // every accepted conn is closed immediately: a small attempt
        // budget must fail fast (no backoff on exchange errors)
        let client = ReqClient::connect(&server.addr);
        assert!(client.request_n(&Msg::Ping, 4).is_err());
        fault::clear();
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);

        fault::install(
            5,
            fault::parse_spec(&format!("delay:accept/{}@1+60", server.addr))
                .unwrap(),
        );
        // fresh client = fresh conn through the delayed acceptor
        let slow = ReqClient::connect(&server.addr);
        let t0 = Instant::now();
        assert_eq!(slow.request(&Msg::Ping).unwrap(), Msg::Pong);
        assert!(
            t0.elapsed() >= Duration::from_millis(60),
            "accept delay must apply, took {:?}",
            t0.elapsed()
        );
        fault::clear();
    }

    /// Satellite: pull-site truncate swallows frames inside the event
    /// loop (bytes counted, nothing delivered), and clears cleanly.
    #[test]
    fn pull_site_truncate_swallows_frames_through_event_loop() {
        let _g = fault::TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        let pull = PullServer::bind("127.0.0.1:0", 16).unwrap();
        let push = PushClient::connect(&pull.addr);
        fault::set_role("pull-epoll-test");
        fault::install(
            3,
            fault::parse_spec(&format!("truncate:pull/{}@1", pull.addr)).unwrap(),
        );
        push.push(&Msg::Ping).unwrap();
        push.push(&Msg::Ping).unwrap();
        let frame = Msg::Ping.to_bytes().len() as u64 + 4;
        let deadline = Instant::now() + Duration::from_secs(5);
        while pull.bytes_in.count() < 2 * frame && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(pull.bytes_in.count(), 2 * frame, "frames must be read");
        assert_eq!(
            pull.recv_timeout(Duration::from_millis(100)),
            None,
            "truncated frames must be swallowed"
        );
        fault::clear();
        push.push(&Msg::Ping).unwrap();
        assert_eq!(pull.recv_timeout(Duration::from_secs(5)), Some(Msg::Ping));
    }

    /// Tentpole: a colocated client negotiates a shared-memory lane and
    /// serves the hot path through it — TCP only carries the hello.
    #[test]
    fn req_rep_over_local_lane() {
        let server = RepServer::serve("127.0.0.1:0", |msg| match msg {
            Msg::Ping => Msg::Pong,
            other => Msg::Err(format!("unexpected {other:?}")),
        })
        .unwrap();
        let client = ReqClient::connect_opts(
            &server.addr,
            LaneOpts { mode: LaneMode::On, dir: None, capacity: 0 },
        );
        for _ in 0..20 {
            assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
        }
        assert_eq!(
            client.lane_requests.count(),
            20,
            "all requests must ride the lane"
        );
    }

    /// Framed (zero-copy) replies are bit-compatible across the lane:
    /// the client decodes the same Model it would get over TCP.
    #[test]
    fn framed_reply_over_local_lane() {
        use crate::proto::{ModelBlob, TAG_MODEL};
        let blob = ModelBlob {
            key: ModelKey::new(4, 2),
            params: vec![0.5, 1.5, -2.0],
            hp: vec![1e-4],
            frozen: true,
        };
        let tail: Arc<[u8]> = blob.to_bytes().into();
        let server = RepServer::serve_frames("127.0.0.1:0", move |_| {
            Reply::framed(vec![TAG_MODEL], tail.clone())
        })
        .unwrap();
        let client = ReqClient::connect_opts(
            &server.addr,
            LaneOpts { mode: LaneMode::On, dir: None, capacity: 0 },
        );
        match client.request(&Msg::Ping).unwrap() {
            Msg::Model(b) => {
                assert_eq!(b.key, ModelKey::new(4, 2));
                assert_eq!(b.params, vec![0.5, 1.5, -2.0]);
            }
            other => panic!("expected Model, got {other:?}"),
        }
        assert_eq!(client.lane_requests.count(), 1);
    }

    /// A frame bigger than the ring falls back to TCP for that request
    /// only; the lane stays active for everything that fits.
    #[test]
    fn lane_falls_back_for_oversized_frames() {
        let server = RepServer::serve("127.0.0.1:0", |_| Msg::Ok).unwrap();
        let client = ReqClient::connect_opts(
            &server.addr,
            LaneOpts { mode: LaneMode::On, dir: None, capacity: 4096 },
        );
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Ok);
        let big = Msg::Traj(TrajSegment {
            model_key: ModelKey::new(1, 1),
            t: 1,
            n_agents: 1,
            obs: vec![0.5; 5000], // ~20 KB payload >> 4 KB ring
            actions: vec![0],
            behavior_logp: vec![-1.0],
            rewards: vec![0.0],
            discounts: vec![0.99],
            trace: None,
        });
        assert_eq!(client.request(&big).unwrap(), Msg::Ok);
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Ok);
        assert_eq!(
            client.lane_requests.count(),
            2,
            "small frames ride the lane; the oversized one used TCP"
        );
    }

    /// One-side-crash detection: when the server goes away its rings
    /// are closed, the client detects it and permanently falls back to
    /// TCP against the restarted server.
    #[test]
    fn lane_peer_crash_falls_back_to_tcp() {
        let mut server = RepServer::serve("127.0.0.1:0", |_| Msg::Pong).unwrap();
        let addr = server.addr.clone();
        let client = ReqClient::connect_opts(
            &addr,
            LaneOpts { mode: LaneMode::On, dir: None, capacity: 0 },
        );
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Pong);
        assert_eq!(client.lane_requests.count(), 1);
        server.shutdown();
        let _server2 = RepServer::serve(&addr, |_| Msg::Ok).unwrap();
        assert_eq!(client.request(&Msg::Ping).unwrap(), Msg::Ok);
        assert_eq!(
            client.lane_requests.count(),
            1,
            "post-crash requests must ride TCP"
        );
    }
}



