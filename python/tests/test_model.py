"""L2 model/algorithm tests: shape contracts, learning sanity, manifest."""

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile import algo, model, nets
from compile.envs_spec import ENV_SPECS, HP_LAYOUT, HP_DEFAULTS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _hp(**over):
    d = dict(HP_DEFAULTS)
    d.update(over)
    return jnp.asarray([d[k] for k in HP_LAYOUT], jnp.float32)


def _fake_batch(spec, seed=0):
    rng = np.random.RandomState(seed)
    T, B, D, A = (spec["train_t"], spec["train_b"], spec["obs_dim"],
                  spec["act_dim"])
    n_ag = (2,) if spec["team"] else ()
    obs = rng.randn(T + 1, B, *n_ag, D).astype(np.float32)
    actions = rng.randint(0, A, (T, B) + n_ag).astype(np.int32)
    behavior_logp = np.full((T, B) + n_ag, -np.log(A), np.float32)
    rewards = rng.randn(T, B).astype(np.float32) * 0.1
    discounts = np.full((T, B), 0.99, np.float32)
    return (obs, actions, behavior_logp, rewards, discounts)


class TestNets:
    @pytest.mark.parametrize("env", list(ENV_SPECS))
    def test_apply_shapes(self, env):
        spec = ENV_SPECS[env]
        flat = nets.init_params(0, nets.specs_for(spec))
        B = 5
        if spec["team"]:
            obs = np.zeros((B, 2, spec["obs_dim"]), np.float32)
            logits, value = nets.apply_team(jnp.asarray(flat), obs, spec)
            assert logits.shape == (B, 2, spec["act_dim"])
        else:
            obs = np.zeros((B, spec["obs_dim"]), np.float32)
            logits, value = nets.apply_solo(jnp.asarray(flat), obs, spec)
            assert logits.shape == (B, spec["act_dim"])
        assert value.shape == (B,)

    def test_flat_roundtrip(self):
        spec = ENV_SPECS["pong2p"]
        specs = nets.specs_for(spec)
        flat = nets.init_params(3, specs)
        parts = nets.unflatten(flat, specs)
        total = sum(int(np.prod(s)) for _, s in specs)
        assert flat.shape == (total,)
        assert parts["policy/w"].shape == (64, 3)

    def test_init_is_deterministic(self):
        spec = ENV_SPECS["rps"]
        a = nets.init_params(17, nets.specs_for(spec))
        b = nets.init_params(17, nets.specs_for(spec))
        np.testing.assert_array_equal(a, b)

    def test_team_value_is_centralized(self):
        # perturbing teammate B's obs must change the (shared) value
        spec = ENV_SPECS["pommerman"]
        flat = jnp.asarray(nets.init_params(0, nets.specs_for(spec)))
        obs = np.random.RandomState(0).randn(1, 2, spec["obs_dim"]) \
            .astype(np.float32)
        _, v1 = nets.apply_team(flat, obs, spec)
        obs2 = obs.copy()
        obs2[0, 1] += 1.0
        _, v2 = nets.apply_team(flat, obs2, spec)
        assert abs(float(v1[0]) - float(v2[0])) > 1e-6


class TestTrainStep:
    @pytest.mark.parametrize("env", ["rps", "pong2p", "pommerman"])
    def test_ppo_loss_decreases_on_fixed_batch(self, env):
        spec = ENV_SPECS[env]
        params = jnp.asarray(nets.init_params(0, nets.specs_for(spec)))
        m = jnp.zeros_like(params)
        v = jnp.zeros_like(params)
        step = jnp.zeros((1,), jnp.float32)
        hp = _hp(lr=1e-3, ent_coef=0.0)
        batch = _fake_batch(spec)
        losses = []
        for _ in range(8):
            params, m, v, step, stats = algo.train_step(
                algo.ppo_loss, params, m, v, step, hp, batch, spec)
            losses.append(float(stats[0]))
        assert losses[-1] < losses[0], losses
        assert float(step[0]) == 8.0

    @pytest.mark.parametrize("loss", ["ppo", "vtrace"])
    def test_policy_learns_rewarded_action(self, loss):
        # reward action 0 (+1) over others (-1): after training, the
        # policy must put more probability on action 0.  This is a real
        # learning-signal test; raw loss curves are not monotone for
        # V-trace because the vs targets move with the value net.
        spec = ENV_SPECS["pong2p"]
        loss_fn = algo.ppo_loss if loss == "ppo" else algo.vtrace_loss
        params = jnp.asarray(nets.init_params(0, nets.specs_for(spec)))
        m = jnp.zeros_like(params)
        v = jnp.zeros_like(params)
        step = jnp.zeros((1,), jnp.float32)
        hp = _hp(lr=2e-3, ent_coef=0.0)
        obs, actions, blogp, rewards, discounts = _fake_batch(spec)
        rewards = np.where(actions == 0, 1.0, -1.0).astype(np.float32)
        discounts = np.zeros_like(discounts)  # bandit-style credit
        batch = (obs, actions, blogp, rewards, discounts)

        def p0(params):
            logits, _ = nets.apply_solo(params, obs[0], spec)
            p = np.exp(np.asarray(logits))
            p /= p.sum(-1, keepdims=True)
            return float(p[:, 0].mean())

        before = p0(params)
        for _ in range(30):
            params, m, v, step, _ = algo.train_step(
                loss_fn, params, m, v, step, hp, batch, spec)
        after = p0(params)
        assert after > before + 0.05, (before, after)

    def test_pallas_and_ref_losses_agree(self):
        spec = ENV_SPECS["pong2p"]
        params = jnp.asarray(nets.init_params(1, nets.specs_for(spec)))
        hp = _hp()
        batch = _fake_batch(spec, seed=2)
        l1, s1 = algo.ppo_loss(params, hp, batch, spec, use_pallas=True)
        l2, s2 = algo.ppo_loss(params, hp, batch, spec, use_pallas=False)
        np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-4)

    def test_grad_plus_apply_equals_fused(self):
        # the split path (grad -> allreduce -> apply) must match the fused
        # train step exactly when run single-learner.
        spec = ENV_SPECS["rps"]
        params = jnp.asarray(nets.init_params(5, nets.specs_for(spec)))
        m = jnp.zeros_like(params)
        v = jnp.zeros_like(params)
        step = jnp.zeros((1,), jnp.float32)
        hp = _hp()
        batch = _fake_batch(spec, seed=9)
        p1, m1, v1, s1, _ = algo.train_step(
            algo.ppo_loss, params, m, v, step, hp, batch, spec)
        grads, _ = algo.grads_of(algo.ppo_loss, params, hp, batch, spec)
        p2, m2, v2, s2 = algo.adam_step(params, m, v, step, grads,
                                        algo.hp_get(hp, "lr"))
        np.testing.assert_allclose(p1, p2, rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(s1, s2)

    def test_grad_clip_bounds_update(self):
        spec = ENV_SPECS["rps"]
        params = jnp.asarray(nets.init_params(2, nets.specs_for(spec)))
        hp = _hp(grad_clip=1e-3)
        batch = _fake_batch(spec, seed=4)
        grads, stats = algo.grads_of(algo.ppo_loss, params, hp, batch, spec)
        gn = float(jnp.sqrt(jnp.sum(grads * grads)))
        assert gn <= 1e-3 * 1.01


class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        path = os.path.join(ART, "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built (run `make artifacts`)")
        with open(path) as f:
            return json.load(f)

    def test_every_env_present(self, manifest):
        assert set(manifest["envs"]) == set(ENV_SPECS)

    def test_param_counts(self, manifest):
        for env, spec in ENV_SPECS.items():
            P = nets.param_count(nets.specs_for(spec))
            assert manifest["envs"][env]["param_count"] == P

    def test_artifact_files_exist_and_shapes(self, manifest):
        for env, ment in manifest["envs"].items():
            for name, art in ment["artifacts"].items():
                path = os.path.join(ART, art["file"])
                assert os.path.exists(path), path
                for label, shape, dt in art["inputs"] + art["outputs"]:
                    assert all(int(s) > 0 for s in shape), (name, label)
                    assert dt in ("f32", "i32")

    def test_init_params_match_manifest(self, manifest):
        import hashlib
        for env, ment in manifest["envs"].items():
            raw = np.fromfile(os.path.join(ART, ment["init_params"]),
                              dtype="<f4")
            assert raw.shape == (ment["param_count"],)
            sha = hashlib.sha256(raw.astype("<f4").tobytes()).hexdigest()
            assert sha[:16] == ment["init_sha"]

    def test_hp_layout_stable(self, manifest):
        assert manifest["hp_layout"] == HP_LAYOUT
