// Seeded-bad fixture: encode writes a literal tag byte and decode
// matches a literal, bypassing the TAG_* registry.
// lint: proto-registry
pub const TAG_A: u8 = 1;

impl Wire for Msg {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Msg::A => buf.put_u8(TAG_A),
            Msg::B => buf.put_u8(2),
        }
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        let tag = cur.u8()?;
        Ok(match tag {
            TAG_A => Msg::A,
            2 => Msg::B,
            t => bail!("unknown tag {t}"),
        })
    }
}
