//! Payoff matrix + Elo ratings over the model pool.
//!
//! The GameMgr (paper §3.2) "maintains a payoff matrix for all the
//! models stored in the pool M".  Outcomes are 1 / 0.5 / 0 from the
//! row player's perspective; win-rates use a weak uniform prior so
//! fresh pairs aren't treated as certainly-even or certainly-lost.

use crate::proto::ModelKey;
use std::collections::BTreeMap;

#[derive(Clone, Copy, Debug, Default)]
pub struct PairStats {
    pub games: u32,
    /// sum of outcomes (win=1, tie=0.5) for the row player
    pub score: f64,
}

#[derive(Default)]
pub struct PayoffMatrix {
    pairs: BTreeMap<(ModelKey, ModelKey), PairStats>,
    elo: BTreeMap<ModelKey, f64>,
    pub elo_k: f64,
}

pub const ELO_BASE: f64 = 1200.0;

impl PayoffMatrix {
    pub fn new() -> Self {
        PayoffMatrix { pairs: BTreeMap::new(), elo: BTreeMap::new(), elo_k: 16.0 }
    }

    pub fn add_model(&mut self, key: ModelKey) {
        self.elo.entry(key).or_insert(ELO_BASE);
    }

    pub fn models(&self) -> Vec<ModelKey> {
        self.elo.keys().copied().collect()
    }

    /// Record `outcome` (row player's view) for row vs col.
    pub fn record(&mut self, row: ModelKey, col: ModelKey, outcome: f32) {
        let e = self.pairs.entry((row, col)).or_default();
        e.games += 1;
        e.score += outcome as f64;
        // mirrored entry keeps lookups one-sided
        let m = self.pairs.entry((col, row)).or_default();
        m.games += 1;
        m.score += 1.0 - outcome as f64;
        // Elo update
        let ra = *self.elo.entry(row).or_insert(ELO_BASE);
        let rb = *self.elo.entry(col).or_insert(ELO_BASE);
        let expect = 1.0 / (1.0 + 10f64.powf((rb - ra) / 400.0));
        let delta = self.elo_k * (outcome as f64 - expect);
        *self.elo.get_mut(&row).unwrap() += delta;
        *self.elo.get_mut(&col).unwrap() -= delta;
    }

    pub fn stats(&self, row: ModelKey, col: ModelKey) -> PairStats {
        self.pairs.get(&(row, col)).copied().unwrap_or_default()
    }

    /// Win-rate of `row` against `col` with a uniform(1 game, 0.5) prior.
    pub fn winrate(&self, row: ModelKey, col: ModelKey) -> f64 {
        let s = self.stats(row, col);
        (s.score + 0.5) / (s.games as f64 + 1.0)
    }

    /// Aggregate win-rate of `key` against the whole pool.
    pub fn pool_winrate(&self, key: ModelKey) -> f64 {
        let mut score = 0.0;
        let mut games = 0u32;
        for (&(r, _c), s) in self.pairs.range((key, ModelKey::new(0, 0))..) {
            if r != key {
                break;
            }
            score += s.score;
            games += s.games;
        }
        (score + 0.5) / (games as f64 + 1.0)
    }

    pub fn elo(&self, key: ModelKey) -> f64 {
        self.elo.get(&key).copied().unwrap_or(ELO_BASE)
    }

    pub fn total_games(&self) -> u64 {
        // each match recorded twice (mirror)
        self.pairs.values().map(|s| s.games as u64).sum::<u64>() / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(v: u32) -> ModelKey {
        ModelKey::new(0, v)
    }

    #[test]
    fn record_mirrors() {
        let mut p = PayoffMatrix::new();
        p.record(k(1), k(2), 1.0);
        p.record(k(1), k(2), 0.0);
        p.record(k(1), k(2), 1.0);
        let s = p.stats(k(1), k(2));
        assert_eq!(s.games, 3);
        assert_eq!(s.score, 2.0);
        let m = p.stats(k(2), k(1));
        assert_eq!(m.games, 3);
        assert_eq!(m.score, 1.0);
    }

    #[test]
    fn winrate_prior_pulls_to_half() {
        let p = PayoffMatrix::new();
        assert_eq!(p.winrate(k(1), k(2)), 0.5);
        let mut p = PayoffMatrix::new();
        p.record(k(1), k(2), 1.0);
        let w = p.winrate(k(1), k(2));
        assert!(w > 0.5 && w < 1.0, "{w}");
    }

    #[test]
    fn elo_moves_toward_winner() {
        let mut p = PayoffMatrix::new();
        p.add_model(k(1));
        p.add_model(k(2));
        for _ in 0..20 {
            p.record(k(1), k(2), 1.0);
        }
        assert!(p.elo(k(1)) > p.elo(k(2)) + 100.0);
        // zero-sum: total Elo conserved
        assert!((p.elo(k(1)) + p.elo(k(2)) - 2.0 * ELO_BASE).abs() < 1e-9);
    }

    #[test]
    fn pool_winrate_aggregates() {
        let mut p = PayoffMatrix::new();
        p.record(k(1), k(2), 1.0);
        p.record(k(1), k(3), 1.0);
        p.record(k(1), k(4), 0.0);
        let w = p.pool_winrate(k(1));
        assert!((w - (2.0 + 0.5) / 4.0).abs() < 1e-9, "{w}");
        assert_eq!(p.total_games(), 3);
    }
}
