// Seeded-bad fixture: two TAG_* consts share a wire value.
// lint: proto-registry
pub const TAG_A: u8 = 1;
pub const TAG_B: u8 = 1;

impl Wire for Msg {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Msg::A => buf.put_u8(TAG_A),
            Msg::B => buf.put_u8(TAG_B),
        }
    }
    fn decode(cur: &mut Cursor) -> Result<Self> {
        let tag = cur.u8()?;
        Ok(match tag {
            TAG_A => Msg::A,
            TAG_B => Msg::B,
            t => bail!("unknown tag {t}"),
        })
    }
}
