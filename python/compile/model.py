"""Artifact entry points: the exact functions AOT-lowered to HLO text.

Each maker returns (fn, example_args, io_spec) where io_spec is the
manifest fragment describing the ordered input/output literals the Rust
runtime will feed/read.  Shapes are fixed at lowering time (PJRT
executables are static-shape); the per-env values come from envs_spec.

Artifact set per env:
  infer_b{1,IB}   (params, obs)                      -> (logits, value)
  train_ppo       (params, m, v, step, hp, batch...) -> (params', m', v',
                                                         step', stats[9])
  grad_ppo        (params, hp, batch...)             -> (grads, stats[9])
  apply_adam      (params, m, v, step, hp, grads)    -> (params', m', v', step')
  train_vtrace    same as train_ppo (solo envs only)
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import algo, nets
from .envs_spec import HP_LAYOUT

F32 = jnp.float32
I32 = jnp.int32


def _sds(shape, dt=F32):
    return jax.ShapeDtypeStruct(shape, dt)


def _io(name, shape, dt="f32"):
    return [name, [int(s) for s in shape], dt]


def _batch_shapes(spec):
    T, B, D = spec["train_t"], spec["train_b"], spec["obs_dim"]
    if spec["team"]:
        return dict(obs=(T + 1, B, 2, D), actions=(T, B, 2),
                    behavior_logp=(T, B, 2), rewards=(T, B),
                    discounts=(T, B))
    return dict(obs=(T + 1, B, D), actions=(T, B),
                behavior_logp=(T, B), rewards=(T, B), discounts=(T, B))


def batch_io(spec):
    shp = _batch_shapes(spec)
    return [
        _io("obs", shp["obs"]),
        _io("actions", shp["actions"], "i32"),
        _io("behavior_logp", shp["behavior_logp"]),
        _io("rewards", shp["rewards"]),
        _io("discounts", shp["discounts"]),
    ]


def _batch_example(spec):
    shp = _batch_shapes(spec)
    return (_sds(shp["obs"]), _sds(shp["actions"], I32),
            _sds(shp["behavior_logp"]), _sds(shp["rewards"]),
            _sds(shp["discounts"]))


def make_infer(spec, batch):
    P = nets.param_count(nets.specs_for(spec))
    D, A = spec["obs_dim"], spec["act_dim"]
    apply_fn = nets.make_apply(spec)
    if spec["team"]:
        obs_shape, log_shape, val_shape = (batch, 2, D), (batch, 2, A), (batch,)
    else:
        obs_shape, log_shape, val_shape = (batch, D), (batch, A), (batch,)

    def infer(params, obs):
        logits, value = apply_fn(params, obs)
        return logits, value

    example = (_sds((P,)), _sds(obs_shape))
    io = dict(
        inputs=[_io("params", (P,)), _io("obs", obs_shape)],
        outputs=[_io("logits", log_shape), _io("value", val_shape)],
    )
    return infer, example, io


def _opt_io(P):
    return [_io("params", (P,)), _io("adam_m", (P,)), _io("adam_v", (P,)),
            _io("step", (1,)), _io("hp", (len(HP_LAYOUT),))]


def make_train(spec, loss_fn, use_pallas=True):
    P = nets.param_count(nets.specs_for(spec))

    def train(params, m, v, step, hp, obs, actions, behavior_logp,
              rewards, discounts):
        batch = (obs, actions, behavior_logp, rewards, discounts)
        kw = {"use_pallas": use_pallas} if loss_fn is algo.ppo_loss else {}
        return algo.train_step(loss_fn, params, m, v, step, hp, batch,
                               spec, **kw)

    example = (_sds((P,)), _sds((P,)), _sds((P,)), _sds((1,)),
               _sds((len(HP_LAYOUT),))) + _batch_example(spec)
    io = dict(
        inputs=_opt_io(P) + batch_io(spec),
        outputs=[_io("params", (P,)), _io("adam_m", (P,)),
                 _io("adam_v", (P,)), _io("step", (1,)),
                 _io("stats", (9,))],
    )
    return train, example, io


def make_grad(spec, loss_fn, use_pallas=True):
    P = nets.param_count(nets.specs_for(spec))

    def grad(params, hp, obs, actions, behavior_logp, rewards, discounts):
        batch = (obs, actions, behavior_logp, rewards, discounts)
        kw = {"use_pallas": use_pallas} if loss_fn is algo.ppo_loss else {}
        return algo.grads_of(loss_fn, params, hp, batch, spec, **kw)

    example = (_sds((P,)), _sds((len(HP_LAYOUT),))) + _batch_example(spec)
    io = dict(
        inputs=[_io("params", (P,)), _io("hp", (len(HP_LAYOUT),))]
        + batch_io(spec),
        outputs=[_io("grads", (P,)), _io("stats", (9,))],
    )
    return grad, example, io


def make_apply_adam(spec):
    P = nets.param_count(nets.specs_for(spec))

    def apply_adam(params, m, v, step, hp, grads):
        lr = algo.hp_get(hp, "lr")
        p2, m2, v2, s2 = algo.adam_step(params, m, v, step, grads, lr)
        return p2, m2, v2, s2

    example = (_sds((P,)), _sds((P,)), _sds((P,)), _sds((1,)),
               _sds((len(HP_LAYOUT),)), _sds((P,)))
    io = dict(
        inputs=_opt_io(P) + [_io("grads", (P,))],
        outputs=[_io("params", (P,)), _io("adam_m", (P,)),
                 _io("adam_v", (P,)), _io("step", (1,))],
    )
    return apply_adam, example, io


def init_state(spec, seed=0):
    """Initial (params, m, v, step) as numpy, for artifacts/init_<env>.f32."""
    specs = nets.specs_for(spec)
    params = nets.init_params(seed, specs)
    return params
