//! End-to-end integration: LeagueMgr + ModelPool + Learner + Actors +
//! (optionally) InfServer, all composing over real TCP + PJRT.
//!
//! These tests need `make artifacts` to have run; they skip otherwise.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use tleague::actor::{Actor, ActorConfig, PolicyBackend};
use tleague::inference::{InfServer, InfServerConfig};
use tleague::league::{LeagueConfig, LeagueMgrServer};
use tleague::learner::replay::ReplayMode;
use tleague::learner::{Learner, LearnerConfig};
use tleague::model_pool::ModelPoolServer;
use tleague::proto::ModelKey;
use tleague::runtime::Engine;

fn engine() -> Option<Arc<Engine>> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(Engine::load(dir).unwrap()))
}

fn league(env: &str, engine: &Engine, game_mgr: &str, n_opponents: usize)
    -> LeagueMgrServer
{
    let _ = env;
    LeagueMgrServer::start(
        "127.0.0.1:0",
        LeagueConfig {
            n_agents: 1,
            n_opponents,
            game_mgr: game_mgr.into(),
            hp_layout: engine.manifest.hp_layout.clone(),
            hp_default: engine.manifest.default_hp(),
            seed: 42,
        },
    )
    .unwrap()
}

/// The core data-plane test: actors generate rps episodes, the learner
/// trains through PJRT, models freeze into the pool, the payoff matrix
/// fills in.
#[test]
fn full_stack_rps_league() {
    let Some(engine) = engine() else { return };
    let pool = ModelPoolServer::start("127.0.0.1:0").unwrap();
    let league = league("rps", &engine, "uniform", 1);
    let pool_addrs = vec![pool.addr.clone()];

    let mut learner = Learner::new(
        LearnerConfig {
            env: "rps".into(),
            agent: 0,
            rank: 0,
            algo: "ppo".into(),
            replay_mode: ReplayMode::Blocking,
            publish_every: 2,
            period_steps: 4,
            replay_cap: 8192,
            seed: 1,
            ..Default::default()
        },
        engine.clone(),
        &pool_addrs,
        &league.addr,
        None,
    )
    .unwrap();
    let data_addr = learner.data_addr();

    let stop = Arc::new(AtomicBool::new(false));
    let mut actor_handles = Vec::new();
    for a in 0..2u64 {
        let engine = engine.clone();
        let league_addr = league.addr.clone();
        let pool_addrs = pool_addrs.clone();
        let data_addr = data_addr.clone();
        let stop = stop.clone();
        actor_handles.push(std::thread::spawn(move || {
            let mut actor = Actor::new(
                ActorConfig {
                    env: "rps".into(),
                    actor_id: format!("0/actor{a}"),
                    seed: 100 + a,
                    gamma: 0.99,
                    refresh_every: 1,
                    train_t: 1,
                    trace_sample: 0.0,
                },
                PolicyBackend::Local(engine),
                &league_addr,
                &pool_addrs,
                &data_addr,
            )
            .unwrap();
            actor.run(u64::MAX, &stop).unwrap();
        }));
    }

    // train for 10 steps (2.5 learning periods)
    let steps = learner.run(10, &AtomicBool::new(false)).unwrap();
    stop.store(true, Ordering::Relaxed);
    for h in actor_handles {
        h.join().unwrap();
    }

    assert_eq!(steps, 10);
    assert!(learner.last_stats.loss.is_finite());
    assert!(learner.last_stats.entropy > 0.0, "policy must keep entropy");
    // 10 steps / period 4 => at least 2 freezes beyond the seed
    let lstats = league.stats();
    assert!(lstats.pool_size >= 3, "pool {}", lstats.pool_size);
    assert!(lstats.episodes > 0);
    // learner advanced to a later version
    assert!(learner.key.version >= 3, "key {}", learner.key);
    // cfps == rfps frames consumed once in blocking mode (tolerate the
    // segments still in flight/replay)
    assert!(learner.cfps_count() <= learner.rfps_count());
    // models are retrievable and correctly sized
    let m = engine.manifest.env("rps").unwrap();
    let client = tleague::model_pool::ModelPoolClient::connect(&pool_addrs);
    let blob = client.get(ModelKey::new(0, 1)).unwrap().unwrap();
    assert_eq!(blob.params.len(), m.param_count);
    assert!(blob.frozen, "period-ended version must be frozen");
}

/// Pommerman team mode through the full stack: exercises the 2-agent
/// meta-agent trajectory layout + centralized-value train artifact.
#[test]
fn full_stack_pommerman_team_smoke() {
    let Some(engine) = engine() else { return };
    let pool = ModelPoolServer::start("127.0.0.1:0").unwrap();
    let league = league("pommerman", &engine, "sp_pfsp", 1);
    let pool_addrs = vec![pool.addr.clone()];

    let mut learner = Learner::new(
        LearnerConfig {
            env: "pommerman".into(),
            agent: 0,
            rank: 0,
            algo: "ppo".into(),
            replay_mode: ReplayMode::Blocking,
            publish_every: 2,
            period_steps: 8,
            replay_cap: 1024,
            seed: 2,
            ..Default::default()
        },
        engine.clone(),
        &pool_addrs,
        &league.addr,
        None,
    )
    .unwrap();
    let data_addr = learner.data_addr();

    let stop = Arc::new(AtomicBool::new(false));
    let engine2 = engine.clone();
    let league_addr = league.addr.clone();
    let pool_addrs2 = pool_addrs.clone();
    let stop2 = stop.clone();
    let h = std::thread::spawn(move || {
        let mut actor = Actor::new(
            ActorConfig {
                env: "pommerman".into(),
                actor_id: "0/pom".into(),
                seed: 7,
                gamma: 0.99,
                refresh_every: 1,
                train_t: 0,
                trace_sample: 0.0,
            },
            PolicyBackend::Local(engine2),
            &league_addr,
            &pool_addrs2,
            &data_addr,
        )
        .unwrap();
        actor.run(u64::MAX, &stop2).unwrap();
    });

    let done = learner.run(2, &AtomicBool::new(false)).unwrap();
    stop.store(true, Ordering::Relaxed);
    h.join().unwrap();
    assert_eq!(done, 2);
    assert!(learner.last_stats.loss.is_finite());
}

/// InfServer-backed actor: remote inference path composes with the
/// league loop.
#[test]
fn full_stack_infserver_actor() {
    let Some(engine) = engine() else { return };
    let pool = ModelPoolServer::start("127.0.0.1:0").unwrap();
    let league = league("rps", &engine, "selfplay", 1);
    let pool_addrs = vec![pool.addr.clone()];

    let mut learner = Learner::new(
        LearnerConfig {
            env: "rps".into(),
            agent: 0,
            rank: 0,
            algo: "ppo".into(),
            replay_mode: ReplayMode::Blocking,
            publish_every: 1,
            period_steps: 100,
            replay_cap: 8192,
            seed: 3,
            ..Default::default()
        },
        engine.clone(),
        &pool_addrs,
        &league.addr,
        None,
    )
    .unwrap();
    let data_addr = learner.data_addr();

    let m = engine.manifest.env("rps").unwrap().clone();
    let inf = InfServer::start(
        "127.0.0.1:0",
        InfServerConfig {
            env: "rps".into(),
            batch: m.infer_b,
            max_wait: Duration::from_millis(2),
            refresh: Duration::from_millis(20),
            net_threads: 0,
        },
        engine.clone(),
        &pool_addrs,
    )
    .unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let league_addr = league.addr.clone();
    let pool_addrs2 = pool_addrs.clone();
    let inf_addr = inf.addr.clone();
    let stop2 = stop.clone();
    let h = std::thread::spawn(move || {
        let mut actor = Actor::new(
            ActorConfig {
                env: "rps".into(),
                actor_id: "0/inf-actor".into(),
                seed: 11,
                gamma: 0.99,
                refresh_every: 1,
                train_t: 1, // rps manifest train_t (required for Remote)
                trace_sample: 0.0,
            },
            PolicyBackend::Remote(tleague::transport::ReqClient::connect(
                &inf_addr,
            )),
            &league_addr,
            &pool_addrs2,
            &data_addr,
        )
        .unwrap();
        actor.run(u64::MAX, &stop2).unwrap();
    });

    let steps = learner.run(3, &AtomicBool::new(false)).unwrap();
    stop.store(true, Ordering::Relaxed);
    h.join().unwrap();
    assert_eq!(steps, 3);
    assert!(inf.rows_meter.count() > 0, "InfServer must have served rows");
}

/// Multi-learner synchronous training: grad + allreduce + apply keeps
/// two ranks bit-identical (the Horovod design point).
#[test]
fn multi_learner_ranks_stay_identical() {
    let Some(engine) = engine() else { return };
    let pool = ModelPoolServer::start("127.0.0.1:0").unwrap();
    let league = league("rps", &engine, "uniform", 1);
    let pool_addrs = vec![pool.addr.clone()];
    let group = tleague::learner::allreduce::Allreduce::new(2);

    let stop = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();
    let mut data_addr_slots: Vec<std::sync::mpsc::Receiver<String>> = Vec::new();
    let params_out = Arc::new(std::sync::Mutex::new(Vec::<Vec<f32>>::new()));
    for rank in 0..2usize {
        let engine = engine.clone();
        let pool_addrs = pool_addrs.clone();
        let league_addr = league.addr.clone();
        let group = group.clone();
        let params_out = params_out.clone();
        let learner_stop = stop.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        data_addr_slots.push(rx);
        handles.push(std::thread::spawn(move || {
            let mut learner = Learner::new(
                LearnerConfig {
                    env: "rps".into(),
                    agent: 0,
                    rank,
                    algo: "ppo".into(),
                    replay_mode: ReplayMode::Blocking,
                    publish_every: 2,
                    period_steps: 3,
                    replay_cap: 8192,
                    seed: 4 + rank as u64,
                    ..Default::default()
                },
                engine,
                &pool_addrs,
                &league_addr,
                Some(group),
            )
            .unwrap();
            tx.send(learner.data_addr()).unwrap();
            learner.run(6, &AtomicBool::new(false)).unwrap();
            params_out.lock().unwrap().push(learner.params().to_vec());
            // keep the PullServer alive until the actors are stopped,
            // else their pushes error out mid-shutdown
            while !learner_stop.load(Ordering::Relaxed) {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
        }));
    }
    let data_addrs: Vec<String> =
        data_addr_slots.iter().map(|rx| rx.recv().unwrap()).collect();

    // one actor per learner rank (M_A = 1)
    let mut actor_handles = Vec::new();
    for (i, da) in data_addrs.iter().enumerate() {
        let engine = engine.clone();
        let league_addr = league.addr.clone();
        let pool_addrs = pool_addrs.clone();
        let da = da.clone();
        let stop = stop.clone();
        actor_handles.push(std::thread::spawn(move || {
            let mut actor = Actor::new(
                ActorConfig {
                    env: "rps".into(),
                    actor_id: format!("0/ml{i}"),
                    seed: 50 + i as u64,
                    gamma: 0.99,
                    refresh_every: 1,
                    train_t: 1,
                    trace_sample: 0.0,
                },
                PolicyBackend::Local(engine),
                &league_addr,
                &pool_addrs,
                &da,
            )
            .unwrap();
            actor.run(u64::MAX, &stop).unwrap();
        }));
    }

    // wait until both ranks finished training, then release everyone
    while params_out.lock().unwrap().len() < 2 {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    stop.store(true, Ordering::Relaxed);
    for h in actor_handles {
        h.join().unwrap();
    }
    for h in handles {
        h.join().unwrap();
    }
    let ps = params_out.lock().unwrap();
    assert_eq!(ps.len(), 2);
    assert_eq!(ps[0], ps[1], "ranks diverged");
}
