//! Benchmark harness (criterion is unavailable offline; this is a
//! hand-rolled runner with warmup + median/mean reporting, wired to
//! `cargo bench`).  Groups:
//!
//!   codec       — trajectory encode/decode throughput (transport hot path)
//!   assemble    — batch assembly (learner hot path)
//!   envs        — env step cost per environment (actor hot path)
//!   infer       — PJRT inference: batch-1 vs batch-32 (ablation A2)
//!   train       — PJRT train-step latency per env
//!   samplers    — GameMgr opponent-sampling cost (ablation A1 substrate)
//!   replay      — blocking vs ratio replay modes (ablation A3)
//!   checkpoint  — league snapshot encode/decode + disk save/restore MB/s
//!   pool        — ModelPool serve path: cold vs frame-cache GetModel,
//!                 if-newer NotModified latency
//!   batcher     — InfServer condvar batcher wake-to-dispatch latency
//!   deploy      — procs-mode control plane: task-assignment round-trip,
//!                 heartbeat overhead at 64 registered workers
//!   telemetry   — stats snapshot encode/decode, 64-slot league merge,
//!                 heartbeat-with-stats round-trip at 64 workers
//!   trace       — request-path tracing: span record overhead, latency
//!                 hist record + 64-way merge, actor row path at
//!                 trace-sample 0 / 1% / 100% (off must match untraced)
//!   faults      — fault-injection guard: disabled hot-path check cost,
//!                 enabled check against a non-matching plan, actor row
//!                 path with injection off vs armed (off must be free)
//!   transport_scale — fan-in echo/heartbeat at 64/512/4096 conns on one
//!                 event-loop pool (fd-limit aware), multi-row infer
//!                 request over loopback TCP vs a shared-memory lane
//!   elastic     — sharded-pool + autoscaler hot paths: consistent-hash
//!                 ring owner lookup, replica-bounce rebalance transfer
//!                 (bytes moved through the rev protocol), scaling-loop
//!                 decision latency at 64 slots
//!
//! Filter with `cargo bench -- <substring> [<substring> ...]` (a bench
//! runs if it matches ANY given substring); add `--json <path>` to also
//! write the rows as JSON (the BENCH_prN.json trajectory files).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use tleague::checkpoint::{CheckpointMgr, LeagueSnapshot};
use tleague::envs::{self, MultiAgentEnv};
use tleague::inference::{infer_remote, InfServer, InfServerConfig};
use tleague::league::game_mgr::make_game_mgr;
use tleague::league::hyper::HyperMgr;
use tleague::league::payoff::PayoffMatrix;
use tleague::learner::replay::{assemble, ReplayMem, ReplayMode};
use tleague::model_pool::{LatestFetch, ModelPoolClient, ModelPoolServer};
use tleague::proto::{ModelBlob, ModelKey, Msg, TrajSegment};
use tleague::runtime::{Engine, Tensor};
use tleague::transport::ReqClient;
use tleague::util::codec::Wire;
use tleague::util::rng::Pcg32;

struct Bench {
    filters: Vec<String>,
    json_out: Option<String>,
    rows: Vec<(String, f64, f64, String)>,
}

impl Bench {
    fn new() -> Bench {
        let mut filters = Vec::new();
        let mut json_out = None;
        let mut it = std::env::args().skip(1);
        while let Some(a) = it.next() {
            if a == "--json" {
                json_out = it.next();
            } else if !a.starts_with('-') {
                filters.push(a);
            } // other flags (cargo's --bench etc.) are ignored
        }
        Bench { filters, json_out, rows: Vec::new() }
    }

    /// Run `f` repeatedly; report median iter time and a throughput note.
    fn bench<F: FnMut() -> u64>(&mut self, name: &str, unit: &str, mut f: F) {
        if !self.filters.is_empty()
            && !self.filters.iter().any(|flt| name.contains(flt.as_str()))
        {
            return;
        }
        // warmup
        let mut units = 0;
        for _ in 0..3 {
            units = f();
        }
        let mut times = Vec::new();
        let target_iters = 10usize;
        for _ in 0..target_iters {
            let t0 = Instant::now();
            units = f();
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(f64::total_cmp);
        let median = times[times.len() / 2];
        let rate = units as f64 / median;
        println!(
            "{name:<44} {:>10.3} ms/iter   {:>12.0} {unit}/s",
            median * 1e3,
            rate
        );
        self.rows
            .push((name.to_string(), median * 1e3, rate, unit.to_string()));
    }

    /// Write the collected rows as JSON (rate units: see each row's
    /// `unit`; `B`-unit rows read as bytes/s, i.e. MB/s = rate / 1e6).
    fn write_json(&self) {
        let Some(path) = &self.json_out else { return };
        let mut s = String::from("{\n  \"benches\": [\n");
        for (i, (name, ms, rate, unit)) in self.rows.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{name}\", \"ms_per_iter\": {ms:.6}, \
                 \"rate_per_s\": {rate:.3}, \"unit\": \"{unit}\"}}{}\n",
                if i + 1 < self.rows.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        std::fs::write(path, s).expect("write bench json");
        println!("wrote {path}");
    }
}

fn sample_seg(t: usize, na: usize, d: usize, rng: &mut Pcg32) -> TrajSegment {
    TrajSegment {
        model_key: ModelKey::new(0, 1),
        t: t as u32,
        n_agents: na as u32,
        obs: (0..(t + 1) * na * d).map(|_| rng.next_f32()).collect(),
        actions: (0..t * na).map(|_| rng.below(6) as i32).collect(),
        behavior_logp: (0..t * na).map(|_| -rng.next_f32()).collect(),
        rewards: (0..t).map(|_| rng.next_f32()).collect(),
        discounts: vec![0.99; t],
        trace: None,
    }
}

fn main() {
    let mut b = Bench::new();
    let mut rng = Pcg32::new(1, 1);

    // ---- codec ---------------------------------------------------------
    let seg = sample_seg(16, 2, 980, &mut rng);
    let msg = Msg::Traj(seg.clone());
    let bytes = msg.to_bytes();
    println!("\n# codec (pommerman-sized segment: {} KiB)", bytes.len() / 1024);
    b.bench("codec/encode_traj_segment", "seg", || {
        let mut n = 0;
        for _ in 0..100 {
            let buf = msg.to_bytes();
            std::hint::black_box(&buf);
            n += 1;
        }
        n
    });
    b.bench("codec/decode_traj_segment", "seg", || {
        let mut n = 0;
        for _ in 0..100 {
            let m = Msg::from_bytes(&bytes).unwrap();
            std::hint::black_box(&m);
            n += 1;
        }
        n
    });

    // ---- batch assembly --------------------------------------------------
    println!("\n# learner batch assembly");
    let segs: Vec<TrajSegment> =
        (0..32).map(|_| sample_seg(16, 2, 980, &mut rng)).collect();
    b.bench("assemble/pommerman_32x16", "batch", || {
        let mut n = 0;
        for _ in 0..20 {
            let batch = assemble(&segs, 980).unwrap();
            std::hint::black_box(&batch);
            n += 1;
        }
        n
    });

    // ---- env stepping -----------------------------------------------------
    println!("\n# env step cost (drives Table-3 in-game fps)");
    for env_name in ["rps", "pong2p", "pommerman", "doom_lite", "synthetic"] {
        let mut env = envs::make(env_name, 1).unwrap();
        let mut obs = env.reset();
        let n_agents = env.n_agents();
        let act_dim = env.act_dim();
        let mut t = 0usize;
        b.bench(&format!("envs/{env_name}/step"), "step", move || {
            let mut n = 0;
            for _ in 0..200 {
                let acts: Vec<usize> =
                    (0..n_agents).map(|i| (t + i) % act_dim).collect();
                let s = env.step(&acts);
                t += 1;
                if s.done {
                    obs = env.reset();
                } else {
                    obs = s.obs;
                }
                n += 1;
            }
            std::hint::black_box(&obs);
            n
        });
    }

    // ---- PJRT inference + training ------------------------------------
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let engine = Arc::new(Engine::load(&dir).unwrap());
        println!("\n# PJRT inference: batch-1 vs batch-32 (InfServer ablation A2)");
        for env_name in ["pommerman", "doom_lite"] {
            let m = engine.manifest.env(env_name).unwrap().clone();
            let params = engine.init_params(env_name).unwrap();
            let na = m.n_agents();
            let obs1 = vec![0.1f32; na * m.obs_dim];
            let eng = engine.clone();
            let p2 = params.clone();
            let en = env_name.to_string();
            b.bench(&format!("infer/{env_name}/b1"), "row", move || {
                let mut n = 0;
                for _ in 0..20 {
                    let out = eng.infer(&en, 1, &p2, &obs1).unwrap();
                    std::hint::black_box(&out);
                    n += 1;
                }
                n
            });
            let obs32 = vec![0.1f32; m.infer_b * na * m.obs_dim];
            let eng = engine.clone();
            let en = env_name.to_string();
            let ib = m.infer_b as u64;
            b.bench(&format!("infer/{env_name}/b32"), "row", move || {
                let mut n = 0;
                for _ in 0..20 {
                    let out = eng.infer(&en, 32, &params, &obs32).unwrap();
                    std::hint::black_box(&out);
                    n += ib;
                }
                n
            });
        }

        println!("\n# PJRT train step (frames/s = cfps upper bound per learner)");
        for env_name in ["rps", "pommerman", "doom_lite"] {
            let m = engine.manifest.env(env_name).unwrap().clone();
            let p = m.param_count;
            let na = m.n_agents();
            let (t, bsz, d) = (m.train_t, m.train_b, m.obs_dim);
            let params = engine.init_params(env_name).unwrap();
            let hp = engine.manifest.default_hp();
            let inputs: Vec<Tensor> = vec![
                Tensor::F32(params),
                Tensor::F32(vec![0.0; p]),
                Tensor::F32(vec![0.0; p]),
                Tensor::F32(vec![0.0]),
                Tensor::F32(hp),
                Tensor::F32(vec![0.1; (t + 1) * bsz * na * d]),
                Tensor::I32(vec![1; t * bsz * na]),
                Tensor::F32(vec![-1.0; t * bsz * na]),
                Tensor::F32(vec![0.1; t * bsz]),
                Tensor::F32(vec![0.99; t * bsz]),
            ];
            let eng = engine.clone();
            let art = format!("train_ppo_{env_name}");
            let en = env_name.to_string();
            let frames = (t * bsz) as u64;
            b.bench(&format!("train/{env_name}/ppo_step"), "frame", move || {
                let out = eng.run(&en, &art, &inputs).unwrap();
                std::hint::black_box(&out);
                frames
            });
        }

        // ---- infserver batcher -------------------------------------------
        println!("\n# infserver batcher (condvar wake-to-dispatch vs old sleep-poll)");
        {
            let m = engine.manifest.env("rps").unwrap().clone();
            let bpool = ModelPoolServer::start("127.0.0.1:0").unwrap();
            let bpc = ModelPoolClient::connect(&[bpool.addr.clone()]);
            let bkey = ModelKey::new(0, 1);
            bpc.put(ModelBlob {
                key: bkey,
                params: engine.init_params("rps").unwrap(),
                hp: vec![],
                frozen: true,
            })
            .unwrap();
            let obs = vec![0.1f32; m.obs_dim];
            // batch=1: every request is a full batch — the latency is
            // pure condvar wake + forward + reply (no deadline wait)
            let inf1 = InfServer::start(
                "127.0.0.1:0",
                InfServerConfig {
                    env: "rps".into(),
                    batch: 1,
                    max_wait: Duration::from_millis(2),
                    refresh: Duration::from_millis(50),
                    net_threads: 0,
                },
                engine.clone(),
                &[bpool.addr.clone()],
            )
            .unwrap();
            let c1 = ReqClient::connect(&inf1.addr);
            let o = obs.clone();
            b.bench("batcher/wake_to_dispatch_b1", "req", move || {
                let mut n = 0;
                for _ in 0..50 {
                    infer_remote(&c1, bkey, &o, 1).unwrap();
                    n += 1;
                }
                n
            });
            // batch=infer_b with a single client: every request rides
            // the max_wait deadline — measures the deadline-timer path
            let infb = InfServer::start(
                "127.0.0.1:0",
                InfServerConfig {
                    env: "rps".into(),
                    batch: m.infer_b,
                    max_wait: Duration::from_millis(2),
                    refresh: Duration::from_millis(50),
                    net_threads: 0,
                },
                engine.clone(),
                &[bpool.addr.clone()],
            )
            .unwrap();
            let cb = ReqClient::connect(&infb.addr);
            b.bench("batcher/deadline_partial_b1", "req", move || {
                let mut n = 0;
                for _ in 0..20 {
                    infer_remote(&cb, bkey, &obs, 1).unwrap();
                    n += 1;
                }
                n
            });
        }
    } else {
        println!("\n(artifacts not built; skipping PJRT benches)");
    }

    // ---- opponent samplers ----------------------------------------------
    println!("\n# GameMgr samplers over a 200-model pool (ablation A1)");
    let pool: Vec<ModelKey> = (0..200).map(|v| ModelKey::new(0, v)).collect();
    let mut payoff = PayoffMatrix::new();
    let mut prng = Pcg32::new(7, 7);
    for _ in 0..2000 {
        let a = pool[prng.below(200) as usize];
        let bq = pool[prng.below(200) as usize];
        payoff.record(a, bq, prng.next_f32());
    }
    let payoff = Arc::new(payoff);
    for name in ["selfplay", "uniform", "pfsp", "sp_pfsp", "elo_match"] {
        let mut mgr = make_game_mgr(name).unwrap();
        let pool = pool.clone();
        let payoff2 = payoff.clone();
        let mut rng2 = Pcg32::new(9, 9);
        let learner = ModelKey::new(0, 200);
        b.bench(&format!("samplers/{name}"), "sample", move || {
            let mut n = 0;
            for _ in 0..1000 {
                let ops =
                    mgr.sample_opponents(learner, 1, &pool, &payoff2, &mut rng2);
                std::hint::black_box(&ops);
                n += 1;
            }
            n
        });
    }

    // ---- replay modes ----------------------------------------------------
    println!("\n# replay memory: blocking vs ratio (ablation A3)");
    for (label, mode) in [
        ("blocking", ReplayMode::Blocking),
        ("ratio4", ReplayMode::Ratio { max_reuse: 4 }),
    ] {
        let mut rng3 = Pcg32::new(3, 3);
        let segs: Vec<TrajSegment> =
            (0..256).map(|_| sample_seg(16, 1, 128, &mut rng3)).collect();
        b.bench(&format!("replay/{label}"), "sample", move || {
            let mut mem = ReplayMem::new(mode, 4096, 1);
            for s in &segs {
                mem.push(s.clone());
            }
            let mut n = 0;
            while let Some(batch) = mem.sample(32) {
                std::hint::black_box(&batch);
                n += 1;
                if n > 64 {
                    break;
                }
            }
            n
        });
    }

    // ---- checkpoint snapshot / restore -----------------------------------
    println!("\n# checkpoint: 100-model synthetic pool (25k params each)");
    let mut payoff = PayoffMatrix::new();
    let mut crng = Pcg32::new(11, 11);
    let keys: Vec<ModelKey> = (0..100).map(|v| ModelKey::new(0, v)).collect();
    for _ in 0..2000 {
        let a = keys[crng.below(100) as usize];
        let bk = keys[crng.below(100) as usize];
        payoff.record(a, bk, crng.next_f32());
    }
    let mut hyper = HyperMgr::new(
        vec!["lr".into(), "ent_coef".into()],
        vec![3e-4, 0.01],
        3,
    );
    for &k in &keys {
        hyper.set(k, vec![3e-4, 0.01]);
    }
    let models: Vec<ModelBlob> = keys
        .iter()
        .map(|&key| ModelBlob {
            key,
            params: (0..25_000u32).map(|i| (i ^ key.version) as f32).collect(),
            hp: vec![3e-4, 0.01],
            frozen: true,
        })
        .collect();
    let snap = LeagueSnapshot {
        pool: keys.clone(),
        current: vec![ModelKey::new(0, 100)],
        next_task: 1000,
        episodes: 5000,
        frames: 500_000,
        n_opponents: 1,
        game_mgr: "pfsp".into(),
        rng: Pcg32::new(1, 1).state_parts(),
        payoff,
        hyper,
        models,
    };
    // units are bytes so the printed rate is exact; MB/s = rate / 1e6
    let snap_bytes = snap.to_bytes();
    let nbytes = snap_bytes.len() as u64;
    println!("snapshot size: {:.2} MB", nbytes as f64 / 1e6);
    b.bench("checkpoint/snapshot_encode", "B", || {
        let buf = snap.to_bytes();
        std::hint::black_box(&buf);
        nbytes
    });
    b.bench("checkpoint/snapshot_decode", "B", || {
        let s = LeagueSnapshot::from_bytes(&snap_bytes).unwrap();
        std::hint::black_box(&s);
        nbytes
    });
    let ckpt_dir = std::env::temp_dir()
        .join(format!("tleague-bench-ckpt-{}", std::process::id()));
    std::fs::remove_dir_all(&ckpt_dir).ok();
    {
        let mgr = CheckpointMgr::open(&ckpt_dir, 2).unwrap();
        b.bench("checkpoint/snapshot_save_disk", "B", || {
            mgr.save(&snap).unwrap();
            nbytes
        });
        b.bench("checkpoint/restore_disk", "B", || {
            let s = mgr.load_latest().unwrap().unwrap();
            std::hint::black_box(&s);
            nbytes
        });
    }
    std::fs::remove_dir_all(&ckpt_dir).ok();

    // ---- model pool serve path --------------------------------------------
    println!("\n# model pool data plane (1M-f32 params = 4 MB per blob, loopback TCP)");
    {
        let srv = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let cli = ModelPoolClient::connect(&[srv.addr.clone()]);
        let pkey = ModelKey::new(0, 1);
        let n_params = 1_000_000usize;
        let blob_bytes = (n_params * 4) as u64;
        let mk = |v: f32| ModelBlob {
            key: pkey,
            params: vec![v; n_params],
            hp: vec![3e-4],
            frozen: false,
        };
        // setup OUTSIDE the (filterable) bench closures so every bench
        // in this section works standalone under any filter
        cli.put(mk(1.0)).unwrap();
        // cold: every iteration re-puts (which invalidates the frame
        // cache) then gets — one params encode per get.  Counted bytes
        // cover both directions, so the rate is the combined MB/s.
        b.bench("pool/reput_then_get_cold", "B", || {
            cli.put(mk(2.0)).unwrap();
            let got = cli.get(pkey).unwrap().unwrap();
            std::hint::black_box(&got);
            2 * blob_bytes
        });
        let cold_encodes = srv.frame_encodes();
        // hot: repeated gets of an unchanged blob — served from the
        // pre-encoded frame cache with zero params copy / zero encode
        b.bench("pool/get_model_hot", "B", || {
            let mut n = 0;
            for _ in 0..4 {
                let got = cli.get(pkey).unwrap().unwrap();
                std::hint::black_box(&got);
                n += blob_bytes;
            }
            n
        });
        let hot_encodes = srv.frame_encodes() - cold_encodes;
        assert!(
            hot_encodes <= 1,
            "hot gets must hit the frame cache (saw {hot_encodes} rebuilds)"
        );
        // steady-state refresh of an unchanged in-training model: O(1)
        // NotModified replies instead of the 4 MB payload
        let rev = match cli.get_latest_if_newer(0, 0, 0).unwrap() {
            LatestFetch::New { rev, .. } => rev,
            other => panic!("expected New, got {other:?}"),
        };
        b.bench("pool/if_newer_hit_notmodified", "req", || {
            let mut n = 0;
            for _ in 0..500 {
                match cli.get_latest_if_newer(0, 1, rev).unwrap() {
                    LatestFetch::NotModified => {}
                    other => panic!("expected NotModified, got {other:?}"),
                }
                n += 1;
            }
            n
        });
        println!(
            "pool frame encodes: {} total (hot gets + if-newer hits add zero)",
            srv.frame_encodes()
        );
    }

    // ---- rollout engine ---------------------------------------------------
    // Vectorized actor frames/s for N in {1, 8, 32} env slots.  The
    // remote rows use a stub inference server (uniform policy, no PJRT)
    // so they isolate the rollout machinery: env stepping, per-key
    // gather/scatter, wire traffic, segment assembly.  The local rows
    // (artifact-gated) run the real b1 / chunked-b32 PJRT artifacts.
    println!("\n# rollout engine: single-env vs vectorized actors (frames/s)");
    {
        use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
        use tleague::actor::{Actor, ActorConfig, PolicyBackend};
        use tleague::proto::TaskSpec;
        use tleague::transport::{PullServer, RepServer};

        let next = AtomicU64::new(1);
        let league = RepServer::serve("127.0.0.1:0", move |msg| match msg {
            Msg::RequestActorTask { .. } => Msg::Task(TaskSpec {
                task_id: next.fetch_add(1, Ordering::Relaxed),
                learner_key: ModelKey::new(0, 1),
                opponents: vec![ModelKey::new(0, 0)],
                hp: vec![],
            }),
            Msg::ReportOutcome(_) => Msg::Ok,
            other => Msg::Err(format!("stub league: {other:?}")),
        })
        .unwrap();
        // sink: drain trajectories in the background so pushes never block
        let sink = PullServer::bind("127.0.0.1:0", 1024).unwrap();
        let sink_addr = sink.addr.clone();
        let drain_stop = Arc::new(AtomicBool::new(false));
        let ds = drain_stop.clone();
        let drainer = std::thread::spawn(move || {
            let sink = sink;
            while !ds.load(Ordering::Relaxed) {
                while sink.try_recv().is_some() {}
                std::thread::sleep(Duration::from_millis(1));
            }
        });
        let mk_pool = |params: Vec<f32>| {
            let pool = ModelPoolServer::start("127.0.0.1:0").unwrap();
            let pc = ModelPoolClient::connect(&[pool.addr.clone()]);
            for (v, frozen) in [(0u32, true), (1u32, false)] {
                pc.put(ModelBlob {
                    key: ModelKey::new(0, v),
                    params: params.clone(),
                    hp: vec![],
                    frozen,
                })
                .unwrap();
            }
            pool
        };

        // raw vectorized env stepping: VecEnv auto-reset (step_all),
        // no inference/wire — the env-side ceiling of the rollout path
        {
            use tleague::envs::VecEnv;
            for n in [1usize, 32] {
                let mut v = VecEnv::make("synthetic:64", n, 5).unwrap();
                v.reset_all();
                let mut t = 0usize;
                let ticks = (256 / n).max(1);
                b.bench(
                    &format!("rollout/vecenv/step_all_n{n}"),
                    "frame",
                    move || {
                        let mut frames = 0u64;
                        for _ in 0..ticks {
                            let acts: Vec<Vec<usize>> = (0..n)
                                .map(|s| vec![(t + s) % 16, (t * 3 + s) % 16])
                                .collect();
                            let steps = v.step_all(&acts);
                            std::hint::black_box(&steps);
                            t += 1;
                            frames += steps.len() as u64;
                        }
                        frames
                    },
                );
            }
        }

        let stub_pool = mk_pool(vec![0.0; 8]);
        for env_name in ["synthetic", "pommerman"] {
            let act_dim = envs::make(env_name, 0).unwrap().act_dim();
            let inf = RepServer::serve("127.0.0.1:0", move |msg| match msg {
                Msg::InferReq { rows, .. } => Msg::InferResp {
                    logits: vec![0.0; rows as usize * act_dim],
                    value: vec![0.0; rows as usize],
                },
                other => Msg::Err(format!("stub inf: {other:?}")),
            })
            .unwrap();
            for n in [1usize, 8, 32] {
                let mut actor = Actor::new_vec(
                    ActorConfig {
                        env: env_name.into(),
                        actor_id: format!("0/bench-{env_name}-r{n}"),
                        seed: 1,
                        gamma: 0.99,
                        refresh_every: 1_000_000,
                        train_t: 8,
                        trace_sample: 0.0,
                    },
                    n,
                    PolicyBackend::Remote(ReqClient::connect(&inf.addr)),
                    &league.addr,
                    &[stub_pool.addr.clone()],
                    &sink_addr,
                )
                .unwrap();
                let never = AtomicBool::new(false);
                b.bench(
                    &format!("rollout/{env_name}/remote_n{n}"),
                    "frame",
                    move || actor.run(1024, &never).unwrap(),
                );
            }
        }

        if dir.join("manifest.json").exists() {
            let engine = Arc::new(Engine::load(&dir).unwrap());
            for env_name in ["synthetic", "pommerman"] {
                let lpool = mk_pool(engine.init_params(env_name).unwrap());
                for n in [1usize, 8, 32] {
                    let mut actor = Actor::new_vec(
                        ActorConfig {
                            env: env_name.into(),
                            actor_id: format!("0/bench-{env_name}-l{n}"),
                            seed: 1,
                            gamma: 0.99,
                            refresh_every: 1_000_000,
                            train_t: 0, // manifest train_t
                            trace_sample: 0.0,
                        },
                        n,
                        PolicyBackend::Local(engine.clone()),
                        &league.addr,
                        &[lpool.addr.clone()],
                        &sink_addr,
                    )
                    .unwrap();
                    let never = AtomicBool::new(false);
                    b.bench(
                        &format!("rollout/{env_name}/local_n{n}"),
                        "frame",
                        move || actor.run(256, &never).unwrap(),
                    );
                }
            }
        } else {
            println!("(artifacts not built; skipping rollout/local benches)");
        }

        drain_stop.store(true, Ordering::Relaxed);
        drainer.join().ok();
    }

    // ---- deploy: procs-mode control plane ---------------------------------
    // Controller protocol cost only (no PJRT, no engine): how fast can
    // slots be assigned, and what does a heartbeat round-trip cost when
    // 64 workers are registered.
    println!("\n# deploy control plane (64 actor slots, loopback TCP)");
    {
        use tleague::config::RunConfig;
        use tleague::orchestrator::controller::Controller;
        let mut cfg = RunConfig::default();
        cfg.env = "rps".into();
        cfg.mode = "procs".into();
        cfg.actors_per_learner = 64;
        cfg.heartbeat_ms = 1_000;
        cfg.heartbeat_timeout_ms = 600_000; // no reaping mid-bench
        let ctrl = Controller::start(cfg, vec!["lr".into()], vec![3e-4]).unwrap();
        let c = ReqClient::connect(&ctrl.addr);
        let register = |c: &ReqClient, role: &str| match c
            .request(&Msg::Register { role: role.into(), slot_hint: -1 })
            .unwrap()
        {
            Msg::Assign(a) => a,
            other => panic!("expected Assign, got {other:?}"),
        };
        let learner = register(&c, "learner");
        c.request(&Msg::WorkerReady {
            worker_id: learner.worker_id,
            addrs: vec!["127.0.0.1:40000".into()],
        })
        .unwrap();

        // task-assignment round trip: Register → Assign → Deregister
        let c2 = ReqClient::connect(&ctrl.addr);
        b.bench("deploy/assign_roundtrip", "req", move || {
            let mut n = 0;
            for _ in 0..50 {
                let a = match c2
                    .request(&Msg::Register {
                        role: "actor".into(),
                        slot_hint: -1,
                    })
                    .unwrap()
                {
                    Msg::Assign(a) => a,
                    other => panic!("expected Assign, got {other:?}"),
                };
                c2.request(&Msg::Deregister { worker_id: a.worker_id })
                    .unwrap();
                n += 1;
            }
            n
        });

        // heartbeat overhead with 64 registered workers
        let ids: Vec<u64> =
            (0..64).map(|_| register(&c, "actor").worker_id).collect();
        let c3 = ReqClient::connect(&ctrl.addr);
        let ids2 = ids.clone();
        b.bench("deploy/heartbeat_64_workers", "req", move || {
            let mut n = 0;
            for &id in &ids2 {
                match c3
                    .request(&Msg::Heartbeat {
                        worker_id: id,
                        steps: 1,
                        done: false,
                        stats: None,
                    })
                    .unwrap()
                {
                    Msg::HeartbeatAck { .. } => n += 1,
                    other => panic!("expected ack, got {other:?}"),
                }
            }
            n
        });
        // clean drain so Controller::drop doesn't sit out its grace period
        for id in ids {
            c.request(&Msg::Deregister { worker_id: id }).unwrap();
        }
        c.request(&Msg::Deregister { worker_id: learner.worker_id })
            .unwrap();
    }

    // ---- telemetry plane ---------------------------------------------------
    // Snapshot wire cost, merge cost at 64 slots, and the heartbeat
    // round-trip when every beat piggybacks a stats snapshot (the
    // telemetry plane's steady-state overhead per worker).
    println!("\n# telemetry plane (snapshot encode/merge, stats-carrying heartbeats)");
    {
        use tleague::config::RunConfig;
        use tleague::orchestrator::controller::Controller;
        use tleague::proto::RoleStats;
        use tleague::telemetry::LeagueView;

        let mk_snap = |slot: u32| RoleStats {
            role: "actor".into(),
            slot,
            seq: 0, // 0 = no dedupe, every delivery merges
            interval_ms: 1_000,
            counters: vec![
                ("env_frames".into(), 4_096),
                ("episodes".into(), 17),
                ("segments".into(), 64),
                ("refreshes".into(), 2),
            ],
            gauges: vec![
                ("staleness".into(), 0.5),
                ("batch_fill".into(), 0.93),
            ],
            ..Default::default()
        };
        let snap = mk_snap(3);
        let snap_bytes = snap.to_bytes();
        b.bench("telemetry/snapshot_encode", "snap", || {
            let mut n = 0;
            for _ in 0..1_000 {
                let buf = snap.to_bytes();
                std::hint::black_box(&buf);
                n += 1;
            }
            n
        });
        b.bench("telemetry/snapshot_decode", "snap", || {
            let mut n = 0;
            for _ in 0..1_000 {
                let s = RoleStats::from_bytes(&snap_bytes).unwrap();
                std::hint::black_box(&s);
                n += 1;
            }
            n
        });
        let snaps: Vec<RoleStats> = (0..64).map(mk_snap).collect();
        let view = LeagueView::default();
        b.bench("telemetry/merge_64_slots", "snap", || {
            for s in &snaps {
                view.ingest(s);
            }
            let r = view.report();
            std::hint::black_box(&r);
            64
        });

        // heartbeat round-trip with a piggybacked snapshot, 64 workers
        let mut cfg = RunConfig::default();
        cfg.env = "rps".into();
        cfg.mode = "procs".into();
        cfg.actors_per_learner = 64;
        cfg.heartbeat_ms = 1_000;
        cfg.heartbeat_timeout_ms = 600_000; // no reaping mid-bench
        let ctrl = Controller::start(cfg, vec!["lr".into()], vec![3e-4]).unwrap();
        let c = ReqClient::connect(&ctrl.addr);
        let register = |c: &ReqClient, role: &str| match c
            .request(&Msg::Register { role: role.into(), slot_hint: -1 })
            .unwrap()
        {
            Msg::Assign(a) => a,
            other => panic!("expected Assign, got {other:?}"),
        };
        let learner = register(&c, "learner");
        c.request(&Msg::WorkerReady {
            worker_id: learner.worker_id,
            addrs: vec!["127.0.0.1:40100".into()],
        })
        .unwrap();
        let ids: Vec<u64> =
            (0..64).map(|_| register(&c, "actor").worker_id).collect();
        let c2 = ReqClient::connect(&ctrl.addr);
        let ids2 = ids.clone();
        b.bench("telemetry/heartbeat_with_stats_64_workers", "req", move || {
            let mut n = 0;
            for (i, &id) in ids2.iter().enumerate() {
                match c2
                    .request(&Msg::Heartbeat {
                        worker_id: id,
                        steps: 1,
                        done: false,
                        stats: Some(mk_snap(i as u32)),
                    })
                    .unwrap()
                {
                    Msg::HeartbeatAck { .. } => n += 1,
                    other => panic!("expected ack, got {other:?}"),
                }
            }
            n
        });
        // merged-view derivation with all 64 live slots ingested
        b.bench("telemetry/controller_report_64_workers", "report", || {
            let r = ctrl.telemetry_report();
            std::hint::black_box(&r);
            1
        });
        for id in ids {
            c.request(&Msg::Deregister { worker_id: id }).unwrap();
        }
        c.request(&Msg::Deregister { worker_id: learner.worker_id })
            .unwrap();
    }

    // ---- request-path tracing ----------------------------------------------
    // Span-record overhead (the cost one traced request adds per hop),
    // hist record + 64-way merge (the per-report controller cost), and
    // the actor row path at trace-sample 0 / 1% / 100% — the off row is
    // the no-new-allocation claim: untraced ticks draw no RNG and build
    // no TraceCtx, so its frames/s must match rollout/remote_n1.
    println!("\n# request-path tracing (span record, hist merge, sampled row path)");
    {
        use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
        use std::time::Instant;
        use tleague::actor::{Actor, ActorConfig, PolicyBackend};
        use tleague::proto::{TaskSpec, TraceCtx};
        use tleague::telemetry::trace;
        use tleague::transport::{PullServer, RepServer};
        use tleague::util::metrics::{Hist, HIST_BUCKETS};

        b.bench("trace/span_record", "span", || {
            let mut n = 0;
            let t0 = Instant::now();
            for i in 0..1_000u64 {
                let ctx = TraceCtx { trace_id: i + 1, span_id: 0 };
                let id = trace::finish_span(ctx, 0, "bench_span", "actor", t0, 1);
                std::hint::black_box(id);
                n += 1;
            }
            n
        });

        let h = Hist::new();
        b.bench("trace/hist_record", "rec", || {
            let mut n = 0;
            for i in 0..10_000u64 {
                h.record(i.wrapping_mul(2654435761) % 1_000_000);
                n += 1;
            }
            n
        });
        let shards: Vec<[u64; HIST_BUCKETS]> = (0..64)
            .map(|s| {
                let sh = Hist::new();
                for i in 0..1_000u64 {
                    sh.record((i + s) * 37 % 500_000);
                }
                sh.totals()
            })
            .collect();
        b.bench("trace/hist_merge_64", "merge", || {
            let mut acc = [0u64; HIST_BUCKETS];
            for t in &shards {
                for (a, v) in acc.iter_mut().zip(t.iter()) {
                    *a += v;
                }
            }
            let p = (
                Hist::quantile_of(&acc, 0.50),
                Hist::quantile_of(&acc, 0.95),
                Hist::quantile_of(&acc, 0.99),
            );
            std::hint::black_box(p);
            64
        });

        // actor row path under sampling: same stub-server rollout as the
        // rollout group, swept over --trace-sample
        let next = AtomicU64::new(1);
        let league = RepServer::serve("127.0.0.1:0", move |msg| match msg {
            Msg::RequestActorTask { .. } => Msg::Task(TaskSpec {
                task_id: next.fetch_add(1, Ordering::Relaxed),
                learner_key: ModelKey::new(0, 1),
                opponents: vec![ModelKey::new(0, 0)],
                hp: vec![],
            }),
            Msg::ReportOutcome(_) => Msg::Ok,
            other => Msg::Err(format!("stub league: {other:?}")),
        })
        .unwrap();
        let sink = PullServer::bind("127.0.0.1:0", 1024).unwrap();
        let sink_addr = sink.addr.clone();
        let drain_stop = Arc::new(AtomicBool::new(false));
        let ds = drain_stop.clone();
        let drainer = std::thread::spawn(move || {
            let sink = sink;
            while !ds.load(Ordering::Relaxed) {
                while sink.try_recv().is_some() {}
                std::thread::sleep(Duration::from_millis(1));
            }
        });
        let tpool = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let tpc = ModelPoolClient::connect(&[tpool.addr.clone()]);
        for (v, frozen) in [(0u32, true), (1u32, false)] {
            tpc.put(ModelBlob {
                key: ModelKey::new(0, v),
                params: vec![0.0; 8],
                hp: vec![],
                frozen,
            })
            .unwrap();
        }
        let act_dim = envs::make("synthetic", 0).unwrap().act_dim();
        let inf = RepServer::serve("127.0.0.1:0", move |msg| match msg {
            Msg::InferReq { rows, .. } => Msg::InferResp {
                logits: vec![0.0; rows as usize * act_dim],
                value: vec![0.0; rows as usize],
            },
            other => Msg::Err(format!("stub inf: {other:?}")),
        })
        .unwrap();
        for (label, sample) in [("off", 0.0f32), ("1pct", 0.01), ("full", 1.0)] {
            let mut actor = Actor::new_vec(
                ActorConfig {
                    env: "synthetic".into(),
                    actor_id: format!("0/bench-trace-{label}"),
                    seed: 1,
                    gamma: 0.99,
                    refresh_every: 1_000_000,
                    train_t: 8,
                    trace_sample: sample,
                },
                1,
                PolicyBackend::Remote(ReqClient::connect(&inf.addr)),
                &league.addr,
                &[tpool.addr.clone()],
                &sink_addr,
            )
            .unwrap();
            let never = AtomicBool::new(false);
            b.bench(&format!("trace/row_sample_{label}"), "frame", move || {
                actor.run(1024, &never).unwrap()
            });
        }
        drain_stop.store(true, Ordering::Relaxed);
        drainer.join().ok();
    }

    // ---- fault injection ----------------------------------------------------
    // The guard every transport op pays: with no plan installed it must
    // be one relaxed atomic load (the disabled rows are the
    // no-overhead claim — faults/row_off must match trace/row_sample_off);
    // with a plan armed the slow path runs per op even when no rule
    // matches, which is the price of running a drill.
    println!("\n# fault injection (disabled vs armed check, actor row path)");
    {
        use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
        use tleague::actor::{Actor, ActorConfig, PolicyBackend};
        use tleague::proto::TaskSpec;
        use tleague::transport::fault;
        use tleague::transport::{PullServer, RepServer};

        fault::clear();
        b.bench("faults/check_disabled", "check", || {
            let mut n = 0;
            for _ in 0..100_000u64 {
                let v = fault::check(fault::SITE_REQ, "127.0.0.1:1", 3);
                std::hint::black_box(v);
                n += 1;
            }
            n
        });
        fault::set_role("bench-faults");
        fault::install_spec(7, "drop:no-such-role@1.0").unwrap();
        b.bench("faults/check_armed_nomatch", "check", || {
            let mut n = 0;
            for _ in 0..100_000u64 {
                let v = fault::check(fault::SITE_REQ, "127.0.0.1:1", 3);
                std::hint::black_box(v);
                n += 1;
            }
            n
        });
        fault::clear();

        // actor row path with injection off vs armed-but-non-matching:
        // the same stub-server rollout as the trace group
        let next = AtomicU64::new(1);
        let league = RepServer::serve("127.0.0.1:0", move |msg| match msg {
            Msg::RequestActorTask { .. } => Msg::Task(TaskSpec {
                task_id: next.fetch_add(1, Ordering::Relaxed),
                learner_key: ModelKey::new(0, 1),
                opponents: vec![ModelKey::new(0, 0)],
                hp: vec![],
            }),
            Msg::ReportOutcome(_) => Msg::Ok,
            other => Msg::Err(format!("stub league: {other:?}")),
        })
        .unwrap();
        let sink = PullServer::bind("127.0.0.1:0", 1024).unwrap();
        let sink_addr = sink.addr.clone();
        let drain_stop = Arc::new(AtomicBool::new(false));
        let ds = drain_stop.clone();
        let drainer = std::thread::spawn(move || {
            let sink = sink;
            while !ds.load(Ordering::Relaxed) {
                while sink.try_recv().is_some() {}
                std::thread::sleep(Duration::from_millis(1));
            }
        });
        let fpool = ModelPoolServer::start("127.0.0.1:0").unwrap();
        let fpc = ModelPoolClient::connect(&[fpool.addr.clone()]);
        for (v, frozen) in [(0u32, true), (1u32, false)] {
            fpc.put(ModelBlob {
                key: ModelKey::new(0, v),
                params: vec![0.0; 8],
                hp: vec![],
                frozen,
            })
            .unwrap();
        }
        let act_dim = envs::make("synthetic", 0).unwrap().act_dim();
        let inf = RepServer::serve("127.0.0.1:0", move |msg| match msg {
            Msg::InferReq { rows, .. } => Msg::InferResp {
                logits: vec![0.0; rows as usize * act_dim],
                value: vec![0.0; rows as usize],
            },
            other => Msg::Err(format!("stub inf: {other:?}")),
        })
        .unwrap();
        for (label, spec) in [("off", None), ("armed_nomatch", Some("drop:no-such-role@1.0"))] {
            match spec {
                None => fault::clear(),
                Some(s) => fault::install_spec(7, s).unwrap(),
            }
            let mut actor = Actor::new_vec(
                ActorConfig {
                    env: "synthetic".into(),
                    actor_id: format!("0/bench-faults-{label}"),
                    seed: 1,
                    gamma: 0.99,
                    refresh_every: 1_000_000,
                    train_t: 8,
                    trace_sample: 0.0,
                },
                1,
                PolicyBackend::Remote(ReqClient::connect(&inf.addr)),
                &league.addr,
                &[fpool.addr.clone()],
                &sink_addr,
            )
            .unwrap();
            let never = AtomicBool::new(false);
            b.bench(&format!("faults/row_{label}"), "frame", move || {
                actor.run(1024, &never).unwrap()
            });
        }
        fault::clear();
        drain_stop.store(true, Ordering::Relaxed);
        drainer.join().ok();
    }

    // ---- transport scale ---------------------------------------------------
    // Fan-in onto ONE RepServer event-loop pool: N persistent client
    // connections, one iter = every conn sends a request then reads its
    // reply.  Per-conn server state is O(buffers) — the old
    // thread-per-connection design would have needed N 8 MB stacks.
    // The lane rows put the same multi-row InferReq bytes over loopback
    // TCP and over a shared-memory ring.
    println!("\n# transport scale (fan-in on one event-loop pool; TCP vs shm lane)");
    {
        use std::net::TcpStream;
        use tleague::transport::{
            poll, read_frame, write_frame, LaneMode, LaneOpts, RepServer,
            ReqClient,
        };

        let server = RepServer::serve("127.0.0.1:0", |msg| match msg {
            Msg::Ping => Msg::Pong,
            Msg::Model(b) => Msg::Model(b), // small-payload echo
            Msg::InferReq { rows, .. } => Msg::InferResp {
                logits: vec![0.0; rows as usize * 3],
                value: vec![0.0; rows as usize],
            },
            other => Msg::Err(format!("stub: {other:?}")),
        })
        .unwrap();

        let ping = Msg::Ping.to_bytes();
        let echo = Msg::Model(ModelBlob {
            key: ModelKey::new(0, 1),
            params: vec![0.5; 64], // 256 B payload
            hp: vec![],
            frozen: true,
        })
        .to_bytes();
        for &conns in &[64usize, 512, 4096] {
            // both socket ends live in this process: 2 fds per conn,
            // plus slack for everything else the bench keeps open
            let need = conns as u64 * 2 + 512;
            let limit = poll::nofile_limit();
            if limit < need {
                println!(
                    "transport_scale/*_c{conns}: SKIPPED \
                     (ulimit -n {limit} < {need})"
                );
                continue;
            }
            let connect_all = || -> Vec<TcpStream> {
                (0..conns)
                    .map(|_| {
                        let s = TcpStream::connect(&server.addr).unwrap();
                        s.set_nodelay(true).unwrap();
                        s
                    })
                    .collect()
            };
            for (row, frame) in [("heartbeat", &ping), ("echo256", &echo)] {
                let mut socks = connect_all();
                let frame = frame.clone();
                let mut buf = Vec::new();
                b.bench(
                    &format!("transport_scale/{row}_c{conns}"),
                    "req",
                    move || {
                        for s in socks.iter_mut() {
                            write_frame(s, &frame).unwrap();
                        }
                        for s in socks.iter_mut() {
                            read_frame(s, &mut buf).unwrap();
                        }
                        socks.len() as u64
                    },
                );
            }
        }

        // multi-row inference payload (64 rows x 32 dims — a vectorized
        // actor's request shape): identical bytes over both paths
        let key = ModelKey::new(0, 1);
        let obs = vec![0.25f32; 64 * 32];
        let tcp = ReqClient::connect(&server.addr);
        let o2 = obs.clone();
        b.bench("transport_scale/infer_multirow_tcp", "req", move || {
            let mut n = 0;
            for _ in 0..50 {
                let req =
                    Msg::InferReq { key, obs: o2.clone(), rows: 64, trace: None };
                match tcp.request(&req).unwrap() {
                    Msg::InferResp { .. } => n += 1,
                    other => panic!("stub inf: {other:?}"),
                }
            }
            n
        });
        let lane = Arc::new(ReqClient::connect_opts(
            &server.addr,
            LaneOpts { mode: LaneMode::On, dir: None, capacity: 0 },
        ));
        let lc = lane.clone();
        b.bench("transport_scale/infer_multirow_shm", "req", move || {
            let mut n = 0;
            for _ in 0..50 {
                let req =
                    Msg::InferReq { key, obs: obs.clone(), rows: 64, trace: None };
                match lc.request(&req).unwrap() {
                    Msg::InferResp { .. } => n += 1,
                    other => panic!("stub inf: {other:?}"),
                }
            }
            n
        });
        // 0 here means the ring was unavailable and the row fell back
        // to TCP — the latency comparison is void in that case
        println!(
            "  (shm row rode the lane for {} requests)",
            lane.lane_requests.count()
        );
    }

    // ---- elastic -----------------------------------------------------------
    // The sharded-pool hot paths: every client read/write resolves
    // owners on the consistent-hash ring; failover cost is the bytes a
    // rebalance pushes through the rev protocol; the autoscaler burns
    // one policy evaluation per tick.
    println!("\n# elastic (shard ring lookup, rebalance transfer, scaling policy)");
    {
        use tleague::model_pool::shard::{self, MapHolder, Ring};
        use tleague::model_pool::{rebalance, PoolOptions};
        use tleague::orchestrator::controller::{policy_decide, ScaleBounds};
        use tleague::proto::ShardMap;

        // owner lookup on an 8-replica R=2 ring (the per-request cost a
        // cached client pays instead of a network round-trip)
        let addrs: Vec<String> = (0..8).map(|i| format!("10.0.0.{i}:9001")).collect();
        let ring = Ring::build(&shard::bootstrap_map(&addrs, 2));
        b.bench("elastic/shard_lookup_r8", "lookup", move || {
            let mut acc = 0u64;
            for agent in 0..4096u32 {
                acc += ring.owners(agent)[0] as u64;
            }
            assert!(acc > 0, "degenerate ring");
            4096
        });

        // replica bounce: tombstone replica 2 out of a 3-replica R=2
        // deployment, rebalance survivors, then re-admit it and
        // rebalance back.  Each direction moves real blob bytes (the
        // eviction on exit voids the rev-protocol cache), so the
        // steady-state bytes/iter is the failover transfer cost.
        let holder = Arc::new(MapHolder::new(shard::bootstrap_map(
            &(0..3).map(|i| format!("pending-{i}")).collect::<Vec<_>>(),
            2,
        )));
        let pools: Vec<_> = (0..3)
            .map(|i| {
                ModelPoolServer::start_sharded(
                    "127.0.0.1:0",
                    PoolOptions::default(),
                    holder.clone(),
                    i as u32,
                )
                .unwrap()
            })
            .collect();
        holder.set_addrs(pools.iter().map(|p| p.addr.clone()).collect());
        let (_, ring) = holder.get();
        for agent in 0..64u32 {
            for ver in 1..=4u32 {
                let blob = ModelBlob {
                    key: ModelKey::new(agent, ver),
                    params: vec![0.5; 1024],
                    hp: vec![],
                    frozen: true,
                };
                for (i, p) in pools.iter().enumerate() {
                    if ring.is_owner(agent, i as u32) {
                        p.preload(std::slice::from_ref(&blob));
                    }
                }
            }
        }
        let full_addrs: Vec<String> = pools.iter().map(|p| p.addr.clone()).collect();
        let h2 = holder.clone();
        let bounced_pools = pools;
        b.bench("elastic/rebalance_bounce_r3", "B", move || {
            let (old_map, _) = h2.get();
            let down = shard::without_replica(&old_map, 2);
            h2.install(down.clone());
            let live = [true, true, true];
            let out = rebalance(&bounced_pools, &live, &old_map, &down);
            let up = ShardMap {
                version: down.version + 1,
                replicas: full_addrs.clone(),
                replication: 2,
            };
            h2.install(up.clone());
            let back = rebalance(&bounced_pools, &live, &down, &up);
            let moved = out.bytes_moved + back.bytes_moved;
            assert!(moved > 0, "bounce moved nothing");
            moved
        });

        // one closed-loop policy evaluation with 64 live slots per role
        let bounds = ScaleBounds { min: 1, max: 256 };
        b.bench("elastic/policy_decide_64slots", "decision", move || {
            let mut moves = 0u64;
            for i in 0..10_000u64 {
                let staleness = Some((i % 5) as f64);
                let fill = Some((i % 10) as f64 / 10.0);
                let (da, di) =
                    policy_decide(staleness, fill, 64, 64, bounds, bounds);
                moves += da.unsigned_abs() + di.unsigned_abs();
            }
            assert!(moves > 0, "policy never moved");
            10_000
        });
    }

    // ---- lint ----------------------------------------------------------
    // Analyzer cost on the real tree: full-tree walk (lexer + all four
    // rules per file) and the proto registry parse alone.  Keeping this
    // measured keeps the CI stage cheap enough to stay a hard gate.
    println!("\n# league-lint (static analysis over rust/src)");
    {
        use tleague::lint;

        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/src");
        let allow = lint::Allowlist::load(
            &PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("lint-allow.toml"),
        )
        .expect("allowlist parses");
        b.bench("lint/full_tree", "file", || {
            let (findings, files, _bytes) =
                lint::lint_tree(&root, &allow).expect("tree walks");
            assert!(findings.is_empty(), "shipped tree must stay lint-clean");
            files as u64
        });

        let proto_src = std::fs::read_to_string(root.join("proto/mod.rs")).unwrap();
        b.bench("lint/proto_registry_parse", "parse", || {
            let mut n = 0;
            for _ in 0..50 {
                let table = lint::proto_tag_table(&proto_src).expect("table parses");
                assert!(table.len() >= 42);
                std::hint::black_box(&table);
                n += 1;
            }
            n
        });
    }

    println!("\n{} benches run", b.rows.len());
    b.write_json();
}
