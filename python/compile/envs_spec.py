"""Per-environment network / batch specifications.

This is the single source of truth for the shapes the AOT artifacts are
compiled with.  The Rust side reads the same numbers from
``artifacts/manifest.json`` (written by aot.py) and its env encoders are
unit-tested against them.

Observation encodings (must match rust/src/envs/*):
  - rps:        4 dummy features (one-step matrix game; obs is constant).
  - pong2p:     8 features (ball x/y/vx/vy, self paddle y/vy, opp paddle y, side).
  - pommerman:  9x9 fogged egocentric view x 12 channels + 8 self attributes.
  - doom_lite:  24 rays x 5 channels (wall depth, enemy, pickup, projectile,
                wall-normal) + 8 self attributes.
  - synthetic:  1024 opaque features (throughput benchmarking; Table 3).
"""

ENV_SPECS = {
    "rps": dict(
        obs_dim=4, act_dim=3, hidden=[32],
        train_t=1, train_b=256, infer_b=32,
        team=False,
    ),
    "pong2p": dict(
        obs_dim=8, act_dim=3, hidden=[64, 64],
        train_t=16, train_b=32, infer_b=32,
        team=False,
    ),
    "pommerman": dict(
        obs_dim=9 * 9 * 12 + 8, act_dim=6, hidden=[512, 256],
        train_t=16, train_b=32, infer_b=32,
        team=True,  # centralized value over the 2 teammates (paper 4.3)
    ),
    "doom_lite": dict(
        obs_dim=24 * 5 + 8, act_dim=6, hidden=[256, 128],
        train_t=16, train_b=32, infer_b=32,
        team=False,
    ),
    "synthetic": dict(
        obs_dim=1024, act_dim=16, hidden=[2048, 2048],
        train_t=8, train_b=16, infer_b=32,
        team=False,
    ),
}

# Hyper-parameter vector layout fed to every train/grad artifact at runtime.
# Kept as a runtime input (not baked constants) so the HyperMgr / PBT can
# perturb them without recompiling artifacts.
HP_LAYOUT = [
    "lr",         # Adam learning rate
    "clip_eps",   # PPO clip epsilon
    "vf_coef",    # value-loss coefficient
    "ent_coef",   # entropy bonus coefficient
    "lam",        # GAE / V-trace lambda
    "grad_clip",  # global-norm gradient clip (<=0 disables)
    "rho_bar",    # V-trace rho clip
    "c_bar",      # V-trace c clip
]

HP_DEFAULTS = {
    "lr": 3e-4, "clip_eps": 0.2, "vf_coef": 0.5, "ent_coef": 0.01,
    "lam": 0.95, "grad_clip": 1.0, "rho_bar": 1.0, "c_bar": 1.0,
}
