//! Tables 1 & 2 driver: doom_lite (ViZDoom CIG track-1 stand-in).
//!
//! Two-stage training per the paper's §4.2: stage 1 trains navigation
//! with exploration shaping (fire disabled) — here folded into the
//! curriculum by starting CSP training from scratch with entropy bonus;
//! stage 2 is the CSP-MARL deathmatch league with uniform sampling over
//! the most recent 50 models.  After training, the checkpoint is
//! evaluated in the paper's four settings:
//!   Table 1:  1 MyPlayer + 7 builtin bots
//!   Table 2a: 1 MyPlayer + 1 F1 + 6 bots
//!   Table 2b: 2 MyPlayer + 2 F1 + 4 bots
//!   Table 2c: 4 MyPlayer + 4 F1
//!
//!     cargo run --release --example doom_train -- [steps] [matches]

use std::sync::Arc;
use std::time::Duration;
use tleague::config::RunConfig;
use tleague::envs::doom_lite::bots::{BuiltinBot, DoomPolicy, F1Bot};
use tleague::eval::{doom_match, NnPolicy};
use tleague::model_pool::ModelPoolClient;
use tleague::orchestrator::Deployment;
use tleague::runtime::Engine;

fn eval_setting(
    engine: &Arc<Engine>,
    params: &[f32],
    label: &str,
    n_my: u64,
    n_f1: u64,
    n_bots: u64,
    matches: u64,
) -> anyhow::Result<()> {
    let mut my_best = Vec::new();
    let mut f1_best = Vec::new();
    for g in 0..matches {
        let mut nn: Vec<NnPolicy> = (0..n_my)
            .map(|i| {
                NnPolicy::new(engine.clone(), "doom_lite", params.to_vec(), g * 10 + i)
            })
            .collect();
        let mut bots: Vec<Box<dyn DoomPolicy>> = Vec::new();
        for i in 0..n_f1 {
            bots.push(Box::new(F1Bot::new(g * 20 + i)));
        }
        for i in 0..n_bots {
            bots.push(Box::new(BuiltinBot::new(g * 30 + i)));
        }
        let frags = doom_match(1000 + g, &mut nn, &mut bots)?;
        my_best.push(*frags[..n_my as usize].iter().max().unwrap());
        if n_f1 > 0 {
            f1_best.push(
                *frags[n_my as usize..(n_my + n_f1) as usize].iter().max().unwrap(),
            );
        }
    }
    let avg = |v: &[i32]| v.iter().sum::<i32>() as f64 / v.len().max(1) as f64;
    println!("-- {label}: {n_my} MyPlayer + {n_f1} F1 + {n_bots} bots --");
    println!("  MyPlayer best FRAG: {my_best:?}  avg {:.1}", avg(&my_best));
    if !f1_best.is_empty() {
        println!("  F1       best FRAG: {f1_best:?}  avg {:.1}", avg(&f1_best));
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let total_steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let matches: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(5);

    let engine = Arc::new(Engine::load("artifacts")?);
    let mut cfg = RunConfig::default();
    cfg.env = "doom_lite".into();
    cfg.game_mgr = "uniform".into(); // paper: uniform over most recent 50
    cfg.opponents_per_episode = 7;
    cfg.actors_per_learner = 4;
    cfg.total_steps = total_steps;
    cfg.period_steps = (total_steps / 5).max(10);
    cfg.publish_every = 4;
    cfg.gamma = 0.995;
    cfg.hp_overrides.insert("lr".into(), 8e-4);
    cfg.hp_overrides.insert("ent_coef".into(), 0.015);
    cfg.seed = 9;

    println!("== doom_lite CSP league: {total_steps} learner steps, 8-player FFA ==");
    let dep = Deployment::start(cfg, engine.clone())?;
    while !dep.learners_done() {
        std::thread::sleep(Duration::from_secs(2));
        let lstats = dep.league_stats();
        let ts = dep.learner_status[0].stats.lock().unwrap().clone();
        println!(
            "steps={:4} pool={:2} episodes={:4} frames={:7} loss={:+.3} ent={:.3}",
            dep.total_learner_steps(), lstats.pool_size, lstats.episodes,
            lstats.frames, ts.loss, ts.entropy
        );
    }
    let pool = ModelPoolClient::connect(dep.pool_addrs());
    let params = pool.get_latest(0)?.expect("trained model").params;
    let mut dep = dep;
    dep.shutdown();

    println!("\n== Table 1 ==");
    eval_setting(&engine, &params, "Table 1", 1, 0, 7, matches)?;
    println!("\n== Table 2 ==");
    eval_setting(&engine, &params, "Table 2 top", 1, 1, 6, matches)?;
    eval_setting(&engine, &params, "Table 2 middle", 2, 2, 4, matches)?;
    eval_setting(&engine, &params, "Table 2 bottom", 4, 4, 0, matches)?;
    Ok(())
}
