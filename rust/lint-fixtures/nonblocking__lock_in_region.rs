// Seeded-bad fixture: a marked nonblocking fn takes a mutex and sleeps
// without waivers.

// lint: nonblocking
fn pump(&mut self) {
    let mut q = self.queue.lock();
    if q.is_empty() {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
}
