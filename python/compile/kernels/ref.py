"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: slow, obvious implementations used by
pytest to validate the Pallas kernels (gae.py, vtrace.py, ppo_loss.py) and
by the model when ``use_pallas=False`` (debugging escape hatch).

All sequence tensors are TIME-MAJOR: rewards/discounts are [T, B], values
are [T+1, B] (the extra row is the bootstrap value of the final
observation).  ``discounts`` already folds gamma and episode termination:
discount_t = gamma * (1 - done_t).
"""

import jax
import jax.numpy as jnp


def gae_ref(rewards, discounts, values, lam):
    """Generalized Advantage Estimation, reverse scan.

    adv_t = delta_t + discount_t * lam * adv_{t+1}
    delta_t = r_t + discount_t * V_{t+1} - V_t

    Returns advantages [T, B] (NOT value-normalized).
    """
    rewards, discounts, values = (jnp.asarray(rewards),
                                  jnp.asarray(discounts), jnp.asarray(values))
    T = rewards.shape[0]

    def step(acc, t):
        delta = rewards[t] + discounts[t] * values[t + 1] - values[t]
        acc = delta + discounts[t] * lam * acc
        return acc, acc

    _, advs = jax.lax.scan(step, jnp.zeros_like(rewards[0]),
                           jnp.arange(T - 1, -1, -1))
    return advs[::-1]


def vtrace_ref(log_rhos, rewards, discounts, values, lam, rho_bar, c_bar):
    """V-trace targets and policy-gradient advantages (IMPALA eq. 1).

    vs_t = V_t + delta_t + discount_t * c_t * (vs_{t+1} - V_{t+1})
    delta_t = rho_t * (r_t + discount_t * V_{t+1} - V_t)
    pg_adv_t = rho_t * (r_t + discount_t * vs_{t+1} - V_t)

    with rho_t = min(rho_bar, e^{log_rho_t}), c_t = lam * min(c_bar, e^{log_rho_t}).
    Returns (vs [T, B], pg_adv [T, B]).
    """
    log_rhos, rewards, discounts, values = (
        jnp.asarray(log_rhos), jnp.asarray(rewards),
        jnp.asarray(discounts), jnp.asarray(values))
    T = rewards.shape[0]
    rhos = jnp.minimum(rho_bar, jnp.exp(log_rhos))
    cs = lam * jnp.minimum(c_bar, jnp.exp(log_rhos))

    def step(acc, t):
        # acc = vs_{t+1} - V_{t+1}
        delta = rhos[t] * (rewards[t] + discounts[t] * values[t + 1] - values[t])
        acc_t = delta + discounts[t] * cs[t] * acc
        return acc_t, acc_t

    _, diffs = jax.lax.scan(step, jnp.zeros_like(rewards[0]),
                            jnp.arange(T - 1, -1, -1))
    diffs = diffs[::-1]                        # vs_t - V_t, [T, B]
    vs = diffs + values[:-1]
    vs_tp1 = jnp.concatenate([vs[1:], values[-1:]], axis=0)
    pg_adv = rhos * (rewards + discounts * vs_tp1 - values[:-1])
    return vs, pg_adv


def ppo_terms_ref(logits, actions, logp_old, adv, value, ret, clip_eps):
    """Per-sample PPO terms; the fused-kernel oracle.

    Args (N = flattened T*B samples, A = action count):
      logits  [N, A] current policy logits
      actions [N]    int32 actions taken by the behaviour policy
      logp_old[N]    behaviour-policy log-prob of those actions
      adv     [N]    advantages (constant w.r.t. params)
      value   [N]    current value predictions
      ret     [N]    value targets (constant)
      clip_eps       PPO clip epsilon
    Returns (pol_loss [N], v_loss [N], entropy [N], approx_kl [N]).
    """
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    logp_all = logits - logz[:, None]
    logp = jnp.take_along_axis(logp_all, actions[:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    ratio = jnp.exp(logp - logp_old)
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
    pol_loss = -jnp.minimum(ratio * adv, clipped * adv)
    v_loss = 0.5 * jnp.square(value - ret)
    p = jnp.exp(logp_all)
    entropy = -jnp.sum(p * logp_all, axis=-1)
    approx_kl = logp_old - logp
    return pol_loss, v_loss, entropy, approx_kl


def ppo_scalar_ref(logits, actions, logp_old, adv, value, ret,
                   clip_eps, vf_coef, ent_coef):
    """Scalar PPO loss used as the autodiff oracle for the fused kernel."""
    pol, vl, ent, _ = ppo_terms_ref(logits, actions, logp_old, adv, value,
                                    ret, clip_eps)
    return jnp.mean(pol) + vf_coef * jnp.mean(vl) - ent_coef * jnp.mean(ent)
