//! tleague CLI: launch a league run, individual services, or evals.
//!
//! Subcommands:
//!   run        --config <spec.json> [--artifacts DIR]   full league (kube-lite)
//!              [--checkpoint-dir D] [--resume D]        durable / resumed runs
//!   eval-doom  --checkpoint <f32 file> --setting 1|2a|2b|2c --games N
//!   eval-rps   --artifacts DIR                           exploitability demo
//!   league-mgr / model-pool                              standalone services
//!   info       --artifacts DIR                           manifest summary

use anyhow::{Context, Result};
use std::sync::Arc;
use std::time::Duration;
use tleague::config::RunConfig;
use tleague::orchestrator::Deployment;
use tleague::runtime::Engine;
use tleague::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn engine(args: &Args) -> Result<Arc<Engine>> {
    let dir = args.str_or("artifacts", "artifacts");
    Ok(Arc::new(Engine::load(dir)?))
}

fn run() -> Result<()> {
    let args = Args::from_env();
    if args.bool("help") {
        println!("{}", tleague::util::cli::USAGE);
        return Ok(());
    }
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("info") => cmd_info(&args),
        Some("eval-doom") => cmd_eval_doom(&args),
        Some("eval-rps") => cmd_eval_rps(&args),
        Some("model-pool") => {
            let s = tleague::model_pool::ModelPoolServer::start(
                &args.str_or("bind", "127.0.0.1:9001"),
            )?;
            println!("model-pool listening on {}", s.addr);
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        Some("league-mgr") => {
            let eng = engine(&args)?;
            let s = tleague::league::LeagueMgrServer::start(
                &args.str_or("bind", "127.0.0.1:9003"),
                tleague::league::LeagueConfig {
                    n_agents: args.usize_or("n-agents", 1) as u32,
                    n_opponents: args.usize_or("n-opponents", 1),
                    game_mgr: args.str_or("game-mgr", "uniform"),
                    hp_layout: eng.manifest.hp_layout.clone(),
                    hp_default: eng.manifest.default_hp(),
                    seed: args.u64_or("seed", 0),
                },
            )?;
            println!("league-mgr listening on {}", s.addr);
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        Some(other) => anyhow::bail!("unknown subcommand '{other}'"),
        None => {
            println!("{}", tleague::util::cli::USAGE);
            Ok(())
        }
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(path)?,
        None => RunConfig {
            env: args.str_or("env", "rps"),
            total_steps: args.u64_or("total-steps", 100),
            period_steps: args.u64_or("period-steps", 25),
            actors_per_learner: args.usize_or("actors", 2),
            game_mgr: args.str_or("game-mgr", "uniform"),
            ..RunConfig::default()
        },
    };
    // vectorized rollouts: episodes per actor (flag overrides the file)
    cfg.envs_per_actor = args.usize_or("envs-per-actor", cfg.envs_per_actor);
    // durability flags override the config file either way
    if let Some(dir) = args.get("checkpoint-dir") {
        cfg.checkpoint_dir = Some(dir.to_string());
    }
    if let Some(dir) = args.get("resume") {
        cfg.resume = Some(dir.to_string());
        // a resumed run keeps checkpointing into the same dir by default
        if cfg.checkpoint_dir.is_none() {
            cfg.checkpoint_dir = Some(dir.to_string());
        }
    }
    cfg.checkpoint_every_secs =
        args.u64_or("checkpoint-every", cfg.checkpoint_every_secs);
    // data-plane knobs (see USAGE): flags override the config file
    cfg.refresh_every =
        args.u64_or("refresh-every", cfg.refresh_every as u64) as u32;
    cfg.infer_max_wait_us =
        args.u64_or("infer-max-wait-us", cfg.infer_max_wait_us);
    cfg.infer_refresh_ms = args.u64_or("infer-refresh-ms", cfg.infer_refresh_ms);
    cfg.validate()?;
    let eng = engine(args)?;
    println!(
        "launching league: env={} M_G={} M_L={} M_A={} sampler={}",
        cfg.env, cfg.n_agents, cfg.learners_per_agent, cfg.actors_per_learner,
        cfg.game_mgr
    );
    if let Some(dir) = &cfg.resume {
        println!("resuming from latest snapshot in {dir}");
    }
    if let Some(dir) = &cfg.checkpoint_dir {
        println!(
            "checkpointing to {dir} every {}s (keep {})",
            cfg.checkpoint_every_secs, cfg.checkpoint_keep
        );
    }
    let mut dep = Deployment::start(cfg, eng)?;
    let mut last = 0;
    while !dep.learners_done() {
        std::thread::sleep(Duration::from_secs(2));
        let steps = dep.total_learner_steps();
        let stats = dep.league_stats();
        let s0 = &dep.learner_status[0];
        let ts = s0.stats.lock().unwrap().clone();
        println!(
            "steps={steps} (+{}) pool={} episodes={} frames={} loss={:.4} ent={:.3}",
            steps - last, stats.pool_size, stats.episodes, stats.frames,
            ts.loss, ts.entropy
        );
        last = steps;
    }
    let stats = dep.league_stats();
    println!(
        "done: pool={} episodes={} frames={} actor restarts={}",
        stats.pool_size,
        stats.episodes,
        stats.frames,
        dep.restarts.load(std::sync::atomic::Ordering::Relaxed)
    );
    dep.shutdown();
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let eng = engine(args)?;
    println!("hp layout: {:?}", eng.manifest.hp_layout);
    for (name, m) in &eng.manifest.envs {
        println!(
            "env {name}: obs={} act={} hidden={:?} team={} P={} T={} B={} artifacts={}",
            m.obs_dim, m.act_dim, m.hidden, m.team, m.param_count, m.train_t,
            m.train_b, m.artifacts.len()
        );
    }
    Ok(())
}

fn load_checkpoint(path: &str, expected: usize) -> Result<Vec<f32>> {
    let raw = std::fs::read(path).with_context(|| format!("read {path}"))?;
    anyhow::ensure!(
        raw.len() == expected * 4,
        "checkpoint has {} bytes, want {}",
        raw.len(),
        expected * 4
    );
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Tables 1 & 2: FRAG matches in doom_lite.
fn cmd_eval_doom(args: &Args) -> Result<()> {
    use tleague::envs::doom_lite::bots::{BuiltinBot, DoomPolicy, F1Bot};
    use tleague::eval::{doom_match, NnPolicy};
    let eng = engine(args)?;
    let m = eng.manifest.env("doom_lite")?.clone();
    let params = match args.get("checkpoint") {
        Some(p) => load_checkpoint(p, m.param_count)?,
        None => eng.init_params("doom_lite")?,
    };
    let games = args.u64_or("games", 5);
    let setting = args.str_or("setting", "1");
    // (n_my, n_f1, n_bots) per Table 1 / Table 2 rows
    let (n_my, n_f1, n_bots) = match setting.as_str() {
        "1" => (1, 0, 7),
        "2a" => (1, 1, 6),
        "2b" => (2, 2, 4),
        "2c" => (4, 4, 0),
        s => anyhow::bail!("setting must be 1|2a|2b|2c, got {s}"),
    };
    println!("setting {setting}: {n_my} MyPlayer + {n_f1} F1 + {n_bots} bots, {games} matches");
    let mut my_best = Vec::new();
    let mut f1_best = Vec::new();
    for g in 0..games {
        let mut nn: Vec<NnPolicy> = (0..n_my)
            .map(|i| NnPolicy::new(eng.clone(), "doom_lite", params.clone(), g * 10 + i))
            .collect();
        let mut bots: Vec<Box<dyn DoomPolicy>> = Vec::new();
        for i in 0..n_f1 {
            bots.push(Box::new(F1Bot::new(g * 20 + i)));
        }
        for i in 0..n_bots {
            bots.push(Box::new(BuiltinBot::new(g * 30 + i)));
        }
        let frags = doom_match(g, &mut nn, &mut bots)?;
        let my = frags[..n_my as usize].iter().max().copied().unwrap_or(0);
        my_best.push(my);
        if n_f1 > 0 {
            let f1 = frags[n_my as usize..(n_my + n_f1) as usize]
                .iter()
                .max()
                .copied()
                .unwrap();
            f1_best.push(f1);
        }
        println!("  match {}: frags {:?}", g + 1, frags);
    }
    let avg = |v: &[i32]| v.iter().sum::<i32>() as f64 / v.len().max(1) as f64;
    println!("MyPlayer best-FRAG per match: {my_best:?}  avg {:.1}", avg(&my_best));
    if !f1_best.is_empty() {
        println!("F1       best-FRAG per match: {f1_best:?}  avg {:.1}", avg(&f1_best));
    }
    Ok(())
}

/// Experiment V1: league-trained RPS pool exploitability.
fn cmd_eval_rps(args: &Args) -> Result<()> {
    use tleague::envs::matrix::MatrixGame;
    use tleague::eval::{rps_pool_exploitability, rps_strategy, NnPolicy};
    let eng = engine(args)?;
    let params = eng.init_params("rps")?;
    let mut nn = NnPolicy::new(eng, "rps", params, 0);
    let s = rps_strategy(&mut nn)?;
    let game = MatrixGame::rps(0);
    println!("seed policy strategy: {s:?}");
    println!("exploitability: {:.4}", rps_pool_exploitability(&game, &[s]));
    println!("(run examples/rps_league for the full FSP-vs-selfplay curve)");
    Ok(())
}
