//! Evaluation harness: regenerates the paper's result tables/figures.
//!
//! - doom_lite FRAG matches (Tables 1 & 2): trained policy + scripted
//!   bots in one synchronous match, ranked by kills − suicides.
//! - Pommerman win-rate curves (Fig 4): trained team vs SimpleAgent /
//!   Navocado over N games (tie = 0.5 win vs SimpleAgent; W/L/T vs
//!   Navocado), evaluated at checkpoints during training.
//! - Matrix-game exploitability (experiment V1): empirical policy
//!   mixture vs the NE.

use crate::envs::doom_lite::bots::DoomPolicy;
use crate::envs::doom_lite::DoomLite;
use crate::envs::matrix::MatrixGame;
use crate::envs::pommerman::agents::ScriptedPolicy;
use crate::envs::pommerman::Pommerman;
use crate::envs::{Info, MultiAgentEnv};
use crate::inference::infer_local_rows;
use crate::runtime::Engine;
use crate::util::rng::{log_softmax_at, Pcg32};
use anyhow::Result;
use std::sync::Arc;

/// A policy driven by NN params through the runtime (greedy-ish
/// sampling with temperature via Gumbel).
pub struct NnPolicy {
    pub engine: Arc<Engine>,
    pub env: String,
    pub params: Vec<f32>,
    buf_id: u64,
    pub rng: Pcg32,
}

impl Drop for NnPolicy {
    fn drop(&mut self) {
        self.engine.evict_cached(self.buf_id);
    }
}

impl NnPolicy {
    pub fn new(engine: Arc<Engine>, env: &str, params: Vec<f32>, seed: u64) -> Self {
        NnPolicy {
            engine,
            env: env.to_string(),
            params,
            buf_id: crate::runtime::new_cache_id(),
            rng: Pcg32::from_label(seed, "nn-policy"),
        }
    }

    /// Sample one action for a single observation row.
    pub fn act(&mut self, obs: &[f32]) -> Result<usize> {
        let (logits, _v) =
            self.engine
                .infer_cached(&self.env, 1, self.buf_id, &self.params, obs)?;
        Ok(self.rng.sample_logits(&logits))
    }

    /// Team forward pass (pommerman): obs [2*D] -> 2 actions.
    pub fn act_team(&mut self, obs: &[f32]) -> Result<[usize; 2]> {
        Ok(self.act_team_rows(obs, 1)?[0])
    }

    /// Vectorized single-agent forward: `rows` independent observation
    /// rows in one chunked wide-artifact call, one sampled action per
    /// row (the eval side of the vectorized rollout path).
    pub fn act_rows(&mut self, obs: &[f32], rows: usize) -> Result<Vec<usize>> {
        let (logits, _v) = infer_local_rows(
            &self.engine, &self.env, self.buf_id, &self.params, obs, rows,
        )?;
        let a = logits.len() / rows;
        Ok((0..rows)
            .map(|i| self.rng.sample_logits(&logits[i * a..(i + 1) * a]))
            .collect())
    }

    /// Vectorized team forward: `rows` team observations (2*D each)
    /// -> 2 actions per row.
    pub fn act_team_rows(
        &mut self,
        obs: &[f32],
        rows: usize,
    ) -> Result<Vec<[usize; 2]>> {
        let (logits, _v) = infer_local_rows(
            &self.engine, &self.env, self.buf_id, &self.params, obs, rows,
        )?;
        let a = logits.len() / rows / 2;
        Ok((0..rows)
            .map(|i| {
                let r = &logits[i * 2 * a..(i + 1) * 2 * a];
                [
                    self.rng.sample_logits(&r[..a]),
                    self.rng.sample_logits(&r[a..]),
                ]
            })
            .collect())
    }

    /// Mean policy distribution over a set of observations (used for
    /// the RPS mixture / exploitability analysis).
    pub fn distribution(&mut self, obs: &[f32]) -> Result<Vec<f64>> {
        let (logits, _v) = self.engine.infer(&self.env, 1, &self.params, obs)?;
        let probs: Vec<f64> = (0..logits.len())
            .map(|a| log_softmax_at(&logits, a).exp() as f64)
            .collect();
        Ok(probs)
    }
}

/// Score `slot`'s outcome at episode end.  An env that truncates (step
/// limit reached without a decisive result) legitimately ends with
/// `outcome: None`; score it as a draw (0.5) with a logged warning
/// instead of aborting the whole eval worker — `.unwrap()` here used to
/// take down every remaining game in the batch.
pub fn outcome_or_draw(info: &Info, slot: usize, ctx: &str) -> f32 {
    match info.outcome.as_ref().and_then(|o| o.get(slot)) {
        Some(&o) => o,
        None => {
            eprintln!(
                "eval: {ctx}: episode truncated without an outcome; \
                 scoring as a draw (0.5)"
            );
            0.5
        }
    }
}

/// One doom_lite match: slot 0.. control by `nn_slots` NN policies, the
/// rest by scripted `bots`.  Returns final FRAGs per slot.
pub fn doom_match(
    seed: u64,
    nn: &mut [NnPolicy],
    bots: &mut [Box<dyn DoomPolicy>],
) -> Result<Vec<i32>> {
    let n = nn.len() + bots.len();
    let mut env = DoomLite::new(seed, n);
    let mut obs = env.reset();
    loop {
        let mut actions = vec![0usize; n];
        for (i, p) in nn.iter_mut().enumerate() {
            actions[i] = p.act(&obs[i])?;
        }
        for (j, b) in bots.iter_mut().enumerate() {
            actions[nn.len() + j] = b.act(&env, nn.len() + j);
        }
        let step = env.step(&actions);
        obs = step.obs;
        if step.done {
            return Ok(step.info.frags.unwrap());
        }
    }
}

/// Pommerman eval game: NN team (slots 0,2) vs scripted team (1,3).
/// Returns the NN team's outcome (1 / 0.5 / 0).
pub fn pommerman_game(
    seed: u64,
    nn: &mut NnPolicy,
    mk_opponent: &mut dyn FnMut(u64) -> Box<dyn ScriptedPolicy>,
) -> Result<f32> {
    let mut env = Pommerman::team(seed);
    let mut obs = env.reset();
    let mut op1 = mk_opponent(seed * 2 + 1);
    let mut op3 = mk_opponent(seed * 2 + 2);
    loop {
        let mut team_obs = Vec::with_capacity(obs[0].len() * 2);
        team_obs.extend_from_slice(&obs[0]);
        team_obs.extend_from_slice(&obs[2]);
        let nn_acts = nn.act_team(&team_obs)?;
        let actions = vec![
            nn_acts[0],
            op1.act(&env, 1),
            nn_acts[1],
            op3.act(&env, 3),
        ];
        let step = env.step(&actions);
        obs = step.obs;
        if step.done {
            return Ok(outcome_or_draw(&step.info, 0, "pommerman_game"));
        }
    }
}

/// Win/Loss/Tie record over `games` pommerman evaluations (sequential:
/// the 1-wide case of [`pommerman_record_vec`]).
pub fn pommerman_record(
    nn: &mut NnPolicy,
    mk_opponent: &mut dyn FnMut(u64) -> Box<dyn ScriptedPolicy>,
    games: u64,
    seed0: u64,
) -> Result<(u32, u32, u32)> {
    pommerman_record_vec(nn, mk_opponent, games, seed0, 1)
}

/// Win/Loss/Tie record over `games` pommerman evaluations, running up
/// to `concurrency` games at once: each tick gathers every active
/// game's team observation into one wide NN forward pass (the same
/// vectorized path the Actor rides), then steps every game.  Finished
/// games retire and the next seed takes their place.
pub fn pommerman_record_vec(
    nn: &mut NnPolicy,
    mk_opponent: &mut dyn FnMut(u64) -> Box<dyn ScriptedPolicy>,
    games: u64,
    seed0: u64,
    concurrency: usize,
) -> Result<(u32, u32, u32)> {
    struct Game {
        env: Pommerman,
        obs: Vec<Vec<f32>>,
        ops: [Box<dyn ScriptedPolicy>; 2],
    }
    let concurrency = concurrency.max(1);
    let (mut w, mut l, mut t) = (0u32, 0u32, 0u32);
    let mut next = 0u64;
    let mut active: Vec<Game> = Vec::new();
    while next < games || !active.is_empty() {
        while active.len() < concurrency && next < games {
            let seed = seed0 + next;
            next += 1;
            let mut env = Pommerman::team(seed);
            let obs = env.reset();
            let ops = [mk_opponent(seed * 2 + 1), mk_opponent(seed * 2 + 2)];
            active.push(Game { env, obs, ops });
        }
        // gather every active game's team observation into one batch
        let rows = active.len();
        let mut obs = Vec::with_capacity(rows * 2 * active[0].obs[0].len());
        for g in &active {
            obs.extend_from_slice(&g.obs[0]);
            obs.extend_from_slice(&g.obs[2]);
        }
        let acts = nn.act_team_rows(&obs, rows)?;
        // step every game with its own actions; retire finished ones
        let mut i = 0usize;
        active.retain_mut(|g| {
            let a = acts[i];
            i += 1;
            let actions =
                vec![a[0], g.ops[0].act(&g.env, 1), a[1], g.ops[1].act(&g.env, 3)];
            let step = g.env.step(&actions);
            if step.done {
                match outcome_or_draw(&step.info, 0, "pommerman_record_vec") {
                    o if o >= 1.0 => w += 1,
                    o if o <= 0.0 => l += 1,
                    _ => t += 1,
                }
                false
            } else {
                g.obs = step.obs;
                true
            }
        });
    }
    Ok((w, l, t))
}

/// Empirical mixed strategy of an RPS policy (one-step game: the obs is
/// constant, so the distribution IS the strategy).
pub fn rps_strategy(nn: &mut NnPolicy) -> Result<Vec<f64>> {
    nn.distribution(&[1.0, 0.0, 0.0, 0.0])
}

/// Exploitability of the average strategy of a pool of RPS policies —
/// the FSP convergence metric (paper §3.1 / experiment V1).
pub fn rps_pool_exploitability(
    game: &MatrixGame,
    strategies: &[Vec<f64>],
) -> f64 {
    let n = game.act_dim();
    let mut avg = vec![0.0; n];
    for s in strategies {
        for i in 0..n {
            avg[i] += s[i] / strategies.len() as f64;
        }
    }
    game.exploitability(&avg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::doom_lite::bots::BuiltinBot;
    use crate::envs::pommerman::agents::SimpleAgent;
    use std::path::PathBuf;

    fn engine() -> Option<Arc<Engine>> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Arc::new(Engine::load(dir).unwrap()))
    }

    /// A stub env that hits its step limit mid-game and ends WITHOUT a
    /// decisive result (`outcome: None`) — the truncation case that
    /// used to panic the eval worker at the `.unwrap()` call sites.
    struct TruncEnv {
        steps: usize,
        limit: usize,
    }

    impl MultiAgentEnv for TruncEnv {
        fn n_agents(&self) -> usize {
            4
        }
        fn obs_dim(&self) -> usize {
            2
        }
        fn act_dim(&self) -> usize {
            3
        }
        fn max_steps(&self) -> usize {
            self.limit
        }
        fn reset(&mut self) -> Vec<Vec<f32>> {
            self.steps = 0;
            vec![vec![0.0; 2]; 4]
        }
        fn step(&mut self, _actions: &[usize]) -> crate::envs::Step {
            self.steps += 1;
            crate::envs::Step {
                obs: vec![vec![0.0; 2]; 4],
                rewards: vec![0.0; 4],
                done: self.steps >= self.limit,
                info: Info::default(), // truncated: outcome stays None
            }
        }
    }

    /// Driving a truncating stub env through the outcome-scoring path
    /// must survive and score every truncated episode as a draw.
    #[test]
    fn truncated_episode_scores_as_draw() {
        let mut env = TruncEnv { steps: 0, limit: 3 };
        env.reset();
        let acts = vec![0usize; env.n_agents()];
        let (mut w, mut l, mut t) = (0u32, 0u32, 0u32);
        for _game in 0..2 {
            loop {
                let step = env.step(&acts);
                if step.done {
                    // the exact scoring expression the pommerman eval
                    // loops use at episode end
                    match outcome_or_draw(&step.info, 0, "trunc-test") {
                        o if o >= 1.0 => w += 1,
                        o if o <= 0.0 => l += 1,
                        _ => t += 1,
                    }
                    env.reset();
                    break;
                }
            }
        }
        assert_eq!((w, l, t), (0, 0, 2), "truncations must score as draws");
        // decisive outcomes still pass through untouched
        let win = Info { outcome: Some(vec![1.0, 0.0, 1.0, 0.0]), frags: None };
        assert_eq!(outcome_or_draw(&win, 0, "trunc-test"), 1.0);
        assert_eq!(outcome_or_draw(&win, 1, "trunc-test"), 0.0);
        // a malformed outcome vector (missing slot) degrades to a draw
        // rather than an index panic
        let short = Info { outcome: Some(vec![1.0]), frags: None };
        assert_eq!(outcome_or_draw(&short, 3, "trunc-test"), 0.5);
    }

    #[test]
    fn doom_match_produces_frags() {
        let Some(engine) = engine() else { return };
        let params = engine.init_params("doom_lite").unwrap();
        let mut nn = vec![NnPolicy::new(engine, "doom_lite", params, 1)];
        let mut bots: Vec<Box<dyn DoomPolicy>> =
            (0..3).map(|i| Box::new(BuiltinBot::new(i)) as _).collect();
        let frags = doom_match(5, &mut nn, &mut bots).unwrap();
        assert_eq!(frags.len(), 4);
    }

    #[test]
    fn pommerman_record_sums_to_games() {
        let Some(engine) = engine() else { return };
        let params = engine.init_params("pommerman").unwrap();
        let mut nn = NnPolicy::new(engine, "pommerman", params, 2);
        let mut mk = |s: u64| Box::new(SimpleAgent::new(s)) as Box<dyn ScriptedPolicy>;
        let (w, l, t) = pommerman_record(&mut nn, &mut mk, 3, 0).unwrap();
        assert_eq!(w + l + t, 3);
    }

    #[test]
    fn vectorized_pommerman_record_sums_to_games() {
        let Some(engine) = engine() else { return };
        let params = engine.init_params("pommerman").unwrap();
        let mut nn = NnPolicy::new(engine, "pommerman", params, 4);
        let mut mk =
            |s: u64| Box::new(SimpleAgent::new(s)) as Box<dyn ScriptedPolicy>;
        let (w, l, t) = pommerman_record_vec(&mut nn, &mut mk, 4, 0, 3).unwrap();
        assert_eq!(w + l + t, 4);
    }

    #[test]
    fn act_rows_batches_independent_rows() {
        let Some(engine) = engine() else { return };
        let params = engine.init_params("rps").unwrap();
        let m = engine.manifest.env("rps").unwrap().clone();
        let mut nn = NnPolicy::new(engine, "rps", params, 5);
        let rows = m.infer_b + 2; // exercises the chunked tail
        let obs: Vec<f32> =
            (0..rows * m.obs_dim).map(|i| i as f32 * 0.01).collect();
        let acts = nn.act_rows(&obs, rows).unwrap();
        assert_eq!(acts.len(), rows);
        assert!(acts.iter().all(|&a| a < m.act_dim));
    }

    #[test]
    fn rps_strategy_is_distribution() {
        let Some(engine) = engine() else { return };
        let params = engine.init_params("rps").unwrap();
        let mut nn = NnPolicy::new(engine, "rps", params, 3);
        let s = rps_strategy(&mut nn).unwrap();
        assert_eq!(s.len(), 3);
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-4);
    }

    #[test]
    fn exploitability_of_uniform_pool_is_zero() {
        let game = MatrixGame::rps(0);
        let pool = vec![
            vec![1.0, 0.0, 0.0],
            vec![0.0, 1.0, 0.0],
            vec![0.0, 0.0, 1.0],
        ];
        assert!(rps_pool_exploitability(&game, &pool).abs() < 1e-9);
        let pure = vec![vec![1.0, 0.0, 0.0]];
        assert!(rps_pool_exploitability(&game, &pure) > 0.9);
    }
}
