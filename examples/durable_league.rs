//! Durable league demo: train with checkpointing on, kill the deployment,
//! then resume from the latest snapshot and keep training.
//!
//!   cargo run --release --example durable_league
//!
//! Needs `make artifacts`.  State (snapshots + spilled model blobs) goes
//! to a temp directory printed at startup.

use std::sync::Arc;
use std::time::Duration;

use tleague::config::RunConfig;
use tleague::orchestrator::Deployment;
use tleague::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Arc::new(Engine::load("artifacts")?);
    let ckpt = std::env::temp_dir().join("tleague-durable-demo");
    std::fs::remove_dir_all(&ckpt).ok();
    println!("== durable league: checkpoints in {} ==", ckpt.display());

    // phase 1: a short run with checkpointing + a tight pool budget
    let mut cfg = RunConfig::default();
    cfg.env = "rps".into();
    cfg.game_mgr = "pfsp".into();
    cfg.total_steps = 40;
    cfg.period_steps = 10;
    cfg.checkpoint_dir = Some(ckpt.to_string_lossy().into_owned());
    cfg.checkpoint_every_secs = 5;
    cfg.pool_mem_budget_bytes = 64 * 1024; // spill cold frozen models
    let mut dep = Deployment::start(cfg.clone(), engine.clone())?;
    dep.wait(Duration::from_secs(300));
    dep.shutdown(); // final snapshot lands here
    let before = dep.league_stats();
    println!(
        "killed after phase 1: pool={} episodes={} frames={}",
        before.pool_size, before.episodes, before.frames
    );
    drop(dep);

    // phase 2: resume — pool/payoff/Elo/counters continue, models reload
    let mut cfg2 = cfg.clone();
    cfg2.resume = Some(ckpt.to_string_lossy().into_owned());
    cfg2.total_steps = 40; // train another 40 steps on top
    let mut dep2 = Deployment::start(cfg2, engine)?;
    let resumed = dep2.league_stats();
    // the pool can only have grown since the kill (training restarts at once)
    assert!(resumed.pool_size >= before.pool_size, "state lost on resume");
    println!(
        "resumed: pool={} episodes={} frames={} (continuing)",
        resumed.pool_size, resumed.episodes, resumed.frames
    );
    dep2.wait(Duration::from_secs(300));
    dep2.shutdown();
    let after = dep2.league_stats();
    println!(
        "done: pool={} episodes={} frames={}",
        after.pool_size, after.episodes, after.frames
    );
    assert!(after.pool_size > before.pool_size, "no new freezes after resume");
    std::fs::remove_dir_all(&ckpt).ok();
    Ok(())
}
